"""Adafactor (factored second moment) for the ≥300B configs.

Memory per param: 4B (f32 canonical) + 2B (bf16 momentum) + ~0 (factored v)
vs AdamW's 12B — the difference between grok-1-314b fitting 256x16 GB and
not (DESIGN §5 and the napkin math in EXPERIMENTS §Dry-run).

Factoring follows Shazeer & Stern: for a leaf (..., n, m) keep row/col
second-moment EMAs (..., n) and (..., m); 0/1-D leaves keep a full v.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.optim.adamw import OptConfig, global_norm, lr_at


def init_adafactor_state(params, cfg: OptConfig):
    def factor(p):
        if p.ndim >= 2:
            return {
                "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32),
            }
        return {"v": jnp.zeros(p.shape, jnp.float32)}

    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, cfg.state_dtype),
                          params),
        "v": jax.tree.map(factor, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adafactor_update(grads, opt_state, params, cfg: OptConfig):
    step = opt_state["step"] + 1
    gnorm = global_norm(grads, policy=cfg.policy)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b2 = cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        g2 = g * g + 1e-30
        if p.ndim >= 2:
            vr = b2 * v["vr"] + (1 - b2) * jnp.mean(g2, axis=-1)
            vc = b2 * v["vc"] + (1 - b2) * jnp.mean(g2, axis=-2)
            denom = jnp.sqrt(
                vr[..., None] * vc[..., None, :]
                / jnp.mean(vr, axis=-1, keepdims=True)[..., None] + cfg.eps)
            v_new = {"vr": vr, "vc": vc}
        else:
            vfull = b2 * v["v"] + (1 - b2) * g2
            denom = jnp.sqrt(vfull) + cfg.eps
            v_new = {"v": vfull}
        u = g / denom
        # RMS update clipping (Adafactor §7) then bf16 momentum
        rms = jnp.sqrt(jnp.mean(u * u) + 1e-30)
        u = u / jnp.maximum(1.0, rms)
        m_new = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * u
        p_new = p - lr * (m_new + cfg.weight_decay * p)
        return p_new, m_new.astype(cfg.state_dtype), v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    vt = jax.tree.structure(params)
    flat_v = vt.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v)
           for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    return (
        jax.tree.unflatten(treedef, [o[0] for o in out]),
        {"m": jax.tree.unflatten(treedef, [o[1] for o in out]),
         "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
         "step": step},
        {"grad_norm": gnorm, "lr": lr},
    )
