"""Gradient compression for the cross-pod all-reduce.

bf16 compression with stochastic rounding + per-leaf error feedback: the
pod-level gradient all-reduce (slow DCN link between pods) moves half the
bytes; the quantisation error is carried to the next step so the expected
update is unbiased. Off by default; enabled per-config for multi-pod runs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def stochastic_round_bf16(x: jax.Array, key: jax.Array) -> jax.Array:
    """f32 -> bf16 with stochastic rounding (unbiased)."""
    xi = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    noise = jax.random.randint(key, x.shape, 0, 1 << 16, jnp.uint32)
    rounded = (xi + noise) & jnp.uint32(0xFFFF0000)
    return jax.lax.bitcast_convert_type(rounded, jnp.float32).astype(
        jnp.bfloat16)


def compress_grads(grads, error_buf, key):
    """-> (bf16 grads to all-reduce, new error buffer)."""
    leaves, treedef = jax.tree.flatten(grads)
    ebuf = jax.tree.leaves(error_buf) if error_buf is not None \
        else [jnp.zeros_like(l) for l in leaves]
    keys = jax.random.split(key, len(leaves))
    comp, errs = [], []
    for g, e, k in zip(leaves, ebuf, keys):
        corrected = g.astype(jnp.float32) + e.astype(jnp.float32)
        q = stochastic_round_bf16(corrected, k)
        comp.append(q)
        errs.append((corrected - q.astype(jnp.float32)).astype(g.dtype))
    return jax.tree.unflatten(treedef, comp), jax.tree.unflatten(treedef, errs)
