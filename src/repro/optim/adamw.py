"""AdamW with configurable moment dtypes + warmup-cosine schedule.

Self-contained (no optax). Canonical params are f32; the ≥100B configs run
bf16 first/second moments (DESIGN §5) to fit 256x16 GB under ZeRO-3. The
global-norm clip reduction runs through ``repro.core.dispatch`` — a Σx²
whose formulation (matmul-form vs native sum) follows the configured
:class:`~repro.core.policy.KernelPolicy` (None = the active policy,
shape-aware ``auto`` by default). The old ``kernel_path=`` string kwarg
is a deprecation shim that warns once and coerces into a policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core import policy as kpolicy
from repro.core.policy import KernelPolicy


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Any = jnp.float32     # m/v dtype (bf16 for ≥100B archs)
    # explicit KernelPolicy for the global-norm reduction (None = the
    # active policy); strings auto-coerce
    policy: KernelPolicy | None = None
    # deprecated spelling of ``policy`` (a bare path label); warns once
    kernel_path: dataclasses.InitVar[str | None] = None

    def __post_init__(self, kernel_path):
        object.__setattr__(self, "policy", kpolicy.coerce_config_policy(
            self.policy, kernel_path, "OptConfig"))


def lr_at(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params, cfg: OptConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.state_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree, *, policy: KernelPolicy | str | None = None
                ) -> jax.Array:
    """sqrt(Σ Σx²) with per-leaf Σx² through the dispatch switch (the
    paper's matmul-form reduction on ``fused``, ``jnp.sum`` on
    ``baseline``; ``auto`` picks per leaf size)."""
    sq = [dispatch.reduce(
        jnp.square(g.astype(jnp.float32)).reshape(1, -1), policy=policy)[0]
        for g in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(sq)))


def adamw_update(grads, opt_state, params, cfg: OptConfig):
    """-> (new_params, new_opt_state, metrics). params/grads f32."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads, policy=cfg.policy)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
        p_new = p - lr * (update + cfg.weight_decay * p)
        return p_new, m_new.astype(cfg.state_dtype), v_new.astype(cfg.state_dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt_state["m"])
    flat_v = jax.tree.leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
