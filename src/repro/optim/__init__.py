from repro.optim.adamw import (
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_at,
)
from repro.optim.compress import compress_grads, stochastic_round_bf16

__all__ = [
    "OptConfig",
    "adamw_update",
    "compress_grads",
    "global_norm",
    "init_opt_state",
    "lr_at",
    "stochastic_round_bf16",
]
