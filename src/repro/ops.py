"""``repro.ops`` — the stable public API for the paper's ops.

This façade is the documented entry point for running any of the repo's
reduce/scan-family operations under a :class:`~repro.core.policy.
KernelPolicy`. Every op accepts ``policy=``:

* ``None`` (default) — the active policy (:func:`get_policy`; its process
  default is built from ``REPRO_KERNEL_PATH``/``REPRO_AUTOTUNE*``),
* a :class:`KernelPolicy`,
* a string shorthand — a bare path label (``"fused"``, ``"tile"``,
  ``"baseline"``, ...), an ``op=path,op=path`` per-op override list, or a
  JSON object of policy fields.

Scoped overrides compose through :func:`using_policy` /
:func:`set_policy`::

    import repro.ops as ops
    from repro.ops import KernelPolicy, using_policy

    ops.reduce(x)                          # active policy (usually auto)
    ops.scan(x, policy="baseline")         # exactly this path
    with using_policy(KernelPolicy(path="auto",
                                   op_paths={"attention": "fused"})):
        ops.attention(q, k, v)             # per-op override beats global

Kernel *geometry* is part of the policy too: :class:`TuneSpec` carries
per-op block/chunk knobs and ``KernelPolicy(op_tuning={"ssd": {"q":
64}})`` (or the ``"tile,ssd.q=64"`` string shorthand) overrides how the
tile kernels run, not just which path does.

:func:`dist_weighted_scan` is the multi-device composition of
``weighted_scan`` (the paper's grid-level scan-then-propagate) for use
inside ``shard_map``; it takes an axis name instead of a policy.

The exported surface is exactly ``__all__``; a CI test pins it. The
``path=`` kwarg is a deprecated alias for a bare-label policy and warns
once per process.
"""
from __future__ import annotations

import jax

from repro.core import dispatch as _dispatch
from repro.core import policy as _policy
from repro.core.distributed import \
    dist_weighted_scan  # noqa: F401  (re-exported API)
from repro.core.policy import (  # noqa: F401  (re-exported API)
    KernelPolicy,
    TuneSpec,
    get_policy,
    set_policy,
    using_policy,
)
from repro.kernels import ops as _kops

__all__ = [
    "KernelPolicy",
    "TuneSpec",
    "attention",
    "dist_weighted_scan",
    "get_policy",
    "ragged_reduce",
    "ragged_scan",
    "reduce",
    "rmsnorm",
    "scan",
    "set_policy",
    "ssd",
    "using_policy",
    "weighted_scan",
]


def _policy_arg(policy, path):
    """Fold the deprecated ``path=`` alias into ``policy`` (warns once)."""
    if path is not None:
        _policy.warn_once(
            "deprecated:repro.ops.path",
            "the path= kwarg on repro.ops is deprecated; pass policy= "
            "(a KernelPolicy or a string shorthand like policy='fused')",
            stacklevel=4)
        if policy is None:
            policy = path
    return policy


def _shard_ops():
    """The shard_map routing layer (deferred: ``parallel`` imports core).

    Under an active :class:`~repro.parallel.mesh_context.MeshContext`,
    eager committed arrays whose bucket axis is sharded over the context's
    mesh run the per-shard kernel inside ``shard_map`` with the grid-level
    carry combine; everything else (tracers included — GSPMD partitions
    the fused forms in-jit) falls back to plain dispatch.
    """
    from repro.parallel import shard_ops

    return shard_ops


def reduce(x: jax.Array, *, policy=None, path: str | None = None
           ) -> jax.Array:
    """Segmented sum over the last axis of ``x (..., n)`` -> f32
    ``(...,)``."""
    policy = _policy_arg(policy, path)
    out = _shard_ops().sharded_reduce(x, policy=policy)
    if out is not None:
        return out
    return _dispatch.reduce(x, policy=policy)


def scan(x: jax.Array, *, policy=None, exclusive: bool = False,
         path: str | None = None) -> jax.Array:
    """Prefix sum over the last axis -> f32, same shape
    (``exclusive=True`` shifts in a leading zero)."""
    policy = _policy_arg(policy, path)
    out = _shard_ops().sharded_scan(x, policy=policy, exclusive=exclusive)
    if out is not None:
        return out
    return _dispatch.scan(x, policy=policy, exclusive=exclusive)


def weighted_scan(x: jax.Array, log_a: jax.Array, *, policy=None,
                  path: str | None = None) -> jax.Array:
    """Decayed scan ``y_i = exp(log_a_i) * y_{i-1} + x_i`` -> f32."""
    policy = _policy_arg(policy, path)
    out = _shard_ops().sharded_weighted_scan(x, log_a, policy=policy)
    if out is not None:
        return out
    return _dispatch.weighted_scan(x, log_a, policy=policy)


def ragged_reduce(x: jax.Array, seg_ids: jax.Array, n_segments: int, *,
                  policy=None, path: str | None = None) -> jax.Array:
    """Bucketed segmented sum: ``x (..., n)`` + ``seg_ids`` -> f32
    ``(..., n_segments)``."""
    return _dispatch.ragged_reduce(x, seg_ids, n_segments,
                                   policy=_policy_arg(policy, path))


def ragged_scan(x: jax.Array, seg_ids: jax.Array, n_segments: int, *,
                policy=None, debug: bool = False,
                path: str | None = None) -> jax.Array:
    """Within-segment inclusive prefix sum -> f32, same shape as ``x``
    (``seg_ids`` must be non-decreasing; ``debug=True`` validates)."""
    return _dispatch.ragged_scan(x, seg_ids, n_segments,
                                 policy=_policy_arg(policy, path),
                                 debug=debug)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            policy=None, path: str | None = None) -> jax.Array:
    """RMSNorm over the last axis (differentiable; MXU Σx² on the kernel
    paths)."""
    return _kops.rmsnorm(x, w, eps=eps, policy=_policy_arg(policy, path))


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None, policy=None,
              path: str | None = None) -> jax.Array:
    """Multi-head attention in model layout: ``q (B, Sq, Hq, D)``,
    ``k``/``v`` ``(B, Sk, Hkv, D)`` -> ``(B, Sq, Hq, D)``."""
    return _dispatch.attention(q, k, v, causal=causal, window=window,
                               scale=scale,
                               policy=_policy_arg(policy, path))


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, policy=None, chunk: int | None = None,
        matmul_dtype=None, return_state: bool = False,
        path: str | None = None):
    """Mamba-2 SSD scan -> ``y (B, L, H, P)``; with ``return_state=True``
    also the final state ``(B, H, P, N)`` f32."""
    policy = _policy_arg(policy, path)
    out = _shard_ops().sharded_ssd(x, dt, a, b, c, policy=policy,
                                   chunk=chunk, matmul_dtype=matmul_dtype,
                                   return_state=return_state)
    if out is not None:
        return out
    return _dispatch.ssd(x, dt, a, b, c, policy=policy, chunk=chunk,
                         matmul_dtype=matmul_dtype,
                         return_state=return_state)
