from repro.training.train_lib import (
    TrainConfig,
    init_train_state,
    make_block_serve_step,
    make_serve_step,
    make_train_step,
    train_state_pspecs,
)

__all__ = [
    "TrainConfig",
    "init_train_state",
    "make_block_serve_step",
    "make_serve_step",
    "make_train_step",
    "train_state_pspecs",
]
