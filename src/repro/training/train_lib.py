"""Training step construction: mixed precision, grad accumulation, ZeRO.

State layout (a pytree the dry-run lowers and the checkpointer saves):

    {"params": f32 master weights, "opt": {"m","v","step"}, "rng": key}

Mixed precision: master params are f32; the loss casts to the model compute
dtype (bf16 on TPU) at step entry, so grads flow f32 <- bf16 automatically.
Under ZeRO-3 rules the cast copy is what gets all-gathered per layer — bf16
bytes on the wire, half the f32 cost (this is the standard
reduce-scatter/all-gather decomposition; XLA inserts it from the shardings).

Gradient accumulation: ``microbatches > 1`` splits the per-step batch on the
leading axis and folds the grads with a ``lax.scan`` — memory for one
microbatch's activations only, identical numerics (mean of means).

Gradient compression: with ``compress_grads=True`` the f32 grads are passed
through bf16 stochastic rounding with an error-feedback buffer carried in
the state (optim/compress.py) before the optimizer — the cross-pod DCN
all-reduce then moves half the bytes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.common import init_params, partition_specs, shape_structs
from repro.models.lm import Bundle
from repro.obs import runtime as _obs
from repro.optim import OptConfig, adamw_update, init_opt_state
from repro.optim.adafactor import adafactor_update, init_adafactor_state
from repro.optim.compress import compress_grads as _compress
from repro.parallel.sharding import spec_for


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1
    optimizer: str = "adamw"           # adamw | adafactor
    param_dtype: Any = jnp.float32     # master weight dtype
    compress_grads: bool = False       # bf16 + error feedback (pod all-reduce)


# ---------------------------------------------------------------------------
# state


def init_train_state(rng: jax.Array, bundle: Bundle, opt_cfg: OptConfig,
                     train_cfg: TrainConfig = TrainConfig()):
    params = init_params(rng, bundle.params_pspec, train_cfg.param_dtype)
    if train_cfg.optimizer == "adafactor":
        opt = init_adafactor_state(params, opt_cfg)
    else:
        opt = init_opt_state(params, opt_cfg)
    state = {"params": params, "opt": opt,
             "rng": jax.random.PRNGKey(17)}
    if train_cfg.compress_grads:
        state["err"] = jax.tree.map(jnp.zeros_like, params)
    return state


def state_shape_structs(bundle: Bundle, opt_cfg: OptConfig,
                        train_cfg: TrainConfig = TrainConfig()):
    """ShapeDtypeStruct tree of the train state (dry-run: no allocation)."""
    params = shape_structs(bundle.params_pspec, train_cfg.param_dtype)
    sds = lambda shape, dt: jax.ShapeDtypeStruct(shape, dt)
    if train_cfg.optimizer == "adafactor":
        def factor(p):
            if len(p.shape) >= 2:
                return {"vr": sds(p.shape[:-1], jnp.float32),
                        "vc": sds(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": sds(p.shape, jnp.float32)}

        opt = {"m": jax.tree.map(lambda p: sds(p.shape, opt_cfg.state_dtype),
                                 params),
               "v": jax.tree.map(factor, params),
               "step": sds((), jnp.int32)}
    else:
        zl = lambda p: sds(p.shape, opt_cfg.state_dtype)
        opt = {"m": jax.tree.map(zl, params),
               "v": jax.tree.map(zl, params),
               "step": sds((), jnp.int32)}
    state = {"params": params, "opt": opt,
             "rng": jax.ShapeDtypeStruct((2,), jnp.uint32)}
    if train_cfg.compress_grads:
        state["err"] = jax.tree.map(lambda p: sds(p.shape, p.dtype), params)
    return state


def train_state_pspecs(bundle: Bundle, rules,
                       train_cfg: TrainConfig = TrainConfig()):
    """PartitionSpec tree matching ``init_train_state``'s output.

    Optimizer moments shard exactly like their parameters (ZeRO); adafactor's
    factored second moments drop the spec entry of the reduced dim.
    """
    p_specs = partition_specs(bundle.params_pspec, rules=rules, fsdp_ok=True)
    from repro.models.common import PSpec, is_pspec

    if train_cfg.optimizer == "adafactor":
        def factor_spec(ps: PSpec):
            full = spec_for(ps.shape, ps.logical, rules=rules, fsdp_ok=True)
            if len(ps.shape) >= 2:
                return {"vr": jax.sharding.PartitionSpec(*full[:-1]),
                        "vc": jax.sharding.PartitionSpec(
                            *(full[:-2] + full[-1:]))}
            return {"v": full}

        v_specs = jax.tree.map(factor_spec, bundle.params_pspec,
                               is_leaf=is_pspec)
    else:
        v_specs = p_specs
    opt = {"m": p_specs, "v": v_specs,
           "step": jax.sharding.PartitionSpec()}
    specs = {"params": p_specs, "opt": opt,
             "rng": jax.sharding.PartitionSpec()}
    if train_cfg.compress_grads:
        specs["err"] = p_specs
    return specs


# ---------------------------------------------------------------------------
# steps


def _cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating)
        else x, tree)


def make_train_step(bundle: Bundle, opt_cfg: OptConfig,
                    train_cfg: TrainConfig = TrainConfig(), *,
                    mesh_ctx=None) -> Callable:
    """-> step(state, batch) -> (state, metrics). Pure; jit at the call
    site with in/out shardings (GSPMD inserts every collective).

    ``mesh_ctx`` (a :class:`~repro.parallel.mesh_context.MeshContext`)
    activates at every call, so tracing sees the context's rules and the
    kernel policy resolves TuneSpecs for the *shard* shapes."""
    from repro.parallel.mesh_context import activate

    compute_dtype = bundle.cfg.dtype
    nmb = train_cfg.microbatches

    def loss_fn(params_f32, batch):
        params = _cast_tree(params_f32, compute_dtype)
        return bundle.loss(params, batch)

    def grads_of(params, batch):
        if nmb == 1:
            return jax.value_and_grad(loss_fn)(params, batch)

        def split(x):
            return x.reshape(nmb, x.shape[0] // nmb, *x.shape[1:])

        mbs = jax.tree.map(split, batch)

        def body(carry, mb):
            loss_acc, g_acc = carry
            loss, g = jax.value_and_grad(loss_fn)(params, mb)
            return (loss_acc + loss,
                    jax.tree.map(jnp.add, g_acc, g)), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads), _ = jax.lax.scan(body, (jnp.float32(0), g0), mbs)
        inv = 1.0 / nmb
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    def step(state, batch):
        if _obs.ACTIVE is not None:
            # trace-time (python body of the jitted step): one event per
            # (re)compile — static fields only, this is a retrace counter
            _obs.ACTIVE.emit(
                "train_step_trace", optimizer=train_cfg.optimizer,
                microbatches=nmb,
                compress=bool(train_cfg.compress_grads))
            _obs.ACTIVE.counter(
                "repro_train_step_traces_total",
                "train-step retraces (jit compiles)").inc()
        with activate(mesh_ctx):
            loss, grads = grads_of(state["params"], batch)
            new_state = dict(state)
            if train_cfg.compress_grads:
                key, sub = jax.random.split(state["rng"])
                grads, err = _compress(grads, state.get("err"), sub)
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.float32), grads)
                new_state["err"] = err
                new_state["rng"] = key
            if train_cfg.optimizer == "adafactor":
                p, opt, metrics = adafactor_update(
                    grads, state["opt"], state["params"], opt_cfg)
            else:
                p, opt, metrics = adamw_update(
                    grads, state["opt"], state["params"], opt_cfg)
            new_state["params"] = p
            new_state["opt"] = opt
            metrics["loss"] = loss
            return new_state, metrics

    return step


def make_serve_step(bundle: Bundle) -> tuple[Callable, Callable]:
    """-> (prefill_step, decode_step); params cast to compute dtype inside
    (serving states store bf16 params directly, so the cast is a no-op).

    Serving only consumes the final position's logits, so the prefill uses
    the ``prefill_last`` variant when the model provides one — at 32k
    prefill this avoids the (B, S, vocab) logits buffer entirely."""
    compute_dtype = bundle.cfg.dtype
    prefill_fn = bundle.prefill_last or bundle.prefill

    def prefill_step(params, batch):
        return prefill_fn(_cast_tree(params, compute_dtype), batch)

    def decode_step(params, cache, batch):
        return bundle.decode(_cast_tree(params, compute_dtype), cache, batch)

    return prefill_step, decode_step


def make_block_serve_step(bundle: Bundle, *, mesh_ctx=None,
                          paged: bool = False) -> Callable | None:
    """-> step(params, cache, tokens (B,T), n_valid (B,), reset_mask (B,))
    -> (next_logits (B, vocab), cache) — the continuous-batching slot
    step. The cache carries per-slot position vectors; ``n_valid`` masks
    each slot's share of the T-token block (chunked prefill and
    single-token decode mix freely in one call); ``reset_mask`` clears a
    slot's sequence state on admission. Returns None when the bundle has
    no block decode (encoder-decoder) — the engine then falls back to
    wave scheduling.

    ``paged=True`` builds the page-pool variant instead: the step takes a
    trailing ``page`` dict (block tables, CoW gather, snapshot save/load,
    reset positions — the per-tick plan from ``serving/kvpool.py``), so
    chunked prefill and decode still mix in the same single jitted call.

    ``mesh_ctx`` activates at every call (sharded serving: the ring KV
    cache shards over the model axis via the context's rules); the
    returned logits are pinned replicated so every host can fetch its
    addressable copy for sampling."""
    decode = bundle.decode_block_paged if paged else bundle.decode_block
    if decode is None:
        return None
    from repro.parallel.mesh_context import activate

    compute_dtype = bundle.cfg.dtype

    def block_step(params, cache, tokens, n_valid, reset_mask, page=None):
        if _obs.ACTIVE is not None:
            # trace-time retrace counter: fires once per compiled shape
            # (the serving engine's T=chunk and T=1 block variants)
            _obs.ACTIVE.emit(
                "serve_block_trace", slots=int(tokens.shape[0]),
                block_t=int(tokens.shape[1]),
                cache_kind="paged" if paged else "ring")
            _obs.ACTIVE.counter(
                "repro_serve_block_traces_total",
                "block-step retraces (jit compiles) by T").inc(
                block_t=str(int(tokens.shape[1])))
        with activate(mesh_ctx):
            kw = {"page": page} if paged else {}
            logits, cache = decode(
                _cast_tree(params, compute_dtype), cache,
                {"tokens": tokens}, n_valid=n_valid, reset_mask=reset_mask,
                **kw)
            if mesh_ctx is not None and mesh_ctx.mesh is not None:
                logits = jax.lax.with_sharding_constraint(
                    logits, jax.sharding.NamedSharding(
                        mesh_ctx.mesh, jax.sharding.PartitionSpec()))
            return logits, cache

    return block_step
