"""grok-1-314b [moe] — 64L d_model=6144 48H (GQA kv=8) expert d_ff=32768
vocab=131072, MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="grok-1-314b", family="moe",
    n_layers=64, d_model=6144, vocab=131072,
    n_heads=48, n_kv_heads=8, head_dim=128,
    n_experts=8, experts_per_tok=2, moe_d_ff=32768,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="grok-1-smoke", family="moe",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    n_experts=4, experts_per_tok=2, moe_d_ff=128,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "pure full attention (GQA); skipped per the brief"}
OPT_STATE_DTYPE = "bfloat16"
# 314B params: AdamW m+v (even bf16) + f32 master + f32 grads blows the
# 16 GiB/chip budget (measured 18.4 GiB in the v0 dry-run). Adafactor's
# factored second moment + bf16 momentum brings the state under budget.
OPTIMIZER = "adafactor"
