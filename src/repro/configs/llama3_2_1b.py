"""llama3.2-1b [dense] — 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256, tied embeddings. [hf:meta-llama/Llama-3.2-1B; unverified]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="llama3.2-1b", family="dense",
    n_layers=16, d_model=2048, vocab=128256,
    n_heads=32, n_kv_heads=8, d_ff=8192, head_dim=64,
    tie_embeddings=True, rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama3.2-smoke", family="dense",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
    tie_embeddings=True, dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "pure full attention (GQA); skipped per the brief"}
OPT_STATE_DTYPE = "float32"
