"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block applied
every 6 layers over concat(h, embeddings). 54L d_model=2560 32H (kv=32)
shared-MLP d_ff=10240 vocab=32000 ssm_state=64. [arXiv:2411.15242; hf]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, vocab=32000,
    n_heads=32, n_kv_heads=32, d_ff=10240,
    ssm_state=64, ssm_head_dim=64, ssm_groups=1, expand=2, conv_kernel=4,
    shared_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid",
    n_layers=4, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=4, d_ff=128,
    ssm_state=16, ssm_head_dim=16, ssm_groups=1, expand=2, conv_kernel=4,
    shared_attn_every=2, dtype=jnp.float32, remat_policy="off",
)

# hybrid: SSM backbone is sub-quadratic; the single shared-attn KV cache at
# 500k/batch-1 is seq-sharded (DESIGN §5) -> long_500k runs.
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPS: dict = {}
OPT_STATE_DTYPE = "float32"
