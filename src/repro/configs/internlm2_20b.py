"""internlm2-20b [dense] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544. [arXiv:2403.17297; hf]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="internlm2-20b", family="dense",
    n_layers=48, d_model=6144, vocab=92544,
    n_heads=48, n_kv_heads=8, d_ff=16384, head_dim=128,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="internlm2-smoke", family="dense",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "pure full attention (GQA); skipped per the brief"}
OPT_STATE_DTYPE = "float32"
