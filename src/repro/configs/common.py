"""Shared shape-cell definitions and input_specs machinery.

Each arch module exposes:
  FULL   : the published config (exact numbers from the assignment table)
  SMOKE  : a reduced same-family config for CPU smoke tests
  SHAPES : the applicable shape cells (with skip reasons for the rest)

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input of that cell — weak-type-correct, shardable, no allocation —
plus the logical axis names the dry-run uses to build in_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.layers import ModelConfig

# (seq_len, global_batch, kind)
SHAPE_TABLE = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}

# smoke-test shape (CPU, reduced configs)
SMOKE_SEQ = 128
SMOKE_BATCH = 2


@dataclasses.dataclass(frozen=True)
class Cell:
    shape: str
    seq: int
    batch: int
    kind: str
    batch_specs: dict[str, Any]        # name -> ShapeDtypeStruct
    batch_logical: dict[str, tuple]    # name -> logical axes
    cache_batch: int = 0               # decode cells: cache batch size
    cache_len: int = 0


def sds(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lm_cell(cfg: ModelConfig, shape: str) -> Cell:
    seq, batch, kind = SHAPE_TABLE[shape]
    stub = cfg.stub_tokens
    if kind in ("train", "prefill"):
        s_text = seq - stub
        specs = {"tokens": sds((batch, s_text))}
        logical = {"tokens": ("batch", None)}
        if kind == "train":
            specs["labels"] = sds((batch, s_text))
            logical["labels"] = ("batch", None)
        if stub:
            specs["stub"] = sds((batch, stub, cfg.stub_dim), jnp.bfloat16)
            logical["stub"] = ("batch", None, None)
        return Cell(shape, seq, batch, kind, specs, logical)
    # decode: one new token against a cache of length seq
    specs = {"tokens": sds((batch, 1))}
    logical = {"tokens": ("batch", None)}
    return Cell(shape, seq, batch, kind, specs, logical,
                cache_batch=batch, cache_len=seq)


def encdec_cell(cfg: ModelConfig, shape: str) -> Cell:
    seq, batch, kind = SHAPE_TABLE[shape]
    half = seq // 2
    if kind in ("train", "prefill"):
        specs = {
            "frames": sds((batch, half, cfg.d_model), jnp.bfloat16),
            "tokens": sds((batch, half)),
        }
        logical = {"frames": ("batch", None, None), "tokens": ("batch", None)}
        if kind == "train":
            specs["labels"] = sds((batch, half))
            logical["labels"] = ("batch", None)
        return Cell(shape, seq, batch, kind, specs, logical)
    specs = {"tokens": sds((batch, 1))}
    logical = {"tokens": ("batch", None)}
    return Cell(shape, seq, batch, kind, specs, logical,
                cache_batch=batch, cache_len=seq)


def make_cell(cfg: ModelConfig, shape: str) -> Cell:
    if cfg.family == "encdec":
        return encdec_cell(cfg, shape)
    return lm_cell(cfg, shape)


def smoke_batch(cfg: ModelConfig, kind: str = "train"):
    """Concrete small inputs for the reduced config (CPU smoke tests)."""
    rng = jax.random.PRNGKey(0)
    b, s = SMOKE_BATCH, SMOKE_SEQ
    stub = cfg.stub_tokens
    if cfg.family == "encdec":
        return {
            "frames": jax.random.normal(rng, (b, s, cfg.d_model),
                                        jnp.float32).astype(cfg.dtype),
            "tokens": jax.random.randint(rng, (b, s), 0, cfg.vocab),
            "labels": jax.random.randint(rng, (b, s), 0, cfg.vocab),
        }
    out = {
        "tokens": jax.random.randint(rng, (b, s - stub), 0, cfg.vocab),
        "labels": jax.random.randint(rng, (b, s - stub), 0, cfg.vocab),
    }
    if stub:
        out["stub"] = jax.random.normal(
            rng, (b, stub, cfg.stub_dim), jnp.float32).astype(cfg.dtype)
    return out
