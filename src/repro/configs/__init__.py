"""Architecture registry: ``get(arch_id)`` -> config module with
FULL / SMOKE / SHAPES / SKIPS / OPT_STATE_DTYPE."""
from __future__ import annotations

import importlib

ARCHS = {
    "zamba2-2.7b": "zamba2_2_7b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "grok-1-314b": "grok_1_314b",
    "internvl2-76b": "internvl2_76b",
    "llama3.2-1b": "llama3_2_1b",
    "internlm2-20b": "internlm2_20b",
    "deepseek-67b": "deepseek_67b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "mamba2-1.3b": "mamba2_1_3b",
    "seamless-m4t-medium": "seamless_m4t_medium",
}


def get(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def all_arch_ids() -> list[str]:
    return list(ARCHS)
