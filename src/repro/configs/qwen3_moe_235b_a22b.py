"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4)
expert d_ff=1536, vocab=151936, MoE 128 experts top-8, head_dim=128.
[hf:Qwen/Qwen3-30B-A3B; hf]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    n_layers=94, d_model=4096, vocab=151936,
    n_heads=64, n_kv_heads=4, head_dim=128,
    n_experts=128, experts_per_tok=8, moe_d_ff=1536,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke", family="moe",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, head_dim=16,
    n_experts=8, experts_per_tok=2, moe_d_ff=96,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "pure full attention (GQA); 500k decode requires "
                      "sub-quadratic attention per the brief — skipped"}
# ZeRO-3 + bf16 m/v needed to fit 256x16GB (DESIGN §5)
OPT_STATE_DTYPE = "bfloat16"
