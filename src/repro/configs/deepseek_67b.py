"""deepseek-67b [dense] — 95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400, llama-arch. [arXiv:2401.02954; hf]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, vocab=102400,
    n_heads=64, n_kv_heads=8, d_ff=22016, head_dim=128,
    rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="deepseek-smoke", family="dense",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "pure full attention (GQA); skipped per the brief"}
OPT_STATE_DTYPE = "bfloat16"
