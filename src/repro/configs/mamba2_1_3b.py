"""mamba2-1.3b [ssm] — 48L d_model=2048 (attention-free) vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

This arch IS the paper's technique at model scale: the SSD layer is the
decay-weighted generalisation of the matmul-form scan (DESIGN §3).
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    n_layers=48, d_model=2048, vocab=50280,
    ssm_state=128, ssm_head_dim=64, ssm_groups=1, expand=2, conv_kernel=4,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm",
    n_layers=3, d_model=64, vocab=256,
    ssm_state=16, ssm_head_dim=16, ssm_groups=1, expand=2, conv_kernel=4,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPS: dict = {}
OPT_STATE_DTYPE = "float32"
