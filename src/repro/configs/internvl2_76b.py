"""internvl2-76b [vlm] — InternViT frontend (STUB: precomputed patch
embeddings, 256 tokens x 3200-dim) + 80L d_model=8192 64H (GQA kv=8)
d_ff=28672 vocab=128256 LLaMA-style backbone. [arXiv:2404.16821; unverified]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="internvl2-76b", family="vlm",
    n_layers=80, d_model=8192, vocab=128256,
    n_heads=64, n_kv_heads=8, d_ff=28672, head_dim=128,
    stub_tokens=256, stub_dim=3200,
    rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="internvl2-smoke", family="vlm",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
    stub_tokens=8, stub_dim=32,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "pure full attention (GQA); skipped per the brief"}
OPT_STATE_DTYPE = "bfloat16"
