"""h2o-danube-3-4b [dense] — 24L d_model=3840 32H (GQA kv=8) d_ff=10240
vocab=32000, llama+mistral mix with sliding-window attention (window 4096).
[arXiv:2401.16818; unverified]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="h2o-danube-3-4b", family="dense",
    n_layers=24, d_model=3840, vocab=32000,
    n_heads=32, n_kv_heads=8, d_ff=10240, head_dim=120,
    swa_window=4096, rope_theta=1e4,
)

SMOKE = ModelConfig(
    name="danube3-smoke", family="dense",
    n_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=2, d_ff=128, head_dim=16,
    swa_window=32, dtype=jnp.float32, remat_policy="off",
)

# SWA => sub-quadratic; long_500k decode uses a window-sized ring cache.
SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
SKIPS: dict = {}
OPT_STATE_DTYPE = "float32"
