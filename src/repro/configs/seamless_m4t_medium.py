"""seamless-m4t-medium [audio] — enc-dec transformer backbone, 12L encoder +
12L decoder, d_model=1024 16H (kv=16) d_ff=4096 vocab=256206. The speech
frontend is a STUB: input_specs provides precomputed frame embeddings
(B, S/2, d_model). [arXiv:2308.11596; hf]
"""
import jax.numpy as jnp

from repro.models.layers import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=12, enc_layers=12, d_model=1024, vocab=256206,
    n_heads=16, n_kv_heads=16, d_ff=4096, head_dim=64,
)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, vocab=256,
    n_heads=4, n_kv_heads=4, d_ff=128, head_dim=16,
    dtype=jnp.float32, remat_policy="off",
)

SHAPES = ("train_4k", "prefill_32k", "decode_32k")
SKIPS = {"long_500k": "full-attention enc-dec; 500k audio decode requires "
                      "sub-quadratic attention — skipped per the brief"}
OPT_STATE_DTYPE = "float32"
