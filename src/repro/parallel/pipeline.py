"""GPipe-style pipeline parallelism over an optional ``stage`` mesh axis.

The assigned production meshes (16x16 and 2x16x16) have no stage axis — the
big archs fit with TP x FSDP — but clusters that prefer PP over FSDP (e.g.
when the data axis is consumed by long-sequence SP) can wrap any scanned
homogeneous block stack in ``pipeline_apply``:

  * layers are split into S contiguous stages; stage s holds layers
    [s*L/S, (s+1)*L/S) — parameters sharded over the ``stage`` axis by the
    leading stage dim;
  * the batch is split into M microbatches; the classic GPipe schedule
    runs S + M - 1 ticks, each tick a step where every stage processes one
    microbatch and hands its activation to the next stage with
    ``jax.lax.ppermute`` — the collective the paper's grid level maps to
    on a ring;
  * bubble fraction = (S-1)/(S+M-1), reported by ``pipeline_stats``.

This module is deliberately self-contained (used by tests and the PP
example) rather than wired into every model: on the assigned meshes the
dry-run exercises TPxFSDP, and PP composes with the same block functions.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.parallel.compat import shard_map


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    n_stages: int
    n_microbatches: int
    axis: str = "stage"


def pipeline_stats(cfg: PipelineConfig) -> dict:
    s, m = cfg.n_stages, cfg.n_microbatches
    return {"ticks": s + m - 1, "bubble_fraction": (s - 1) / (s + m - 1)}


def pipeline_apply(
    block_fn: Callable,      # (stage_params, x) -> y   one stage's layers
    stage_params,            # pytree, leading dim = n_stages
    x: jax.Array,            # (B, ...) global batch
    cfg: PipelineConfig,
    mesh: jax.sharding.Mesh,
) -> jax.Array:
    """Run the GPipe schedule. ``block_fn`` must be shape-preserving
    (residual-block semantics), which all our layer stacks are."""
    s, m = cfg.n_stages, cfg.n_microbatches
    b = x.shape[0]
    assert b % m == 0, (b, m)
    mb = b // m
    xq = x.reshape(m, mb, *x.shape[1:])          # microbatch queue

    def run(params_local, xq_local):
        idx = jax.lax.axis_index(cfg.axis)
        take = lambda t: t[0]                     # strip the stage dim
        p_loc = jax.tree.map(take, params_local)
        buf0 = jnp.where(idx == 0, xq_local[0], jnp.zeros_like(xq_local[0]))
        outq0 = jnp.zeros_like(xq_local)
        # mark the carries as stage-varying for shard_map's VMA tracking
        # (buf0 already varies through idx; outq0 is a plain zeros tensor)
        pcast = getattr(jax.lax, "pcast", None)
        if pcast is not None:
            outq0 = pcast(outq0, (cfg.axis,), to="varying")

        def tick_step(state, tick):
            buf, outq = state
            y = block_fn(p_loc, buf)
            # the last stage finishes microbatch (tick - (S-1)) at this tick
            done_mb = tick - (s - 1)
            upd = jax.lax.dynamic_update_slice_in_dim(
                outq, y[None], jnp.maximum(done_mb, 0), axis=0)
            emit = jnp.logical_and(idx == s - 1, done_mb >= 0)
            outq = jnp.where(emit, upd, outq)
            # hand activations down the ring: stage i -> i+1
            y_next = jax.lax.ppermute(
                y, cfg.axis, [(i, (i + 1) % s) for i in range(s)])
            # stage 0 pulls the next microbatch from the queue
            nxt = tick + 1
            feed = jax.lax.dynamic_slice_in_dim(
                xq_local, jnp.clip(nxt, 0, m - 1), 1, axis=0)[0]
            feed = jnp.where(nxt < m, feed, jnp.zeros_like(feed))
            buf = jnp.where(idx == 0, feed, y_next)
            return (buf, outq), None

        (_, outq), _ = jax.lax.scan(tick_step, (buf0, outq0),
                                    jnp.arange(s + m - 1))
        # only the last stage holds real outputs; gather via masked psum
        mask = (idx == s - 1).astype(outq.dtype)
        return jax.lax.psum(outq * mask, cfg.axis)

    out = shard_map(
        run, mesh=mesh,
        in_specs=(P(cfg.axis), P()),
        out_specs=P(),
    )(stage_params, xq)
    return out.reshape(b, *x.shape[1:])
