"""Logical-axis sharding rules (GSPMD NamedSharding flavoured).

Every parameter / activation dimension carries a *logical* name; a ``Rules``
table maps logical names to mesh axes. The same model code then runs

  * unsharded on the CPU smoke-test path (empty rules),
  * TP+DP on the single-pod ``(data=16, model=16)`` mesh,
  * TP+DP+pod-DP on the multi-pod ``(pod=2, data=16, model=16)`` mesh,

by swapping rule tables only. Divisibility is checked against concrete dim
sizes: a logical rule that does not divide the dimension degrades to
replication (how 4-or-8 kv-head / 8-expert archs live on a 16-way model
axis, see DESIGN §5).

FSDP (ZeRO-3): when ``rules.fsdp`` is set, parameters additionally shard
their largest not-yet-sharded dimension over the data axis; XLA inserts the
per-layer all-gather (fwd) / reduce-scatter (bwd) this implies.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Mapping, Sequence

import jax
import numpy as np
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    """logical-name -> mesh-axis (or tuple of axes) mapping."""

    table: Mapping[str, str | tuple[str, ...] | None] = dataclasses.field(
        default_factory=dict
    )
    # shard params' largest free dim over this axis (ZeRO-3); None = off
    fsdp: str | None = None
    # mesh axis sizes, used for divisibility checks
    axis_sizes: Mapping[str, int] = dataclasses.field(default_factory=dict)

    def axes_for(self, name: str):
        return self.table.get(name)

    def axis_size(self, axes) -> int:
        if axes is None:
            return 1
        if isinstance(axes, str):
            return self.axis_sizes.get(axes, 1)
        size = 1
        for a in axes:
            size *= self.axis_sizes.get(a, 1)
        return size


# ---------------------------------------------------------------------------
# active rules (thread-local so tests can nest)

_state = threading.local()


def set_rules(rules: Rules | None) -> None:
    _state.rules = rules


def current_rules() -> Rules | None:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Rules | None):
    prev = current_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


# ---------------------------------------------------------------------------


def spec_for(
    shape: Sequence[int],
    logical: Sequence[str | None],
    *,
    rules: Rules | None = None,
    fsdp_ok: bool = False,
) -> P:
    """Build a PartitionSpec for ``shape`` from logical dim names.

    Rules that do not divide the concrete dim are dropped (replicated).
    With ``fsdp_ok`` and ``rules.fsdp``, the largest still-unsharded dim
    that the fsdp axis divides is additionally sharded over it.
    """
    rules = rules if rules is not None else current_rules()
    if rules is None:
        return P(*([None] * len(shape)))
    assert len(shape) == len(logical), (shape, logical)
    out: list = []
    used_axes: set[str] = set()
    for dim, name in zip(shape, logical):
        axes = rules.axes_for(name) if name else None
        if axes is None:
            out.append(None)
            continue
        ax_tuple = (axes,) if isinstance(axes, str) else tuple(axes)
        ax_tuple = tuple(a for a in ax_tuple if a not in used_axes)
        size = rules.axis_size(ax_tuple)
        if size > 1 and dim % size == 0:
            out.append(ax_tuple if len(ax_tuple) > 1 else ax_tuple[0])
            used_axes.update(ax_tuple)
        else:
            out.append(None)
    if fsdp_ok and rules.fsdp and rules.fsdp not in used_axes:
        fs = rules.axis_sizes.get(rules.fsdp, 1)
        if fs > 1:
            # largest unsharded dim divisible by the fsdp axis
            cands = [
                (dim, i) for i, (dim, s) in enumerate(zip(shape, out))
                if s is None and dim % fs == 0
            ]
            if cands:
                _, i = max(cands)
                out[i] = rules.fsdp
    return P(*out)


def logical_constraint(x: jax.Array, *logical: str | None) -> jax.Array:
    """with_sharding_constraint via logical names; no-op without rules/mesh."""
    rules = current_rules()
    if rules is None:
        return x
    spec = spec_for(x.shape, logical, rules=rules)
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, TypeError):
        return x


def param_sharding_tree(shapes_tree, logical_tree, mesh, rules: Rules):
    """Map (ShapeDtypeStruct tree, logical tree) -> NamedSharding tree."""
    from jax.sharding import NamedSharding

    def one(sds, logical):
        spec = spec_for(sds.shape, logical, rules=rules, fsdp_ok=True)
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, shapes_tree, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(i, (str, type(None))) for i in x))


def count_params(tree) -> int:
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
