from repro.parallel.sharding import (
    Rules,
    current_rules,
    logical_constraint,
    set_rules,
    spec_for,
    use_rules,
)

__all__ = [
    "Rules",
    "current_rules",
    "logical_constraint",
    "set_rules",
    "spec_for",
    "use_rules",
]
