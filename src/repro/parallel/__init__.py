from repro.parallel.mesh_context import (
    MeshContext,
    activate,
    current_mesh_context,
    make_context,
    parse_mesh_arg,
    shard_local_scope,
)
from repro.parallel.sharding import (
    Rules,
    current_rules,
    logical_constraint,
    set_rules,
    spec_for,
    use_rules,
)

__all__ = [
    "MeshContext",
    "Rules",
    "activate",
    "current_mesh_context",
    "current_rules",
    "logical_constraint",
    "make_context",
    "parse_mesh_arg",
    "set_rules",
    "shard_local_scope",
    "spec_for",
    "use_rules",
]
