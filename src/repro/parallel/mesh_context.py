"""MeshContext — mesh + rules + process topology as one first-class object.

PRs 1-6 built the pieces separately: ``Rules`` (logical-name sharding
table), ``compat.make_mesh`` (version shim), and ad-hoc ``(mesh, rules)``
pairs constructed at every launch site. Multi-host execution needs them to
travel together, because three layers consult the same topology:

* **kernel resolution** — under an active MeshContext,
  :meth:`~repro.core.policy.KernelPolicy.resolve` divides the call's
  bucket axis by the context's shard divisor for that op
  (:meth:`MeshContext.effective_n`): the per-device shard is just another
  small-n shape band, which is exactly the regime where the paper's
  matmul-form reduction/scan wins. ``op_shard_axes`` declares which mesh
  axis shards each op's bucket axis.
* **shard_map dispatch** — ``repro.parallel.shard_ops`` wraps the kernel
  dispatch paths in ``shard_map`` over the context's mesh, keeping the
  tile kernels on per-shard shapes with a psum/carry combine.
* **step builders / serving** — ``make_train_step`` /
  ``make_block_serve_step`` / ``ServingEngine`` activate the context at
  trace time so logical sharding constraints and shard-shape resolution
  both see it.

Activation is scoped (``with ctx:``): it enters the jax mesh (so bare
``PartitionSpec`` constraints resolve), installs the rule table
(``sharding.use_rules``), and publishes the context through a contextvar
(:func:`current_mesh_context`). Inside a ``shard_map`` body shapes are
already per-shard; :func:`shard_local_scope` suppresses the divisor there
so shard shapes are never divided twice.

``mesh=None`` builds a *topology-only* context (axis sizes from
``rules.axis_sizes``): policy resolution and unit tests work without
devices; anything needing a real mesh (shard_ops, constraints) is a no-op.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.policy import KNOWN_OPS, OP_ALIASES
from repro.parallel import compat
from repro.parallel.sharding import Rules, spec_for, use_rules

_ACTIVE: contextvars.ContextVar["MeshContext | None"] = \
    contextvars.ContextVar("repro_mesh_context", default=None)
_LOCAL: contextvars.ContextVar[bool] = \
    contextvars.ContextVar("repro_mesh_context_local", default=False)


def current_mesh_context() -> "MeshContext | None":
    """The innermost active context (None outside any ``with ctx:``)."""
    return _ACTIVE.get()


@contextlib.contextmanager
def shard_local_scope():
    """Mark the dynamic extent as *already per-shard* (a ``shard_map``
    body): :func:`effective_call_n` stops dividing so a shard's n is never
    divided twice."""
    token = _LOCAL.set(True)
    try:
        yield
    finally:
        _LOCAL.reset(token)


def effective_call_n(op: str, n: int) -> int:
    """The bucket-axis size kernel resolution should key off for one call:
    the per-shard size under an active (non-local) MeshContext, else ``n``
    unchanged. This is the hook :meth:`KernelPolicy.resolve` calls."""
    ctx = _ACTIVE.get()
    if ctx is None or _LOCAL.get():
        return n
    return ctx.effective_n(op, n)


def parse_mesh_arg(spec: str) -> tuple[tuple[str, int], ...]:
    """Parse a ``--mesh``-style string: ``"data=2,model=2"`` ->
    ``(("data", 2), ("model", 2))`` (order preserved = mesh axis order)."""
    axes = []
    for part in spec.split(","):
        part = part.strip()
        if not part:
            continue
        name, sep, size = part.partition("=")
        if not sep or not name.strip():
            raise ValueError(
                f"mesh spec must be 'axis=size,...', got {spec!r}")
        axes.append((name.strip(), int(size)))
    if not axes:
        raise ValueError(f"empty mesh spec {spec!r}")
    for name, size in axes:
        if size < 1:
            raise ValueError(f"mesh axis {name!r} must be >= 1, got {size}")
    return tuple(axes)


# The union logical-name table the smoke/launch paths share (the
# production tables in launch/mesh.py refine it per mesh shape).
DEFAULT_RULE_TABLE = {
    "batch": ("data",), "heads": "model", "kv_heads": "model",
    "ff": "model", "e_ff": "model", "experts": "model",
    "vocab": "model", "inner": "model", "inner_all": "model",
    "ssm_heads": "model", "embed": None, "layers": None,
    "moe_groups": ("data",), "exp_slots": "model",
    "exp_cap": None, "kv_seq": None,
}


@dataclasses.dataclass(frozen=True, eq=False)
class MeshContext:
    """Mesh + rules + process topology, activated with ``with ctx:``.

    ``mesh``
        The device mesh (or None for a topology-only context — policy
        resolution still works off ``rules.axis_sizes``).
    ``rules``
        The logical-name sharding table (divisibility-degrading, see
        ``parallel.sharding``).
    ``op_shard_axes``
        Which mesh axis shards each op's *bucket* axis (the last axis for
        the reduce/scan family, the sequence axis for attention/ssd) — a
        mapping or tuple of ``(op, axis)`` pairs, validated against
        ``KNOWN_OPS`` and the mesh axis names. Drives
        :meth:`effective_n`, hence shard-shape kernel resolution.

    Identity-hashed (``eq=False``) so it can key caches directly; use
    :meth:`key` for a value-based cache key.
    """

    mesh: jax.sharding.Mesh | None = None
    rules: Rules = dataclasses.field(default_factory=Rules)
    op_shard_axes: tuple = ()

    def __post_init__(self):
        pairs = self.op_shard_axes
        if isinstance(pairs, Mapping):
            pairs = pairs.items()
        norm = tuple(sorted(
            (OP_ALIASES.get(str(op), str(op)), str(ax)) for op, ax in pairs))
        for op, ax in norm:
            if op not in KNOWN_OPS:
                raise ValueError(
                    f"op_shard_axes: unknown op {op!r}; expected one of "
                    f"{KNOWN_OPS} (or a kernel-registry alias "
                    f"{tuple(OP_ALIASES)})")
            if ax not in self.axis_sizes_of(op_check=False):
                raise ValueError(
                    f"op_shard_axes[{op!r}]: unknown mesh axis {ax!r}; "
                    f"have {tuple(self.axis_sizes_of(op_check=False))}")
        object.__setattr__(self, "op_shard_axes", norm)

    # -- topology -----------------------------------------------------------

    def axis_sizes_of(self, *, op_check: bool = True) -> dict[str, int]:
        if self.mesh is not None:
            return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        return dict(self.rules.axis_sizes)

    @property
    def axis_sizes(self) -> dict[str, int]:
        return self.axis_sizes_of()

    @property
    def process_index(self) -> int:
        return jax.process_index()

    @property
    def process_count(self) -> int:
        return jax.process_count()

    def label(self) -> str:
        """Compact mesh-shape label for benchmark rows (``"data=2,model=2"``;
        ``"none"`` for a mesh-less context)."""
        sizes = self.axis_sizes
        return ",".join(f"{a}={s}" for a, s in sizes.items()) or "none"

    def key(self) -> tuple:
        """Value-based cache key (Rules holds dicts, so the dataclass
        itself is identity-hashed)."""
        return (tuple(sorted(self.axis_sizes.items())),
                tuple(sorted((k, v if not isinstance(v, list) else tuple(v))
                             for k, v in self.rules.table.items())),
                self.rules.fsdp, self.op_shard_axes)

    # -- shard-shape resolution ---------------------------------------------

    def shard_axis(self, op: str) -> str | None:
        op = OP_ALIASES.get(op, op)
        for name, ax in self.op_shard_axes:
            if name == op:
                return ax
        return None

    def shard_divisor(self, op: str, n: int) -> int:
        """The factor the op's bucket axis is sharded by: the registered
        axis size when it divides ``n``, else 1 (the same divisibility
        degradation as ``spec_for`` — a non-dividing rule replicates)."""
        ax = self.shard_axis(op)
        if ax is None:
            return 1
        size = self.axis_sizes.get(ax, 1)
        return size if size > 1 and n % size == 0 else 1

    def effective_n(self, op: str, n: int) -> int:
        return n // self.shard_divisor(op, n)

    # -- sharding helpers ---------------------------------------------------

    def spec_for(self, shape: Sequence[int],
                 logical: Sequence[str | None], *,
                 fsdp_ok: bool = False) -> P:
        return spec_for(shape, logical, rules=self.rules, fsdp_ok=fsdp_ok)

    def named_sharding(self, spec: P) -> NamedSharding:
        if self.mesh is None:
            raise ValueError("named_sharding needs a real mesh "
                             "(this context is topology-only)")
        return NamedSharding(self.mesh, spec)

    # -- activation ---------------------------------------------------------

    def __enter__(self) -> "MeshContext":
        stack = contextlib.ExitStack()
        stack.enter_context(use_rules(self.rules))
        if self.mesh is not None:
            stack.enter_context(self.mesh)
        token = _ACTIVE.set(self)
        stack.callback(_ACTIVE.reset, token)
        object.__setattr__(self, "_stack", stack)
        return self

    def __exit__(self, *exc) -> None:
        stack = getattr(self, "_stack", None)
        object.__setattr__(self, "_stack", None)
        if stack is not None:
            stack.close()


@contextlib.contextmanager
def activate(ctx: "MeshContext | None"):
    """``with activate(ctx):`` — like ``with ctx:`` but a no-op for None
    (step builders thread an optional context through)."""
    if ctx is None:
        yield None
    else:
        with ctx:
            yield ctx


def make_context(
    mesh_spec: "str | Sequence[tuple[str, int]]",
    *,
    table: Mapping | None = None,
    fsdp: bool | None = None,
    op_shard_axes: "Mapping | tuple" = (),
) -> MeshContext:
    """Build a MeshContext from a mesh spec (``"data=2,model=2"`` or parsed
    pairs) over this process's global device set.

    The mesh is built through ``compat.make_mesh`` (the one sanctioned
    ``jax.make_mesh`` call site); axis sizes must multiply to the global
    device count. ``table`` defaults to :data:`DEFAULT_RULE_TABLE`;
    ``fsdp`` defaults to sharding over ``data`` when that axis is > 1.
    """
    axes = parse_mesh_arg(mesh_spec) if isinstance(mesh_spec, str) \
        else tuple(mesh_spec)
    names = tuple(a for a, _ in axes)
    shape = tuple(s for _, s in axes)
    total = 1
    for s in shape:
        total *= s
    ndev = jax.device_count()
    if total != ndev:
        raise ValueError(
            f"mesh {dict(axes)} needs {total} devices; this process group "
            f"has {ndev}")
    mesh = compat.make_mesh(shape, names)
    sizes = dict(axes)
    if fsdp is None:
        fsdp = sizes.get("data", 1) > 1
    rules = Rules(table=dict(table if table is not None
                             else DEFAULT_RULE_TABLE),
                  fsdp="data" if fsdp and sizes.get("data", 1) > 1 else None,
                  axis_sizes=sizes)
    return MeshContext(mesh=mesh, rules=rules, op_shard_axes=op_shard_axes)
