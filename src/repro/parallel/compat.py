"""Version shims for the mesh/sharding surface, sibling of
``repro.kernels.backend`` (which shims the Pallas surface).

Covers the renames between jax 0.4.x and 0.6+:

* ``shard_map``: ``jax.experimental.shard_map.shard_map`` → ``jax.shard_map``
* ``jax.make_mesh(..., axis_types=...)``: the kwarg and the
  ``jax.sharding.AxisType`` enum only exist on 0.6+ (where meshes default to
  explicit sharding; ``Auto`` restores the 0.4.x behaviour every caller in
  this repo assumes).
"""
from __future__ import annotations

import inspect
from typing import Sequence

import jax

try:                                    # jax >= 0.6
    shard_map = jax.shard_map
except AttributeError:                  # pragma: no cover
    from jax.experimental.shard_map import shard_map  # noqa: F401


def make_mesh(axis_shapes: Sequence[int], axis_names: Sequence[str],
              **kwargs) -> jax.sharding.Mesh:
    """``jax.make_mesh`` pinned to auto (0.4.x-style) axis semantics, with
    unknown kwargs dropped on older JAX."""
    params = inspect.signature(jax.make_mesh).parameters
    axis_type = getattr(jax.sharding, "AxisType", None)
    if ("axis_types" in params and "axis_types" not in kwargs
            and axis_type is not None):
        kwargs["axis_types"] = (axis_type.Auto,) * len(tuple(axis_names))
    kwargs = {k: v for k, v in kwargs.items() if k in params}
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kwargs)
