"""shard_map'd kernel dispatch — sharded arrays stay on tile kernels.

The paper's grid level (§4.3/§5.3) combines per-processor partials that
were themselves produced by the tile/block levels. ``core.distributed``
expresses that combine as mesh collectives *inside* ``shard_map``; this
module is the missing outer half: given an **eager, committed** array
whose bucket axis is sharded over a mesh axis of the active
:class:`~repro.parallel.mesh_context.MeshContext`, wrap the normal
``core.dispatch`` call in ``shard_map`` so that

* each device runs the policy-resolved kernel on its **shard** (under
  :func:`~repro.parallel.mesh_context.shard_local_scope`, so the policy's
  shard-shape division is not applied a second time to the already-local
  shape), and
* the cross-device carry is the matmul-form combine from
  ``core.distributed`` (psum for reduce, the strictly-lower-triangular
  ones matmul for scan, the 1-semiseparable decay matmul for
  weighted-scan/SSD).

Routing is deliberately conservative: these helpers return ``None``
(caller falls back to plain dispatch) unless the call is eager (not under
a trace — inside jit, GSPMD already partitions the fused forms), the
array's sharding is a ``NamedSharding`` over the context's mesh, the
bucket axis is actually sharded, and the shard is even. ``repro.ops``
consults them; ``core.dispatch`` itself stays mesh-free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.core.distributed import (
    dist_exclusive_carry,
    weighted_exclusive_carry,
)
from repro.obs import runtime as _obs
from repro.parallel.compat import shard_map
from repro.parallel.mesh_context import (
    current_mesh_context,
    shard_local_scope,
)

__all__ = ["sharded_reduce", "sharded_scan", "sharded_weighted_scan",
           "sharded_ssd"]


def _emit_route(op: str, x, dim: int, ctx, axes) -> None:
    """One ``sharded_dispatch`` event when a shard_map route is taken
    (only called when an obs session is active) — the audit record that a
    call left plain dispatch for the mesh path, and over which axes."""
    sess = _obs.ACTIVE
    if sess is None:
        return
    sizes = ctx.axis_sizes
    nshards = 1
    for a in axes:
        nshards *= sizes.get(a, 1)
    sess.emit("sharded_dispatch", op=op, n=int(x.shape[dim]),
              dim=int(dim), mesh_axes=list(axes), nshards=int(nshards))
    sess.counter(
        "repro_sharded_dispatch_total",
        "calls routed through shard_map by op").inc(op=op)


def _routing_ctx(x, dim: int):
    """The (ctx, full-rank spec, bucket-axis names) triple when ``x``'s
    ``dim`` is sharded under the active MeshContext, else None."""
    ctx = current_mesh_context()
    if ctx is None or ctx.mesh is None:
        return None
    if isinstance(x, jax.core.Tracer):       # in-jit: GSPMD's job
        return None
    sharding = getattr(x, "sharding", None)
    if not isinstance(sharding, NamedSharding) or sharding.mesh != ctx.mesh:
        return None
    spec = _full_spec(sharding.spec, x.ndim)
    axes = spec[dim]
    if axes is None:
        return None
    axes = (axes,) if isinstance(axes, str) else tuple(axes)
    sizes = ctx.axis_sizes
    nshards = 1
    for a in axes:
        nshards *= sizes.get(a, 1)
    if nshards <= 1 or x.shape[dim] % nshards != 0:
        return None
    return ctx, spec, axes


def _full_spec(spec, ndim: int) -> tuple:
    spec = tuple(spec)
    return spec + (None,) * (ndim - len(spec))


def sharded_reduce(x, *, policy=None):
    """Last-axis reduce of a sharded array: per-shard kernel + psum.
    Returns None when the call should fall back to plain dispatch."""
    route = _routing_ctx(x, x.ndim - 1)
    if route is None:
        return None
    ctx, spec, axes = route
    if _obs.ACTIVE is not None:
        _emit_route("reduce", x, x.ndim - 1, ctx, axes)
    from repro.core import dispatch

    def body(xs):
        with shard_local_scope():
            part = dispatch.reduce(xs, policy=policy)
        return jax.lax.psum(part, axes)

    return shard_map(body, mesh=ctx.mesh, in_specs=(P(*spec),),
                     out_specs=P(*spec[:-1]), check_rep=False)(x)


def sharded_scan(x, *, policy=None, exclusive: bool = False):
    """Last-axis inclusive scan of a sharded array: per-shard kernel +
    exclusive carry of shard totals (scan-then-propagate). The exclusive
    variant needs a cross-shard element shift, so it falls back."""
    if exclusive:
        return None
    route = _routing_ctx(x, x.ndim - 1)
    if route is None:
        return None
    ctx, spec, axes = route
    if len(axes) != 1:
        return None  # multi-axis bucket sharding: fall back
    if _obs.ACTIVE is not None:
        _emit_route("scan", x, x.ndim - 1, ctx, axes)
    from repro.core import dispatch

    def body(xs):
        with shard_local_scope():
            local = dispatch.scan(xs, policy=policy)
        carry = dist_exclusive_carry(local[..., -1], axes[0])
        return local + carry[..., None]

    return shard_map(body, mesh=ctx.mesh, in_specs=(P(*spec),),
                     out_specs=P(*spec), check_rep=False)(x)


def sharded_weighted_scan(x, log_a, *, policy=None):
    """Last-axis decayed scan of a sharded array: per-shard kernel + the
    1-semiseparable carry combine, propagated through prefix decays."""
    route = _routing_ctx(x, x.ndim - 1)
    if route is None:
        return None
    ctx, spec, axes = route
    if len(axes) != 1:
        return None
    la_sh = getattr(log_a, "sharding", None)
    if not isinstance(la_sh, NamedSharding) \
            or _full_spec(la_sh.spec, log_a.ndim) != spec:
        return None
    if _obs.ACTIVE is not None:
        _emit_route("weighted_scan", x, x.ndim - 1, ctx, axes)
    from repro.core import dispatch

    def body(xs, las):
        with shard_local_scope():
            local = dispatch.weighted_scan(xs, las, policy=policy)
        log_decay = jnp.sum(las.astype(jnp.float32), axis=-1)
        carry = weighted_exclusive_carry(local[..., -1], log_decay, axes[0])
        prefix = jnp.cumsum(las.astype(jnp.float32), axis=-1)
        return local + carry[..., None] * jnp.exp(prefix)

    return shard_map(body, mesh=ctx.mesh, in_specs=(P(*spec), P(*spec)),
                     out_specs=P(*spec), check_rep=False)(x, log_a)


def sharded_ssd(x, dt, a, b, c, *, policy=None, chunk=None,
                matmul_dtype=None, return_state: bool = False):
    """Sequence-sharded SSD: per-shard chunked scan + cross-device state
    carry (the same recurrence one level up: shard finals are chunk finals).

    ``x (B, L, H, P)`` sharded on L (dim 1); ``dt (B, L, H)``, ``b``/``c``
    ``(B, L, G, N)`` must be sharded identically on L; ``a (H,)`` is
    host-replicated. The returned final state is replicated.
    """
    route = _routing_ctx(x, 1)
    if route is None:
        return None
    ctx, spec, axes = route
    if len(axes) != 1:
        return None
    axis = axes[0]
    specs = {"dt": dt, "b": b, "c": c}
    arg_specs = []
    for name, arr in specs.items():
        sh = getattr(arr, "sharding", None)
        if not isinstance(sh, NamedSharding) or sh.mesh != ctx.mesh:
            return None
        s = _full_spec(sh.spec, arr.ndim)
        if s[1] != spec[1] or s[0] != spec[0]:
            return None
        arg_specs.append(s)
    if getattr(a, "sharding", None) is not None and \
            isinstance(a.sharding, NamedSharding) and \
            any(e is not None for e in _full_spec(a.sharding.spec, a.ndim)):
        return None
    dt_spec, b_spec, c_spec = arg_specs
    if _obs.ACTIVE is not None:
        _emit_route("ssd", x, 1, ctx, axes)
    from repro.core import dispatch

    nd = ctx.axis_sizes[axis]
    heads = x.shape[2]
    groups = b.shape[2]

    def body(xs, dts, a_r, bs, cs):
        with shard_local_scope():
            y, h_last = dispatch.ssd(
                xs, dts, a_r, bs, cs, policy=policy, chunk=chunk,
                matmul_dtype=matmul_dtype, return_state=True)
        # shard-level recurrence: H_i = exp(L_i) H_{i-1} + h_last_i
        lam = dts.astype(jnp.float32) * a_r.astype(jnp.float32)  # (B, Ll, H)
        log_decay = jnp.sum(lam, axis=1)                         # (B, H)
        h_in = weighted_exclusive_carry(h_last, log_decay, axis)
        # inject the incoming state into every position of this shard:
        # y_l += C_l · (prod_{k<=l} exp(lam_k)) h_in
        cdec = jnp.repeat(cs, heads // groups, axis=2).astype(jnp.float32) \
            * jnp.exp(jnp.cumsum(lam, axis=1))[..., None]        # (B,Ll,H,N)
        y = y + jnp.einsum("blhn,bhpn->blhp", cdec,
                           h_in).astype(y.dtype)
        if not return_state:
            return y
        h_fin = jnp.exp(log_decay)[..., None, None] * h_in + h_last
        last = jax.lax.axis_index(axis) == nd - 1
        h_glob = jax.lax.psum(
            jnp.where(last, h_fin, jnp.zeros_like(h_fin)), axis)
        return y, h_glob

    out_specs = P(*spec) if not return_state else (P(*spec), P())
    out = shard_map(
        body, mesh=ctx.mesh,
        in_specs=(P(*spec), P(*dt_spec), P(), P(*b_spec), P(*c_spec)),
        out_specs=out_specs, check_rep=False)(x, dt, a, b, c)
    return out
