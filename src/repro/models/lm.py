"""Decoder-only LM assembly for the dense / moe / ssm / hybrid / vlm
families. One scanned-homogeneous-stack implementation parameterised by
``ModelConfig``; heterogeneous archs (Zamba2 hybrid) compose scanned groups
with a shared attention block.

Everything is functional: params are PSpec trees (materialise with
``init_params`` for smoke tests, ``shape_structs`` for the dry-run).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import (
    PSpec,
    cross_entropy,
    embed_tokens,
    rmsnorm,
    unembed,
)
from repro.parallel.sharding import logical_constraint

MOE_AUX_WEIGHT = 0.01


def _remat(fn, policy: str):
    if policy == "off":
        return fn
    if policy == "none":
        return jax.checkpoint(fn)
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    raise ValueError(policy)


@dataclasses.dataclass(frozen=True)
class Bundle:
    """Everything the launcher/trainer/server needs for one architecture."""

    cfg: L.ModelConfig
    params_pspec: Any
    loss: Callable          # (params, batch) -> scalar
    prefill: Callable       # (params, batch) -> (logits, cache)
    decode: Callable        # (params, cache, batch) -> (logits, cache)
    cache_pspec: Callable   # (batch_size, max_len) -> PSpec tree
    n_params: int = 0
    n_active_params: int = 0
    # serving-prefill: unembed only the last position (B, 1, vocab) —
    # avoids the (B, S, vocab) logits buffer at 32k prefill
    prefill_last: Callable = None
    # continuous-batching slot step: (params, cache, batch{tokens (B,T)},
    # n_valid (B,), reset_mask (B,)) -> (next_logits (B, vocab), cache).
    # Per-slot positions, slot-masked cache updates, chunked prefill and
    # single-token decode in one call. None = wave scheduling only.
    decode_block: Callable = None
    # paged-pool variant: same signature plus page=dict of page-table
    # inputs from serving/kvpool.py (tables, kv_copy, snap_save/load,
    # reset_pos per family). None = ring cache only.
    decode_block_paged: Callable = None


# ---------------------------------------------------------------------------
# parameter declaration


def lm_pspec(cfg: L.ModelConfig):
    d, v = cfg.d_model, cfg.vocab
    p: dict[str, Any] = {
        "embed": PSpec((v, d), ("vocab", "embed"), "normal"),
        "final_norm": PSpec((d,), ("embed",), "ones"),
    }
    if not cfg.tie_embeddings:
        p["head"] = PSpec((v, d), ("vocab", "embed"), "normal")
    if cfg.stub_tokens:
        p["stub_proj"] = PSpec((cfg.stub_dim, d), (None, "embed"))

    if cfg.family in ("dense", "vlm"):
        p["blocks"] = {
            "ln1": PSpec((cfg.n_layers, d), ("layers", "embed"), "ones"),
            "attn": L.attn_pspec(cfg),
            "ln2": PSpec((cfg.n_layers, d), ("layers", "embed"), "ones"),
            "mlp": L.mlp_pspec(cfg),
        }
    elif cfg.family == "moe":
        p["blocks"] = {
            "ln1": PSpec((cfg.n_layers, d), ("layers", "embed"), "ones"),
            "attn": L.attn_pspec(cfg),
            "ln2": PSpec((cfg.n_layers, d), ("layers", "embed"), "ones"),
            "moe": L.moe_pspec(cfg),
        }
    elif cfg.family == "ssm":
        p["blocks"] = {
            "ln": PSpec((cfg.n_layers, d), ("layers", "embed"), "ones"),
            "mamba": L.mamba_pspec(cfg),
        }
    elif cfg.family == "hybrid":
        p["blocks"] = {
            "ln": PSpec((cfg.n_layers, d), ("layers", "embed"), "ones"),
            "mamba": L.mamba_pspec(cfg),
        }
        # Zamba2-style shared transformer block over concat(h, embeddings)
        p["shared"] = {
            "ln_in": PSpec((2 * d,), ("embed",), "ones"),
            "attn": L.attn_pspec(cfg, n=0, d_in=2 * d),
            "ln_mlp": PSpec((d,), ("embed",), "ones"),
            "mlp": L.mlp_pspec(cfg, n=0),
        }
    else:
        raise ValueError(cfg.family)
    return p


# ---------------------------------------------------------------------------
# forward


def _positions(b, s, offset=0):
    return offset + jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


def _dense_block(lp, cfg, h, positions, collect_kv=False):
    a_in = rmsnorm(h, lp["ln1"], cfg.norm_eps)
    a_out, kv = L.attn_apply(lp["attn"], cfg, a_in, positions=positions,
                             window=cfg.swa_window)
    h = h + a_out
    m_in = rmsnorm(h, lp["ln2"], cfg.norm_eps)
    if "moe" in lp:
        m_out, aux = L.moe_apply(lp["moe"], cfg, m_in)
    else:
        m_out, aux = L.mlp_apply(lp["mlp"], cfg, m_in), jnp.float32(0)
    h = h + m_out
    h = logical_constraint(h, "batch", None, "embed")
    return h, aux, (kv if collect_kv else None)


def _mamba_block(lp, cfg, h, collect_cache=False):
    m_in = rmsnorm(h, lp["ln"], cfg.norm_eps)
    out, cache = L.mamba_apply(lp["mamba"], cfg, m_in,
                               collect_cache=collect_cache)
    h = h + out
    return logical_constraint(h, "batch", None, "embed"), cache


def _shared_block(sp, cfg, h, emb0, positions, cache=None):
    """Zamba2 shared attention+MLP; input concat(h, emb0) (B,S,2d)."""
    cat = jnp.concatenate([h, emb0], axis=-1)
    a_in = rmsnorm(cat, sp["ln_in"], cfg.norm_eps)
    if cache is None:
        a_out, kv = L.attn_apply(sp["attn"], cfg, a_in, positions=positions)
    else:
        a_out, kv = L.attn_decode(sp["attn"], cfg, a_in, cache)
    h = h + a_out
    m_in = rmsnorm(h, sp["ln_mlp"], cfg.norm_eps)
    h = h + L.mlp_apply(sp["mlp"], cfg, m_in)
    return logical_constraint(h, "batch", None, "embed"), kv


def _embed_input(params, cfg, batch):
    """tokens (+ optional stub embeddings) -> (h (B,S,d), emb copy)."""
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens)
    if cfg.stub_tokens:
        stub = batch["stub"].astype(h.dtype)              # (B, P, stub_dim)
        prefix = jnp.einsum("bpe,ed->bpd", stub, params["stub_proj"])
        h = jnp.concatenate([prefix, h], axis=1)
    return h


def lm_apply(params, cfg: L.ModelConfig, batch, *, collect_cache=False,
             last_only=False):
    """Full-sequence forward. Returns (logits, aux, cache-or-None).

    ``last_only`` unembeds just the final position — the serving-prefill
    path (only the next-token logits are needed), which avoids
    materialising the (B, S, vocab) logits tensor at 32k prefill."""
    h = _embed_input(params, cfg, batch)
    b, s, _ = h.shape
    positions = _positions(b, s)
    emb0 = h
    aux_total = jnp.float32(0)
    kv_stack = None
    mamba_cache = None

    if cfg.family in ("dense", "vlm", "moe"):
        def body(carry, lp):
            hh, aux = carry
            hh, a, kv = _dense_block(lp, cfg, hh, positions,
                                     collect_kv=collect_cache)
            return (hh, aux + a), kv

        body = _remat(body, cfg.remat_policy)
        (h, aux_total), kv_stack = jax.lax.scan(body, (h, aux_total),
                                                params["blocks"])
    elif cfg.family == "ssm":
        def body(hh, lp):
            return _mamba_block(lp, cfg, hh, collect_cache=collect_cache)

        body = _remat(body, cfg.remat_policy)
        h, mamba_cache = jax.lax.scan(body, h, params["blocks"])
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every
        shared_kvs, mamba_caches = [], []

        def body(hh, lp):
            return _mamba_block(lp, cfg, hh, collect_cache=collect_cache)

        body = _remat(body, cfg.remat_policy)
        for gi in range(n_groups):
            grp = jax.tree.map(lambda x: x[gi * every:(gi + 1) * every],
                               params["blocks"])
            h, mc = jax.lax.scan(body, h, grp)
            mamba_caches.append(mc)
            h, kv = _shared_block(params["shared"], cfg, h, emb0, positions)
            shared_kvs.append(kv)
        if collect_cache:
            kv_stack = (
                jnp.stack([k for k, _ in shared_kvs]),
                jnp.stack([v for _, v in shared_kvs]),
            )
            mamba_cache = jax.tree.map(
                lambda *xs: jnp.concatenate(xs), *mamba_caches)
    else:
        raise ValueError(cfg.family)

    if last_only:
        h = h[:, -1:]
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    logits = unembed(h, head)

    cache = None
    if collect_cache:
        cache = _build_cache_from_kv(cfg, kv_stack, b, s)
        if mamba_cache is not None:
            cache["mamba"] = mamba_cache
    return logits, aux_total, cache


def lm_loss(params, cfg: L.ModelConfig, batch):
    logits, aux, _ = lm_apply(params, cfg, batch)
    labels = batch["labels"]
    if cfg.stub_tokens:                     # loss only over the text tail
        logits = logits[:, -labels.shape[1]:]
    loss = cross_entropy(logits, labels)
    return loss + MOE_AUX_WEIGHT * aux


# ---------------------------------------------------------------------------
# caches / decode


def _n_cache_layers(cfg):
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.shared_attn_every   # shared-attn uses
    return cfg.n_layers


def lm_cache_pspec(cfg: L.ModelConfig, batch: int, smax: int,
                   per_slot_pos: bool = False, *, kind: str = "ring",
                   pool_pages: int = 0, page_rows: int = 0,
                   state_pages: int = 0):
    """Decode-cache declaration. ``per_slot_pos=True`` declares the
    continuous-batching layout: ``pos`` is a (batch,) vector — every slot
    carries its own position counter instead of sharing one scalar.

    ``kind="paged"`` swaps the per-slot KV rings for one shared pool of
    ``pool_pages`` pages of ``page_rows`` rows (block tables map slots to
    pages; see ``serving/kvpool.py``); ``smax`` then only fixes the table
    width implicitly via the engine. SSM families keep their live per-slot
    conv/state arrays unchanged and add a ``state_pages``-slot snapshot
    pool for prompt-boundary prefix sharing."""
    pshape = (batch,) if per_slot_pos else ()
    plog = ("batch",) if per_slot_pos else ()
    cache: dict[str, Any] = {"pos": PSpec(pshape, plog, "zeros", jnp.int32)}
    if kind == "paged":
        assert per_slot_pos, "paged cache is continuous-batching only"
        if cfg.family in ("dense", "vlm", "moe"):
            cache["attn"] = L.attn_page_cache_pspec(
                cfg, cfg.n_layers, pool_pages, page_rows)
        elif cfg.family == "ssm":
            cache["mamba"] = L.mamba_cache_pspec(cfg, cfg.n_layers, batch)
            cache["snap"] = L.mamba_snap_pspec(cfg, cfg.n_layers,
                                               state_pages)
        elif cfg.family == "hybrid":
            cache["mamba"] = L.mamba_cache_pspec(cfg, cfg.n_layers, batch)
            cache["snap"] = L.mamba_snap_pspec(cfg, cfg.n_layers,
                                               state_pages)
            cache["attn"] = L.attn_page_cache_pspec(
                cfg, _n_cache_layers(cfg), pool_pages, page_rows)
        else:
            raise ValueError(f"no paged cache for family {cfg.family!r}")
        return cache
    if cfg.family in ("dense", "vlm", "moe"):
        cache["attn"] = L.attn_cache_pspec(cfg, cfg.n_layers, batch, smax)
        del cache["attn"]["pos"]
    elif cfg.family == "ssm":
        cache["mamba"] = L.mamba_cache_pspec(cfg, cfg.n_layers, batch)
    elif cfg.family == "hybrid":
        cache["mamba"] = L.mamba_cache_pspec(cfg, cfg.n_layers, batch)
        cache["attn"] = L.attn_cache_pspec(cfg, _n_cache_layers(cfg), batch,
                                           smax)
        del cache["attn"]["pos"]
    return cache


def _build_cache_from_kv(cfg, kv_stack, b, s):
    """Assemble a decode cache from prefill K/V (prefill path)."""
    cache: dict[str, Any] = {"pos": jnp.int32(s)}
    if cfg.family in ("dense", "vlm", "moe", "hybrid") and kv_stack is not None:
        k, v = kv_stack                                  # (L, B, S, Hkv, Dh)
        if cfg.swa_window and cfg.swa_window < s:
            # ring layout: position p lives at slot p % window; the last
            # `window` positions in natural order need a roll of S % window
            k = jnp.roll(k[:, :, -cfg.swa_window:], s % cfg.swa_window,
                         axis=2)
            v = jnp.roll(v[:, :, -cfg.swa_window:], s % cfg.swa_window,
                         axis=2)
        cache["attn"] = {
            "k": logical_constraint(k, "layers", "batch", "kv_seq",
                                    "kv_heads", None),
            "v": logical_constraint(v, "layers", "batch", "kv_seq",
                                    "kv_heads", None),
        }
    return cache


def lm_decode(params, cfg: L.ModelConfig, cache, batch):
    """One decode step. batch {"tokens": (B, 1)} -> (logits, new cache)."""
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens)            # (B, 1, d)
    b = h.shape[0]
    pos = cache["pos"]
    emb0 = h
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        def step(hh, xs):
            lp, kc, vc = xs
            c = {"k": kc, "v": vc, "pos": pos}
            a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            a_out, c = L.attn_decode(lp["attn"], cfg, a_in, c,
                                     window=cfg.swa_window)
            hh = hh + a_out
            m_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                m_out, _ = L.moe_apply(lp["moe"], cfg, m_in)
            else:
                m_out = L.mlp_apply(lp["mlp"], cfg, m_in)
            return hh + m_out, (c["k"], c["v"])

        h, (ks, vs) = jax.lax.scan(
            step, h, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"]))
        new_cache["attn"] = {"k": ks, "v": vs}
    elif cfg.family == "ssm":
        def step(hh, xs):
            lp, conv, state = xs
            m_in = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            out, c = L.mamba_decode(lp["mamba"], cfg, m_in,
                                    {"conv": conv, "state": state})
            return hh + out, (c["conv"], c["state"])

        h, (convs, states) = jax.lax.scan(
            step, h, (params["blocks"], cache["mamba"]["conv"],
                      cache["mamba"]["state"]))
        new_cache["mamba"] = {"conv": convs, "state": states}
    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every

        def step(hh, xs):
            lp, conv, state = xs
            m_in = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            out, c = L.mamba_decode(lp["mamba"], cfg, m_in,
                                    {"conv": conv, "state": state})
            return hh + out, (c["conv"], c["state"])

        convs, states, ks, vs = [], [], [], []
        for gi in range(n_groups):
            sl = slice(gi * every, (gi + 1) * every)
            grp = jax.tree.map(lambda x: x[sl], params["blocks"])
            h, (cv, st) = jax.lax.scan(
                step, h, (grp, cache["mamba"]["conv"][sl],
                          cache["mamba"]["state"][sl]))
            c = {"k": cache["attn"]["k"][gi], "v": cache["attn"]["v"][gi],
                 "pos": pos}
            h, c = _shared_decode(params["shared"], cfg, h, emb0, c)
            convs.append(cv); states.append(st)
            ks.append(c["k"]); vs.append(c["v"])
        new_cache["mamba"] = {"conv": jnp.concatenate(convs),
                              "state": jnp.concatenate(states)}
        new_cache["attn"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    new_cache["pos"] = pos + 1

    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(h, head), new_cache


def _shared_decode(sp, cfg, h, emb0, cache):
    cat = jnp.concatenate([h, emb0], axis=-1)
    a_in = rmsnorm(cat, sp["ln_in"], cfg.norm_eps)
    a_out, cache = L.attn_decode(sp["attn"], cfg, a_in, cache)
    h = h + a_out
    m_in = rmsnorm(h, sp["ln_mlp"], cfg.norm_eps)
    h = h + L.mlp_apply(sp["mlp"], cfg, m_in)
    return h, cache


def _shared_decode_block(sp, cfg, h, emb0, cache, n_valid):
    cat = jnp.concatenate([h, emb0], axis=-1)
    a_in = rmsnorm(cat, sp["ln_in"], cfg.norm_eps)
    a_out, cache = L.attn_decode_block(sp["attn"], cfg, a_in, cache,
                                       n_valid=n_valid)
    h = h + a_out
    m_in = rmsnorm(h, sp["ln_mlp"], cfg.norm_eps)
    h = h + L.mlp_apply(sp["mlp"], cfg, m_in)
    return h, cache


def lm_decode_block(params, cfg: L.ModelConfig, cache, batch, *,
                    n_valid, reset_mask):
    """Slot-masked T-token step: the continuous-batching workhorse.

    batch {"tokens": (B, T)}; ``n_valid`` (B,) int32 in [0, T] — slot b
    consumes its first ``n_valid[b]`` tokens (0 = untouched slot);
    ``reset_mask`` (B,) bool clears a slot's sequence state (pos -> 0,
    SSM conv/state -> 0) before it consumes tokens, i.e. admission of a
    new request into a recycled slot. Stale KV rows need no clearing: the
    per-slot valid-length mask hides them until they are overwritten.

    One call serves chunked prefill (n_valid up to T prompt tokens) and
    single-token decode (n_valid == 1) simultaneously across slots, so
    admission never stalls decode. The cache carries a per-slot ``pos``
    vector; KV writes are ring-buffered per slot. Token positions past
    ``n_valid`` hold junk the masks keep out of every slot's state (MoE
    capacity is the one shared resource junk tokens can touch; decode-
    sized batches stay far below the 128-rounded capacity).

    Returns (next_logits (B, vocab) — logits after each slot's last valid
    token — and the new cache)."""
    tokens = batch["tokens"]
    b, t_len = tokens.shape
    n_valid = jnp.asarray(n_valid, jnp.int32)
    reset_mask = jnp.asarray(reset_mask, jnp.bool_)
    pos = jnp.where(reset_mask, 0, cache["pos"])          # (B,)
    h = embed_tokens(params["embed"], tokens)             # (B, T, d)
    emb0 = h
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        def step(hh, xs):
            lp, kc, vc = xs
            c = {"k": kc, "v": vc, "pos": pos}
            a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            a_out, c = L.attn_decode_block(lp["attn"], cfg, a_in, c,
                                           n_valid=n_valid)
            hh = hh + a_out
            m_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                m_out, _ = L.moe_apply(lp["moe"], cfg, m_in)
            else:
                m_out = L.mlp_apply(lp["mlp"], cfg, m_in)
            return hh + m_out, (c["k"], c["v"])

        h, (ks, vs) = jax.lax.scan(
            step, h, (params["blocks"], cache["attn"]["k"],
                      cache["attn"]["v"]))
        new_cache["attn"] = {"k": ks, "v": vs}
    elif cfg.family == "ssm":
        conv0 = jnp.where(reset_mask[None, :, None, None], 0,
                          cache["mamba"]["conv"])
        state0 = jnp.where(reset_mask[None, :, None, None, None], 0,
                           cache["mamba"]["state"])

        def step(hh, xs):
            lp, conv, state = xs
            m_in = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            out, c = L.mamba_decode_block(lp["mamba"], cfg, m_in,
                                          {"conv": conv, "state": state},
                                          n_valid=n_valid)
            return hh + out, (c["conv"], c["state"])

        h, (convs, states) = jax.lax.scan(
            step, h, (params["blocks"], conv0, state0))
        new_cache["mamba"] = {"conv": convs, "state": states}
    elif cfg.family == "hybrid":
        conv0 = jnp.where(reset_mask[None, :, None, None], 0,
                          cache["mamba"]["conv"])
        state0 = jnp.where(reset_mask[None, :, None, None, None], 0,
                           cache["mamba"]["state"])
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every

        def step(hh, xs):
            lp, conv, state = xs
            m_in = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            out, c = L.mamba_decode_block(lp["mamba"], cfg, m_in,
                                          {"conv": conv, "state": state},
                                          n_valid=n_valid)
            return hh + out, (c["conv"], c["state"])

        convs, states, ks, vs = [], [], [], []
        for gi in range(n_groups):
            sl = slice(gi * every, (gi + 1) * every)
            grp = jax.tree.map(lambda x: x[sl], params["blocks"])
            h, (cv, st) = jax.lax.scan(step, h, (grp, conv0[sl],
                                                 state0[sl]))
            c = {"k": cache["attn"]["k"][gi], "v": cache["attn"]["v"][gi],
                 "pos": pos}
            h, c = _shared_decode_block(params["shared"], cfg, h, emb0, c,
                                        n_valid)
            convs.append(cv); states.append(st)
            ks.append(c["k"]); vs.append(c["v"])
        new_cache["mamba"] = {"conv": jnp.concatenate(convs),
                              "state": jnp.concatenate(states)}
        new_cache["attn"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    else:
        raise ValueError(cfg.family)
    new_cache["pos"] = pos + n_valid

    # next-token logits at each slot's last valid token (idle slots clamp
    # to position 0; their row is garbage the engine ignores)
    last = jnp.maximum(n_valid - 1, 0)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)
    h_last = rmsnorm(h_last, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(h_last, head)[:, 0], new_cache


def _shared_decode_block_paged(sp, cfg, h, emb0, cache, n_valid, tables):
    cat = jnp.concatenate([h, emb0], axis=-1)
    a_in = rmsnorm(cat, sp["ln_in"], cfg.norm_eps)
    a_out, cache = L.attn_decode_paged(sp["attn"], cfg, a_in, cache,
                                       n_valid=n_valid, tables=tables)
    h = h + a_out
    m_in = rmsnorm(h, sp["ln_mlp"], cfg.norm_eps)
    h = h + L.mlp_apply(sp["mlp"], cfg, m_in)
    return h, cache


def _snap_io(cfg, reset_mask, snap_load, snap_save, live_conv, live_state,
             snap):
    """SSM snapshot pool plumbing for the paged path.

    Returns the tick's initial conv/state (reset -> zeros, or a snapshot
    gathered from the pool when the host planned a prefix-sharing load)
    and the updated snapshot pool (pre-tick state of slots the host
    marked for capture scattered in via a one-hot matmul — capture runs
    at the first tick after prefill, when live state is exactly
    state-after-prompt). Save destinations are freshly allocated pages,
    never a page being loaded this tick, so save-before-load ordering is
    immaterial."""
    use = reset_mask & (snap_load >= 0)
    li = jnp.maximum(snap_load, 0)
    lconv = jnp.take(snap["conv"], li, axis=1).astype(live_conv.dtype)
    lstate = jnp.take(snap["state"], li, axis=1)
    conv0 = jnp.where(reset_mask[None, :, None, None], 0, live_conv)
    conv0 = jnp.where(use[None, :, None, None], lconv, conv0)
    state0 = jnp.where(reset_mask[None, :, None, None, None], 0, live_state)
    state0 = jnp.where(use[None, :, None, None, None], lstate, state0)
    sp = snap["conv"].shape[1]
    ohs = (jnp.arange(sp)[:, None] == snap_save[None, :]
           ).astype(jnp.float32)                          # (Sp, B)
    keep = 1.0 - ohs.sum(axis=1)                          # (Sp,)
    nconv = (snap["conv"].astype(jnp.float32) * keep[None, :, None, None]
             + jnp.einsum("sb,lbkc->lskc", ohs, conv0.astype(jnp.float32))
             ).astype(snap["conv"].dtype)
    nstate = (snap["state"] * keep[None, :, None, None, None]
              + jnp.einsum("sb,lbhpn->lshpn", ohs, state0))
    return conv0, state0, {"conv": nconv, "state": nstate}


def lm_decode_block_paged(params, cfg: L.ModelConfig, cache, batch, *,
                          n_valid, reset_mask, page):
    """Paged-pool twin of :func:`lm_decode_block`.

    ``page`` carries the host manager's per-tick plan
    (``serving/kvpool.py``): ``reset_pos`` (B,) — admission start
    positions (> 0 when a shared prefix is skipped); attention families
    add ``tables`` (B, MP) block tables and ``kv_copy`` (P,) — a pool-
    level page gather (identity rows except copy-on-write destinations,
    which read their source page) applied ONCE before the layer scan so a
    CoW costs one gather for all layers; SSM families add ``snap_save`` /
    ``snap_load`` (B,) snapshot-pool page indices (-1 = none). Same
    contract otherwise: returns (next_logits (B, vocab), new cache)."""
    tokens = batch["tokens"]
    b, t_len = tokens.shape
    n_valid = jnp.asarray(n_valid, jnp.int32)
    reset_mask = jnp.asarray(reset_mask, jnp.bool_)
    pos = jnp.where(reset_mask, jnp.asarray(page["reset_pos"], jnp.int32),
                    cache["pos"])                          # (B,)
    h = embed_tokens(params["embed"], tokens)              # (B, T, d)
    emb0 = h
    new_cache = dict(cache)

    if cfg.family in ("dense", "vlm", "moe"):
        tables = jnp.asarray(page["tables"], jnp.int32)
        kv_copy = jnp.asarray(page["kv_copy"], jnp.int32)
        kpool = jnp.take(cache["attn"]["k"], kv_copy, axis=1)
        vpool = jnp.take(cache["attn"]["v"], kv_copy, axis=1)

        def step(hh, xs):
            lp, kc, vc = xs
            c = {"k": kc, "v": vc, "pos": pos}
            a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
            a_out, c = L.attn_decode_paged(lp["attn"], cfg, a_in, c,
                                           n_valid=n_valid, tables=tables)
            hh = hh + a_out
            m_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
            if "moe" in lp:
                m_out, _ = L.moe_apply(lp["moe"], cfg, m_in)
            else:
                m_out = L.mlp_apply(lp["mlp"], cfg, m_in)
            return hh + m_out, (c["k"], c["v"])

        h, (ks, vs) = jax.lax.scan(step, h, (params["blocks"], kpool,
                                             vpool))
        new_cache["attn"] = {"k": ks, "v": vs}
    elif cfg.family == "ssm":
        conv0, state0, new_cache["snap"] = _snap_io(
            cfg, reset_mask, jnp.asarray(page["snap_load"], jnp.int32),
            jnp.asarray(page["snap_save"], jnp.int32),
            cache["mamba"]["conv"], cache["mamba"]["state"], cache["snap"])

        def step(hh, xs):
            lp, conv, state = xs
            m_in = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            out, c = L.mamba_decode_block(lp["mamba"], cfg, m_in,
                                          {"conv": conv, "state": state},
                                          n_valid=n_valid)
            return hh + out, (c["conv"], c["state"])

        h, (convs, states) = jax.lax.scan(
            step, h, (params["blocks"], conv0, state0))
        new_cache["mamba"] = {"conv": convs, "state": states}
    elif cfg.family == "hybrid":
        conv0, state0, new_cache["snap"] = _snap_io(
            cfg, reset_mask, jnp.asarray(page["snap_load"], jnp.int32),
            jnp.asarray(page["snap_save"], jnp.int32),
            cache["mamba"]["conv"], cache["mamba"]["state"], cache["snap"])
        tables = jnp.asarray(page["tables"], jnp.int32)
        kv_copy = jnp.asarray(page["kv_copy"], jnp.int32)
        kpool = jnp.take(cache["attn"]["k"], kv_copy, axis=1)
        vpool = jnp.take(cache["attn"]["v"], kv_copy, axis=1)
        every = cfg.shared_attn_every
        n_groups = cfg.n_layers // every

        def step(hh, xs):
            lp, conv, state = xs
            m_in = rmsnorm(hh, lp["ln"], cfg.norm_eps)
            out, c = L.mamba_decode_block(lp["mamba"], cfg, m_in,
                                          {"conv": conv, "state": state},
                                          n_valid=n_valid)
            return hh + out, (c["conv"], c["state"])

        convs, states, ks, vs = [], [], [], []
        for gi in range(n_groups):
            sl = slice(gi * every, (gi + 1) * every)
            grp = jax.tree.map(lambda x: x[sl], params["blocks"])
            h, (cv, st) = jax.lax.scan(step, h, (grp, conv0[sl],
                                                 state0[sl]))
            c = {"k": kpool[gi], "v": vpool[gi], "pos": pos}
            h, c = _shared_decode_block_paged(params["shared"], cfg, h,
                                              emb0, c, n_valid, tables)
            convs.append(cv); states.append(st)
            ks.append(c["k"]); vs.append(c["v"])
        new_cache["mamba"] = {"conv": jnp.concatenate(convs),
                              "state": jnp.concatenate(states)}
        new_cache["attn"] = {"k": jnp.stack(ks), "v": jnp.stack(vs)}
    else:
        raise ValueError(cfg.family)
    new_cache["pos"] = pos + n_valid

    last = jnp.maximum(n_valid - 1, 0)
    h_last = jnp.take_along_axis(h, last[:, None, None], axis=1)
    h_last = rmsnorm(h_last, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["head"]
    return unembed(h_last, head)[:, 0], new_cache


# ---------------------------------------------------------------------------
# bundle


def build_lm(cfg: L.ModelConfig) -> Bundle:
    pspec = lm_pspec(cfg)

    def loss(params, batch):
        return lm_loss(params, cfg, batch)

    def prefill(params, batch):
        logits, _, cache = lm_apply(params, cfg, batch, collect_cache=True)
        return logits, cache

    def prefill_last(params, batch):
        logits, _, cache = lm_apply(params, cfg, batch, collect_cache=True,
                                    last_only=True)
        return logits, cache

    def decode(params, cache, batch):
        return lm_decode(params, cfg, cache, batch)

    def decode_block(params, cache, batch, *, n_valid, reset_mask):
        return lm_decode_block(params, cfg, cache, batch,
                               n_valid=n_valid, reset_mask=reset_mask)

    def decode_block_paged(params, cache, batch, *, n_valid, reset_mask,
                           page):
        return lm_decode_block_paged(params, cfg, cache, batch,
                                     n_valid=n_valid, reset_mask=reset_mask,
                                     page=page)

    def cache_pspec(batch: int, smax: int, per_slot_pos: bool = False,
                    **kind_kwargs):
        return lm_cache_pspec(cfg, batch, smax, per_slot_pos=per_slot_pos,
                              **kind_kwargs)

    from repro.models.common import count_pspec_params

    n = count_pspec_params(pspec)
    n_active = n
    if cfg.family == "moe":
        moe_total = count_pspec_params(pspec["blocks"]["moe"])
        per_expert = moe_total // cfg.n_experts
        n_active = n - moe_total + per_expert * cfg.experts_per_tok \
            + count_pspec_params(pspec["blocks"]["moe"]["router"])
    return Bundle(cfg=cfg, params_pspec=pspec, loss=loss, prefill=prefill,
                  decode=decode, cache_pspec=cache_pspec, n_params=n,
                  n_active_params=n_active, prefill_last=prefill_last,
                  decode_block=decode_block,
                  decode_block_paged=decode_block_paged)
