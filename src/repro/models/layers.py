"""Layer implementations: GQA attention, SwiGLU MLP, token-choice MoE,
Mamba-2 (SSD) mixer. All functional: ``<layer>_pspec(cfg)`` declares params,
``<layer>_apply(params, cfg, x, ...)`` computes, ``<layer>_decode`` steps a
cache. Every reduce/scan/attention/SSD formulation is reached through
``repro.core.dispatch`` — ``ModelConfig.policy`` plumbs an explicit
:class:`~repro.core.policy.KernelPolicy` into every call site (None =
the active policy, whose process default follows ``REPRO_KERNEL_PATH``),
so the env vars, the benchmarks, and the autotuner all see the same ops.
The old ``kernel_path=`` string kwarg is kept as a deprecation shim that
warns once and coerces into a policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import dispatch
from repro.core import policy as kpolicy
from repro.core.policy import KernelPolicy
from repro.core.ssd import ssd_decode_step
from repro.models.common import PSpec, rmsnorm, rope, swiglu
from repro.models.xla_attention import decode_attention
from repro.parallel.sharding import logical_constraint


# ---------------------------------------------------------------------------
# config


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    swa_window: int | None = None
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    experts_per_tok: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # dispatch granularity: "grouped" keeps routing/dispatch local to
    # token groups aligned with the data axis (GShard-style); "global"
    # is the naive whole-batch sort (13-16x flop inflation + TB-scale
    # collectives under GSPMD — kept as the measured baseline)
    moe_impl: str = "grouped"
    moe_groups: int = 32
    # SSM (Mamba-2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    conv_kernel: int = 4
    expand: int = 2
    ssd_chunk: int = 128           # intra-chunk tile (M traffic scales L*Q)
    # hybrid (Zamba2-style shared attention block)
    shared_attn_every: int = 0
    # enc-dec
    enc_layers: int = 0
    # modality stub (vlm/audio): prefix embeddings fed past the frontend
    stub_tokens: int = 0
    stub_dim: int = 0
    # numerics / execution
    dtype: Any = jnp.bfloat16
    remat_policy: str = "none"     # none | dots | offload-ready
    scan_layers: bool = True
    # explicit KernelPolicy for every core op in the model (attention,
    # SSD, MoE counts/offsets); strings auto-coerce; None = the active
    # policy (shape-aware "auto" by default)
    policy: KernelPolicy | None = None
    # deprecated spelling of ``policy`` (a bare path label); warns once
    kernel_path: dataclasses.InitVar[str | None] = None

    def __post_init__(self, kernel_path):
        object.__setattr__(self, "policy", kpolicy.coerce_config_policy(
            self.policy, kernel_path, "ModelConfig"))

    @property
    def dh(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim


# ---------------------------------------------------------------------------
# attention


def attn_pspec(cfg: ModelConfig, n: int | None = None, d_in: int | None = None):
    """Stacked attention params for ``n`` layers (None -> cfg.n_layers)."""
    nl = cfg.n_layers if n is None else n
    d = d_in or cfg.d_model
    dh, hq, hkv = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    lead = (nl,) if nl else ()
    ll = ("layers",) if nl else ()
    return {
        "wq": PSpec(lead + (d, hq * dh), ll + ("embed", "heads")),
        "wk": PSpec(lead + (d, hkv * dh), ll + ("embed", "kv_heads")),
        "wv": PSpec(lead + (d, hkv * dh), ll + ("embed", "kv_heads")),
        # wo always projects back to the residual width (d_in may differ,
        # e.g. Zamba2's shared block consumes concat(h, embeddings))
        "wo": PSpec(lead + (hq * dh, cfg.d_model), ll + ("heads", "embed")),
    }


def attn_apply(p, cfg: ModelConfig, x, *, positions=None, causal=True,
               window=None, kv=None):
    """x (B,S,d) -> (out (B,S,d), (k, v) for caching).

    ``kv`` overrides the self-attention K/V source (cross-attention)."""
    b, s, _ = x.shape
    dh, hq, hkv = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, s, hq, dh)
    src = x if kv is None else kv
    sk = src.shape[1]
    k = jnp.einsum("bsd,dh->bsh", src, p["wk"]).reshape(b, sk, hkv, dh)
    v = jnp.einsum("bsd,dh->bsh", src, p["wv"]).reshape(b, sk, hkv, dh)
    if positions is not None and kv is None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = logical_constraint(q, "batch", None, "heads", None)
    k = logical_constraint(k, "batch", None, "kv_heads", None)
    o = dispatch.attention(q, k, v, causal=causal and kv is None,
                           window=window, policy=cfg.policy)
    o = o.reshape(b, s, hq * dh)
    return jnp.einsum("bsh,hd->bsd", o, p["wo"]), (k, v)


def attn_decode(p, cfg: ModelConfig, x, cache, *, window=None):
    """x (B,1,d); cache dict {k,v: (B,Smax,Hkv,Dh), pos: ()} -> out, cache."""
    b = x.shape[0]
    dh, hq, hkv = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    pos = cache["pos"]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, 1, hq, dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, 1, hkv, dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, 1, hkv, dh)
    posv = jnp.full((b, 1), pos, jnp.int32)
    q = rope(q, posv, cfg.rope_theta)
    k = rope(k, posv, cfg.rope_theta)
    smax = cache["k"].shape[1]
    slot = pos % smax if window is not None else pos  # ring buffer for SWA
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    if window is None:
        o = decode_attention(q, kc, vc, pos + 1)
    else:
        # ring cache: all entries valid once warm; mask handled by recency
        valid = jnp.minimum(pos + 1, smax)
        o = decode_attention(q, kc, vc, valid)  # positions are ring-local
    o = o.reshape(b, 1, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": kc, "v": vc, "pos": pos + 1}


def attn_decode_block(p, cfg: ModelConfig, x, cache, *, n_valid):
    """Slot-masked T-token decode against a ring KV cache.

    x (B,T,d); cache {k, v: (B,S,Hkv,Dh), pos: (B,)}; ``n_valid`` (B,)
    int32 in [0, T] — token t of slot b is real iff ``t < n_valid[b]``.
    Real token t is written at ring row ``(pos[b]+t) % S`` and attends
    ``min(pos[b]+t+1, S)`` rows (ring recency semantics once wrapped, i.e.
    sliding-window truncation; RoPE uses absolute positions, so storage
    order does not matter to the softmax). Slots with ``n_valid == 0``
    write nothing and keep their position; invalid tokens produce garbage
    outputs the caller must discard. Requires T <= S so ring rows written
    within one call are distinct. Returns (out (B,T,d), new cache)."""
    b, t_len = x.shape[:2]
    dh, hq, hkv = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    pos = cache["pos"]                                    # (B,)
    posmat = pos[:, None] + jnp.arange(t_len, dtype=jnp.int32)[None, :]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, t_len, hq, dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, t_len, hkv, dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, t_len, hkv, dh)
    q = rope(q, posmat, cfg.rope_theta)
    k = rope(k, posmat, cfg.rope_theta)
    smax = cache["k"].shape[1]
    assert t_len <= smax, (t_len, smax)
    idx = posmat % smax                                   # (B, T) ring rows
    valid = jnp.arange(t_len)[None, :] < n_valid[:, None]
    # masked one-hot scatter: row s of slot b is overwritten by the (at
    # most one — rows within a call are distinct) valid token t with
    # idx[b, t] == s; an f32 one-hot matmul keeps the write exact
    oh = ((jnp.arange(smax)[None, :, None] == idx[:, None, :])
          & valid[:, None, :]).astype(jnp.float32)        # (B, S, T)
    keep = (1.0 - oh.sum(axis=2))[..., None, None]        # (B, S, 1, 1)
    def write(c, new):
        upd = jnp.einsum("bst,bthd->bshd", oh, new.astype(jnp.float32))
        return (c.astype(jnp.float32) * keep + upd).astype(c.dtype)
    kc = write(cache["k"], k)
    vc = write(cache["v"], v)
    lens = jnp.minimum(posmat + 1, smax)                  # (B, T)
    o = decode_attention(q, kc, vc, lens)
    o = o.reshape(b, t_len, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": kc, "v": vc, "pos": pos + n_valid}


def attn_decode_paged(p, cfg: ModelConfig, x, cache, *, n_valid, tables):
    """Block-table variant of :func:`attn_decode_block` for the paged KV
    pool (``serving/kvpool.py``).

    x (B,T,d); cache {k, v: (P, R, Hkv, Dh) — the *shared* page pool —
    pos: (B,)}; ``tables`` (B, MP) int32 maps slot b's logical page
    ``(pos // R) % MP`` to a physical pool page. Token t of slot b lands
    at flat pool row ``tables[b, (pos_t//R) % MP] * R + pos_t % R`` via
    the same masked one-hot f32-matmul scatter the ring path uses (the
    paper's MMA-form data movement, exact for 0/1 weights); the host
    manager guarantees written rows are globally exclusive across slots
    (copy-on-write precedes any write to a shared page), so one einsum
    scatters every slot into the pool at once. Attention gathers the
    slot's pages back into ring order — for position p the gathered row
    index is ``((p//R)%MP)*R + p%R == p % (MP*R)``, exactly the ring row
    of a capacity-``MP*R`` cache — so paged attention is bit-identical to
    the ring path, sliding-window truncation included."""
    b, t_len = x.shape[:2]
    dh, hq, hkv = cfg.dh, cfg.n_heads, cfg.n_kv_heads
    pos = cache["pos"]                                    # (B,)
    posmat = pos[:, None] + jnp.arange(t_len, dtype=jnp.int32)[None, :]
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(b, t_len, hq, dh)
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"]).reshape(b, t_len, hkv, dh)
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"]).reshape(b, t_len, hkv, dh)
    q = rope(q, posmat, cfg.rope_theta)
    k = rope(k, posmat, cfg.rope_theta)
    n_pages, r = cache["k"].shape[:2]
    mp = tables.shape[1]
    cap = mp * r                                          # ring-equivalent
    assert t_len <= cap, (t_len, cap)
    logical = (posmat // r) % mp                          # (B, T)
    phys = jnp.take_along_axis(tables, logical, axis=1)   # (B, T)
    rows = phys * r + posmat % r                          # flat pool rows
    valid = jnp.arange(t_len)[None, :] < n_valid[:, None]
    flat = n_pages * r
    oh = ((jnp.arange(flat)[None, :, None] == rows[:, None, :])
          & valid[:, None, :]).astype(jnp.float32)        # (B, PR, T)
    # rows are globally exclusive (CoW) -> sum over slots AND tokens
    keep = (1.0 - oh.sum(axis=(0, 2)))[:, None, None]     # (PR, 1, 1)

    def write(c, new):
        cf = c.reshape(flat, hkv, dh).astype(jnp.float32)
        upd = jnp.einsum("bst,bthd->shd", oh, new.astype(jnp.float32))
        return (cf * keep + upd).astype(c.dtype).reshape(c.shape)

    kc = write(cache["k"], k)
    vc = write(cache["v"], v)
    # gather each slot's pages back into ring order: (B, MP*R, Hkv, Dh)
    k_seq = jnp.take(kc, tables, axis=0).reshape(b, cap, hkv, dh)
    v_seq = jnp.take(vc, tables, axis=0).reshape(b, cap, hkv, dh)
    lens = jnp.minimum(posmat + 1, cap)                   # (B, T)
    o = decode_attention(q, k_seq, v_seq, lens)
    o = o.reshape(b, t_len, hq * dh)
    out = jnp.einsum("bsh,hd->bsd", o, p["wo"])
    return out, {"k": kc, "v": vc, "pos": pos + n_valid}


def attn_cache_pspec(cfg: ModelConfig, n_layers: int, batch: int, smax: int):
    cap = min(smax, cfg.swa_window) if cfg.swa_window else smax
    shp = (n_layers, batch, cap, cfg.n_kv_heads, cfg.dh)
    log = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "k": PSpec(shp, log, "zeros"),
        "v": PSpec(shp, log, "zeros"),
        "pos": PSpec((), (), "zeros", jnp.int32),
    }


def attn_page_cache_pspec(cfg: ModelConfig, n_layers: int, pages: int,
                          page_rows: int):
    """Paged-pool KV declaration: one pool of ``pages`` fixed-height pages
    shared by every slot (block tables map slots to pages). The page axes
    stay unsharded — pages are a pooled resource, not a batch dim; the
    model axis still shards ``kv_heads`` exactly as the ring cache."""
    shp = (n_layers, pages, page_rows, cfg.n_kv_heads, cfg.dh)
    log = ("layers", None, None, "kv_heads", None)
    return {"k": PSpec(shp, log, "zeros"), "v": PSpec(shp, log, "zeros")}


# ---------------------------------------------------------------------------
# dense MLP


def mlp_pspec(cfg: ModelConfig, n: int | None = None):
    nl = cfg.n_layers if n is None else n
    lead = (nl,) if nl else ()
    ll = ("layers",) if nl else ()
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_in": PSpec(lead + (d, f), ll + ("embed", "ff")),
        "w_gate": PSpec(lead + (d, f), ll + ("embed", "ff")),
        "w_out": PSpec(lead + (f, d), ll + ("ff", "embed")),
    }


def mlp_apply(p, cfg: ModelConfig, x):
    return swiglu(x, p["w_in"], p["w_gate"], p["w_out"])


# ---------------------------------------------------------------------------
# MoE (token-choice top-k, sort-based capacity dispatch)


def moe_pspec(cfg: ModelConfig, n: int | None = None):
    nl = cfg.n_layers if n is None else n
    lead = (nl,) if nl else ()
    ll = ("layers",) if nl else ()
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    return {
        "router": PSpec(lead + (d, e), ll + ("embed", None), "normal"),
        "w_in": PSpec(lead + (e, d, f), ll + ("experts", "embed", "e_ff")),
        "w_gate": PSpec(lead + (e, d, f), ll + ("experts", "embed", "e_ff")),
        "w_out": PSpec(lead + (e, f, d), ll + ("experts", "e_ff", "embed")),
    }


def moe_apply(p, cfg: ModelConfig, x):
    """Token-choice top-k with capacity; counts/offsets via the paper's
    matmul-form reduce + exclusive scan. Returns (y, aux_loss)."""
    if cfg.moe_impl == "grouped":
        return moe_apply_grouped(p, cfg, x)
    return moe_apply_global(p, cfg, x)


def moe_apply_grouped(p, cfg: ModelConfig, x):
    """Group-local token-choice top-k MoE (GShard-style capacity groups).

    Tokens are split into ``moe_groups`` groups whose leading dim maps onto
    the data mesh axis, so the routing sort, the capacity-buffer scatter,
    and the combine gather are all *local* to a data shard. The only
    cross-chip communication left is the expert-partial combine (a psum
    over the model axis — the same all-reduce TP already pays for dense
    MLPs) plus FSDP weight gathers. Per-(group, expert) counts and
    capacity offsets run through the paper's matmul-form reduce and
    exclusive scan.

    Versus ``moe_apply_global`` (whole-batch sort): the v0 dry-run measured
    13-16x per-chip flop inflation (capacity buffer replicated over data)
    and TB-scale scatter all-reduces; grouping removes both structurally.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    t = b * s
    import math

    g = math.gcd(t, cfg.moe_groups)
    tg = t // g
    n = tg * k                                  # routed slots per group
    xg = x.reshape(g, tg, d)
    xg = logical_constraint(xg, "moe_groups", None, None)

    logits = jnp.einsum("gtd,de->gte", xg, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                   # (g, tg, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    e_flat = logical_constraint(top_i.reshape(g, n), "moe_groups", None)
    w_flat = logical_constraint(top_w.reshape(g, n), "moe_groups", None)
    order = jnp.argsort(e_flat, axis=-1)                     # per-group sort
    order = logical_constraint(order, "moe_groups", None)
    e_sorted = jnp.take_along_axis(e_flat, order, axis=-1)
    e_sorted = logical_constraint(e_sorted, "moe_groups", None)

    # per-(group, expert) counts: a ragged reduce of ones over the expert
    # assignment (matmul-form one-hot on the default path)
    counts = dispatch.ragged_reduce(
        jnp.ones(e_flat.shape, jnp.float32), e_flat, e,
        policy=cfg.policy)                                   # (g, e)
    # capacity offsets: exclusive scan over experts
    offsets = dispatch.scan(counts, exclusive=True,
                            policy=cfg.policy)               # (g, e)
    rank = jnp.arange(n)[None, :] - jnp.take_along_axis(
        offsets, e_sorted, axis=-1).astype(jnp.int32)

    cap = max(8, int(cfg.capacity_factor * n / e + 127) // 128 * 128)
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, e * cap)   # e*cap = drop
    slot = logical_constraint(slot, "moe_groups", None)
    tok_idx = jnp.take_along_axis(
        jnp.broadcast_to(jnp.arange(n)[None], (g, n)), order, axis=-1) // k
    tok_idx = logical_constraint(tok_idx, "moe_groups", None)

    # All dispatch data movement is vmapped over the group dim: the
    # resulting gathers carry explicit batch dims, which GSPMD partitions
    # shard-locally (the explicit arange-index form measured 1e12+ bytes
    # of involuntary all-reduce per layer). Dispatch is formulated as a
    # slot->token GATHER (tokens are already expert-sorted), not a
    # token->slot scatter: scatter lowering materialises full-buffer u32
    # index maps (~20% of the HBM traffic in the v2 measurement).
    pos = offsets[..., None].astype(jnp.int32) + \
        jnp.arange(cap, dtype=jnp.int32)[None, None, :]      # (g, e, cap)
    valid = jnp.arange(cap)[None, None, :] < \
        jnp.minimum(counts, cap)[..., None]                  # (g, e, cap)
    posc = jnp.minimum(pos, n - 1).reshape(g, e * cap)
    tok_for_slot = jax.vmap(lambda tb, pb: tb[pb])(tok_idx, posc)
    hbuf = jax.vmap(lambda xb, ib: xb[ib])(xg, tok_for_slot)  # (g, e*cap, d)
    hbuf = hbuf * valid.reshape(g, e * cap, 1).astype(x.dtype)
    # shard the flat slot dim over model so each TP shard gathers only its
    # experts' slots (replicating here cost a 10.7 GB/layer f32 all-gather
    # of grad_h on the backward pass in the v3 measurement)
    hbuf = logical_constraint(hbuf, "moe_groups", "exp_slots", None)
    h = hbuf.reshape(g, e, cap, d)
    # NOTE (measured, kept for the record): sharding the capacity dim over
    # model here ("exp_slots") instead of exp_cap helps nothing for qwen3
    # (no-op: "experts" owns the axis) and HURTS grok (x 120s -> 226s: the
    # cap-sharded FFN must gather the f-sharded expert weights, which
    # costs more than the grad all-reduce it removes). grok's structural
    # fix would be 2-D expert sharding (EP8 x TP2) on a factored mesh
    # axis — out of scope for the fixed (data=16, model=16) mesh.
    h = logical_constraint(h, "moe_groups", "experts", "exp_cap", None)
    up = jnp.einsum("gecd,edf->gecf", h, p["w_in"])
    gate = jnp.einsum("gecd,edf->gecf", h, p["w_gate"])
    act = jax.nn.silu(gate) * up          # native-dtype silu (see swiglu)
    act = logical_constraint(act, "moe_groups", "experts", "exp_cap",
                             "e_ff")
    y = jnp.einsum("gecf,efd->gecd", act, p["w_out"])
    y = logical_constraint(y, "moe_groups", "experts", "exp_cap", None)

    yflat = logical_constraint(y.reshape(g, e * cap, d),
                               "moe_groups", None, None)
    y_tok = jax.vmap(lambda yb, sb: yb[sb])(
        yflat, jnp.minimum(slot, e * cap - 1))               # (g, n, d)
    y_tok = logical_constraint(y_tok, "moe_groups", None, None)
    w_sorted = jnp.take_along_axis(w_flat, order, axis=-1)
    contrib = y_tok * (w_sorted * keep.astype(jnp.float32)
                       )[..., None].astype(y.dtype)
    out = jax.vmap(
        lambda cb, ib: jnp.zeros((tg, d), x.dtype).at[ib].add(cb))(
        contrib, tok_idx)
    out = logical_constraint(out, "moe_groups", None, None)

    # switch-style load-balance aux: E * <f_e, p_e> (mean over groups)
    frac = counts / jnp.maximum(
        jnp.sum(counts, axis=-1, keepdims=True), 1.0)        # (g, e)
    mean_p = jnp.mean(probs, axis=1)                         # (g, e)
    aux = e * jnp.mean(jnp.sum(frac * mean_p, axis=-1))
    return out.reshape(b, s, d), aux


def moe_apply_global(p, cfg: ModelConfig, x):
    """Whole-batch sort dispatch (the measured v0 baseline; see
    moe_apply_grouped for why this does not shard)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    t = b * s
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                   # (t, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)

    e_flat = top_i.reshape(t * k)
    w_flat = top_w.reshape(t * k)
    order = jnp.argsort(e_flat)                              # stable
    e_sorted = e_flat[order]

    # per-expert counts: ragged reduce of ones over the assignment
    # (matmul-form one-hot on the default path)
    counts = dispatch.ragged_reduce(
        jnp.ones(e_flat.shape, jnp.float32), e_flat, e,
        policy=cfg.policy)                                   # (e,)
    # capacity offsets: exclusive scan (stream compaction)
    offsets = dispatch.scan(counts, exclusive=True,
                            policy=cfg.policy)               # (e,)
    rank = jnp.arange(t * k) - jnp.take(offsets, e_sorted).astype(jnp.int32)

    cap = max(8, int(cfg.capacity_factor * t * k / e + 127) // 128 * 128)
    keep = rank < cap
    slot = jnp.where(keep, e_sorted * cap + rank, e * cap)

    xin = jnp.take(xf, order // k, axis=0)                   # (t*k, d)
    buf = jnp.zeros((e * cap, d), x.dtype).at[slot].set(xin, mode="drop")
    h = buf.reshape(e, cap, d)
    h = logical_constraint(h, "experts", "exp_cap", None)
    up = jnp.einsum("ecd,edf->ecf", h, p["w_in"])
    gate = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    act = jax.nn.silu(gate.astype(jnp.float32)).astype(up.dtype) * up
    act = logical_constraint(act, "experts", "exp_cap", "e_ff")
    y = jnp.einsum("ecf,efd->ecd", act, p["w_out"])
    y = logical_constraint(y, "experts", "exp_cap", None)

    y_sorted = jnp.take(y.reshape(e * cap, d), jnp.minimum(slot, e * cap - 1),
                        axis=0)
    w_sorted = jnp.take(w_flat, order)
    contrib = y_sorted * (w_sorted * keep.astype(jnp.float32))[:, None].astype(y.dtype)
    out = jnp.zeros((t, d), x.dtype).at[order // k].add(contrib)

    # switch-style load-balance aux: E * <f_e, p_e>
    frac = counts / jnp.maximum(jnp.sum(counts), 1.0)
    mean_p = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac * mean_p)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Mamba-2 mixer


def mamba_pspec(cfg: ModelConfig, n: int | None = None):
    nl = cfg.n_layers if n is None else n
    lead = (nl,) if nl else ()
    ll = ("layers",) if nl else ()
    d, di = cfg.d_model, cfg.d_inner
    g, ns, hh = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * ns
    return {
        "in_proj": PSpec(lead + (d, 2 * di + 2 * g * ns + hh),
                         ll + ("embed", "inner_all")),
        "conv_w": PSpec(lead + (cfg.conv_kernel, conv_dim),
                        ll + (None, "inner_all"), "fan_in"),
        "conv_b": PSpec(lead + (conv_dim,), ll + ("inner_all",), "zeros"),
        "dt_bias": PSpec(lead + (hh,), ll + ("ssm_heads",), "dt_bias",
                         jnp.float32),
        "a_log": PSpec(lead + (hh,), ll + ("ssm_heads",), "a_log",
                       jnp.float32),
        "d_skip": PSpec(lead + (hh,), ll + ("ssm_heads",), "ones",
                        jnp.float32),
        "norm_w": PSpec(lead + (di,), ll + ("inner",), "ones"),
        "out_proj": PSpec(lead + (di, d), ll + ("inner", "embed")),
    }


def _split_inproj(cfg: ModelConfig, zxbcdt):
    di, g, ns, hh = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * ns]
    dt = zxbcdt[..., di + di + 2 * g * ns:]
    assert dt.shape[-1] == hh
    return z, xbc, dt


def _causal_conv(xbc, w, bias):
    """Depthwise causal conv. xbc (B,S,C), w (K,C) -> (B,S,C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = jax.lax.conv_general_dilated(
        pad.astype(jnp.float32),
        w.astype(jnp.float32)[:, None, :],       # (K, 1, C) HIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[1],
    )
    return jax.nn.silu(out + bias.astype(jnp.float32)).astype(xbc.dtype)


def mamba_apply(p, cfg: ModelConfig, x, *, collect_cache: bool = False):
    """x (B,S,d) -> (out (B,S,d), cache-or-None). Full-sequence path."""
    b, s, d = x.shape
    di, g, ns = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    hh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_raw, dt_raw = _split_inproj(cfg, zxbcdt)
    xbc = _causal_conv(xbc_raw, p["conv_w"], p["conv_b"])
    xs = xbc[..., :di].reshape(b, s, hh, hp)
    bmat = xbc[..., di:di + g * ns].reshape(b, s, g, ns)
    cmat = xbc[..., di + g * ns:].reshape(b, s, g, ns)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    xs = logical_constraint(xs, "batch", None, "ssm_heads", None)
    # big-einsum operands in the compute dtype (f32 masks + accumulation
    # stay; see core/ssd.py)
    y, state = dispatch.ssd(xs, dt, a, bmat, cmat, chunk=cfg.ssd_chunk,
                            matmul_dtype=cfg.dtype, return_state=True,
                            policy=cfg.policy)
    y = y + p["d_skip"][:, None].astype(jnp.float32) * xs.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    cache = None
    if collect_cache:
        # conv cache = last K-1 *raw* mixer inputs; state (B,H,P,N) from SSD
        cache = {"conv": xbc_raw[:, -(cfg.conv_kernel - 1):], "state": state}
    return out, cache


def mamba_cache_pspec(cfg: ModelConfig, n_layers: int, batch: int):
    di, g, ns = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    hh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * g * ns
    return {
        "conv": PSpec((n_layers, batch, cfg.conv_kernel - 1, conv_dim),
                      ("layers", "batch", None, "inner_all"), "zeros"),
        "state": PSpec((n_layers, batch, hh, hp, ns),
                       ("layers", "batch", "ssm_heads", None, None), "zeros",
                       jnp.float32),
    }


def mamba_snap_pspec(cfg: ModelConfig, n_layers: int, pages: int):
    """SSM state-snapshot pool for the paged serving cache: ``pages``
    slots each holding a full (conv history, SSD state) pair captured at
    a prompt boundary, so later requests extending that exact prompt skip
    its prefill. Live per-slot state stays in :func:`mamba_cache_pspec`;
    only snapshots are pooled. Page axis unsharded (pooled resource);
    model axis shards the channel dims exactly as the live arrays."""
    di, g, ns = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    hh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    conv_dim = di + 2 * g * ns
    return {
        "conv": PSpec((n_layers, pages, cfg.conv_kernel - 1, conv_dim),
                      ("layers", None, None, "inner_all"), "zeros"),
        "state": PSpec((n_layers, pages, hh, hp, ns),
                       ("layers", None, "ssm_heads", None, None), "zeros",
                       jnp.float32),
    }


def mamba_decode(p, cfg: ModelConfig, x, cache):
    """x (B,1,d); cache {conv (B,K-1,C), state (B,H,P,N)}."""
    b = x.shape[0]
    di, g, ns = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    hh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc, dt_raw = _split_inproj(cfg, zxbcdt)
    hist = jnp.concatenate([cache["conv"], xbc.astype(cache["conv"].dtype)],
                           axis=1)                        # (B, K, C)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xbc_t = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    xbc_t = xbc_t.astype(x.dtype)
    xs = xbc_t[..., :di].reshape(b, hh, hp)
    bmat = xbc_t[..., di:di + g * ns].reshape(b, g, ns)
    cmat = xbc_t[..., di + g * ns:].reshape(b, g, ns)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    y, state = ssd_decode_step(cache["state"], xs, dt, a, bmat, cmat)
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, {"conv": hist[:, 1:], "state": state}


def mamba_decode_block(p, cfg: ModelConfig, x, cache, *, n_valid):
    """Slot-masked T-token recurrent step.

    x (B,T,d); cache {conv (B,K-1,C), state (B,H,P,N)}; ``n_valid`` (B,)
    — slot b consumes its first ``n_valid[b]`` tokens. The causal conv
    runs VALID over [cached history | chunk] (exact conv-with-history, no
    zero pad), and the SSD recurrence is a masked ``lax.scan`` of
    ``ssd_decode_step`` so a slot's state stops advancing at its own
    ``n_valid`` — tokens past it (other slots' chunk tail) cannot pollute
    the carried state. The new conv history ends at each slot's last
    valid token. Invalid tokens produce garbage outputs (discarded by the
    caller)."""
    b, t_len = x.shape[:2]
    di, g, ns = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    hh, hp = cfg.ssm_heads, cfg.ssm_head_dim
    kk = cfg.conv_kernel
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xbc_raw, dt_raw = _split_inproj(cfg, zxbcdt)
    hist = jnp.concatenate(
        [cache["conv"], xbc_raw.astype(cache["conv"].dtype)],
        axis=1)                                          # (B, K-1+T, C)
    conv_out = jax.lax.conv_general_dilated(
        hist.astype(jnp.float32),
        p["conv_w"].astype(jnp.float32)[:, None, :],     # (K, 1, C) HIO
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=p["conv_w"].shape[1],
    )                                                    # (B, T, C)
    xbc = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))
    xbc = xbc.astype(x.dtype)
    xs = xbc[..., :di].reshape(b, t_len, hh, hp)
    bmat = xbc[..., di:di + g * ns].reshape(b, t_len, g, ns)
    cmat = xbc[..., di + g * ns:].reshape(b, t_len, g, ns)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    upd = jnp.arange(t_len)[:, None] < n_valid[None, :]  # (T, B)

    def step(state, inp):
        x_t, dt_t, b_t, c_t, m_t = inp
        y_t, new_state = ssd_decode_step(state, x_t, dt_t, a, b_t, c_t)
        state = jnp.where(m_t[:, None, None, None], new_state, state)
        return state, y_t

    state, ys = jax.lax.scan(
        step, cache["state"],
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(bmat, 1, 0), jnp.moveaxis(cmat, 1, 0), upd))
    y = jnp.moveaxis(ys, 0, 1).astype(jnp.float32)       # (B, T, H, P)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, t_len, di).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rmsnorm(y, p["norm_w"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    # per-slot conv history: hist rows [n_valid, n_valid + K - 2] — the
    # K-1 raw inputs preceding the slot's next token
    newconv = jax.vmap(
        lambda h_b, nv: jax.lax.dynamic_slice_in_dim(h_b, nv, kk - 1,
                                                     axis=0))(hist, n_valid)
    return out, {"conv": newconv, "state": state}
