"""Model zoo: one scanned decoder-only implementation (dense/moe/ssm/
hybrid/vlm) plus an encoder-decoder; all consuming repro.core's matmul-form
reduce/scan through RMSNorm, MoE routing, SSD, and attention."""
from repro.models.layers import ModelConfig
from repro.models.lm import Bundle, build_lm


def build(cfg: ModelConfig) -> Bundle:
    if cfg.family == "encdec":
        from repro.models.encdec import build_encdec

        return build_encdec(cfg)
    return build_lm(cfg)


__all__ = ["Bundle", "ModelConfig", "build"]
