"""Encoder-decoder LM (Seamless-M4T-style backbone).

The audio frontend is a stub per the brief: ``input_specs`` provides
precomputed frame embeddings (B, S_enc, d) — the w2v-BERT-style frontend
output — and the transformer backbone (bidirectional encoder + causal
decoder with cross-attention) is fully modeled. Decode caches precomputed
cross-attention K/V (standard seq2seq serving layout).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.common import (
    PSpec,
    cross_entropy,
    embed_tokens,
    rmsnorm,
    unembed,
)
from repro.models.lm import Bundle, _positions, _remat
from repro.parallel.sharding import logical_constraint


def encdec_pspec(cfg: L.ModelConfig):
    d, v = cfg.d_model, cfg.vocab
    ne, nd = cfg.enc_layers, cfg.n_layers
    return {
        "embed": PSpec((v, d), ("vocab", "embed"), "normal"),
        "head": PSpec((v, d), ("vocab", "embed"), "normal"),
        "enc": {
            "ln1": PSpec((ne, d), ("layers", "embed"), "ones"),
            "attn": L.attn_pspec(cfg, n=ne),
            "ln2": PSpec((ne, d), ("layers", "embed"), "ones"),
            "mlp": L.mlp_pspec(cfg, n=ne),
        },
        "enc_norm": PSpec((d,), ("embed",), "ones"),
        "dec": {
            "ln1": PSpec((nd, d), ("layers", "embed"), "ones"),
            "attn": L.attn_pspec(cfg, n=nd),
            "lnx": PSpec((nd, d), ("layers", "embed"), "ones"),
            "xattn": L.attn_pspec(cfg, n=nd),
            "ln2": PSpec((nd, d), ("layers", "embed"), "ones"),
            "mlp": L.mlp_pspec(cfg, n=nd),
        },
        "final_norm": PSpec((d,), ("embed",), "ones"),
    }


def encode(params, cfg: L.ModelConfig, frames):
    """frames (B, S_enc, d) -> encoder memory (B, S_enc, d)."""
    h = frames.astype(cfg.dtype)
    b, s, _ = h.shape
    positions = _positions(b, s)

    def body(hh, lp):
        a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        a_out, _ = L.attn_apply(lp["attn"], cfg, a_in, positions=positions,
                                causal=False)
        hh = hh + a_out
        m_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        hh = hh + L.mlp_apply(lp["mlp"], cfg, m_in)
        return logical_constraint(hh, "batch", None, "embed"), None

    body = _remat(body, cfg.remat_policy)
    h, _ = jax.lax.scan(body, h, params["enc"])
    return rmsnorm(h, params["enc_norm"], cfg.norm_eps)


def decode_train(params, cfg: L.ModelConfig, tokens, memory,
                 collect_cache=False):
    h = embed_tokens(params["embed"], tokens)
    b, s, _ = h.shape
    positions = _positions(b, s)

    def body(hh, lp):
        a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        a_out, kv = L.attn_apply(lp["attn"], cfg, a_in, positions=positions)
        hh = hh + a_out
        x_in = rmsnorm(hh, lp["lnx"], cfg.norm_eps)
        x_out, xkv = L.attn_apply(lp["xattn"], cfg, x_in, kv=memory)
        hh = hh + x_out
        m_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        hh = hh + L.mlp_apply(lp["mlp"], cfg, m_in)
        hh = logical_constraint(hh, "batch", None, "embed")
        return hh, (kv, xkv) if collect_cache else None

    body = _remat(body, cfg.remat_policy)
    h, caches = jax.lax.scan(body, h, params["dec"])
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return unembed(h, params["head"]), caches


def encdec_loss(params, cfg: L.ModelConfig, batch):
    memory = encode(params, cfg, batch["frames"])
    logits, _ = decode_train(params, cfg, batch["tokens"], memory)
    return cross_entropy(logits, batch["labels"])


def encdec_cache_pspec(cfg: L.ModelConfig, batch: int, smax: int):
    """smax split evenly between encoder memory and decoder self cache."""
    s_enc = s_dec = smax // 2
    nd, hkv, dh = cfg.n_layers, cfg.n_kv_heads, cfg.dh
    log = ("layers", "batch", "kv_seq", "kv_heads", None)
    return {
        "self_k": PSpec((nd, batch, s_dec, hkv, dh), log, "zeros"),
        "self_v": PSpec((nd, batch, s_dec, hkv, dh), log, "zeros"),
        "cross_k": PSpec((nd, batch, s_enc, hkv, dh), log, "zeros"),
        "cross_v": PSpec((nd, batch, s_enc, hkv, dh), log, "zeros"),
        "pos": PSpec((), (), "zeros", jnp.int32),
    }


def encdec_prefill(params, cfg: L.ModelConfig, batch):
    memory = encode(params, cfg, batch["frames"])
    logits, caches = decode_train(params, cfg, batch["tokens"], memory,
                                  collect_cache=True)
    (sk, sv), (xk, xv) = caches
    cache = {"self_k": sk, "self_v": sv, "cross_k": xk, "cross_v": xv,
             "pos": jnp.int32(batch["tokens"].shape[1])}
    return logits, cache


def encdec_decode(params, cfg: L.ModelConfig, cache, batch):
    tokens = batch["tokens"]
    h = embed_tokens(params["embed"], tokens)
    pos = cache["pos"]
    s_enc = cache["cross_k"].shape[2]

    def step(hh, xs):
        lp, sk, sv, xk, xv = xs
        c = {"k": sk, "v": sv, "pos": pos}
        a_in = rmsnorm(hh, lp["ln1"], cfg.norm_eps)
        a_out, c = L.attn_decode(lp["attn"], cfg, a_in, c)
        hh = hh + a_out
        x_in = rmsnorm(hh, lp["lnx"], cfg.norm_eps)
        # cross attention against fixed memory K/V (no rope, all valid)
        b = hh.shape[0]
        dh, hq, hkv = cfg.dh, cfg.n_heads, cfg.n_kv_heads
        q = jnp.einsum("bsd,dh->bsh", x_in, lp["xattn"]["wq"]).reshape(
            b, 1, hq, dh)
        from repro.models.xla_attention import decode_attention
        o = decode_attention(q, xk, xv, jnp.int32(s_enc))
        x_out = jnp.einsum("bsh,hd->bsd", o.reshape(b, 1, hq * dh),
                           lp["xattn"]["wo"])
        hh = hh + x_out
        m_in = rmsnorm(hh, lp["ln2"], cfg.norm_eps)
        hh = hh + L.mlp_apply(lp["mlp"], cfg, m_in)
        return hh, (c["k"], c["v"])

    h, (ks, vs) = jax.lax.scan(
        step, h, (params["dec"], cache["self_k"], cache["self_v"],
                  cache["cross_k"], cache["cross_v"]))
    new_cache = dict(cache)
    new_cache.update({"self_k": ks, "self_v": vs, "pos": pos + 1})
    h = rmsnorm(h, params["final_norm"], cfg.norm_eps)
    return unembed(h, params["head"]), new_cache


def build_encdec(cfg: L.ModelConfig) -> Bundle:
    pspec = encdec_pspec(cfg)
    from repro.models.common import count_pspec_params

    return Bundle(
        cfg=cfg,
        params_pspec=pspec,
        loss=lambda p, b: encdec_loss(p, cfg, b),
        prefill=lambda p, b: encdec_prefill(p, cfg, b),
        decode=lambda p, c, b: encdec_decode(p, cfg, c, b),
        cache_pspec=lambda bsz, smax: encdec_cache_pspec(cfg, bsz, smax),
        n_params=count_pspec_params(pspec),
        n_active_params=count_pspec_params(pspec),
    )
