"""Shared model machinery: parameter declaration, init, RoPE, norms, loss.

Parameters are declared as trees of ``PSpec`` (shape + logical axis names +
init rule). From one declaration we derive: materialised params (smoke
tests / real training), ``ShapeDtypeStruct`` stand-ins (dry-run — no
allocation), and ``PartitionSpec`` trees (via parallel.sharding rules).
Layer stacks are declared with a leading "layers" dim and consumed with
``jax.lax.scan`` so HLO size is O(1) in depth.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.parallel.sharding import logical_constraint, spec_for


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple
    logical: tuple
    init: str = "fan_in"      # fan_in | zeros | ones | normal(std=0.02) | const:<v>
    dtype: Any = None          # None = model default


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def init_params(rng: jax.Array, tree, default_dtype=jnp.bfloat16):
    """Materialise a PSpec tree into concrete arrays."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_pspec)
    keys = jax.random.split(rng, len(leaves))
    out = []
    for key, ps in zip(keys, leaves):
        dt = ps.dtype or default_dtype
        if ps.init == "zeros":
            arr = jnp.zeros(ps.shape, dt)
        elif ps.init == "ones":
            arr = jnp.ones(ps.shape, dt)
        elif ps.init.startswith("const:"):
            arr = jnp.full(ps.shape, float(ps.init[6:]), dt)
        elif ps.init == "normal":
            arr = (0.02 * jax.random.normal(key, ps.shape, jnp.float32)).astype(dt)
        elif ps.init == "fan_in":
            fan = ps.shape[-2] if len(ps.shape) >= 2 else ps.shape[-1]
            std = 1.0 / np.sqrt(max(fan, 1))
            arr = (std * jax.random.normal(key, ps.shape, jnp.float32)).astype(dt)
        elif ps.init == "dt_bias":  # mamba dt bias: softplus^-1 of U(1e-3, 1e-1)
            u = jax.random.uniform(key, ps.shape, jnp.float32, 1e-3, 1e-1)
            arr = jnp.log(jnp.expm1(u)).astype(dt)
        elif ps.init == "a_log":    # mamba A_log: log U(1, 16)
            u = jax.random.uniform(key, ps.shape, jnp.float32, 1.0, 16.0)
            arr = jnp.log(u).astype(dt)
        else:
            raise ValueError(ps.init)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def shape_structs(tree, default_dtype=jnp.bfloat16):
    """PSpec tree -> ShapeDtypeStruct tree (dry-run: no allocation)."""
    return jax.tree.map(
        lambda ps: jax.ShapeDtypeStruct(ps.shape, ps.dtype or default_dtype),
        tree, is_leaf=is_pspec,
    )


def partition_specs(tree, *, rules=None, fsdp_ok=True):
    """PSpec tree -> PartitionSpec tree under the active (or given) rules."""
    return jax.tree.map(
        lambda ps: spec_for(ps.shape, ps.logical, rules=rules, fsdp_ok=fsdp_ok),
        tree, is_leaf=is_pspec,
    )


def count_pspec_params(tree) -> int:
    return sum(int(np.prod(ps.shape))
               for ps in jax.tree.leaves(tree, is_leaf=is_pspec))


# ---------------------------------------------------------------------------
# building blocks


def rmsnorm(x, w, eps=1e-6):
    return kops.rmsnorm(x, w, eps=eps)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, Dh); positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs          # (..., S, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                                          # (..., S, 1, half)
    sin = sin[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def swiglu(x, w_in, w_gate, w_out):
    """SwiGLU MLP: (..., d) -> (..., d). TP: ff dim sharded over model.

    silu runs in the native compute dtype: the f32 upcast materialised a
    4.3 GB f32 (b, s, d_ff) buffer per deepseek layer (measured ~12% of
    the cell's HBM traffic) for no training-quality benefit — bf16 silu
    is standard practice (the f32 path is only kept where the operand is
    already f32, i.e. the smoke configs)."""
    h = jnp.einsum("...d,df->...f", x, w_in)
    g = jnp.einsum("...d,df->...f", x, w_gate)
    h = jax.nn.silu(g) * h
    h = logical_constraint(h, "batch", None, "ff")
    return jnp.einsum("...f,fd->...d", h, w_out)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logsumexp in f32."""
    lf = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


def embed_tokens(emb: jax.Array, tokens: jax.Array) -> jax.Array:
    out = jnp.take(emb, tokens, axis=0)
    return logical_constraint(out, "batch", None, "embed")


def unembed(x: jax.Array, emb_or_head: jax.Array) -> jax.Array:
    logits = jnp.einsum("...d,vd->...v", x, emb_or_head)
    return logical_constraint(logits, "batch", None, "vocab")
