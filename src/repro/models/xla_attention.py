"""Memory-bounded attention in pure XLA (the dry-run / CPU path).

Same blocked online-softmax computation as kernels/flash_attention.py, but
expressed with ``lax.scan`` so it lowers on any backend and shards under
GSPMD (batch over data, heads over model). Used by every model for training
and prefill; the Pallas kernel takes over on real TPUs.

GQA is computed in grouped layout (B, Hkv, rep, ...) — no repeated-KV
materialisation. Sliding-window attention slices the KV window per q-chunk
(flops proportional to the window, not the full sequence).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = float(-1e30)


def _group(q, hkv):
    b, s, hq, d = q.shape
    return q.reshape(b, s, hkv, hq // hkv, d)


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "q_chunk",
                              "kv_chunk"),
)
def chunked_attention(
    q: jax.Array,   # (B, Sq, Hq, D)
    k: jax.Array,   # (B, Sk, Hkv, D)
    v: jax.Array,   # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    q_chunk: int = 256,
    kv_chunk: int = 1024,
) -> jax.Array:
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    rep = hq // hkv
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    offs = sk - sq  # align sequence ends
    q_chunk = min(q_chunk, sq)
    nq = sq // q_chunk

    qg = _group(q, hkv)                                   # (B,Sq,Hkv,rep,D)
    qc = qg.reshape(b, nq, q_chunk, hkv, rep, d)
    qc = jnp.moveaxis(qc, 1, 0)                           # (nq,B,Cq,Hkv,rep,D)

    if window is not None and window < sk:
        # SWA: per q-chunk, slice kv to [qlo-window, qlo+Cq) (padded front)
        wlen = window + q_chunk
        kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

        def q_step(_, iq):
            qi = qc[iq].astype(jnp.float32)               # (B,Cq,Hkv,rep,D)
            qlo = iq * q_chunk + offs
            ks = jax.lax.dynamic_slice_in_dim(kp, qlo, wlen, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(vp, qlo, wlen, axis=1)
            s = jnp.einsum("bqhrd,bkhd->bhrqk", qi,
                           ks.astype(jnp.float32)) * sc
            qpos = qlo + jnp.arange(q_chunk)[:, None]
            kpos = qlo - window + jnp.arange(wlen)[None, :]
            mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos >= 0)
            s = jnp.where(mask, s, NEG_INF)
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bhrqk,bkhd->bqhrd", p, vs.astype(jnp.float32))
            return None, o

        _, out = jax.lax.scan(q_step, None, jnp.arange(nq))
    else:
        nk = max(sk // kv_chunk, 1)
        ck = sk // nk
        kc = jnp.moveaxis(k.reshape(b, nk, ck, hkv, d), 1, 0)
        vc = jnp.moveaxis(v.reshape(b, nk, ck, hkv, d), 1, 0)

        def q_step(_, iq):
            qi = qc[iq].astype(jnp.float32)               # (B,Cq,Hkv,rep,D)
            qlo = iq * q_chunk + offs

            def kv_step(carry, jk):
                m, l, acc = carry
                ks = kc[jk].astype(jnp.float32)           # (B,Ck,Hkv,D)
                vs = vc[jk].astype(jnp.float32)
                s = jnp.einsum("bqhrd,bkhd->bhrqk", qi, ks) * sc
                qpos = qlo + jnp.arange(q_chunk)[:, None]
                kpos = jk * ck + jnp.arange(ck)[None, :]
                mask = jnp.ones((q_chunk, ck), jnp.bool_)
                if causal:
                    mask = mask & (kpos <= qpos)
                s = jnp.where(mask, s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                corr = jnp.exp(m - m_new)
                # rowsum(p) in matmul form (p @ 1) — the paper's P-matrix
                # reduction; on TPU this rides the MXU with the s/p dots
                psum = jax.lax.dot_general(
                    p, jnp.ones((ck,), jnp.float32),
                    (((p.ndim - 1,), (0,)), ((), ())),
                    preferred_element_type=jnp.float32)
                l_new = corr * l + psum
                acc_new = corr[..., None] * acc + jnp.einsum(
                    "bhrqk,bkhd->bhrqd", p, vs)
                return (m_new, l_new, acc_new), None

            m0 = jnp.full((b, hkv, rep, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, hkv, rep, q_chunk), jnp.float32)
            a0 = jnp.zeros((b, hkv, rep, q_chunk, d), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                          jnp.arange(nk))
            l = jnp.where(l > 0, l, 1.0)
            o = acc / l[..., None]                        # (B,Hkv,rep,Cq,D)
            return None, jnp.moveaxis(o, 3, 1)            # (B,Cq,Hkv,rep,D)

        _, out = jax.lax.scan(q_step, None, jnp.arange(nq))

    out = jnp.moveaxis(out, 0, 1)                         # (B,nq,Cq,Hkv,rep,D)
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def decode_attention(
    q: jax.Array,        # (B, T, Hq, D) — T is 1 for classic decode
    k_cache: jax.Array,  # (B, S, Hkv, D)
    v_cache: jax.Array,  # (B, S, Hkv, D)
    cur_len: jax.Array,  # (), (B,) or (B, T) int32 — valid cache positions
    *,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Attention of T query tokens against a (possibly ring-buffered) KV
    cache with a per-slot (and optionally per-query) valid length.

    ``cur_len`` broadcasts over (B, T): a scalar is the classic shared
    counter; a (B,) vector gives every slot its own position (continuous
    batching); a (B, T) matrix additionally lets query token t see
    ``cur_len[b, t]`` cache rows — the chunked-prefill case, where token t
    of the chunk may attend exactly the rows written up to and including
    itself."""
    b, s, hkv, d = k_cache.shape
    tq, hq = q.shape[1], q.shape[2]
    rep = hq // hkv
    sc = scale if scale is not None else 1.0 / (d ** 0.5)
    qg = q.reshape(b, tq, hkv, rep, d).astype(jnp.float32)
    logits = jnp.einsum("bqhrd,bkhd->bhrqk", qg,
                        k_cache.astype(jnp.float32)) * sc
    cl = jnp.asarray(cur_len, jnp.int32)
    if cl.ndim == 0:
        cl = cl[None, None]
    elif cl.ndim == 1:
        cl = cl[:, None]
    lens = jnp.broadcast_to(cl, (b, tq))                  # (B, T)
    kpos = jnp.arange(s)[None, None, :]
    valid = kpos < lens[..., None]                        # (B, T, S)
    if window is not None:
        valid = valid & (kpos >= lens[..., None] - window)
    valid = valid[:, None, None]                          # (B, 1, 1, T, S)
    logits = jnp.where(valid, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhrqk,bkhd->bqhrd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, tq, hq, d).astype(q.dtype)
