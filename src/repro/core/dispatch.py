"""One switch for every reduce/scan formulation in the repo.

``repro.kernels.backend`` answers "which *implementation* of a kernel runs"
(fused XLA vs Pallas tile vs interpret). This module sits one level up and
also exposes the *algorithmic* contenders the paper compares, so benchmarks
and tests get every fused-vs-tile-vs-kernel comparison from a single
``path=`` argument instead of ad-hoc imports:

  ``fused``      beyond-paper fused matmul form (repro.core, XLA)
  ``xla_tile``   paper-faithful tile algebra in pure XLA (repro.core)
  ``tile``       explicit Pallas tile kernel (native on TPU)
  ``interpret``  Pallas kernel body through the interpreter (CPU validation)
  ``baseline``   XLA's native vector op (jnp.sum / jnp.cumsum / sequential)
  ``auto``       ``tile`` on TPU, ``fused`` otherwise

``path=None`` defers to ``REPRO_KERNEL_PATH``, then ``auto``.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.core.reduce import tcu_segmented_reduce
from repro.core.scan import tcu_scan, tcu_weighted_scan
from repro.core.ssd import ssd_chunked
from repro.kernels import backend, ops, ref

PATHS = ("auto", "fused", "xla_tile", "tile", "interpret", "baseline")


def resolve_path(path: str | None = None) -> str:
    """Like :func:`backend.resolve_path` but admitting the two extra
    algorithm-level paths (``xla_tile``, ``baseline``)."""
    if path is None:
        path = os.environ.get(backend.ENV_PATH, "").strip().lower() or "auto"
    if path not in PATHS:
        raise ValueError(f"unknown path {path!r}; expected one of {PATHS}")
    if path in ("xla_tile", "baseline"):
        return path
    return backend.resolve_path(path)


def reduce(x: jax.Array, *, path: str | None = None) -> jax.Array:
    """Segmented sum over the last axis -> f32 ``(...,)``."""
    p = resolve_path(path)
    if p == "fused":
        return tcu_segmented_reduce(x, formulation="fused")
    if p == "xla_tile":
        return tcu_segmented_reduce(x, formulation="tile")
    if p == "baseline":
        return jnp.sum(x.astype(jnp.float32), axis=-1)
    return ops.segmented_reduce(x, path=p)


def scan(x: jax.Array, *, path: str | None = None,
         exclusive: bool = False) -> jax.Array:
    """Prefix sum over the last axis -> f32, same shape."""
    p = resolve_path(path)
    if p in ("fused", "xla_tile"):  # core's scan IS the tile algebra, fused
        return tcu_scan(x, exclusive=exclusive)
    if p == "baseline":
        out = jnp.cumsum(x.astype(jnp.float32), axis=-1)
        if exclusive:
            out = jnp.concatenate(
                [jnp.zeros_like(out[..., :1]), out[..., :-1]], axis=-1)
        return out
    out = ops.segmented_scan(x, path=p)
    if exclusive:
        out = out - x.astype(out.dtype)
    return out


def weighted_scan(x: jax.Array, log_a: jax.Array, *,
                  path: str | None = None) -> jax.Array:
    """Decayed scan ``y_i = exp(log_a_i) * y_{i-1} + x_i`` -> f32."""
    p = resolve_path(path)
    if p in ("fused", "xla_tile"):
        return tcu_weighted_scan(x, log_a)
    if p == "baseline":
        return ref.weighted_scan_ref(x, log_a)
    return ops.weighted_scan(x, log_a, path=p)


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, path: str | None = None) -> jax.Array:
    """Mamba-2 SSD scan -> (B, L, H, P); ``baseline`` is the sequential
    recurrence, ``fused``/``xla_tile`` the pure-XLA chunked form."""
    p = resolve_path(path)
    if p in ("fused", "xla_tile"):
        return ssd_chunked(x, dt, a, b, c)[0]
    if p == "baseline":
        return ref.ssd_scan_ref(x, dt, a, b, c)
    return ops.ssd_scan(x, dt, a, b, c, path=p)
