"""One switch for every reduce/scan formulation in the repo.

``repro.kernels.backend`` answers "which *implementation* of a kernel runs"
(fused XLA vs Pallas tile vs interpret). This module sits one level up and
also exposes the *algorithmic* contenders the paper compares, so benchmarks,
models, optimizers, and the serving engine get every fused-vs-tile-vs-kernel
comparison from a single ``path=`` argument instead of ad-hoc imports:

  ``fused``      beyond-paper fused matmul form (repro.core, XLA)
  ``xla_tile``   paper-faithful tile algebra in pure XLA (repro.core)
  ``tile``       explicit Pallas tile kernel for this host's backend
                 (Pallas-TPU on TPU, Pallas-Triton on GPU)
  ``tile_tpu``   force the Pallas-TPU kernel (raises off-TPU)
  ``tile_gpu``   force the Pallas-Triton kernel (raises off-GPU)
  ``tile_logdepth``  log-depth MatMulScan contender (scan/weighted_scan/
                 ssd only): carry-free local block kernels + an O(log)
                 tree combine of batched MMAs — the linear-vs-log-depth
                 crossover is swept into the v3 autotune tables
  ``interpret``  Pallas kernel body through the interpreter (CPU validation)
  ``baseline``   XLA's native vector op (jnp.sum / jnp.cumsum / segment_sum
                 / sequential scan)
  ``auto``       per-shape measured choice via ``repro.core.autotune``
                 (backend-keyed tables; falls back to the static "tile on
                 TPU/GPU, fused elsewhere" when ``REPRO_AUTOTUNE=off`` or
                 no shape is known)

Which contender runs is decided by the active :class:`repro.core.policy.
KernelPolicy` (the repo's single resolution algorithm): every op here
accepts ``policy=`` (a ``KernelPolicy``, or a string shorthand) plus the
per-call ``path=`` label, which beats the policy. With neither, the
active policy applies — its process default is built from
``REPRO_KERNEL_PATH``/``REPRO_AUTOTUNE*`` by ``repro.core.policy``, the
only module that reads those env vars. The stable public surface over
these ops is :mod:`repro.ops`. Every op here is shape-bucketed for the
autotuner by its *segment size* (trailing-axis length; sequence length
for attention/ssd).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import autotune  # noqa: F401  (re-export: measured tables)
from repro.core import policy as kpolicy
from repro.core.ragged import (
    guard_contiguous,
    tcu_ragged_segment_reduce,
    tcu_ragged_segment_scan,
)
from repro.core.reduce import tcu_segmented_reduce
from repro.core.scan import tcu_scan, tcu_weighted_scan
from repro.core.ssd import ssd_chunked
from repro.kernels import backend, ops, ref  # noqa: F401  (backend: probes)

PATHS = kpolicy.DISPATCH_PATHS


def _resolve(op: str, n: int | None, dtype, policy, path: str | None) -> str:
    """Per-op entry into the policy resolver (dispatch level)."""
    return kpolicy.as_policy(policy).resolve(op=op, n=n, dtype=dtype,
                                             explicit=path)


def reduce(x: jax.Array, *, policy=None, path: str | None = None
           ) -> jax.Array:
    """Segmented sum over the last axis -> f32 ``(...,)``."""
    p = _resolve("reduce", x.shape[-1], x.dtype, policy, path)
    if p == "fused":
        return tcu_segmented_reduce(x, formulation="fused")
    if p == "xla_tile":
        return tcu_segmented_reduce(x, formulation="tile")
    if p == "baseline":
        return jnp.sum(x.astype(jnp.float32), axis=-1)
    return ops.segmented_reduce(x, policy=policy, path=p)


def scan(x: jax.Array, *, policy=None, path: str | None = None,
         exclusive: bool = False) -> jax.Array:
    """Prefix sum over the last axis -> f32, same shape."""
    p = _resolve("scan", x.shape[-1], x.dtype, policy, path)
    if p in ("fused", "xla_tile"):  # core's scan IS the tile algebra, fused
        return tcu_scan(x, exclusive=exclusive)
    if p == "baseline":
        out = jnp.cumsum(x.astype(jnp.float32), axis=-1)
    else:
        out = ops.segmented_scan(x, policy=policy, path=p)
    if exclusive:
        # shift, never subtract: reconstructing the exclusive scan as
        # ``inclusive - x`` cancels catastrophically when |x_i| dwarfs the
        # running prefix (the prefix is absorbed into x_i's rounding)
        out = jnp.concatenate(
            [jnp.zeros_like(out[..., :1]), out[..., :-1]], axis=-1)
    return out


def weighted_scan(x: jax.Array, log_a: jax.Array, *, policy=None,
                  path: str | None = None) -> jax.Array:
    """Decayed scan ``y_i = exp(log_a_i) * y_{i-1} + x_i`` -> f32."""
    p = _resolve("weighted_scan", x.shape[-1], x.dtype, policy, path)
    if p in ("fused", "xla_tile"):
        return tcu_weighted_scan(x, log_a)
    if p == "baseline":
        return ref.weighted_scan_ref(x, log_a)
    return ops.weighted_scan(x, log_a, policy=policy, path=p)


# ---------------------------------------------------------------------------
# ragged (irregular) segments — the paper's footnote-4 case


def ragged_reduce(x: jax.Array, seg_ids: jax.Array, n_segments: int, *,
                  policy=None, path: str | None = None) -> jax.Array:
    """Bucketed segmented sum: ``x (..., n)`` + ``seg_ids`` -> f32
    ``(..., n_segments)``.

    ``fused``/``xla_tile`` is the one-hot matmul form (one MXU pass, no
    scatter); ``baseline`` is ``jax.ops.segment_sum``. There is no Pallas
    ragged kernel yet, so ``tile``/``interpret`` run the matmul form.
    ``seg_ids`` may carry leading batch dims; any id order is valid.
    """
    p = _resolve("ragged_reduce", x.shape[-1], x.dtype, policy, path)
    if p == "baseline":
        return _segment_sum_baseline(x, seg_ids, n_segments)
    return tcu_ragged_segment_reduce(x, seg_ids, n_segments)


def ragged_scan(x: jax.Array, seg_ids: jax.Array, n_segments: int, *,
                policy=None, path: str | None = None,
                debug: bool = False) -> jax.Array:
    """Within-segment inclusive prefix sum -> f32, same shape as ``x``.

    Requires non-decreasing ``seg_ids`` on *every* path (see
    ``tcu_ragged_segment_scan`` for the contract; ``debug=True`` validates).
    ``fused``/``xla_tile`` is the matmul form; ``baseline`` composes
    ``jnp.cumsum`` + ``segment_sum`` + a gather. ``tile``/``interpret``
    run the matmul form (no Pallas ragged kernel yet).
    """
    p = _resolve("ragged_scan", x.shape[-1], x.dtype, policy, path)
    if p == "baseline":
        out = _ragged_scan_baseline(x, seg_ids, n_segments)
        return guard_contiguous(seg_ids, out) if debug else out
    return tcu_ragged_segment_scan(x, seg_ids, n_segments, debug=debug)


def _segment_sum_baseline(x: jax.Array, seg_ids: jax.Array,
                          n_segments: int) -> jax.Array:
    """``jax.ops.segment_sum`` over the trailing axis, batched as needed."""
    xf = x.astype(jnp.float32)
    if seg_ids.ndim == 1:
        out = jax.ops.segment_sum(jnp.moveaxis(xf, -1, 0), seg_ids,
                                  num_segments=n_segments)
        return jnp.moveaxis(out, 0, -1)
    n = x.shape[-1]
    ids = jnp.broadcast_to(seg_ids, xf.shape).reshape(-1, n)
    flat = xf.reshape(-1, n)
    out = jax.vmap(
        lambda a, i: jax.ops.segment_sum(a, i, num_segments=n_segments)
    )(flat, ids)
    return out.reshape(*xf.shape[:-1], n_segments)


def _ragged_scan_baseline(x: jax.Array, seg_ids: jax.Array,
                          n_segments: int) -> jax.Array:
    """Global cumsum minus gathered preceding-segment totals (native ops)."""
    xf = x.astype(jnp.float32)
    gs = jnp.cumsum(xf, axis=-1)
    totals = _segment_sum_baseline(x, seg_ids, n_segments)   # (..., S)
    prior = jnp.concatenate(
        [jnp.zeros_like(totals[..., :1]),
         jnp.cumsum(totals, axis=-1)[..., :-1]], axis=-1)
    ids = jnp.broadcast_to(seg_ids, xf.shape)
    return gs - jnp.take_along_axis(prior, ids, axis=-1)


# ---------------------------------------------------------------------------
# model-level ops (attention, SSD)


def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, window: int | None = None,
              scale: float | None = None, policy=None,
              path: str | None = None) -> jax.Array:
    """Multi-head attention in model layout: ``q (B, Sq, Hq, D)``,
    ``k``/``v`` ``(B, Sk, Hkv, D)`` -> ``(B, Sq, Hq, D)``.

    ``fused``/``xla_tile`` is the blocked online-softmax XLA path
    (shards under GSPMD; its row-sums already ride the paper's P-matrix
    reduction); ``tile``/``interpret`` the Pallas flash kernel;
    ``baseline`` plain materialised softmax attention.
    """
    p = _resolve("attention", q.shape[1], q.dtype, policy, path)
    if p in ("fused", "xla_tile"):
        from repro.models.xla_attention import chunked_attention  # lazy: cycle

        return chunked_attention(q, k, v, causal=causal, window=window,
                                 scale=scale)
    t = lambda a: jnp.swapaxes(a, 1, 2)  # model (B,S,H,D) <-> kernel (B,H,S,D)
    if p == "baseline":
        return t(ref.flash_attention_ref(t(q), t(k), t(v), causal=causal,
                                         window=window, scale=scale))
    return t(ops.attention(t(q), t(k), t(v), causal=causal, window=window,
                           scale=scale, policy=policy, path=p))


def ssd(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
        c: jax.Array, *, policy=None, path: str | None = None,
        chunk: int | None = None, matmul_dtype=None,
        return_state: bool = False):
    """Mamba-2 SSD scan -> ``y (B, L, H, P)``; with ``return_state=True``
    also the final state ``(B, H, P, N)`` f32 (prefill -> decode handoff).

    ``baseline`` is the sequential recurrence, ``fused``/``xla_tile`` the
    pure-XLA chunked form, ``tile``/``interpret`` the Pallas kernel.
    ``chunk``/``matmul_dtype`` tune the chunked XLA form only; the Pallas
    kernel's chunk is the ``ssd.q`` tuning knob (policy ``op_tuning`` /
    ``--tune "ssd.q=..."``, swept into v3 autotune tables).
    """
    p = _resolve("ssd", x.shape[1], x.dtype, policy, path)
    if p in ("fused", "xla_tile"):
        kw = {}
        if chunk is not None:
            kw["chunk"] = chunk
        if matmul_dtype is not None:
            kw["matmul_dtype"] = matmul_dtype
        y, h_last = ssd_chunked(x, dt, a, b, c, **kw)
        return (y, h_last) if return_state else y
    if p == "baseline":
        return ref.ssd_scan_ref(x, dt, a, b, c, return_state=return_state)
    return ops.ssd_scan(x, dt, a, b, c, policy=policy, path=p,
                        return_state=return_state)
