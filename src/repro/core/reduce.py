"""Matmul-form reduction (the paper's Section 4), TPU-adapted.

Two formulations are provided:

* ``formulation="tile"`` — the paper-faithful tile algebra: the input is
  partitioned into TxT tiles, each tile is hit with ``P @ A`` (reducing the
  tile's columns), partial rows are accumulated across tiles
  (work-efficient Reduction_{256N}, the paper's Fig. 7), and a final
  ``V @ P^T`` collapses the surviving row.
* ``formulation="fused"`` — the beyond-paper simplification: a single
  ``dot(x_blocks, ones)``. On TPU XLA lowers this onto the MXU directly and
  fuses it with neighbouring ops; it performs T× fewer FLOPs than the tile
  form while exercising the same unit. This is the default for the pure-JAX
  path; the Pallas kernels in ``repro.kernels.tcu_reduce`` implement the
  tile form explicitly.

All reductions accumulate in float32 (``preferred_element_type``), matching
the MXU's native bf16-in/f32-accumulate mode (the paper's "mixed precision").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tiles import DEFAULT_TILE, p_matrix


def _accum_dtype(dtype) -> jnp.dtype:
    return jnp.float32 if jnp.issubdtype(dtype, jnp.floating) else jnp.dtype(dtype)


def _pad_last_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[-1]
    rem = (-n) % multiple
    if rem:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
        x = jnp.pad(x, pad)
    return x


def tcu_segmented_reduce(
    x: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    formulation: str = "fused",
) -> jax.Array:
    """Reduce the last axis of ``x``; leading axes index segments.

    A regular segmented reduction (the paper's Reduction_K with
    K = x.shape[-1]): ``out[..., ] = sum(x[..., :])``. Padding to the tile
    multiple is zero-fill, exactly the paper's approach to arbitrary segment
    sizes ("padding introduces minimal overhead").
    """
    acc = _accum_dtype(x.dtype)
    n = x.shape[-1]
    if formulation == "fused":
        xp = _pad_last_to(x, tile)
        blocks = xp.reshape(*x.shape[:-1], -1, tile)
        ones = jnp.ones((tile,), x.dtype)
        partial = jax.lax.dot_general(
            blocks, ones,
            (((blocks.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=acc,
        )  # (..., n_tiles)
        return jnp.sum(partial, axis=-1).astype(acc)
    if formulation != "tile":
        raise ValueError(f"unknown formulation {formulation!r}")

    # Paper-faithful tile algebra. Partition into (..., k, T, T) tiles; the
    # work-efficient accumulation V_i = P @ A_i + V_{i-1} followed by the
    # V @ P^T epilogue (Fig. 7). Segments shorter than T*T degrade to a
    # single P @ A (Reduction_16 analogue, packed rows).
    p = p_matrix(tile, x.dtype)
    if n <= tile:
        # (..., n) -> pad to (..., T): one row per segment; reduce via A @ P^T
        xp = _pad_last_to(x, tile)
        lead = xp.shape[:-1]
        flat = xp.reshape(-1, tile)
        v = jax.lax.dot_general(
            flat, p.T, (((1,), (0,)), ((), ())), preferred_element_type=acc
        )  # (rows, T); column 0 holds the sums
        return v[:, 0].reshape(lead).astype(acc)

    xp = _pad_last_to(x, tile * tile)
    k = xp.shape[-1] // (tile * tile)
    tiles = xp.reshape(*x.shape[:-1], k, tile, tile)

    def body(v, a):
        # V <- P @ A + V   : reduces each tile column into the first row.
        pa = jax.lax.dot_general(
            p.astype(acc), a.astype(acc),
            (((1,), (a.ndim - 2,)), ((), ())),
            preferred_element_type=acc,
        )
        # dot_general(p, a) with batch dims absent: contract p's dim1 with
        # a's row dim; result (T, ..., T) — move tile row axis back in place.
        pa = jnp.moveaxis(pa, 0, -2)
        return v + pa, None

    v0 = jnp.zeros((*x.shape[:-1], tile, tile), acc)
    tiles_t = jnp.moveaxis(tiles, -3, 0)  # (k, ..., T, T) for scan
    v, _ = jax.lax.scan(body, v0, tiles_t)
    # Epilogue: R = V @ P^T reduces the first row to a scalar at [0, 0].
    r = jax.lax.dot_general(
        v, p.T.astype(acc), (((v.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=acc,
    )
    return r[..., 0, 0]


def tcu_reduce(x: jax.Array, *, tile: int = DEFAULT_TILE,
               formulation: str = "fused") -> jax.Array:
    """Full reduction of ``x`` (flattened), matmul-form."""
    return tcu_segmented_reduce(
        x.reshape(1, -1), tile=tile, formulation=formulation
    )[0]
