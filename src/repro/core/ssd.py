"""Chunked SSD (Mamba-2) in pure JAX — the paper's weighted tile scan.

Structure per chunk of Q tokens (Q = 128, the MXU tile edge):

  intra   Y₁ = ((C Bᵀ) ∘ M) (dt∘X)      M = exp(segsum(λ)) — weighted A·U
  state   S  = (B ∘ w)ᵀ (dt∘X)           w = remaining-chunk decay
  carry   Hₖ = exp(Σλ)·Hₖ₋₁ + Sₖ          the paper's Broadcast(R[last]) chain
  inter   Y₂ = (C ∘ exp(Λ))·Hₖ₋₁

The inter-chunk carry is a *weighted scan over chunks*, computed here with
``jax.lax.scan`` (sequential per device — the TPU grid is sequential anyway)
and across devices with ``repro.core.dist_weighted_scan``. The Pallas twin
is kernels/ssd_scan.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.tiles import segsum

CHUNK = 128


@functools.partial(jax.jit, static_argnames=("chunk", "matmul_dtype"))
def ssd_chunked(
    x: jax.Array,    # (B, L, H, P)
    dt: jax.Array,   # (B, L, H)   positive
    a: jax.Array,    # (H,)        negative
    b: jax.Array,    # (B, L, G, N)
    c: jax.Array,    # (B, L, G, N)
    *,
    chunk: int = CHUNK,
    matmul_dtype=None,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y (B,L,H,P), final_state (B,H,P,N)).

    ``matmul_dtype`` casts the *operands* of the large intra-chunk einsums
    (decay masks stay f32; accumulation stays f32 via
    preferred_element_type). bf16 operands halve the HBM traffic of the
    (B,k,H,Q,Q) mask products — the dominant tensors of the XLA path —
    and match the MXU's native bf16-in/f32-acc mode. None keeps full f32
    (the reference/tests path)."""
    bsz, seqlen, nheads, hdim = x.shape
    ngroups, nstate = b.shape[2], b.shape[3]
    rem = (-seqlen) % chunk
    if rem:
        # zero-pad: decay exp(0)=1 and input 0 leave the carried state exact
        padt = lambda t: jnp.pad(t, [(0, 0), (0, rem)] +
                                 [(0, 0)] * (t.ndim - 2))
        y, h_last = ssd_chunked(padt(x), padt(dt), a, padt(b), padt(c),
                                chunk=chunk, matmul_dtype=matmul_dtype)
        return y[:, :seqlen], h_last
    nchunks = seqlen // chunk
    rep = nheads // ngroups
    mm = (lambda t: t) if matmul_dtype is None else \
        (lambda t: t.astype(matmul_dtype))
    acc = jnp.float32

    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    af = a.astype(jnp.float32)
    lam = dtf * af                                       # (B, L, H) log decays
    xdt = xf * dtf[..., None]

    # chunked views: (B, k, Q, ...)
    xdt = xdt.reshape(bsz, nchunks, chunk, nheads, hdim)
    lam = lam.reshape(bsz, nchunks, chunk, nheads)
    bg = b.astype(jnp.float32).reshape(bsz, nchunks, chunk, ngroups, nstate)
    cg = c.astype(jnp.float32).reshape(bsz, nchunks, chunk, ngroups, nstate)

    lam_t = jnp.moveaxis(lam, -1, -2)                    # (B, k, H, Q)
    m = jnp.exp(segsum(lam_t))                           # (B, k, H, Q, Q)
    cum = jnp.cumsum(lam_t, axis=-1)                     # (B, k, H, Q) = Λ
    total = cum[..., -1]                                 # (B, k, H)

    # intra-chunk: cb (B,k,G,Q,Q) broadcast to heads within group
    cb = jnp.einsum("bkqgn,bksgn->bkgqs", mm(cg), mm(bg),
                    preferred_element_type=acc)
    cb = jnp.repeat(cb, rep, axis=2)                     # (B,k,H,Q,Q)
    y_intra = jnp.einsum("bkhqs,bkshp->bkqhp", mm(cb * m), mm(xdt),
                         preferred_element_type=acc)     # (B,k,Q,H,P)

    # chunk input states: S (B,k,H,P,N)
    w = jnp.exp(total[..., None] - cum)                  # (B,k,H,Q)
    bw = jnp.repeat(bg, rep, axis=3)                     # (B,k,Q,H,N)
    s_chunk = jnp.einsum(
        "bkqhn,bkqhp->bkhpn",
        mm(bw * jnp.moveaxis(w, -1, -2)[..., None]), mm(xdt),
        preferred_element_type=acc)

    # inter-chunk recurrence over k (sequential weighted scan)
    def step(h, inp):
        s_k, tot_k = inp                                 # (B,H,P,N), (B,H)
        h = jnp.exp(tot_k)[..., None, None] * h + s_k
        return h, h

    h0 = jnp.zeros((bsz, nheads, hdim, nstate), jnp.float32)
    s_seq = jnp.moveaxis(s_chunk, 1, 0)                  # (k,B,H,P,N)
    t_seq = jnp.moveaxis(total, 1, 0)                    # (k,B,H)
    h_last, h_all = jax.lax.scan(step, h0, (s_seq, t_seq))
    # states *entering* each chunk: shift right
    h_prev = jnp.concatenate([h0[None], h_all[:-1]], axis=0)
    h_prev = jnp.moveaxis(h_prev, 0, 1)                  # (B,k,H,P,N)

    cdec = jnp.repeat(cg, rep, axis=3) * jnp.exp(
        jnp.moveaxis(cum, -1, -2))[..., None]            # (B,k,Q,H,N)
    y_inter = jnp.einsum("bkqhn,bkhpn->bkqhp", mm(cdec), mm(h_prev),
                         preferred_element_type=acc)

    y = (y_intra + y_inter).reshape(bsz, seqlen, nheads, hdim)
    return y.astype(x.dtype), h_last


def ssd_decode_step(
    state: jax.Array,   # (B, H, P, N) f32
    x_t: jax.Array,     # (B, H, P)
    dt_t: jax.Array,    # (B, H)
    a: jax.Array,       # (H,)
    b_t: jax.Array,     # (B, G, N)
    c_t: jax.Array,     # (B, G, N)
) -> tuple[jax.Array, jax.Array]:
    """One recurrent step: h ← exp(a·dt)h + dt·b xᵀ;  y = c·h."""
    bsz, nheads, hdim, nstate = state.shape
    ngroups = b_t.shape[1]
    rep = nheads // ngroups
    dec = jnp.exp(dt_t.astype(jnp.float32) * a.astype(jnp.float32))
    bf = jnp.repeat(b_t.astype(jnp.float32), rep, axis=1)   # (B,H,N)
    cf = jnp.repeat(c_t.astype(jnp.float32), rep, axis=1)
    xdt = x_t.astype(jnp.float32) * dt_t.astype(jnp.float32)[..., None]
    state = dec[..., None, None] * state + xdt[..., None] * bf[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", state, cf)
    return y.astype(x_t.dtype), state
