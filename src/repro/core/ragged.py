"""Irregular (ragged) segmented reduction and scan, matmul-form.

The paper handles irregular segments by padding to regular ones
(footnote 4). The TPU-native generalisation is more direct: a ragged
segmented reduction *is* a matrix multiplication against the segment
one-hot matrix —

    out[s] = sum_i 1[seg_id[i] == s] * x[i]     =     O^T @ x

with ``O[i, s] = 1[seg_id[i] == s]`` built from a broadcasted-iota compare
(the same constructor discipline as the P/U/L tiles; no gather/scatter, so
it shards and differentiates trivially). The ragged scan composes the
regular matmul-form scan with a segment-restart correction: within-segment
prefix = global prefix minus the segment's preceding total, which is one
more one-hot matmul.

Cost: O(n * n_segments) MXU flops — the paper's GEMV trade ("resource and
computation waste" tolerated because the matrix unit is otherwise idle);
for n_segments <= a few thousand this stays memory-bound like everything
else here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import tcu_scan


def _onehot(seg_ids: jax.Array, n_segments: int, dtype) -> jax.Array:
    """O[i, s] = 1[seg_ids[i] == s], built from iota (traceable)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (seg_ids.shape[-1],
                                                n_segments), 1)
    return (seg_ids[..., None] == cols).astype(dtype)


def tcu_ragged_segment_reduce(x: jax.Array, seg_ids: jax.Array,
                              n_segments: int) -> jax.Array:
    """Sum ``x (..., n)`` into ``(..., n_segments)`` buckets by ``seg_ids``.

    Matmul-form: ``out = x @ O`` — one MXU pass, no scatter.
    """
    o = _onehot(seg_ids, n_segments, jnp.float32)
    return jax.lax.dot_general(
        x.astype(jnp.float32), o,
        (((x.ndim - 1,), (o.ndim - 2,)), ((), ())),
        preferred_element_type=jnp.float32)


def tcu_ragged_segment_scan(x: jax.Array, seg_ids: jax.Array,
                            n_segments: int) -> jax.Array:
    """Within-segment inclusive prefix sum for contiguous ragged segments.

    ``y_i = sum_{j <= i, seg[j] == seg[i]} x_j`` — the global matmul-form
    scan minus each segment's preceding total, where the preceding totals
    are an exclusive ragged reduce re-broadcast through the one-hot
    (two more matmuls; everything stays on the MXU).
    """
    xf = x.astype(jnp.float32)
    global_scan = tcu_scan(xf)                               # (..., n)
    o = _onehot(seg_ids, n_segments, jnp.float32)            # (n, S)
    totals = jax.lax.dot_general(                            # (..., S)
        xf, o, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # exclusive totals of *preceding* segments, then re-broadcast per elem
    prior = tcu_scan(totals, exclusive=True)                 # (..., S)
    offset = jax.lax.dot_general(                            # (..., n)
        prior, o.T, (((prior.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return global_scan - offset
