"""Irregular (ragged) segmented reduction and scan, matmul-form.

The paper handles irregular segments by padding to regular ones
(footnote 4). The TPU-native generalisation is more direct: a ragged
segmented reduction *is* a matrix multiplication against the segment
one-hot matrix —

    out[s] = sum_i 1[seg_id[i] == s] * x[i]     =     O^T @ x

with ``O[i, s] = 1[seg_id[i] == s]`` built from a broadcasted-iota compare
(the same constructor discipline as the P/U/L tiles; no gather/scatter, so
it shards and differentiates trivially). The ragged scan composes the
regular matmul-form scan with a segment-restart correction: within-segment
prefix = global prefix minus the segment's preceding total, which is one
more one-hot matmul.

``seg_ids`` may carry leading batch dims (broadcast against ``x``) — the
MoE router uses per-group expert assignments this way.

Cost: O(n * n_segments) MXU flops — the paper's GEMV trade ("resource and
computation waste" tolerated because the matrix unit is otherwise idle);
for n_segments <= a few thousand this stays memory-bound like everything
else here.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import tcu_scan


def _onehot(seg_ids: jax.Array, n_segments: int, dtype) -> jax.Array:
    """O[..., i, s] = 1[seg_ids[..., i] == s], built from iota (traceable)."""
    cols = jax.lax.broadcasted_iota(jnp.int32, (seg_ids.shape[-1],
                                                n_segments), 1)
    return (seg_ids[..., None] == cols).astype(dtype)


def guard_contiguous(seg_ids: jax.Array, out: jax.Array) -> jax.Array:
    """Validity gate for contiguous-segment algorithms (debug path).

    Checks ``seg_ids`` is non-decreasing along the last axis — the exact
    precondition of the prefix-minus-preceding-totals scan. With concrete
    (non-traced) ids this raises ``ValueError`` eagerly; under ``jit`` the
    check stays traceable and *poisons the output with NaN* instead (a
    traced value cannot raise), so bad ids are loud in either mode.
    """
    ok = jnp.all(seg_ids[..., 1:] >= seg_ids[..., :-1])
    try:
        concrete = bool(ok)
    except jax.errors.ConcretizationTypeError:
        return jnp.where(ok, out, jnp.nan)
    if not concrete:
        raise ValueError(
            "tcu_ragged_segment_scan: seg_ids must be non-decreasing "
            "(contiguous segments); sort inputs by segment first or use "
            "tcu_ragged_segment_reduce, which accepts any order")
    return out


def tcu_ragged_segment_reduce(x: jax.Array, seg_ids: jax.Array,
                              n_segments: int) -> jax.Array:
    """Sum ``x (..., n)`` into ``(..., n_segments)`` buckets by ``seg_ids``.

    Matmul-form: ``out = x @ O`` — one MXU pass, no scatter. ``seg_ids``
    may be ``(n,)`` or batched ``(..., n)``; any id order is valid
    (bucketing is order-free). Ids outside ``[0, n_segments)`` contribute
    nowhere (their one-hot row is all zero).
    """
    o = _onehot(seg_ids, n_segments, jnp.float32)
    return jnp.einsum("...i,...is->...s", x.astype(jnp.float32), o,
                      preferred_element_type=jnp.float32)


def tcu_ragged_segment_scan(x: jax.Array, seg_ids: jax.Array,
                            n_segments: int, *,
                            debug: bool = False) -> jax.Array:
    """Within-segment inclusive prefix sum for contiguous ragged segments.

    ``y_i = sum_{j <= i, seg[j] == seg[i]} x_j`` — the global matmul-form
    scan minus each segment's preceding total, where the preceding totals
    are an exclusive ragged reduce re-broadcast through the one-hot
    (two more matmuls; everything stays on the MXU).

    Contract: ``seg_ids`` MUST be non-decreasing along the last axis
    (each segment occupies one contiguous run, segments in ascending id
    order) — the correction subtracts the totals of all *lower-id*
    segments, which only matches "preceding positions" for sorted ids.
    Non-contiguous ids silently produce wrong values; pass ``debug=True``
    to validate (eager ``ValueError``, or NaN-poisoned output under jit —
    see :func:`guard_contiguous`). The check is one compare-and-reduce
    over ``seg_ids``, cheap enough for test/debug builds but off the hot
    path by default.
    """
    xf = x.astype(jnp.float32)
    global_scan = tcu_scan(xf)                               # (..., n)
    o = _onehot(seg_ids, n_segments, jnp.float32)            # (..., n, S)
    totals = jnp.einsum("...i,...is->...s", xf, o,
                        preferred_element_type=jnp.float32)  # (..., S)
    # exclusive totals of *preceding* segments, then re-broadcast per elem
    prior = tcu_scan(totals, exclusive=True)                 # (..., S)
    offset = jnp.einsum("...s,...is->...i", prior, o,
                        preferred_element_type=jnp.float32)  # (..., n)
    out = global_scan - offset
    if debug:
        out = guard_contiguous(seg_ids, out)
    return out
