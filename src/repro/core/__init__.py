"""repro.core — the paper's contribution: matmul-form reduction and scan.

Tile level  (paper: warp/WMMA fragment)  -> tiles.py constructors + reduce/scan
Block level (paper: thread block)        -> multi-tile composition in reduce/scan
Device/grid level (paper: multi-kernel)  -> distributed.py mesh collectives
"""
from repro.core.distributed import (
    dist_exclusive_carry,
    dist_reduce,
    dist_scan,
    dist_weighted_scan,
)
from repro.core.ragged import (
    tcu_ragged_segment_reduce,
    tcu_ragged_segment_scan,
)
from repro.core.reduce import tcu_reduce, tcu_segmented_reduce
from repro.core.scan import (
    tcu_scan,
    tcu_segmented_scan,
    tcu_weighted_scan,
)
from repro.core import autotune, dispatch, policy
from repro.core.policy import (
    KernelPolicy,
    get_policy,
    set_policy,
    using_policy,
)
from repro.core.tiles import (
    DEFAULT_TILE,
    l_matrix,
    ones_matrix,
    p_matrix,
    segsum,
    strict_u_matrix,
    u_matrix,
)

__all__ = [
    "DEFAULT_TILE",
    "KernelPolicy",
    "autotune",
    "dispatch",
    "get_policy",
    "policy",
    "set_policy",
    "using_policy",
    "dist_exclusive_carry",
    "dist_reduce",
    "dist_scan",
    "dist_weighted_scan",
    "l_matrix",
    "ones_matrix",
    "p_matrix",
    "segsum",
    "strict_u_matrix",
    "tcu_ragged_segment_reduce",
    "tcu_ragged_segment_scan",
    "tcu_reduce",
    "tcu_scan",
    "tcu_segmented_reduce",
    "tcu_segmented_scan",
    "tcu_weighted_scan",
    "u_matrix",
]
