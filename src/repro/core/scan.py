"""Matmul-form scan / prefix-sum (the paper's Section 5), TPU-adapted.

The paper's identity for a TxT tile A holding 256 (here 16384) elements
row-major:

    Scan(A) = A @ U  +  (L @ A) @ 1

where ``A @ U`` scans each row, ``L @ A`` is the column-wise exclusive scan
(whose row j holds the sums of all rows above j), and ``@ 1`` broadcasts
those sums across the row. Tiles are chained with a scalar carry S
(Algorithm 6). We additionally provide:

* arbitrary-length inputs via *recursive* two-level composition
  (scan tiles → scan the tile totals → add exclusive carries), which is the
  paper's scan-then-propagate grid strategy applied within a device;
* ``tcu_weighted_scan`` — the decayed generalisation
  ``y_i = a_i * y_{i-1} + x_i`` obtained by replacing the triangular ones
  masks with ``exp(segsum(log a))``; this is the bridge between the paper's
  scan and Mamba-2's SSD (see kernels/ssd_scan.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.tiles import (
    DEFAULT_TILE,
    l_matrix,
    ones_matrix,
    segsum,
    strict_u_matrix,
    u_matrix,
)


def _accum_dtype(dtype) -> jnp.dtype:
    return jnp.float32 if jnp.issubdtype(dtype, jnp.floating) else jnp.dtype(dtype)


def _row_scan(x: jax.Array, tile: int, *, exclusive: bool = False) -> jax.Array:
    """Scan the last axis (must equal ``tile``) via a triangular matmul."""
    acc = _accum_dtype(x.dtype)
    u = (strict_u_matrix if exclusive else u_matrix)(tile, x.dtype)
    return jax.lax.dot_general(
        x, u, (((x.ndim - 1,), (0,)), ((), ())), preferred_element_type=acc
    )


def tcu_scan(
    x: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
    exclusive: bool = False,
) -> jax.Array:
    """Inclusive (or exclusive) prefix sum along the last axis, matmul-form.

    Strategy (scan-then-propagate, recursively):
      1. pad the last axis to a tile multiple, view as (..., k, T);
      2. row-scan every tile with one triangular matmul;
      3. recursively scan the k tile-totals (a length-k problem);
      4. add the *exclusive* totals back as per-tile carries.
    Depth is ceil(log_T n): 2 levels cover 16K elements, 3 cover 2M.
    """
    acc = _accum_dtype(x.dtype)
    n = x.shape[-1]
    if n == 0:
        return x.astype(acc)
    if n <= tile:
        t_eff = tile if n > 8 else n  # tiny inputs: exact-size triangle
        rem = (-n) % t_eff
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, rem)]) if rem else x
        out = _row_scan(xp, t_eff, exclusive=exclusive)
        return out[..., :n]

    rem = (-n) % tile
    xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, rem)]) if rem else x
    k = xp.shape[-1] // tile
    tiles = xp.reshape(*x.shape[:-1], k, tile)
    scanned = _row_scan(tiles, tile)            # (..., k, T) inclusive per tile
    totals = scanned[..., -1]                   # (..., k)
    carries = tcu_scan(totals, tile=tile, exclusive=True)  # (..., k)
    out = scanned + carries[..., None].astype(acc)
    if exclusive:
        excl = _row_scan(tiles, tile, exclusive=True)
        out = excl + carries[..., None].astype(acc)
    return out.reshape(*x.shape[:-1], k * tile)[..., :n]


def tcu_segmented_scan(
    x: jax.Array, *, tile: int = DEFAULT_TILE, exclusive: bool = False
) -> jax.Array:
    """Regular segmented scan: scans the last axis independently per segment
    (leading axes index segments) — the paper's Scan_K."""
    return tcu_scan(x, tile=tile, exclusive=exclusive)


def tcu_weighted_scan(
    x: jax.Array,
    log_a: jax.Array,
    *,
    tile: int = DEFAULT_TILE,
) -> jax.Array:
    """Decayed scan ``y_i = a_i * y_{i-1} + x_i`` with ``a = exp(log_a)``.

    Matmul-form: within a tile, ``y = M @ x`` with
    ``M = exp(segsum(log_a))`` (lower-triangular, M[i,j] = prod a[j+1..i]).
    Across tiles the carry chain generalises the paper's broadcast-S:
    ``carry_{k} = A_k * carry_{k-1} + total_k`` where ``A_k`` is the tile's
    total decay. The cross-tile recurrence is itself a weighted scan over k,
    computed with the same tile algebra (one recursion level) — so the whole
    thing is triangular matmuls end to end.
    """
    acc = _accum_dtype(x.dtype)
    n = x.shape[-1]
    if n <= tile:
        m = jnp.exp(segsum(log_a.astype(acc)))
        return jnp.einsum("...ij,...j->...i", m, x.astype(acc))

    rem = (-n) % tile
    if rem:
        pad = [(0, 0)] * (x.ndim - 1) + [(0, rem)]
        x = jnp.pad(x, pad)
        log_a = jnp.pad(log_a, pad)  # log a = 0 → decay 1, harmless tail
    k = x.shape[-1] // tile
    xt = x.reshape(*x.shape[:-1], k, tile)
    lat = log_a.reshape(*log_a.shape[:-1], k, tile)
    m = jnp.exp(segsum(lat.astype(acc)))                     # (..., k, T, T)
    intra = jnp.einsum("...ij,...j->...i", m, xt.astype(acc))  # per-tile scan
    totals = intra[..., -1]                                   # (..., k)
    tile_decay = jnp.sum(lat.astype(acc), axis=-1)            # log total decay
    # cross-tile weighted scan of totals (length-k problem)
    carry_in = _weighted_exclusive(totals, tile_decay)        # (..., k)
    # propagate: y = intra + carry_in * cumdecay_within_tile
    cum_in_tile = jnp.cumsum(lat.astype(acc), axis=-1)        # prefix log-decay
    out = intra + carry_in[..., None] * jnp.exp(cum_in_tile)
    return out.reshape(*out.shape[:-2], k * tile)[..., :n]


def _weighted_exclusive(totals: jax.Array, log_decay: jax.Array) -> jax.Array:
    """Exclusive weighted scan over the last axis: carry entering block i is
    the *inclusive* weighted-scan state after block i-1 (carry_0 = 0).

    Matmul-form: ``s = exp(segsum(log_decay)) @ totals`` gives the inclusive
    states (s_i = sum_{j<=i} prod_{q=j+1..i} d_q * t_j); the exclusive carry
    is s shifted right by one.
    """
    m = jnp.exp(segsum(log_decay))
    s = jnp.einsum("...ij,...j->...i", m, totals)
    return jnp.concatenate([jnp.zeros_like(s[..., :1]), s[..., :-1]], axis=-1)
