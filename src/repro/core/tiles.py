"""Constructor matrices for the matmul-form reduction/scan algebra.

The paper (Dakkak et al., ICS'19) expresses reduction and scan in terms of
three constant matrices over a TxT tile:

  P  : ones in row 0, zeros elsewhere.         P @ A   reduces each column of A.
  U  : upper-triangular ones (incl. diagonal). A @ U   row-wise inclusive scan.
  L  : strictly-lower-triangular ones.         L @ A   column-wise exclusive scan.

On the V100 the tile is 16x16 (WMMA fragment); on TPU we default to the
MXU-native 128. All constructors are traceable (built from iota, no host
constants) so they can be materialised *inside* Pallas kernels without the
constant-memory restrictions the paper had to work around (their Listing 3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# MXU-native tile edge on TPU (the paper's "16").
DEFAULT_TILE = 128


def p_matrix(t: int = DEFAULT_TILE, dtype=jnp.float32) -> jax.Array:
    """P: ones in the first row. ``P @ A`` sums each column of A."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    return (rows == 0).astype(dtype)


def u_matrix(t: int = DEFAULT_TILE, dtype=jnp.float32) -> jax.Array:
    """U: upper-triangular ones including the diagonal.

    ``A @ U`` is a row-wise inclusive scan of A.
    """
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return (rows <= cols).astype(dtype)


def strict_u_matrix(t: int = DEFAULT_TILE, dtype=jnp.float32) -> jax.Array:
    """Strictly-upper-triangular ones. ``A @ sU`` is a row-wise exclusive scan."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return (rows < cols).astype(dtype)


def l_matrix(t: int = DEFAULT_TILE, dtype=jnp.float32) -> jax.Array:
    """L: strictly-lower-triangular ones. ``L @ A`` column-wise exclusive scan."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return (rows > cols).astype(dtype)


def ones_matrix(t: int = DEFAULT_TILE, dtype=jnp.float32) -> jax.Array:
    """The paper's all-ones broadcast matrix (their bold-1)."""
    return jnp.ones((t, t), dtype)


def segsum(log_a: jax.Array) -> jax.Array:
    """Stable segment-sum: ``out[..., i, j] = sum(log_a[..., j+1:i+1])`` (tril).

    This generalises the paper's L/U masks to *weighted* triangular masks:
    ``exp(segsum(log a))`` is the decay matrix M with
    ``M[i, j] = prod_{k=j+1..i} a_k`` for ``j <= i`` — the Mamba-2 / SSD
    "1-semiseparable" matrix. With ``log_a == 0`` it degenerates to
    ``tril(ones)`` = the paper's (L + I) mask. Entries above the diagonal
    are ``-inf`` so that ``exp`` gives exact zeros.
    """
    t = log_a.shape[-1]
    # cumulative sums along the last axis, prepended with 0
    csum = jnp.cumsum(log_a, axis=-1)
    csum = jnp.concatenate([jnp.zeros_like(csum[..., :1]), csum], axis=-1)
    # out[i, j] = csum[i+1] - csum[j+1]  ... for j <= i
    diff = csum[..., :, None] - csum[..., None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (t + 1, t + 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t + 1, t + 1), 1)
    mask = rows >= cols
    out = jnp.where(mask, diff, -jnp.inf)
    # drop the prepended row/col back to (t, t): M[i, j] over original indices
    return out[..., 1:, 1:]
