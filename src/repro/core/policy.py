"""`KernelPolicy` — the single home for kernel-selection state.

The paper's contribution is one idea (express reduction/scan as TCU
matmuls and pick the matmul form where it wins), but by PR 3 the *choice*
of formulation was smeared across four mechanisms: two overlapping
``resolve_path()`` functions (``repro.core.dispatch`` and
``repro.kernels.backend``), bare ``path=`` strings on every op,
``kernel_path`` fields duplicated on ``ModelConfig``/``OptConfig``/
``ServeConfig``, and ``REPRO_KERNEL_PATH``/``REPRO_AUTOTUNE*`` env vars
re-read at call sites. This module replaces all of that with one object:

* :class:`KernelPolicy` — a frozen, hashable dataclass capturing the full
  selection state: global ``path``, per-op overrides (``op_paths``), a
  ``backend`` preference, the ``autotune`` mode and table source, per-op
  tuning-knob overrides (``op_tuning``), and the off-accelerator
  ``interpret_fallback`` behaviour. Hashable means it can ride through
  ``jit`` static args and config dataclasses unchanged.
* :class:`TuneSpec` — the per-op kernel *geometry* (block/chunk shapes,
  GPU ``num_warps``/``num_stages``) as data instead of constants, each
  knob validated against :data:`KNOB_SCHEMA` the way ``op_paths``
  validates against :data:`KNOWN_OPS`.
* :meth:`KernelPolicy.resolve` — THE resolution algorithm; nothing else
  in the repo decides which formulation runs (the pre-policy
  ``resolve_path`` delegates are gone). It returns a :class:`ResolvedPath`
  — a plain ``str`` path label that also carries the resolved
  :class:`TuneSpec` (defaults from ``repro.kernels.layout``, overlaid by
  the autotune table's swept winner, overlaid by ``op_tuning``), so every
  kernel takes its geometry from the same resolution pass that picked it.
* A process-default policy built from the env vars — **this module is the
  only place that reads** ``REPRO_KERNEL_PATH`` / ``REPRO_AUTOTUNE`` /
  ``REPRO_AUTOTUNE_TABLE`` (a grep-guard test enforces it).
* :func:`get_policy` / :func:`set_policy` / :func:`using_policy` — a
  context-var based active policy, so overrides are scoped, thread-safe,
  and safe under ``jit`` tracing (the policy is read eagerly at trace
  time, never captured as a tracer).

The stable public surface for running ops under a policy is
:mod:`repro.ops`.

String shorthands (accepted everywhere a policy is):

* ``"fused"`` (any bare path label) — run exactly this path; per-call it
  overlays the active policy with ``path=<label>`` and clears per-op
  overrides.
* ``"attention=fused,reduce=tile"`` — per-op overrides (a bare label mixed
  in sets the global path: ``"baseline,attention=fused"``).
* ``'{"path": "auto", "autotune": "off"}'`` — JSON field overrides.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import json
import os
import warnings
from typing import Any, Iterator, Mapping

from repro.obs import runtime as _obs

# The env vars (parsed ONLY here; other modules may re-export the names):
ENV_PATH = "REPRO_KERNEL_PATH"         # default path label
ENV_AUTOTUNE = "REPRO_AUTOTUNE"        # "off"/"0"/"static"/"false" -> off
ENV_TABLE = "REPRO_AUTOTUNE_TABLE"     # explicit autotune table file

# Path labels by level. "dispatch" admits the algorithm-level contenders
# the paper compares (xla_tile, baseline); "kernel" is the
# implementation-level subset the Pallas registry understands.
# "tile_logdepth" is the log-depth MatMulScan contender (scan-family only):
# backend-agnostic like "tile" — it runs the host's native local kernels
# plus an XLA tree combine, or the interpreter off-accelerator.
DISPATCH_PATHS = ("auto", "fused", "xla_tile", "tile", "tile_tpu",
                  "tile_gpu", "tile_logdepth", "interpret", "baseline")
KERNEL_PATHS = ("auto", "fused", "tile", "tile_tpu", "tile_gpu",
                "tile_logdepth", "interpret")
_DISPATCH_ONLY = ("xla_tile", "baseline")

BACKENDS = ("cpu", "gpu", "tpu")
AUTOTUNE_MODES = ("on", "off")
INTERPRET_FALLBACKS = ("warn", "silent", "error")

# Canonical (dispatch-level) op names a policy can carry overrides for;
# the kernel-registry spellings alias onto them so one override steers
# both layers. Unknown keys are rejected at construction — a typo'd
# override that silently no-ops is exactly the failure mode this
# subsystem exists to remove.
KNOWN_OPS = ("reduce", "scan", "weighted_scan", "ragged_reduce",
             "ragged_scan", "rmsnorm", "attention", "ssd")
OP_ALIASES = {"segmented_reduce": "reduce", "segmented_scan": "scan",
              "ssd_scan": "ssd"}

# Per-op tuning-knob schema: the only knob names a TuneSpec (and the
# ``tuning`` field of an autotune-table entry) may carry for each op.
# The knob *values* — per-backend defaults and sweep candidates — live in
# ``repro.kernels.layout`` (the one module allowed to spell out geometry
# numbers); this schema is the validation contract, owned by the policy
# layer the way KNOWN_OPS is. ``num_warps``/``num_stages`` are GPU-only
# at runtime (the TPU glue ignores them) but legal in any spec so one
# override string can serve both backends.
KNOB_SCHEMA = {
    "reduce": ("block_s", "block_n", "num_warps", "num_stages"),
    "scan": ("block_s", "block_n", "radix", "fan_in",
             "num_warps", "num_stages"),
    "weighted_scan": ("q", "radix", "fan_in", "num_warps", "num_stages"),
    "ragged_reduce": (),     # no Pallas kernel yet (XLA matmul form)
    "ragged_scan": (),
    "rmsnorm": ("row_block", "block_d", "num_warps", "num_stages"),
    "attention": ("block_q", "block_k", "num_warps", "num_stages"),
    "ssd": ("q", "radix", "fan_in", "num_warps", "num_stages"),
}


# ---------------------------------------------------------------------------
# one-time warnings (deprecation shims warn once per process, not per call)


_WARNED: set[str] = set()


def warn_once(key: str, message: str, category: type = DeprecationWarning,
              stacklevel: int = 3) -> None:
    """Emit ``message`` the first time ``key`` is seen this process."""
    if key in _WARNED:
        return
    _WARNED.add(key)
    warnings.warn(message, category, stacklevel=stacklevel + 1)


_TILE_DOWNGRADE_WARNED = False


def _warn_tile_downgrade() -> None:
    """One-time notice that the generic ``tile`` label fell back to the
    interpreter — silent interpreter execution looks like a hang at real
    sizes, so say so once per process."""
    global _TILE_DOWNGRADE_WARNED
    if _TILE_DOWNGRADE_WARNED:
        return
    _TILE_DOWNGRADE_WARNED = True
    import jax

    warnings.warn(
        f"path='tile' has no native Pallas lowering on the "
        f"{jax.default_backend()!r} backend (tile_tpu needs a TPU, tile_gpu "
        "a GPU with Pallas-Triton); running the kernel body through the "
        "Pallas interpreter instead. Pass path='interpret' explicitly to "
        "silence this one-time warning.",
        UserWarning, stacklevel=5)


_LOGDEPTH_DOWNGRADE_WARNED = False


def _warn_logdepth_downgrade() -> None:
    """One-time notice that ``tile_logdepth``'s local kernels will run
    through the interpreter (the label is kept — the log-depth algorithm
    still runs, only its Pallas block passes are interpreted)."""
    global _LOGDEPTH_DOWNGRADE_WARNED
    if _LOGDEPTH_DOWNGRADE_WARNED:
        return
    _LOGDEPTH_DOWNGRADE_WARNED = True
    import jax

    warnings.warn(
        f"path='tile_logdepth' has no native Pallas lowering on the "
        f"{jax.default_backend()!r} backend; the log-depth tree combine "
        "still runs as XLA matmuls but the local block kernels go through "
        "the Pallas interpreter. Set interpret_fallback='silent' to "
        "silence this one-time warning.",
        UserWarning, stacklevel=5)


def _shard_effective_n(op: str, n: int) -> int:
    """Per-shard bucket size under an active ``parallel.MeshContext``.

    Sharding is what makes the per-device problem small — a model-parallel
    shard of a reduce/scan call is just another small-n band, so the
    crossover table and TuneSpec must key off the shard's shape, not the
    global one. Deferred import: ``parallel`` imports this module.
    """
    try:
        from repro.parallel import mesh_context
    except ImportError:  # parallel package stripped from a minimal install
        return n
    return mesh_context.effective_call_n(op, n)


# ---------------------------------------------------------------------------
# tuning specs


@dataclasses.dataclass(frozen=True)
class TuneSpec:
    """Per-op kernel tuning geometry, frozen and hashable.

    Mirrors :class:`KernelPolicy`'s contract one level down: where the
    policy decides *which* formulation runs, a ``TuneSpec`` decides *how*
    it runs — block/chunk shapes and (on GPU) ``num_warps``/``num_stages``
    as data instead of constants baked into the kernel files.

    ``op``
        Canonical op name (any of :data:`KNOWN_OPS`; kernel-registry
        spellings like ``segmented_reduce`` alias onto them).
    ``knobs``
        The knob values — a mapping (or tuple of ``(knob, value)`` pairs;
        normalised to a sorted tuple so the spec stays hashable and can
        ride through ``jit`` static args). Every key is validated against
        :data:`KNOB_SCHEMA` and every value must be a positive int — a
        typo'd knob that silently no-ops is exactly the failure mode this
        subsystem exists to remove.

    Construction accepts the same spellings as a policy: a ``TuneSpec``,
    a mapping, or a string shorthand (``"q=64,num_warps=8"``) via
    :meth:`from_spec`. The per-backend *default* values live in
    ``repro.kernels.layout``; :meth:`KernelPolicy.tuning_for` merges
    defaults < autotune-table winner < policy ``op_tuning`` override into
    the spec every kernel consumes.
    """

    op: str
    knobs: tuple = ()

    def __post_init__(self):
        op = OP_ALIASES.get(str(self.op), str(self.op))
        object.__setattr__(self, "op", op)
        if op not in KNOWN_OPS:
            raise ValueError(
                f"TuneSpec: unknown op {op!r}; expected one of {KNOWN_OPS} "
                f"(or a kernel-registry alias {tuple(OP_ALIASES)})")
        pairs = self.knobs
        if isinstance(pairs, Mapping):
            pairs = pairs.items()
        allowed = KNOB_SCHEMA[op]
        norm = []
        for k, v in sorted((str(k), v) for k, v in pairs):
            if k not in allowed:
                raise ValueError(
                    f"TuneSpec({op!r}): unknown knob {k!r}; expected one "
                    f"of {allowed} — a typo here would silently no-op")
            if isinstance(v, bool) or not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"TuneSpec({op!r}): knob {k!r} must be a positive "
                    f"int, got {v!r}")
            norm.append((k, v))
        object.__setattr__(self, "knobs", tuple(norm))

    @classmethod
    def from_spec(cls, op: str, spec: "TuneSpec | Mapping | str"
                  ) -> "TuneSpec":
        """Coerce a tuning spec for ``op``: a :class:`TuneSpec`, a mapping
        of knob values, or a ``"knob=value,knob=value"`` string."""
        if isinstance(spec, TuneSpec):
            if OP_ALIASES.get(str(op), str(op)) != spec.op:
                raise ValueError(
                    f"TuneSpec for op {spec.op!r} used under op {op!r}")
            return spec
        if isinstance(spec, Mapping):
            return cls(op=op, knobs=spec)
        if not isinstance(spec, str):
            raise TypeError(
                f"cannot build a TuneSpec from {type(spec).__name__}: "
                f"{spec!r}")
        knobs = {}
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            k, sep, v = part.partition("=")
            if not sep:
                raise ValueError(
                    f"TuneSpec string must be 'knob=value,...', got "
                    f"{spec!r}")
            knobs[k.strip()] = int(v)
        return cls(op=op, knobs=knobs)

    def get(self, key: str, default=None):
        """The value of one knob, or ``default`` when the spec doesn't
        carry it (the kernel glue then falls back to the layout default)."""
        for k, v in self.knobs:
            if k == key:
                return v
        return default

    def as_dict(self) -> dict:
        return dict(self.knobs)

    def label(self) -> str:
        """Compact human-readable form for benchmark rows / sweep keys
        (``"block_n=64;block_s=32"``; ``"-"`` for an empty spec)."""
        return ";".join(f"{k}={v}" for k, v in self.knobs) or "-"


class ResolvedPath(str):
    """What :meth:`KernelPolicy.resolve` returns: a plain ``str`` path
    label (every existing comparison and dict key keeps working) that also
    carries the resolved :class:`TuneSpec` as ``.tuning`` (None when the
    call had no op context). ``pallas_op`` hands the spec to the tile
    kernels; the fused/baseline XLA forms ignore it."""

    __slots__ = ("tuning",)

    def __new__(cls, label: str, tuning: "TuneSpec | None" = None):
        self = str.__new__(cls, label)
        self.tuning = tuning
        return self


# ---------------------------------------------------------------------------
# the policy object


@dataclasses.dataclass(frozen=True)
class KernelPolicy:
    """Full kernel-selection state, frozen and hashable.

    ``path``
        Global path label (any of :data:`DISPATCH_PATHS`).
    ``op_paths``
        Per-op overrides that beat ``path`` — a mapping (or tuple of
        ``(op, path)`` pairs; normalised to a sorted tuple so the policy
        stays hashable), e.g. ``{"attention": "fused"}``.
    ``backend``
        Tile-backend preference: None (host-native), ``"tpu"``/``"gpu"``
        (the generic ``tile`` label forces that backend's kernel, raising
        off-host like the explicit ``tile_tpu``/``tile_gpu`` labels), or
        ``"cpu"`` (``tile`` runs the interpreter, silently — an explicit
        CPU choice is not a downgrade).
    ``autotune``
        ``"on"`` (shape-aware ``auto`` via the measured table / heuristic)
        or ``"off"`` (static ``auto``: tile on TPU/GPU, fused elsewhere).
    ``autotune_table``
        Explicit table file. None falls back to the checked-in default;
        a set-but-unusable table fails loudly (see ``repro.core.autotune``).
    ``op_tuning``
        Per-op :class:`TuneSpec` overrides — a mapping (or tuple of
        ``(op, spec)`` pairs; normalised to a sorted tuple of
        ``(op, TuneSpec)``) from op name to a spec, mapping, or
        ``"knob=value,..."`` string, e.g. ``{"ssd": {"q": 64}}``. These
        beat both the layout defaults and the autotune table's swept
        winner in :meth:`tuning_for`.
    ``interpret_fallback``
        What the generic ``tile`` does off-accelerator: ``"warn"`` (run the
        interpreter, warn once), ``"silent"``, or ``"error"``.
    """

    path: str = "auto"
    op_paths: tuple = ()
    backend: str | None = None
    autotune: str = "on"
    autotune_table: str | None = None
    op_tuning: tuple = ()
    interpret_fallback: str = "warn"

    def __post_init__(self):
        pairs = self.op_paths
        if isinstance(pairs, Mapping):
            pairs = pairs.items()
        pairs = tuple(sorted(
            (OP_ALIASES.get(str(op), str(op)), str(p)) for op, p in pairs))
        object.__setattr__(self, "op_paths", pairs)
        tune = self.op_tuning
        if isinstance(tune, Mapping):
            tune = tune.items()
        # merge entries that alias onto the same canonical op ("ssd" and
        # "ssd_scan" are one op): knobs combine, but a conflicting value
        # for the same knob is ambiguous and must raise — first-match
        # resolution would silently depend on insertion order
        merged: dict[str, dict] = {}
        for op_name, spec in tune:
            canon = OP_ALIASES.get(str(op_name), str(op_name))
            ts = TuneSpec.from_spec(str(op_name), spec)
            cur = merged.setdefault(canon, {})
            for k, v in ts.knobs:
                if k in cur and cur[k] != v:
                    raise ValueError(
                        f"op_tuning: conflicting values for "
                        f"{canon}.{k} ({cur[k]} vs {v}) — the op was "
                        "specified twice under aliased names")
                cur[k] = v
        tune = tuple(sorted(
            ((op, TuneSpec(op, kn)) for op, kn in merged.items()),
            key=lambda kv: kv[0]))
        object.__setattr__(self, "op_tuning", tune)
        if self.path not in DISPATCH_PATHS:
            raise ValueError(
                f"unknown path {self.path!r}; expected one of "
                f"{DISPATCH_PATHS}")
        for op, p in pairs:
            if op not in KNOWN_OPS:
                raise ValueError(
                    f"op_paths: unknown op {op!r}; expected one of "
                    f"{KNOWN_OPS} (or a kernel-registry alias "
                    f"{tuple(OP_ALIASES)}) — a typo here would silently "
                    "no-op")
            if p not in DISPATCH_PATHS:
                raise ValueError(
                    f"op_paths[{op!r}]: unknown path {p!r}; expected one "
                    f"of {DISPATCH_PATHS}")
        if self.backend is not None and self.backend not in BACKENDS:
            raise ValueError(
                f"unknown backend {self.backend!r}; expected one of "
                f"{BACKENDS} or None")
        if self.autotune not in AUTOTUNE_MODES:
            raise ValueError(
                f"unknown autotune mode {self.autotune!r}; expected one of "
                f"{AUTOTUNE_MODES}")
        if self.interpret_fallback not in INTERPRET_FALLBACKS:
            raise ValueError(
                f"unknown interpret_fallback {self.interpret_fallback!r}; "
                f"expected one of {INTERPRET_FALLBACKS}")

    # -- construction -------------------------------------------------------

    @classmethod
    def from_spec(cls, spec: "KernelPolicy | Mapping | str",
                  base: "KernelPolicy | None" = None) -> "KernelPolicy":
        """Coerce a policy spec onto ``base`` (default: a fresh policy).

        Accepts a :class:`KernelPolicy` (returned as-is), a mapping of
        field overrides, or a string: a bare path label, an
        ``op=path,op=path`` shorthand (a bare label mixed in sets the
        global path; dotted keys are tuning-knob overrides —
        ``"tile,ssd.q=64"`` pins the global path AND the SSD chunk), or a
        JSON object of field overrides (which may include ``op_tuning``).
        """
        if isinstance(spec, KernelPolicy):
            return spec
        if base is None:
            base = cls()
        if isinstance(spec, Mapping):
            return dataclasses.replace(base, **dict(spec))
        if not isinstance(spec, str):
            raise TypeError(
                f"cannot build a KernelPolicy from {type(spec).__name__}: "
                f"{spec!r}")
        s = spec.strip()
        if s.startswith("{"):
            fields = json.loads(s)
            if not isinstance(fields, dict):
                raise ValueError(
                    f"policy JSON must be an object, got: {s!r}")
            return dataclasses.replace(base, **fields)
        if "=" in s:
            overrides = dict(base.op_paths)
            tuning = {op: spec.as_dict() for op, spec in base.op_tuning}
            path = base.path
            for part in s.split(","):
                part = part.strip()
                if not part:
                    continue
                if "=" in part:
                    key, _, val = part.partition("=")
                    key = key.strip()
                    if "." in key:      # op.knob=value tuning override
                        op, _, kn = key.partition(".")
                        op = OP_ALIASES.get(op.strip(), op.strip())
                        tuning.setdefault(op, {})[kn.strip()] = int(val)
                    else:
                        overrides[key] = val.strip()
                else:
                    path = part
            return dataclasses.replace(
                base, path=path, op_paths=tuple(overrides.items()),
                op_tuning=tuning)
        return dataclasses.replace(base, path=s, op_paths=())

    # -- resolution ---------------------------------------------------------

    def for_op(self, op: str | None) -> str:
        """The label this policy requests for ``op`` (override > global).

        Kernel-registry spellings alias onto the canonical op names, so
        an ``op_paths={"reduce": ...}`` override also steers a direct
        ``kernels.ops.segmented_reduce`` call.
        """
        if op is not None:
            op = OP_ALIASES.get(op, op)
            for name, p in self.op_paths:
                if name == op:
                    return p
        return self.path

    def tuning_for(self, op: str | None, n: int | None = None,
                   dtype: Any = None, *,
                   label: str | None = None) -> "TuneSpec | None":
        """The :class:`TuneSpec` this policy resolves for one call.

        Three layers, later wins: the per-backend defaults in
        ``repro.kernels.layout`` (keyed by the *kernel* backend the
        resolved ``label`` implies — ``tile_gpu`` reads the GPU defaults,
        everything else the TPU/interpret ones), the autotune table's
        swept winner for this call's shape bucket (v3 tables; gated by
        this policy's ``autotune``/``autotune_table`` like path
        resolution), and this policy's own ``op_tuning`` override. Knobs
        that tile the bucket axis itself are then clamped against ``n``
        (``layout.clamp_spec``), so the returned spec reports the
        geometry that actually runs — a ``q=64`` override on a TPU host
        comes back as the 128 the MXU-edge clamp will execute, never a
        phantom value (row-axis knobs clamp at the call site instead).
        Returns None for calls with no op context.
        """
        if op is None:
            return None
        op = OP_ALIASES.get(op, op)
        if op not in KNOWN_OPS:
            return None
        if not KNOB_SCHEMA[op]:
            return TuneSpec(op)
        from repro.kernels import layout  # deferred: avoids a cycle

        if label == "tile_gpu":
            bk = "gpu"
        elif label == "tile_logdepth":
            # backend-agnostic label: read the defaults of whichever
            # backend's local kernels will actually run
            from repro.kernels import backend as kb

            bk = "gpu" if kb.native_tile_backend() == "tile_gpu" else "tpu"
        else:
            bk = "tpu"
        knobs = layout.default_tuning(bk, op)
        if n is not None and self.autotune != "off":
            from repro.core import autotune  # deferred: imports us

            swept = autotune.tuning_entry(op, n, dtype, policy=self)
            if swept:
                knobs.update(swept)
        for name, spec in self.op_tuning:
            if name == op:
                knobs.update(spec.as_dict())
        # clamp the knobs that tile the bucket axis itself, so the spec
        # this method reports IS the geometry the glue will run (row-axis
        # knobs depend on batch shape and clamp at the call site)
        return TuneSpec(op, layout.clamp_spec(bk, op, knobs, n=n))

    def resolve(self, op: str | None = None, n: int | None = None,
                dtype: Any = None, *, level: str = "dispatch",
                explicit: str | None = None) -> "ResolvedPath":
        """Resolve one call to a concrete execution path.

        This is the repo's ONLY resolution algorithm (grep-guarded; the
        pre-policy ``resolve_path`` delegates were removed once every
        caller migrated).

        ``op``/``n``/``dtype`` describe the call shape: with them,
        ``auto`` consults the measured per-shape crossover table
        (``repro.core.autotune``, gated by this policy's ``autotune`` /
        ``autotune_table``) instead of the static backend check.

        ``level`` is ``"dispatch"`` (admits the algorithm-level
        ``xla_tile``/``baseline`` contenders) or ``"kernel"`` (the Pallas
        registry's subset; policy-sourced dispatch-only labels downgrade
        to their nearest kernel equivalent, ``fused``).

        ``explicit`` is a per-call label that beats everything in the
        policy (the ``path=`` kwarg); it is validated against ``level``'s
        label set.

        Returns a :class:`ResolvedPath`: a plain ``str`` label whose
        ``.tuning`` attribute carries the :class:`TuneSpec` resolved via
        :meth:`tuning_for` (None when ``op`` is unknown) — the tile
        kernels take their geometry from it.
        """
        n_raw = n
        if op is not None and n is not None:
            n = _shard_effective_n(op, n)
        label = self._resolve_label(op=op, n=n, dtype=dtype, level=level,
                                    explicit=explicit)
        resolved = ResolvedPath(
            label, self.tuning_for(op, n, dtype, label=label))
        if _obs.ACTIVE is not None:   # observability off by default: the
            # disabled path costs one module-global load and this branch
            self._emit_resolution(op=op, n_raw=n_raw, n=n, dtype=dtype,
                                  level=level, explicit=explicit,
                                  resolved=resolved)
        return resolved

    def _emit_resolution(self, *, op, n_raw, n, dtype, level, explicit,
                         resolved: "ResolvedPath") -> None:
        """Record one resolution into the active obs session (only called
        when a session is active): a ``resolution`` event carrying the
        dispatch-audit schema (``repro.obs.events.RESOLUTION_FIELDS``) and
        a ``repro_resolutions_total`` counter labelled by op/path/level."""
        sess = _obs.ACTIVE
        if sess is None:   # raced a disable(); nothing to record into
            return
        from repro.core import autotune  # deferred: imports us

        shaped = op is not None and n is not None
        requested = explicit if explicit is not None else self.for_op(op)
        if requested != "auto":
            table_src = "none"        # no table consultation happened
        elif not shaped or self.autotune == "off":
            table_src = "static"      # static backend check resolved auto
        else:
            entries = autotune.current_entries(self)
            if entries is not None and \
                    autotune.bucket_key(op, n, dtype) in entries:
                table_src = str(autotune.table_path(self))
            else:
                table_src = "heuristic"
        tuning = resolved.tuning.as_dict() \
            if resolved.tuning is not None else None
        sess.emit(
            "resolution",
            op=op, n=n_raw, shard_n=n,
            shard_divisor=(max(1, n_raw // n) if shaped and n else 1),
            dtype=autotune.dtype_tag(dtype) if shaped else None,
            backend=autotune.current_backend(),
            band=autotune.band(n) if shaped else None,
            level=level, explicit=explicit, chosen_path=str(resolved),
            tuning=tuning, table_src=table_src)
        sess.counter(
            "repro_resolutions_total",
            "KernelPolicy.resolve() calls by op/path/level").inc(
            op=str(op), path=str(resolved), level=str(level))

    def _resolve_label(self, op: str | None, n: int | None, dtype: Any,
                       level: str, explicit: str | None) -> str:
        from repro.kernels import backend as kb  # deferred: avoids a cycle

        valid = DISPATCH_PATHS if level == "dispatch" else KERNEL_PATHS
        if explicit is not None:
            if explicit not in valid:
                noun = "path" if level == "dispatch" else "kernel path"
                raise ValueError(
                    f"unknown {noun} {explicit!r}; expected one of {valid}")
            label = explicit
        else:
            label = self.for_op(op)
            if level == "kernel" and label in _DISPATCH_ONLY:
                # the env var / policy is process-wide, so kernel-level
                # call sites run the nearest kernel-level equivalent
                label = "fused"
        native = kb.native_tile_backend()
        if label == "auto":
            choice = None
            if op is not None and n is not None:
                from repro.core import autotune  # deferred: imports us

                if level == "kernel":
                    canon = OP_ALIASES.get(op, op)
                    choice = autotune.choose(
                        op, n, dtype,
                        candidates=("fused", "tile", "tile_tpu", "tile_gpu",
                                    "tile_logdepth", "interpret"),
                        level="kernel", policy=self,
                        use_heuristic=(canon
                                       not in autotune.FUSED_DEFAULT_OPS))
                else:
                    choice = autotune.choose(op, n, dtype, policy=self)
                # auto must never force a tile backend the host can't lower
                if choice in ("tile_tpu", "tile_gpu") and choice != native:
                    choice = None
            label = choice or ("tile" if native else "fused")
            if level == "kernel" and label in _DISPATCH_ONLY:
                label = "fused"
        if label in _DISPATCH_ONLY:
            return label
        if label == "tile":
            if self.backend == "cpu":
                return "interpret"   # explicit CPU preference, no downgrade
            if self.backend in ("gpu", "tpu"):
                label = f"tile_{self.backend}"   # strict checks below
            elif native is None:
                if self.interpret_fallback == "error":
                    import jax

                    raise RuntimeError(
                        "path='tile' has no native Pallas lowering on the "
                        f"{jax.default_backend()!r} backend and this "
                        "policy's interpret_fallback='error' forbids the "
                        "interpreter downgrade")
                if self.interpret_fallback == "warn":
                    _warn_tile_downgrade()
                return "interpret"   # nothing to compile the tile kernel for
            else:
                return native
        if label == "tile_logdepth":
            # backend-agnostic like "tile", but the label survives: the
            # log-depth algorithm still runs off-accelerator — only its
            # local Pallas block passes drop to the interpreter (decided
            # by the registry via native_tile_backend()).
            if native is None and self.backend != "cpu":
                if self.interpret_fallback == "error":
                    import jax

                    raise RuntimeError(
                        "path='tile_logdepth' has no native Pallas lowering "
                        f"on the {jax.default_backend()!r} backend and this "
                        "policy's interpret_fallback='error' forbids the "
                        "interpreter downgrade of its local block kernels")
                if self.interpret_fallback == "warn":
                    _warn_logdepth_downgrade()
            return label
        if label == "tile_tpu" and native != "tile_tpu":
            import jax

            raise RuntimeError(
                "path='tile_tpu' requires a TPU host with the Pallas-TPU "
                f"lowering (active backend: {jax.default_backend()!r}); use "
                "path='interpret' for CPU validation or path='tile' for "
                "backend-appropriate selection")
        if label == "tile_gpu" and native != "tile_gpu":
            import jax

            raise RuntimeError(
                "path='tile_gpu' requires a GPU host with the Pallas-Triton "
                f"lowering (active backend: {jax.default_backend()!r}); use "
                "path='interpret' for CPU validation or path='tile' for "
                "backend-appropriate selection")
        return label


# ---------------------------------------------------------------------------
# the process default (built from the env vars — the ONLY place they are
# read) and the context-var active policy


_DEFAULT_CACHE: dict[tuple, KernelPolicy] = {}


def default_policy() -> KernelPolicy:
    """The process-default policy, built from the env vars.

    Parsed once per distinct env-var state (memoised on the raw values, so
    tests that monkeypatch the environment see the change without a
    process restart — the *parsing* still has exactly one home).
    """
    raw = (os.environ.get(ENV_PATH, ""), os.environ.get(ENV_AUTOTUNE, ""),
           os.environ.get(ENV_TABLE, ""))
    if raw not in _DEFAULT_CACHE:
        mode = "off" if raw[1].strip().lower() in (
            "off", "0", "static", "false") else "on"
        table = raw[2].strip() or None
        pol = KernelPolicy(autotune=mode, autotune_table=table)
        spec = raw[0].strip()
        if spec:
            # full from_spec grammar: a bare path label, an
            # "op=path,op.knob=value" shorthand, or JSON field overrides
            # (JSON is case-sensitive; the simple forms stay lowercased)
            if not spec.startswith("{"):
                spec = spec.lower()
            pol = KernelPolicy.from_spec(spec, base=pol)
        _DEFAULT_CACHE[raw] = pol
    return _DEFAULT_CACHE[raw]


_ACTIVE: contextvars.ContextVar[KernelPolicy | None] = \
    contextvars.ContextVar("repro_kernel_policy", default=None)


def get_policy() -> KernelPolicy:
    """The active policy: the innermost override, else the env default."""
    pol = _ACTIVE.get()
    return pol if pol is not None else default_policy()


def set_policy(policy: "KernelPolicy | Mapping | str | None"
               ) -> contextvars.Token:
    """Install ``policy`` as the active policy (None restores the env
    default). Returns a token for :func:`reset_policy`; prefer the scoped
    :func:`using_policy` unless the override should outlive the frame."""
    pol = None if policy is None else \
        KernelPolicy.from_spec(policy, base=get_policy())
    return _ACTIVE.set(pol)


def reset_policy(token: contextvars.Token) -> None:
    """Undo a :func:`set_policy` (restores the previous active policy)."""
    _ACTIVE.reset(token)


@contextlib.contextmanager
def using_policy(policy: "KernelPolicy | Mapping | str | None"
                 ) -> Iterator[KernelPolicy]:
    """Scoped policy override; nests and restores on exit.

    Context-var based, so it is thread-safe and ``jit``-trace-safe (the
    policy is read eagerly at trace time).
    """
    token = set_policy(policy)
    try:
        yield get_policy()
    finally:
        reset_policy(token)


def coerce_config_policy(policy, kernel_path: str | None,
                         owner: str) -> KernelPolicy | None:
    """Shared ``__post_init__`` shim for configs that hold a policy.

    Folds the deprecated ``kernel_path=`` string (warns once, keyed by
    ``owner``) into ``policy`` and coerces strings/mappings absolutely
    via :meth:`KernelPolicy.from_spec` (a config is a durable artifact —
    it must not capture whatever policy happens to be active at
    construction time). Returns the coerced policy, or None (= defer to
    the active policy at call time).
    """
    if kernel_path is not None:
        warn_once(
            f"deprecated:{owner}.kernel_path",
            f"{owner}(kernel_path=...) is deprecated; pass policy= "
            "(a KernelPolicy or a path-label string)", stacklevel=5)
        if policy is None:
            policy = kernel_path
    if policy is not None and not isinstance(policy, KernelPolicy):
        policy = KernelPolicy.from_spec(policy)
    return policy


def policy_from_cli(policy_arg: str | None, kernel_path_arg: str | None,
                    warn_key: str,
                    tune_arg: str | None = None) -> KernelPolicy | None:
    """Shared ``--policy`` / ``--tune`` / deprecated ``--kernel-path``
    merge for CLIs.

    ``--kernel-path <label>`` warns once and acts as ``--policy <label>``
    unless ``--policy`` was also given. ``--tune "op.knob=value,..."``
    (e.g. ``--tune "ssd.q=64,attention.block_q=256"``) layers per-op
    tuning overrides on top of whatever policy the other flags produced.
    The spec is applied on top of the env-derived default policy (CLIs are
    process entry points — the env vars must keep steering whatever the
    flags don't override). Returns None when no flag was passed.
    """
    spec = policy_arg
    if kernel_path_arg is not None:
        warn_once(warn_key, "--kernel-path is deprecated; use --policy")
        spec = spec if spec is not None else kernel_path_arg
    if spec is None and tune_arg is None:
        return None
    pol = default_policy()
    if spec is not None:
        pol = KernelPolicy.from_spec(spec, base=pol)
    if tune_arg is not None:
        for part in tune_arg.split(","):
            part = part.strip()
            if part and "." not in part.split("=", 1)[0]:
                raise ValueError(
                    f"--tune expects op.knob=value pairs (e.g. "
                    f"'ssd.q=64'), got {part!r} — path overrides belong "
                    "in --policy")
        pol = KernelPolicy.from_spec(tune_arg, base=pol)
    return pol


def as_policy(policy: "KernelPolicy | Mapping | str | None" = None
              ) -> KernelPolicy:
    """Coerce a per-call ``policy=`` argument.

    None means the active policy; strings/mappings overlay it (a bare
    path label additionally clears per-op overrides — "run exactly this
    path"). Configs that persist a policy coerce absolutely via
    :meth:`KernelPolicy.from_spec` instead.
    """
    if policy is None:
        return get_policy()
    if isinstance(policy, KernelPolicy):
        return policy
    return KernelPolicy.from_spec(policy, base=get_policy())
