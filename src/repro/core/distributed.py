"""Device-level ("grid-level", paper §4.3/§5.3) reduction and scan.

The paper's grid level uses multiple kernel launches with partials in global
memory. The TPU-native analogue is a mesh collective: within-device partials
are produced by the tile/block levels (repro.core.reduce / .scan), and the
cross-device combination is expressed with jax collectives inside
``shard_map``. The scan follows the paper's *scan-then-propagate* strategy:

  kernel 1: per-device segmented scan          -> local scan + local total
  kernel 2: scan of the per-device totals      -> matmul-form over the axis
  kernel 3: uniform add of the exclusive carry -> one fused add

Kernel 2 is itself in matmul form: the gathered totals vector is hit with a
strictly-lower-triangular ones matrix — the same L as the tile level, with
the mesh axis playing the role of the tile row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import tcu_scan, tcu_weighted_scan


def dist_reduce(x_local: jax.Array, axis_name: str) -> jax.Array:
    """Grid-level full reduction: local matmul-form partials + psum."""
    from repro.core.reduce import tcu_reduce

    return jax.lax.psum(tcu_reduce(x_local), axis_name)


def dist_exclusive_carry(local_total: jax.Array, axis_name: str) -> jax.Array:
    """Exclusive scan of per-device totals over a mesh axis, matmul-form.

    all_gather the totals (one scalar-ish leaf per device), multiply with the
    strictly-lower triangular ones matrix, and select this device's row —
    the paper's grid-level "scan of partials" with the matmul executing
    redundantly-but-locally on every device (cheaper than a second collective
    round for the axis sizes used here).
    """
    gathered = jax.lax.all_gather(local_total, axis_name)          # (ndev, ...)
    ndev = gathered.shape[0]
    idx = jax.lax.axis_index(axis_name)
    rows = jax.lax.broadcasted_iota(jnp.int32, (ndev, ndev), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (ndev, ndev), 1)
    l_mask = (rows > cols).astype(gathered.dtype)
    flat = gathered.reshape(ndev, -1)
    carries = l_mask @ flat                                        # (ndev, -1)
    return carries[idx].reshape(gathered.shape[1:])


def dist_scan(x_local: jax.Array, axis_name: str) -> jax.Array:
    """Grid-level inclusive scan: the last axis of the *global* array is
    sharded over ``axis_name``; returns the correctly-carried local shard."""
    local = tcu_scan(x_local)
    carry = dist_exclusive_carry(local[..., -1], axis_name)
    return local + carry[..., None]


def dist_weighted_scan(
    x_local: jax.Array, log_a_local: jax.Array, axis_name: str
) -> jax.Array:
    """Grid-level decayed scan (sequence-parallel SSD carry propagation).

    Local chunks compute their weighted scan and total decay; the cross-
    device carry is the weighted exclusive scan of (totals, decays) over the
    mesh axis, then propagated through each position's prefix decay.
    """
    acc = jnp.float32
    local = tcu_weighted_scan(x_local, log_a_local)
    total = local[..., -1]
    log_decay = jnp.sum(log_a_local.astype(acc), axis=-1)

    gathered_t = jax.lax.all_gather(total, axis_name)              # (ndev, ...)
    gathered_d = jax.lax.all_gather(log_decay, axis_name)
    ndev = gathered_t.shape[0]
    idx = jax.lax.axis_index(axis_name)

    # weighted exclusive scan over the device axis (leading), matmul-form
    from repro.core.tiles import segsum

    # move device axis last for segsum convenience
    t = jnp.moveaxis(gathered_t, 0, -1)
    d = jnp.moveaxis(gathered_d, 0, -1)
    m = jnp.exp(segsum(d))
    s = jnp.einsum("...ij,...j->...i", m, t)
    excl = jnp.concatenate([jnp.zeros_like(s[..., :1]), s[..., :-1]], axis=-1)
    carry = jnp.take(excl, idx, axis=-1)

    prefix = jnp.cumsum(log_a_local.astype(acc), axis=-1)
    return local + carry[..., None] * jnp.exp(prefix)
