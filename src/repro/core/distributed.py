"""Device-level ("grid-level", paper §4.3/§5.3) reduction and scan.

The paper's grid level uses multiple kernel launches with partials in global
memory. The TPU-native analogue is a mesh collective: within-device partials
are produced by the tile/block levels (repro.core.reduce / .scan), and the
cross-device combination is expressed with jax collectives inside
``shard_map``. The scan follows the paper's *scan-then-propagate* strategy:

  kernel 1: per-device segmented scan          -> local scan + local total
  kernel 2: scan of the per-device totals      -> matmul-form over the axis
  kernel 3: uniform add of the exclusive carry -> one fused add

Kernel 2 is itself in matmul form: the gathered totals vector is hit with a
strictly-lower-triangular ones matrix — the same L as the tile level, with
the mesh axis playing the role of the tile row.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.scan import tcu_scan, tcu_weighted_scan


def dist_reduce(x_local: jax.Array, axis_name: str) -> jax.Array:
    """Grid-level full reduction: local matmul-form partials + psum."""
    from repro.core.reduce import tcu_reduce

    return jax.lax.psum(tcu_reduce(x_local), axis_name)


def dist_exclusive_carry(local_total: jax.Array, axis_name: str) -> jax.Array:
    """Exclusive scan of per-device totals over a mesh axis, matmul-form.

    all_gather the totals (one scalar-ish leaf per device), multiply with the
    strictly-lower triangular ones matrix, and select this device's row —
    the paper's grid-level "scan of partials" with the matmul executing
    redundantly-but-locally on every device (cheaper than a second collective
    round for the axis sizes used here).
    """
    gathered = jax.lax.all_gather(local_total, axis_name)          # (ndev, ...)
    ndev = gathered.shape[0]
    idx = jax.lax.axis_index(axis_name)
    rows = jax.lax.broadcasted_iota(jnp.int32, (ndev, ndev), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (ndev, ndev), 1)
    l_mask = (rows > cols).astype(gathered.dtype)
    flat = gathered.reshape(ndev, -1)
    carries = l_mask @ flat                                        # (ndev, -1)
    return carries[idx].reshape(gathered.shape[1:])


def dist_scan(x_local: jax.Array, axis_name: str) -> jax.Array:
    """Grid-level inclusive scan: the last axis of the *global* array is
    sharded over ``axis_name``; returns the correctly-carried local shard."""
    local = tcu_scan(x_local)
    carry = dist_exclusive_carry(local[..., -1], axis_name)
    return local + carry[..., None]


def weighted_exclusive_carry(
    total: jax.Array, log_decay: jax.Array, axis_name: str
) -> jax.Array:
    """Weighted exclusive scan of per-device (total, log-decay) pairs.

    Solves the cross-device recurrence ``H_i = exp(L_i) * H_{i-1} + T_i``
    over mesh axis ``axis_name`` and returns this device's *incoming* carry
    ``H_{i-1}`` (zeros on device 0). ``total`` may carry extra trailing
    state dims beyond ``log_decay``'s shape — ``log_decay`` broadcasts over
    them (the SSD case: totals are ``(B, H, P, N)`` states decayed by a
    per-``(B, H)`` scalar; the weighted-scan case has no extra dims).

    Matmul form throughout: all_gather both, hit the totals with the decay
    matrix ``exp(segsum(L))`` — the same 1-semiseparable mask as the tile
    level, with the mesh axis playing the role of the tile row — and select
    this device's row of the shifted result.
    """
    from repro.core.tiles import segsum

    if total.shape[:log_decay.ndim] != log_decay.shape:
        raise ValueError(
            f"log_decay shape {log_decay.shape} must prefix total shape "
            f"{total.shape}")
    gathered_t = jax.lax.all_gather(total, axis_name)              # (ndev, ...)
    gathered_d = jax.lax.all_gather(log_decay, axis_name)
    ndev = gathered_t.shape[0]
    idx = jax.lax.axis_index(axis_name)

    d = jnp.moveaxis(gathered_d, 0, -1)                 # (*D, ndev)
    m = jnp.exp(segsum(d))                              # (*D, ndev, ndev)
    # flatten the extra state dims so the combine is one batched matmul
    t = jnp.moveaxis(gathered_t.reshape((ndev,) + log_decay.shape + (-1,)),
                     0, -2)                             # (*D, ndev, extra)
    s = m @ t                                           # inclusive H_i
    excl = jnp.concatenate(
        [jnp.zeros_like(s[..., :1, :]), s[..., :-1, :]], axis=-2)
    return jnp.take(excl, idx, axis=-2).reshape(total.shape)


def dist_weighted_scan(
    x_local: jax.Array, log_a_local: jax.Array, axis_name: str
) -> jax.Array:
    """Grid-level decayed scan (sequence-parallel SSD carry propagation).

    Local chunks compute their weighted scan and total decay; the cross-
    device carry is the weighted exclusive scan of (totals, decays) over the
    mesh axis, then propagated through each position's prefix decay.
    """
    acc = jnp.float32
    local = tcu_weighted_scan(x_local, log_a_local)
    carry = weighted_exclusive_carry(
        local[..., -1], jnp.sum(log_a_local.astype(acc), axis=-1), axis_name)
    prefix = jnp.cumsum(log_a_local.astype(acc), axis=-1)
    return local + carry[..., None] * jnp.exp(prefix)
