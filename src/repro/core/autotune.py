"""Shape-aware autotuning for the dispatch layer's ``auto`` path.

The paper's central result (Fig. 10-11) is a *crossover*: the matmul-form
reduction/scan beats the native vector op by up to 100x at small segment
sizes and loses the advantage as segments grow. Both TCU-reduction
follow-ups in PAPERS.md (Navarro et al., Chowdhury et al.) model exactly
this crossover, which a static "tile on TPU, fused elsewhere" ``auto``
ignores. This module makes ``auto`` consult a *measured* table instead:

* **Buckets** — a call shape maps to ``{op}/{dtype-tag}/{log2-band}``
  (e.g. ``reduce/f32/9`` for a 512-element f32 segmented reduce). Bands
  are powers of two, matching the paper's sweep axes.
* **Table** — a JSON file keyed *by backend*: ``{"version": 3,
  "backends": {"cpu": {"jax": ..., "entries": {bucket: {...}}}}}``. Each
  backend section maps bucket -> winning dispatch path, with the raw
  per-contender timings kept alongside for auditability; a table measured
  on a GPU host merges in as a ``"gpu"`` section and steers *only* GPU
  hosts — CPU/TPU resolution never reads it. v3 entries may additionally
  record the winning :class:`~repro.core.policy.TuneSpec` of the kernel
  geometry sweep as ``"tuning": {knob: value}`` (validated against
  ``policy.KNOB_SCHEMA`` — unknown knob keys in an explicit
  ``$REPRO_AUTOTUNE_TABLE`` fail loudly) plus the per-spec sweep timings
  as ``"sweep"``. Resolution order: ``$REPRO_AUTOTUNE_TABLE`` (explicit
  file) > the checked-in default (``autotune_default.json``, measured on
  CPU with kernels in interpret mode) > the built-in heuristic. Legacy v1
  files (one flat ``backend`` + ``entries``) and v2 files (backend
  sections, no tuning) up-convert on load.
* **Harness** — :func:`measure_table` times every registered contender of
  ``repro.core.dispatch`` per bucket and records the argmin for the host's
  backend; on hosts with a native tile lowering (or under
  ``sweep_interpret=True``, the CI smoke mode) it also sweeps each op's
  candidate TuneSpecs from ``repro.kernels.layout`` and persists the
  winning geometry. Regenerate with ``python -m repro.core.autotune
  --write`` (merges into an existing multi-backend file — run it on a GPU
  host to add the ``gpu`` section without touching the CPU one;
  ``--sweep-budget tiny`` is the fast smoke variant); CI checks the
  checked-in default for staleness with ``--check``.
* **Fallbacks** — a missing bucket (or a section for a different backend
  only) falls back to :func:`heuristic` (deterministic: the paper's
  small-segment crossover off-accelerator, the tile kernel on TPU/GPU);
  ``REPRO_AUTOTUNE=off`` disables table *and* heuristic, restoring the
  pre-autotune static choice (tile on TPU/GPU, fused elsewhere). An
  *explicitly requested* table (``$REPRO_AUTOTUNE_TABLE``) that is
  malformed — unknown backend keys, bad paths, unparseable JSON — fails
  loudly instead of silently degrading; only the implicit default degrades.

Numerical contract: every contender of an op agrees to tolerance (the
dispatch-path agreement tests), so the table only moves work between
formulations — it never changes results beyond accumulation order.
"""
from __future__ import annotations

import json
import math
import time
from pathlib import Path
from typing import Any, Iterable, Sequence

import jax
import jax.numpy as jnp

from repro.core import policy as kpolicy
from repro.kernels import backend

# env-var names, re-exported for callers; repro.core.policy is the only
# module that parses them (they land here as KernelPolicy fields)
ENV_AUTOTUNE = kpolicy.ENV_AUTOTUNE      # "off"/"0"/"static" -> static auto
ENV_TABLE = kpolicy.ENV_TABLE            # path to a JSON table
DEFAULT_TABLE_PATH = Path(__file__).with_name("autotune_default.json")
TABLE_VERSION = 3
_UPCONVERTIBLE_VERSIONS = (2,)   # v2 = backend sections, no tuning
MAX_BAND = 20

# the backend axis of the table; jax.default_backend() spellings normalise
# onto these keys
KNOWN_BACKENDS = ("cpu", "gpu", "tpu")

# Ops with a measured matmul-form vs native-op crossover (the paper's
# reduction/scan family). Other ops (attention, ssd, rmsnorm) keep the
# static choice unless a table entry says otherwise.
CROSSOVER_OPS = ("reduce", "scan", "weighted_scan",
                 "ragged_reduce", "ragged_scan")
# Paper Fig. 11: the matmul form wins the small-segment regime; 2^9 is the
# conservative boundary used when no measurement is available.
HEURISTIC_CROSSOVER = 512

# Model-level ops whose ``auto`` default keeps the chunked/fused XLA form
# even on TPU/GPU: those forms shard under GSPMD and carry knobs (SSD chunk
# size, matmul dtype) the Pallas kernels drop, and the flash kernels fall
# back to the materialised oracle on unaligned lengths. The kernels are
# opted in explicitly (path="tile") or via a measured table entry.
FUSED_DEFAULT_OPS = ("attention", "ssd")

# Kernel-registry op names -> the dispatch-level op the table is keyed by
# (the policy layer's alias map — one spelling contract for both layers).
_OP_ALIAS = dict(kpolicy.OP_ALIASES)

# The harness's default measurement grid — shared with check_default so the
# CI staleness check always validates exactly the bucket set --write emits.
DEFAULT_BANDS = tuple(range(4, 14))
DEFAULT_DTYPES = (jnp.float32, jnp.bfloat16)

# Contenders the harness times per op (dispatch-level paths). ``xla_tile``
# only differs from ``fused`` for reduce (core's scan IS the tile algebra);
# ``tile`` is appended on hosts with a native Pallas lowering (TPU or GPU);
# ``interpret`` is validation-only (orders of magnitude slow on CPU) and
# excluded from measurement.
OP_CONTENDERS = {
    "reduce": ("fused", "xla_tile", "baseline"),
    "scan": ("fused", "baseline"),
    "weighted_scan": ("fused", "baseline"),
    "ragged_reduce": ("fused", "baseline"),
    "ragged_scan": ("fused", "baseline"),
}


def current_backend() -> str:
    """jax.default_backend() normalised onto the table's backend keys."""
    b = jax.default_backend()
    return "gpu" if b in ("cuda", "rocm") else b


# ---------------------------------------------------------------------------
# bucketing


def dtype_tag(dtype: Any) -> str:
    """Canonical short tag for a dtype (``f32``, ``bf16``, ...)."""
    if dtype is None:
        return "f32"
    name = jnp.dtype(dtype).name
    return {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
            "float64": "f64"}.get(name, name)


_DTYPE_FROM_TAG = {"f32": "float32", "bf16": "bfloat16", "f16": "float16",
                   "f64": "float64"}


def dtype_from_tag(tag: str):
    """Inverse of :func:`dtype_tag` — obs resolution events carry the
    short tag, and re-resolving one (tests, ``--check``) needs the real
    dtype back."""
    return jnp.dtype(_DTYPE_FROM_TAG.get(tag, tag))


def band(n: int) -> int:
    """log2 segment-size band, clamped to [0, MAX_BAND]."""
    return max(0, min(int(math.log2(max(int(n), 1))), MAX_BAND))


def bucket_key(op: str, n: int, dtype: Any = None) -> str:
    return f"{_OP_ALIAS.get(op, op)}/{dtype_tag(dtype)}/{band(n)}"


# ---------------------------------------------------------------------------
# table load / save


_TABLE_CACHE: dict[str, dict | None] = {}


def invalidate_cache() -> None:
    _TABLE_CACHE.clear()


def _valid_paths() -> tuple[str, ...]:
    # dispatch-level paths minus "auto" (a table must be fully resolved)
    return ("fused", "xla_tile", "tile", "tile_tpu", "tile_gpu",
            "tile_logdepth", "interpret", "baseline")


def _check_entries(entries: Any, where: str) -> None:
    if not isinstance(entries, dict) or not entries:
        raise ValueError(f"autotune table {where}: no entries")
    ok = _valid_paths()
    for key, ent in entries.items():
        if not isinstance(ent, dict) or ent.get("path") not in ok:
            raise ValueError(
                f"autotune table {where}: entry {key!r} has invalid path "
                f"{ent.get('path') if isinstance(ent, dict) else ent!r}")
        tuning = ent.get("tuning")
        if tuning is None:
            continue
        op = key.split("/", 1)[0]
        allowed = kpolicy.KNOB_SCHEMA.get(op, ())
        if not isinstance(tuning, dict):
            raise ValueError(
                f"autotune table {where}: entry {key!r} tuning must be an "
                f"object, got {tuning!r}")
        for k, v in tuning.items():
            if k not in allowed:
                raise ValueError(
                    f"autotune table {where}: entry {key!r} has unknown "
                    f"tuning knob {k!r}; expected one of {allowed} — a "
                    "typo'd knob would silently no-op")
            if isinstance(v, bool) or not isinstance(v, int) or v <= 0:
                raise ValueError(
                    f"autotune table {where}: entry {key!r} tuning knob "
                    f"{k!r} must be a positive int, got {v!r}")


def load_table(path: str | Path) -> dict:
    """Load and validate a table; raises ValueError on a malformed file.

    Returns the v3 shape ``{"version": 3, "backends": {key: {"jax": ...,
    "entries": {...}}}}``; legacy v1 files (flat ``backend``/``entries``)
    and v2 files (backend sections without per-entry ``tuning``) are
    up-converted — a v2 entry simply has no swept geometry, so resolution
    keeps the layout defaults for its bucket. Unknown backend keys, and
    unknown tuning-knob keys in any entry, are an error — a typo'd or
    future-format table must fail loudly, never silently steer nothing.
    """
    with open(path) as f:
        table = json.load(f)
    if not isinstance(table, dict):
        raise ValueError(f"autotune table {path}: not a JSON object")
    version = table.get("version")
    if version == 1:  # legacy single-backend layout
        bk = table.get("backend")
        bk = "gpu" if bk in ("cuda", "rocm") else bk  # old raw spellings
        if bk not in KNOWN_BACKENDS:
            raise ValueError(
                f"autotune table {path}: unknown backend key {bk!r}; "
                f"expected one of {KNOWN_BACKENDS}")
        _check_entries(table.get("entries"), str(path))
        return {"version": TABLE_VERSION,
                "backends": {bk: {"jax": table.get("jax"),
                                  "entries": table["entries"]}}}
    if version in _UPCONVERTIBLE_VERSIONS:
        table = dict(table, version=TABLE_VERSION)
        version = TABLE_VERSION
    if version != TABLE_VERSION:
        raise ValueError(
            f"autotune table {path}: version {version!r} != {TABLE_VERSION}")
    backends = table.get("backends")
    if not isinstance(backends, dict) or not backends:
        raise ValueError(f"autotune table {path}: no backend sections")
    for bk, section in backends.items():
        if bk not in KNOWN_BACKENDS:
            raise ValueError(
                f"autotune table {path}: unknown backend key {bk!r}; "
                f"expected one of {KNOWN_BACKENDS}")
        if not isinstance(section, dict):
            raise ValueError(
                f"autotune table {path}: backend {bk!r} section is not an "
                "object")
        _check_entries(section.get("entries"), f"{path} [{bk}]")
    return table


def save_table(table: dict, path: str | Path) -> None:
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
        f.write("\n")
    invalidate_cache()


def merge_tables(base: dict | None, new: dict) -> dict:
    """Overlay ``new``'s backend sections onto ``base`` (v2 shapes).

    This is how a GPU-measured table drops into the checked-in default
    unchanged: only the sections the new measurement covers are replaced.
    """
    merged = {"version": TABLE_VERSION, "backends": {}}
    if base is not None:
        merged["backends"].update(base.get("backends", {}))
    merged["backends"].update(new.get("backends", {}))
    return merged


def _entry_us(ent: dict) -> float:
    """The winning path's measured time for one entry (inf when the entry
    carries no timing — e.g. an up-converted v1/v2 table)."""
    us = ent.get("us")
    if isinstance(us, dict):
        t = us.get(ent.get("path"))
        if isinstance(t, (int, float)):
            return float(t)
    return math.inf


def merge_host_tables(paths: Sequence[str | Path]) -> dict:
    """Fold per-host table files from a multi-host job into one table.

    Unlike :func:`merge_tables` (whole-section overlay, for dropping a
    GPU-measured table into the checked-in default), this merges at
    *entry* granularity: each host of a multi-host run measures only the
    buckets its shards exercised, and the union is the job's table. When
    two hosts measured the same bucket for the same backend, the faster
    winning time takes the cell — hosts are assumed homogeneous per
    backend, so a slower duplicate is just a noisier measurement of the
    same machine class. Every merged entry records which file it came
    from under ``"src"`` (provenance; ignored by resolution, preserved by
    ``load_table``).
    """
    if not paths:
        raise ValueError("merge_host_tables: no input tables")
    merged: dict = {"version": TABLE_VERSION, "backends": {}}
    for path in paths:
        table = load_table(path)
        src = Path(path).name
        for bk, section in table["backends"].items():
            out = merged["backends"].setdefault(
                bk, {"jax": section.get("jax"), "entries": {}})
            for key, ent in section["entries"].items():
                ent = dict(ent, src=src)
                have = out["entries"].get(key)
                if have is None or _entry_us(ent) < _entry_us(have):
                    out["entries"][key] = ent
    return merged


def table_path(policy: kpolicy.KernelPolicy | None = None) -> Path | None:
    """The active table file: the policy's ``autotune_table`` (the env
    var's one home, ``repro.core.policy``, feeds it), else the default."""
    pol = policy if policy is not None else kpolicy.get_policy()
    if pol.autotune_table:
        return Path(pol.autotune_table)
    return DEFAULT_TABLE_PATH if DEFAULT_TABLE_PATH.exists() else None


def current_table(policy: kpolicy.KernelPolicy | None = None) -> dict | None:
    """The active, validated table (cached per path), or None.

    An *explicitly requested* table (``policy.autotune_table``, i.e.
    ``$REPRO_AUTOTUNE_TABLE``) that fails to load raises — pointing
    resolution at a table and getting the heuristic would be a silent
    no-op. The implicit checked-in default degrades to None instead (CI
    lints it separately).
    """
    pol = policy if policy is not None else kpolicy.get_policy()
    path = table_path(pol)
    if path is None:
        return None
    explicit = bool(pol.autotune_table)
    key = str(path)
    if key not in _TABLE_CACHE:
        try:
            _TABLE_CACHE[key] = load_table(path)
        except (OSError, ValueError, json.JSONDecodeError) as e:
            if explicit:
                raise ValueError(
                    f"{ENV_TABLE}={path} is unusable: {e}") from e
            _TABLE_CACHE[key] = None
    return _TABLE_CACHE[key]


def current_entries(policy: kpolicy.KernelPolicy | None = None
                    ) -> dict | None:
    """The active table's entries for *this host's* backend, or None.

    The backend key is the isolation boundary: a ``gpu`` section is never
    consulted on a CPU/TPU host (its crossovers do not transfer).
    """
    table = current_table(policy)
    if table is None:
        return None
    section = table["backends"].get(current_backend())
    return section["entries"] if section else None


def enabled(policy: kpolicy.KernelPolicy | None = None) -> bool:
    """False when the policy asks for the static heuristic
    (``autotune="off"``, i.e. ``REPRO_AUTOTUNE=off``)."""
    pol = policy if policy is not None else kpolicy.get_policy()
    return pol.autotune != "off"


# ---------------------------------------------------------------------------
# resolution


def heuristic(op: str, n: int, dtype: Any = None,
              candidates: Iterable[str] | None = None) -> str:
    """Deterministic shape-aware fallback (no measurement needed).

    On TPU and GPU the tile kernels are native for the reduction/scan
    family; model-level ops (``FUSED_DEFAULT_OPS``) keep their chunked XLA
    forms there (see that constant for why). On GPU the paper's crossover
    still applies between the Triton kernel and the native vector op
    (arXiv:1903.03640 measures the same small-segment regime), so large
    segments fall back to ``baseline``. Off-accelerator the crossover is
    between the matmul-form ``fused`` and the native op. Everything else
    keeps the static ``fused``.
    """
    op = _OP_ALIAS.get(op, op)
    if op in FUSED_DEFAULT_OPS:
        want = "fused"
    elif backend.on_tpu() and backend.has_pallas_tpu():
        want = "tile"
    elif backend.on_gpu() and backend.has_pallas_triton():
        want = "baseline" if (op in CROSSOVER_OPS
                              and n > HEURISTIC_CROSSOVER) else "tile"
    elif op in CROSSOVER_OPS and n > HEURISTIC_CROSSOVER:
        want = "baseline"
    else:
        want = "fused"
    if candidates is not None:
        cands = tuple(candidates)
        if want not in cands:
            for fb in ("fused", "tile", "interpret", "baseline"):
                if fb in cands:
                    return fb
    return want


# dispatch-level path labels -> the kernel-level implementation that runs
# the same code. backend's "fused" is the native-op reference in ref.py —
# i.e. the dispatch layer's "baseline"; the matmul forms ("fused"/
# "xla_tile") live in repro.core and have no kernel-registry twin.
_KERNEL_EQUIV = {"baseline": "fused", "tile": "tile",
                 "tile_tpu": "tile_tpu", "tile_gpu": "tile_gpu",
                 "tile_logdepth": "tile_logdepth", "interpret": "interpret"}


def _backend_compatible(path: str) -> bool:
    """A table entry may only steer onto a tile backend this host lowers."""
    if path == "tile_tpu":
        return backend.native_tile_backend() == "tile_tpu"
    if path == "tile_gpu":
        return backend.native_tile_backend() == "tile_gpu"
    return True


def choose(op: str, n: int, dtype: Any = None,
           candidates: Iterable[str] | None = None, *,
           level: str = "dispatch",
           policy: kpolicy.KernelPolicy | None = None,
           use_heuristic: bool = True) -> str | None:
    """Resolve ``auto`` for one call shape.

    ``policy`` carries the autotune mode and table source (None = the
    active policy); :meth:`KernelPolicy.resolve` passes itself here.
    Returns a concrete path, or None when the policy disables autotuning
    (``autotune="off"``) — the caller then applies the static choice.
    Only the table section for this host's backend is consulted (a
    GPU-measured section never steers CPU/TPU); a missing bucket falls
    back to :func:`heuristic` (unless ``use_heuristic=False`` — the
    kernel level passes that for ``FUSED_DEFAULT_OPS``, whose heuristic
    rationale is dispatch-level: at the kernel level their "fused" twin
    is the *materialised* reference, so without a table entry the static
    choice — tile on a native host — must stand).

    ``level="kernel"`` translates the table's dispatch-level labels onto
    the kernel registry's implementations via ``_KERNEL_EQUIV`` (a naive
    label pass-through would hand backend's native-op "fused" a bucket the
    *matmul-form* "fused" won); when the measured winner has no kernel
    twin, the fastest recorded contender that does is chosen instead.
    """
    if not enabled(policy):
        return None
    entries = current_entries(policy)
    if entries is not None:
        ent = entries.get(bucket_key(op, n, dtype))
        if ent is not None and _backend_compatible(ent["path"]):
            if level == "kernel":
                if ent["path"] in _KERNEL_EQUIV:
                    return _KERNEL_EQUIV[ent["path"]]
                us = {k: v for k, v in (ent.get("us") or {}).items()
                      if k in _KERNEL_EQUIV}
                if us:
                    return _KERNEL_EQUIV[min(us, key=us.get)]
            else:
                path = ent["path"]
                if candidates is None or path in tuple(candidates):
                    return path
    if not use_heuristic:
        return None
    return heuristic(op, n, dtype, candidates)


def tuning_entry(op: str, n: int, dtype: Any = None, *,
                 policy: kpolicy.KernelPolicy | None = None) -> dict | None:
    """The swept winning tuning knobs for one call shape, or None.

    Consulted by :meth:`KernelPolicy.tuning_for` the same way
    :func:`choose` serves path resolution: only this host's backend
    section, gated by the policy's autotune mode; a v2-era entry (no
    ``tuning``) or a missing bucket returns None so the layout defaults
    apply. Knob keys were validated at load time, so the dict can be
    merged into a TuneSpec as-is.
    """
    if not enabled(policy):
        return None
    entries = current_entries(policy)
    if entries is None:
        return None
    ent = entries.get(bucket_key(op, n, dtype))
    tuning = ent.get("tuning") if ent else None
    return dict(tuning) if tuning else None


# ---------------------------------------------------------------------------
# measurement harness


def _time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall seconds per call of a jit'd fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def _bench_inputs(op: str, n: int, dtype, rng: jax.Array):
    """Representative arguments for one (op, segment-size) bucket."""
    rows = max(4, min(4096, (1 << 16) // n))
    k1, k2 = jax.random.split(rng)
    if op in ("reduce", "scan"):
        return (jax.random.normal(k1, (rows, n)).astype(dtype),)
    if op == "weighted_scan":
        x = jax.random.normal(k1, (rows, n)).astype(dtype)
        la = (-jax.random.uniform(k2, (rows, n))).astype(dtype)
        return (x, la)
    if op in ("ragged_reduce", "ragged_scan"):
        s = min(128, max(2, n // 16))
        x = jax.random.normal(k1, (n,)).astype(dtype)
        seg = jnp.sort(jax.random.randint(k2, (n,), 0, s))
        return (x, seg, s)
    raise ValueError(op)


def measure_table(
    *,
    ops: Iterable[str] = tuple(OP_CONTENDERS),
    bands: Iterable[int] = DEFAULT_BANDS,
    dtypes: Iterable[Any] = DEFAULT_DTYPES,
    iters: int = 3,
    sweep: bool = True,
    sweep_interpret: bool = False,
    max_candidates: int | None = None,
) -> dict:
    """Time every contender per (op, dtype, band) bucket -> a v3 table
    holding one section for this host's backend.

    Runs through ``repro.core.dispatch`` (the same entry every consumer
    uses), so the table steers exactly what it measured. On hosts with a
    native tile lowering the tile contender is a *geometry sweep*: every
    candidate TuneSpec from ``repro.kernels.layout`` is clamped against
    the bucket's shape and deduplicated (small buckets can collapse
    several candidates onto one executed geometry — timing them all would
    crown a noise winner that never ran), then timed under a pinned
    policy (``op_tuning={op: spec}``, autotune off); the best one becomes
    the recorded ``tile`` timing and the entry persists it as
    ``"tuning"`` (plus the full per-spec timings as ``"sweep"``).
    For the ops with a log-depth MatMulScan contender (``scan``,
    ``weighted_scan``) the same sweep also times ``tile_logdepth`` across
    ``layout.logdepth_candidate_tuning`` — its per-spec timings land in
    the entry's ``"sweep"`` under ``tile_logdepth:``-prefixed keys (the
    linear tile keys stay unprefixed, so existing tables keep their
    meaning) and the faster tile-family contender's spec is the one
    persisted as ``"tuning"``. ``sweep_interpret=True`` runs the same
    sweeps through the Pallas interpreter on hosts with no native
    lowering — validation-speed, for the CI tiny-sweep smoke leg only.
    Merge the result into a multi-backend file with :func:`merge_tables`
    (what ``--write`` does) — measuring on a GPU host adds/refreshes the
    ``gpu`` section without touching the others.
    """
    from repro.core import dispatch  # deferred: dispatch imports us
    from repro.kernels import layout

    fns = {
        "reduce": dispatch.reduce,
        "scan": dispatch.scan,
        "weighted_scan": dispatch.weighted_scan,
        "ragged_reduce": dispatch.ragged_reduce,
        "ragged_scan": dispatch.ragged_scan,
    }
    native = backend.native_tile_backend()
    tile_path = "tile" if native else \
        ("interpret" if sweep_interpret else None)
    # tile_logdepth keeps its label on every host (interpreted off-
    # accelerator); it is swept only where the linear tile contender is
    # (native host, or the CI interpret smoke) so a plain-CPU --write
    # leaves the checked-in default table's contents unchanged
    ld_path = "tile_logdepth" if (native or sweep_interpret) else None
    axis = "gpu" if native == "tile_gpu" else "tpu"
    entries: dict[str, dict] = {}
    rng = jax.random.PRNGKey(0)
    for op in ops:
        contenders = OP_CONTENDERS[op]
        specs = layout.candidate_tuning(axis, op) if sweep else []
        ld_specs = layout.logdepth_candidate_tuning(axis, op) if sweep else []
        if max_candidates is not None:
            specs = specs[:max_candidates]
            ld_specs = ld_specs[:max_candidates]
        sweep_op = bool(specs) and tile_path is not None
        sweep_ld = bool(ld_specs) and ld_path is not None
        for dtype in dtypes:
            for b in bands:
                n = 1 << b
                rng, sub = jax.random.split(rng)
                args = _bench_inputs(op, n, dtype, sub)

                def timed(policy):
                    if op in ("ragged_reduce", "ragged_scan"):
                        x, seg, s = args
                        fn = jax.jit(
                            lambda a, i, p=policy, o=op: fns[o](
                                a, i, s, policy=p))
                        return _time_fn(fn, x, seg, iters=iters)
                    fn = jax.jit(
                        lambda *a, p=policy, o=op: fns[o](*a, policy=p))
                    return _time_fn(fn, *args, iters=iters)

                timings = {path: timed(path) for path in contenders}
                rows = args[0].shape[0] if args[0].ndim > 1 else None
                best_spec = sweep_us = None
                if native and tile_path and not sweep_op and \
                        op in ("reduce", "scan", "weighted_scan"):
                    # sweep disabled: still time the tile contender at its
                    # default geometry (a native host's table must be able
                    # to record 'tile' as a bucket winner)
                    timings[tile_path] = timed(tile_path)
                if sweep_op:
                    # clamp each candidate against this bucket's shape and
                    # dedupe: two specs that collapse onto the same
                    # executed geometry must not be timed twice (the
                    # "winner" between them would be noise that never
                    # ran). The spec PERSISTED is clamped on the bucket
                    # axis only — row-axis knobs reflect the probe input's
                    # row count, which real calls in this bucket won't
                    # share (their glue re-clamps per call).
                    fitted: list[tuple[dict, dict]] = []
                    for spec in specs:
                        ex = layout.clamp_spec(axis, op, spec, n=n,
                                               rows=rows)
                        if all(ex != e for e, _ in fitted):
                            fitted.append(
                                (ex, layout.clamp_spec(axis, op, spec,
                                                       n=n)))
                    sweep_us = {}
                    persist = {}
                    for ex, keep in fitted:
                        pol = kpolicy.KernelPolicy(
                            path=tile_path, autotune="off",
                            op_tuning={op: ex},
                            interpret_fallback="silent")
                        label = kpolicy.TuneSpec(op, ex).label()
                        sweep_us[label] = timed(pol)
                        persist[label] = keep
                    best = min(sweep_us, key=sweep_us.get)
                    best_spec = persist[best]
                    timings[tile_path] = sweep_us[best]
                if sweep_ld:
                    # the log-depth contender rides the same clamp/dedupe
                    # discipline; its sweep keys carry a "tile_logdepth:"
                    # prefix so they never collide with the linear tile
                    # labels in the entry's "sweep" record
                    fitted_ld: list[tuple[dict, dict]] = []
                    for spec in ld_specs:
                        ex = layout.clamp_spec(axis, op, spec, n=n,
                                               rows=rows)
                        if all(ex != e for e, _ in fitted_ld):
                            fitted_ld.append(
                                (ex, layout.clamp_spec(axis, op, spec,
                                                       n=n)))
                    ld_us = {}
                    ld_persist = {}
                    for ex, keep in fitted_ld:
                        pol = kpolicy.KernelPolicy(
                            path=ld_path, autotune="off",
                            op_tuning={op: ex},
                            interpret_fallback="silent")
                        label = ("tile_logdepth:"
                                 + kpolicy.TuneSpec(op, ex).label())
                        ld_us[label] = timed(pol)
                        ld_persist[label] = keep
                    ld_best = min(ld_us, key=ld_us.get)
                    timings[ld_path] = ld_us[ld_best]
                    sweep_us = dict(sweep_us or {}, **ld_us)
                    # persist the spec of the faster tile-family
                    # contender — that is the one tuning_for will feed
                    # whichever label the bucket resolves onto
                    linear_us = (timings.get(tile_path, math.inf)
                                 if tile_path else math.inf)
                    if best_spec is None or ld_us[ld_best] < linear_us:
                        best_spec = ld_persist[ld_best]
                winner = min(timings, key=timings.get)
                ent = {
                    "path": winner,
                    "us": {k: round(v * 1e6, 2) for k, v in timings.items()},
                }
                if best_spec is not None:
                    ent["tuning"] = dict(sorted(best_spec.items()))
                    ent["sweep"] = {k: round(v * 1e6, 2)
                                    for k, v in sweep_us.items()}
                entries[bucket_key(op, n, dtype)] = ent
    return {
        "version": TABLE_VERSION,
        "backends": {current_backend(): {"jax": jax.__version__,
                                         "entries": entries}},
    }


def check_default(default_path: str | Path = DEFAULT_TABLE_PATH) -> list[str]:
    """Structural staleness check for the checked-in default table.

    Parses/validates the file (including backend keys) and regenerates the
    *key set* the harness would produce today for this host's backend (no
    timing involved); returns a list of problems (empty = fresh). Winning
    paths are machine-dependent and deliberately not compared; sections for
    *other* backends are validated structurally but their bucket sets are
    not compared (they were measured on hardware this host doesn't have).
    """
    problems: list[str] = []
    try:
        table = load_table(default_path)
    except (OSError, ValueError, json.JSONDecodeError) as e:
        return [f"unparseable: {e}"]
    bk = current_backend()
    section = table["backends"].get(bk)
    if section is None:
        return [f"no section for this host's backend {bk!r} "
                f"(have: {sorted(table['backends'])})"]
    want = set()
    for op in OP_CONTENDERS:
        for dtype in DEFAULT_DTYPES:
            for b in DEFAULT_BANDS:
                want.add(bucket_key(op, 1 << b, dtype))
    have = set(section["entries"])
    if missing := sorted(want - have):
        problems.append(f"missing buckets: {missing}")
    if extra := sorted(have - want):
        problems.append(f"stale buckets: {extra}")
    return problems


def describe_bucket(key: str, ent: dict | None = None) -> str:
    """One human-readable line for a table bucket, rendered with the obs
    resolution-event formatter so ``--check`` output reads the same as a
    traced dispatch. With ``ent``, shows what the table recorded (winning
    path, tuning, winning time); without, shows what this host's default
    policy would resolve for the bucket today."""
    from repro.obs import events as _ev

    op, tag, b = key.split("/")
    n = 1 << int(b)
    if ent is not None:
        event = {"op": op, "n": n, "dtype": tag, "band": int(b),
                 "backend": current_backend(),
                 "chosen_path": ent.get("path"),
                 "tuning": ent.get("tuning") or {},
                 "table_src": "table-entry"}
        return f"{_ev.format_resolution(event)} us={_entry_us(ent):.2f}"
    from repro.core import policy as kpolicy

    probe = kpolicy.KernelPolicy(interpret_fallback="silent")
    try:
        resolved = probe.resolve(op=op, n=n, dtype=dtype_from_tag(tag))
    except (RuntimeError, ValueError) as e:
        return f"op={op} n={n} dtype={tag}: unresolvable here ({e})"
    return _ev.format_resolution({
        "op": op, "n": n, "dtype": tag, "band": int(b),
        "backend": current_backend(), "chosen_path": str(resolved),
        "tuning": (resolved.tuning.as_dict()
                   if resolved.tuning is not None else {}),
        "table_src": "heuristic"})


def check_report(default_path: str | Path = DEFAULT_TABLE_PATH) -> list[str]:
    """Per-bucket detail behind ``--check``: one :func:`describe_bucket`
    line for every bucket the structural check flagged — missing buckets
    show what this host would resolve today, stale buckets show what the
    table recorded. Empty when the table is unreadable or fresh (the
    structural problems from :func:`check_default` stand alone then)."""
    lines: list[str] = []
    try:
        table = load_table(default_path)
    except (OSError, ValueError, json.JSONDecodeError):
        return lines
    section = table["backends"].get(current_backend())
    if section is None:
        return lines
    want = {bucket_key(op, 1 << b, dtype)
            for op in OP_CONTENDERS for dtype in DEFAULT_DTYPES
            for b in DEFAULT_BANDS}
    have = set(section["entries"])
    for key in sorted(want - have):
        lines.append(f"  missing {key}: today -> {describe_bucket(key)}")
    for key in sorted(have - want):
        lines.append(f"  stale   {key}: table -> "
                     f"{describe_bucket(key, section['entries'][key])}")
    return lines


def main(argv: list[str] | None = None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="Measure/refresh the dispatch autotune table.")
    ap.add_argument("--write", action="store_true",
                    help="measure this host's backend and merge the section "
                         "into the table file")
    ap.add_argument("--out", default=str(DEFAULT_TABLE_PATH),
                    help="output path for --write")
    ap.add_argument("--check", action="store_true",
                    help="verify the checked-in default parses and matches "
                         "the harness's bucket set (exit 1 if stale)")
    ap.add_argument("--merge", nargs="+", metavar="TABLE",
                    help="fold per-host table files from a multi-host job "
                         "into one table at --out (entry-level union; "
                         "duplicate buckets resolved by winning time, "
                         "provenance recorded per entry)")
    ap.add_argument("--iters", type=int, default=3)
    ap.add_argument("--sweep-budget", choices=("full", "tiny"),
                    default="full",
                    help="'full' measures the whole default grid (geometry "
                         "sweeps run only on hosts with a native tile "
                         "lowering); 'tiny' is the CI smoke mode: a few "
                         "buckets, one dtype, and the candidate-spec sweep "
                         "forced through the Pallas interpreter so v3 "
                         "tuning entries are exercised on any host")
    args = ap.parse_args(argv)

    if args.merge:
        table = merge_host_tables(args.merge)
        save_table(table, args.out)
        load_table(args.out)  # round-trip: the merged file must validate
        sections = {bk: len(sec["entries"])
                    for bk, sec in table["backends"].items()}
        print(f"merged {len(args.merge)} host tables into {args.out} "
              f"(buckets per backend: {sections})")
        return 0
    if args.check:
        problems = check_default()
        for p in problems:
            print(f"STALE: {p}")
        for line in check_report():
            print(line)
        if not problems:
            print(f"autotune default table OK ({DEFAULT_TABLE_PATH})")
        return 1 if problems else 0
    if args.write:
        if args.sweep_budget == "tiny":
            # bands big enough that >= 2 candidate geometries stay
            # distinct after the per-bucket clamp
            measured = measure_table(
                ops=("reduce", "scan", "weighted_scan"), bands=(8, 10),
                dtypes=(jnp.float32,), iters=1, sweep_interpret=True,
                max_candidates=2)
        else:
            measured = measure_table(iters=args.iters)
        base = None
        if Path(args.out).exists():
            try:
                base = load_table(args.out)
            except (OSError, ValueError, json.JSONDecodeError):
                base = None  # overwrite an unusable file
        table = merge_tables(base, measured)
        save_table(table, args.out)
        bk = current_backend()
        n = len(table["backends"][bk]["entries"])
        print(f"wrote {n} buckets for backend={bk} to {args.out} "
              f"(sections: {sorted(table['backends'])}, jax={jax.__version__})")
        return 0
    ap.print_help()
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
