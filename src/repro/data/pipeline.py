"""Deterministic synthetic LM data pipeline, host-sharded.

Determinism is the fault-tolerance contract: batch contents are a pure
function of (seed, step, global example index), so a host that is replaced
mid-run regenerates exactly its shard — no data-order drift on restart and
no stateful shuffle buffer to checkpoint. Each host materialises only its
addressable slice (``make_array_from_process_local_data``); a double-buffer
prefetch thread hides generation latency behind the step.
"""
from __future__ import annotations

import dataclasses
import queue
import threading

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    prefetch: int = 2


def _philox_tokens(cfg: DataConfig, step: int, lo: int, hi: int) -> np.ndarray:
    """Tokens for global examples [lo, hi) at ``step`` — pure function."""
    rng = np.random.Generator(np.random.Philox(
        key=cfg.seed, counter=[0, 0, step, 0]))
    # skip-ahead is per-example so hosts draw disjoint, stable streams
    all_tok = rng.integers(1, cfg.vocab, size=(cfg.global_batch,
                                               cfg.seq_len + 1),
                           dtype=np.int32)
    return all_tok[lo:hi]


class SyntheticLMPipeline:
    """Iterator of sharded {"tokens","labels"} device batches."""

    def __init__(self, cfg: DataConfig, sharding=None):
        self.cfg = cfg
        self.sharding = sharding
        self._q: queue.Queue = queue.Queue(maxsize=cfg.prefetch)
        self._step = 0
        self._thread: threading.Thread | None = None

    def host_range(self, process_index: int | None = None,
                   process_count: int | None = None) -> tuple[int, int]:
        """This host's [lo, hi) slice of the global batch.

        Remainder-aware: when ``global_batch`` is not divisible by the
        process count, the first ``global_batch % process_count`` hosts
        take one extra example, so the host slices exactly cover
        ``[0, global_batch)`` — disjoint, no example dropped or doubled.
        Pass explicit ``process_index``/``process_count`` to inspect
        another host's slice (tests simulate whole topologies this way).
        """
        n_proc = (jax.process_count() if process_count is None
                  else process_count)
        idx = (jax.process_index() if process_index is None
               else process_index)
        base, rem = divmod(self.cfg.global_batch, n_proc)
        lo = idx * base + min(idx, rem)
        return lo, lo + base + (1 if idx < rem else 0)

    def _host_range(self) -> tuple[int, int]:
        return self.host_range()

    def host_batch(self, step: int) -> dict[str, np.ndarray]:
        lo, hi = self.host_range()
        tok = _philox_tokens(self.cfg, step, lo, hi)
        return {"tokens": tok[:, :-1], "labels": tok[:, 1:]}

    def device_batch(self, step: int):
        hb = self.host_batch(step)
        if self.sharding is None:
            return {k: jax.numpy.asarray(v) for k, v in hb.items()}
        return {
            k: jax.make_array_from_process_local_data(self.sharding, v)
            for k, v in hb.items()
        }

    def __iter__(self):
        def worker():
            s = self._step
            while True:
                self._q.put((s, self.device_batch(s)))
                s += 1

        if self._thread is None:
            self._thread = threading.Thread(target=worker, daemon=True)
            self._thread.start()
        while True:
            s, b = self._q.get()
            yield s, b

    def skip_to(self, step: int) -> None:
        """Resume support: restart generation at ``step`` (pure function of
        step, so this is just a counter)."""
        if self._thread is not None:
            raise RuntimeError("skip_to must be called before iteration")
        self._step = step
