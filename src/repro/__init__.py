"""JAX/Pallas reproduction of "Accelerating Reduction and Scan Using
Tensor Core Units", grown into a small model/serving stack.

The stable public surface is :mod:`repro.ops` (the paper's ops under a
:class:`~repro.core.policy.KernelPolicy`); everything else is internal
plumbing. Both are imported lazily so ``import repro`` stays cheap.
"""
from __future__ import annotations

__all__ = ["ops"]


def __getattr__(name):
    if name == "ops":
        import repro.ops as ops

        return ops
    if name == "KernelPolicy":
        from repro.core.policy import KernelPolicy

        return KernelPolicy
    if name == "TuneSpec":
        from repro.core.policy import TuneSpec

        return TuneSpec
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
