"""Shared observability flags for the launch/bench CLIs.

Every entry point that can run hot sections takes the same three flags:

``--obs-events PATH``
    Enable observability and tee every event (resolution, kernel_invoke,
    serving, ckpt, ...) to a JSON-lines file.
``--metrics-out PATH``
    Enable observability and write the Prometheus text exposition of the
    run's metrics to PATH on exit.
``--profile-dir DIR``
    Wrap the run in a ``jax.profiler`` trace into DIR (TensorBoard /
    Perfetto viewable); the repo's hot sections are annotated via
    ``repro.obs.profiling.span``.

Any one of them activates a scoped :class:`~repro.obs.runtime.ObsSession`
for the run; with none passed the run is exactly as uninstrumented as
before (the default: observability off).
"""
from __future__ import annotations

import contextlib
from typing import Iterator

from repro.obs import profiling, runtime


def add_obs_args(ap) -> None:
    """Install the shared observability flags on an ArgumentParser."""
    ap.add_argument("--obs-events", default=None, metavar="PATH",
                    help="enable observability and append every structured "
                         "event (resolution/kernel_invoke/serving/ckpt/...) "
                         "to this JSON-lines file")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable observability and write the run's metrics "
                         "as Prometheus text to this file on exit")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="wrap the run in a jax.profiler trace written to "
                         "this directory")


@contextlib.contextmanager
def obs_scope(args) -> Iterator["runtime.ObsSession | None"]:
    """Activate observability per the CLI flags for the enclosed run.

    Yields the active :class:`~repro.obs.runtime.ObsSession`, or None when
    no observability flag was passed (the run stays uninstrumented). The
    Prometheus text file, if requested, is written when the block exits —
    after the profiler trace stops, so the export itself is not traced.
    """
    events = getattr(args, "obs_events", None)
    metrics = getattr(args, "metrics_out", None)
    profile = getattr(args, "profile_dir", None)
    if not (events or metrics or profile):
        yield None
        return
    with runtime.using_obs(events_path=events, profile_dir=profile) as sess:
        with profiling.tracing(profile):
            yield sess
        if metrics:
            sess.write_prometheus(metrics)
