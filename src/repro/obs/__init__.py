"""repro.obs — unified observability: metrics, tracing, profiling.

Off by default; scoped-enable mirrors ``using_policy``::

    from repro import obs

    with obs.using_obs(events_path="events.jsonl") as sess:
        ...                       # kernels/serving/training record here
        print(sess.prometheus_text())

Submodules:

* :mod:`repro.obs.metrics` — counters/gauges/histograms + exporters.
* :mod:`repro.obs.cli` — the shared ``--obs-events`` / ``--metrics-out`` /
  ``--profile-dir`` flags for the launch and bench CLIs.
* :mod:`repro.obs.events` — bounded event ring + JSON-lines tee; the
  resolution-event schema (:data:`RESOLUTION_FIELDS`).
* :mod:`repro.obs.runtime` — the active-session machinery
  (``enable``/``disable``/``using_obs``/``active``).
* :mod:`repro.obs.profiling` — ``jax.profiler`` trace hooks behind
  ``--profile-dir``.
"""
from repro.obs.events import (DEFAULT_RING, RESOLUTION_FIELDS, EventSink,
                              format_resolution, load_jsonl)
from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricsRegistry)
from repro.obs.profiling import span, tracing
from repro.obs.runtime import (ObsSession, active, disable, emit, enable,
                               using_obs)

__all__ = [
    "DEFAULT_BUCKETS", "DEFAULT_RING", "RESOLUTION_FIELDS",
    "Counter", "EventSink", "Gauge", "Histogram", "MetricsRegistry",
    "ObsSession", "active", "disable", "emit", "enable",
    "format_resolution", "load_jsonl", "span", "tracing", "using_obs",
]
