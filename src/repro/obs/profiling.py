"""Profiling hooks: ``jax.profiler`` traces behind one CLI flag.

``--profile-dir <dir>`` on the train/serve/bench CLIs wraps the run in
:func:`tracing`, which starts a ``jax.profiler`` trace into the directory
(viewable with TensorBoard / Perfetto). Hot sections inside the run are
annotated with :func:`span`, which is a no-op unless a trace is active —
the annotations therefore cost nothing in normal operation, same contract
as the metrics layer.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

# True while a jax.profiler trace started by tracing() is running; span()
# guards on it so annotations stay free when not profiling.
_TRACING = False


@contextlib.contextmanager
def tracing(profile_dir: str | None) -> Iterator[None]:
    """Trace the enclosed block into ``profile_dir`` (no-op when None)."""
    global _TRACING
    if not profile_dir:
        yield
        return
    import jax

    jax.profiler.start_trace(str(profile_dir))
    _TRACING = True
    try:
        yield
    finally:
        _TRACING = False
        jax.profiler.stop_trace()


@contextlib.contextmanager
def span(name: str) -> Iterator[None]:
    """Named trace annotation around a hot section (serving block step,
    train step, checkpoint snapshot). No-op unless :func:`tracing` is
    active, so call sites can annotate unconditionally."""
    if not _TRACING:
        yield
        return
    import jax

    with jax.profiler.TraceAnnotation(str(name)):
        yield
