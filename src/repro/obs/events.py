"""Structured event stream for ``repro.obs``: bounded ring + JSON-lines.

An *event* is a flat dict: ``{"kind": <str>, "ts": <unix seconds>, ...}``
plus kind-specific fields. The two kinds every tool in the repo agrees on:

``resolution``
    One ``KernelPolicy.resolve()`` call. Fields (the dispatch-audit
    schema — see :data:`RESOLUTION_FIELDS`): ``op``, ``n`` (the caller's
    bucket-axis size), ``shard_n`` (after the MeshContext division),
    ``shard_divisor``, ``dtype`` (canonical tag, e.g. ``"f32"``),
    ``backend`` (the jax host backend), ``band`` (log2 bucket),
    ``level`` (``dispatch``/``kernel``), ``explicit`` (the per-call
    ``path=`` label or None), ``chosen_path``, ``tuning`` (knob dict or
    None) and ``table_src`` (the autotune table file that supplied the
    bucket, else ``"heuristic"``/``"static"``/``"none"``).
``kernel_invoke``
    One kernel-registry execution (``backend.pallas_op``): ``op`` (the
    registry spelling), ``n``, ``dtype``, ``path``, ``tuning``.

Everything else (``serving``, ``train_step``, ``ckpt``, ...) is
free-form but follows the same flat-dict convention so one JSON-lines
file interleaves all subsystems on a shared clock.

The sink keeps a bounded in-memory ring (newest-wins, so a long serving
run cannot grow without bound — the fix for the unbounded
``ServingEngine.trace`` list) and optionally appends each event to a
JSON-lines file as it is emitted. Both paths are thread-safe.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque

# The resolution-event schema, in emission order. Exported so the CI
# schema check and the tests validate against one source of truth.
RESOLUTION_FIELDS = ("op", "n", "shard_n", "shard_divisor", "dtype",
                    "backend", "band", "level", "explicit", "chosen_path",
                    "tuning", "table_src")

DEFAULT_RING = 4096


class EventSink:
    """Bounded event ring with an optional JSON-lines tee.

    ``ring`` caps the in-memory history (oldest events drop first);
    ``jsonl_path`` appends every event as one JSON object per line. A
    non-serialisable field value is stringified rather than dropping the
    event — an audit stream must not lose records to a repr quirk.
    """

    def __init__(self, ring: int = DEFAULT_RING,
                 jsonl_path: str | None = None):
        if ring < 1:
            raise ValueError(f"event ring must be >= 1, got {ring}")
        self._lock = threading.Lock()
        self._ring: deque[dict] = deque(maxlen=int(ring))
        self._emitted = 0
        self._path = str(jsonl_path) if jsonl_path else None
        self._file = open(self._path, "a") if self._path else None

    @property
    def jsonl_path(self) -> str | None:
        return self._path

    @property
    def emitted(self) -> int:
        """Total events emitted (including any that fell off the ring)."""
        with self._lock:
            return self._emitted

    def emit(self, kind: str, **fields) -> dict:
        event = {"kind": str(kind), "ts": time.time(), **fields}
        with self._lock:
            self._emitted += 1
            self._ring.append(event)
            if self._file is not None:
                self._file.write(json.dumps(event, default=str) + "\n")
                self._file.flush()
        return event

    def events(self, kind: str | None = None) -> list[dict]:
        """The ring's current contents, oldest first (filtered by kind)."""
        with self._lock:
            evs = list(self._ring)
        if kind is None:
            return evs
        return [e for e in evs if e.get("kind") == kind]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._file.close()
                self._file = None


def load_jsonl(path: str) -> list[dict]:
    """Read a JSON-lines event file back into a list of event dicts."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out


def format_resolution(event: dict) -> str:
    """One-line human rendering of a resolution-shaped event dict.

    Shared by the JSON-lines consumers and ``python -m repro.core.autotune
    --check``'s staleness diff, so the audit trail and the CI gate speak
    the same dialect. Tolerates partial dicts (missing fields print as
    ``-``), because the --check diff renders table *entries*, which carry
    path/tuning but no live call shape.
    """
    def g(key, default="-"):
        v = event.get(key)
        return default if v is None else v

    tuning = event.get("tuning")
    tuning_s = ";".join(f"{k}={v}" for k, v in sorted(tuning.items())) \
        if isinstance(tuning, dict) and tuning else "-"
    parts = [f"op={g('op')}", f"n={g('n')}", f"dtype={g('dtype')}",
             f"band={g('band')}", f"backend={g('backend')}",
             f"level={g('level')}"]
    if event.get("shard_divisor") not in (None, 1):
        parts.append(f"shard_divisor={event['shard_divisor']}"
                     f"(shard_n={g('shard_n')})")
    parts += [f"path={g('chosen_path')}", f"tuning={tuning_s}",
              f"src={g('table_src')}"]
    return " ".join(parts)
