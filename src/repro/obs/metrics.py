"""Metrics primitives for ``repro.obs``: counters, gauges, histograms.

Design constraints (mirroring the rest of the repo's subsystems):

* **Near-zero cost when disabled.** No instrument in this module is ever
  touched unless an :class:`~repro.obs.runtime.ObsSession` is active —
  call sites guard on ``runtime.ACTIVE is not None`` (one global load)
  before constructing label tuples or reading clocks. The registry itself
  therefore optimises for correctness and auditability, not nanoseconds.
* **Thread-safe.** The serving engine, the training loop, and the
  ``AsyncCheckpointer``'s background writer all record into one registry;
  every mutation takes the registry lock. Snapshots are consistent.
* **Fixed bucket edges.** Histograms use explicit, immutable bucket
  uppers (Prometheus ``le`` semantics: cumulative counts of observations
  ``<= edge``, with a ``+Inf`` bucket always present), so two runs of the
  same binary export comparable series and the regression gate can diff
  them structurally.

Exporters: :meth:`MetricsRegistry.prometheus_text` renders the standard
Prometheus text exposition format; :meth:`MetricsRegistry.snapshot`
returns plain dicts for the JSON-lines exporter in ``repro.obs.events``.
"""
from __future__ import annotations

import threading
from typing import Iterable, Mapping

# Latency-shaped default edges (seconds): sub-millisecond ticks on a warm
# CPU host through multi-second cold compiles all land in a real bucket.
DEFAULT_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
                   0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _label_key(labels: Mapping[str, object]) -> tuple:
    """Normalise a label mapping to a hashable, sorted series key."""
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _label_str(key: tuple) -> str:
    """Prometheus label block for one series key (empty string when the
    series is unlabelled)."""
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class _Instrument:
    """Shared per-metric state: name, help text, per-series values."""

    kind = "untyped"

    def __init__(self, name: str, help: str, lock: threading.Lock):
        self.name = name
        self.help = help
        self._lock = lock
        self._series: dict[tuple, object] = {}

    def series(self) -> dict[tuple, object]:
        with self._lock:
            return dict(self._series)


class Counter(_Instrument):
    """Monotonically increasing count, optionally labelled."""

    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name}: negative inc {amount}")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(_label_key(labels), 0)


class Gauge(_Instrument):
    """Last-set value, optionally labelled."""

    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def value(self, **labels) -> float | None:
        with self._lock:
            return self._series.get(_label_key(labels))


class Histogram(_Instrument):
    """Fixed-bucket histogram (Prometheus ``le`` semantics).

    Each series holds cumulative bucket counts for the configured edges
    plus the implicit ``+Inf`` bucket, and running ``sum``/``count`` so
    mean latencies and phase-time totals are recoverable exactly.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, lock: threading.Lock,
                 buckets: Iterable[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, lock)
        edges = tuple(sorted(float(e) for e in buckets))
        if not edges:
            raise ValueError(f"histogram {self.name}: no bucket edges")
        if len(set(edges)) != len(edges):
            raise ValueError(f"histogram {name}: duplicate bucket edges")
        self.buckets = edges

    def observe(self, value: float, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            s = self._series.get(key)
            if s is None:
                s = {"counts": [0] * (len(self.buckets) + 1),
                     "sum": 0.0, "count": 0}
                self._series[key] = s
            for i, edge in enumerate(self.buckets):
                if value <= edge:
                    s["counts"][i] += 1
                    break
            else:
                s["counts"][-1] += 1           # +Inf bucket
            s["sum"] += float(value)
            s["count"] += 1

    def stats(self, **labels) -> dict | None:
        """``{"sum", "count", "counts"}`` for one series (None if never
        observed). ``counts`` are per-bucket (non-cumulative) in edge
        order with the ``+Inf`` bucket last."""
        with self._lock:
            s = self._series.get(_label_key(labels))
            return None if s is None else {"sum": s["sum"],
                                           "count": s["count"],
                                           "counts": list(s["counts"])}


class MetricsRegistry:
    """One process-wide family of named instruments.

    ``counter()``/``gauge()``/``histogram()`` are get-or-create: the first
    call fixes the kind (and a histogram's bucket edges); a later call
    under the same name with a different kind raises — a silently forked
    metric is exactly the failure mode an observability layer must not
    have.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict[str, _Instrument] = {}

    def _get(self, cls, name: str, help: str, **kw) -> _Instrument:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, self._lock, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}, "
                    f"requested {cls.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> _Instrument | None:
        with self._lock:
            return self._metrics.get(name)

    def names(self) -> list[str]:
        with self._lock:
            return sorted(self._metrics)

    # -- exporters ----------------------------------------------------------

    def snapshot(self) -> dict:
        """Plain-dict dump of every metric (JSON-serialisable)."""
        out: dict[str, dict] = {}
        with self._lock:
            metrics = dict(self._metrics)
        for name, m in sorted(metrics.items()):
            entry: dict = {"kind": m.kind, "help": m.help, "series": []}
            if isinstance(m, Histogram):
                entry["buckets"] = list(m.buckets)
            for key, val in sorted(m.series().items()):
                labels = dict(key)
                if isinstance(m, Histogram):
                    entry["series"].append(
                        {"labels": labels, "sum": val["sum"],
                         "count": val["count"],
                         "counts": list(val["counts"])})
                else:
                    entry["series"].append({"labels": labels, "value": val})
            out[name] = entry
        return out

    def prometheus_text(self) -> str:
        """Standard Prometheus text exposition of every metric."""
        lines: list[str] = []
        with self._lock:
            metrics = dict(self._metrics)
        for name, m in sorted(metrics.items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, val in sorted(m.series().items()):
                if isinstance(m, Histogram):
                    cum = 0
                    for edge, c in zip(m.buckets, val["counts"]):
                        cum += c
                        lkey = key + (("le", _fmt(edge)),)
                        lines.append(
                            f"{name}_bucket{_label_str(lkey)} {cum}")
                    cum += val["counts"][-1]
                    lkey = key + (("le", "+Inf"),)
                    lines.append(f"{name}_bucket{_label_str(lkey)} {cum}")
                    lines.append(
                        f"{name}_sum{_label_str(key)} {_fmt(val['sum'])}")
                    lines.append(
                        f"{name}_count{_label_str(key)} {val['count']}")
                else:
                    lines.append(f"{name}{_label_str(key)} {_fmt(val)}")
        return "\n".join(lines) + ("\n" if lines else "")


def _fmt(v) -> str:
    """Compact numeric rendering (ints stay ints; floats use repr)."""
    f = float(v)
    return str(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)
