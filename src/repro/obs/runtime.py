"""The active observability session — ``repro.obs``'s ``get_policy``.

Observability is **off by default** and scoped-enable, mirroring the
kernel-policy layer's ``using_policy``: nothing in the repo records a
metric or emits an event unless a session is active, and the hot-path
check is a single module-global load (``runtime.ACTIVE is not None``) so
the disabled path adds no measurable work to ``KernelPolicy.resolve()``
or the serving tick loop.

Unlike the policy layer, the active session is a *process* global, not a
context-var: the instrumented subsystems span threads the enabling frame
never sees (the serving engine's caller, the ``AsyncCheckpointer``'s
background writer, jit tracing), and a per-context session would silently
lose exactly those records. ``using_obs`` still nests — it saves and
restores the previous session — it just isn't thread-local.

Typical use::

    from repro import obs

    with obs.using_obs(events_path="events.jsonl") as sess:
        engine.run(requests)
        print(sess.metrics.prometheus_text())
        for e in sess.events.events("resolution"):
            print(obs.format_resolution(e))
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator

from repro.obs.events import DEFAULT_RING, EventSink
from repro.obs.metrics import MetricsRegistry

# THE hot-path flag: instrumented call sites guard on ``ACTIVE is not
# None`` before doing any observability work. Assigned only under _LOCK.
ACTIVE: "ObsSession | None" = None

_LOCK = threading.Lock()


class ObsSession:
    """One observability scope: a metrics registry + an event sink.

    ``events_path`` tees every event to a JSON-lines file; ``ring`` caps
    the in-memory event history; ``profile_dir`` is carried for the
    profiling hooks (``repro.obs.profiling``) so one flag threads through
    the CLIs.
    """

    def __init__(self, *, events_path: str | None = None,
                 ring: int = DEFAULT_RING,
                 profile_dir: str | None = None):
        self.metrics = MetricsRegistry()
        self.events = EventSink(ring=ring, jsonl_path=events_path)
        self.profile_dir = profile_dir

    # -- convenience passthroughs ------------------------------------------

    def emit(self, kind: str, **fields) -> dict:
        return self.events.emit(kind, **fields)

    def counter(self, name: str, help: str = ""):
        return self.metrics.counter(name, help)

    def gauge(self, name: str, help: str = ""):
        return self.metrics.gauge(name, help)

    def histogram(self, name: str, help: str = "", **kw):
        return self.metrics.histogram(name, help, **kw)

    def prometheus_text(self) -> str:
        return self.metrics.prometheus_text()

    def write_prometheus(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.metrics.prometheus_text())

    def close(self) -> None:
        self.events.close()


def active() -> ObsSession | None:
    """The active session, or None (the default: observability off)."""
    return ACTIVE


def enable(session: ObsSession | None = None, **kw) -> ObsSession:
    """Install ``session`` (or a fresh one built from ``kw``) as the
    active session and return it. Prefer the scoped :func:`using_obs`
    unless the session should outlive the frame."""
    global ACTIVE
    sess = session if session is not None else ObsSession(**kw)
    with _LOCK:
        ACTIVE = sess
    return sess


def disable() -> None:
    """Deactivate observability (the active session, if any, is left
    intact for post-hoc reads — only emission stops)."""
    global ACTIVE
    with _LOCK:
        ACTIVE = None


@contextlib.contextmanager
def using_obs(session: ObsSession | None = None,
              **kw) -> Iterator[ObsSession]:
    """Scoped observability: activate a session, restore the previous one
    (usually None) on exit. The session's JSON-lines file, if any, is
    closed on exit; its in-memory metrics/events stay readable."""
    global ACTIVE
    sess = session if session is not None else ObsSession(**kw)
    with _LOCK:
        prev, ACTIVE = ACTIVE, sess
    try:
        yield sess
    finally:
        with _LOCK:
            ACTIVE = prev
        if session is None:       # we own the sink: release the file
            sess.close()


def emit(kind: str, **fields) -> dict | None:
    """Emit one event into the active session (no-op when disabled)."""
    sess = ACTIVE
    return None if sess is None else sess.emit(kind, **fields)
