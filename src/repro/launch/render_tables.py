"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts, replacing the <!-- DRYRUN_TABLE --> and
<!-- ROOFLINE_TABLE --> markers in place.

  PYTHONPATH=src python -m repro.launch.render_tables
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..",
                                    ".."))
ART = os.path.join(ROOT, "artifacts", "dryrun")

LEVERS = {
    "compute_s": "fewer remat dots / bigger fused tiles",
    "memory_s": "fuse attention/SSD chains (Pallas kernels), bf16 "
                "intermediates",
    "collective_s": "reduce-scatter forms, overlap FSDP gathers, trim "
                    "replicated KV",
}


def _load(mesh):
    out = {}
    for p in sorted(glob.glob(os.path.join(ART, mesh, "*.json"))):
        r = json.load(open(p))
        out[(r["arch"], r["shape"])] = r
    return out


def dryrun_table() -> str:
    single, multi = _load("single"), _load("multi")
    lines = [
        "| arch | shape | kind | peak GiB/chip (256c) | peak GiB/chip "
        "(512c) | compile s | HLO flops/chip | collective B/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(single):
        s, m = single[key], multi.get(key)
        h = s["hlo_analysis"]
        lines.append(
            f"| {key[0]} | {key[1]} | "
            f"{'train' if key[1].startswith('train') else 'serve'} | "
            f"{s['memory']['peak_bytes'] / 2**30:.2f} | "
            f"{(m['memory']['peak_bytes'] / 2**30):.2f} | "
            f"{s['compile_s']:.0f} | {h['flops']:.3g} | "
            f"{h['collective_total']:.3g} |")
    import importlib

    from repro import configs as cfgs
    skips = []
    for arch in cfgs.all_arch_ids():
        mod = cfgs.get(arch)
        for shape, why in mod.SKIPS.items():
            skips.append(f"| {arch} | {shape} | skipped | {why} |")
    lines.append("")
    lines.append(f"{len(single)} cells x 2 meshes compiled. Skipped cells "
                 "(with reasons):")
    lines.append("")
    lines.append("| arch | shape | status | reason |")
    lines.append("|---|---|---|---|")
    lines.extend(skips)
    return "\n".join(lines)


def roofline_table() -> str:
    single = _load("single")
    lines = [
        "| arch | shape | compute_s | memory_s | collective_s | dominant | "
        "MODEL/HLO flops | roofline frac | lever on dominant |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    worst = []
    doms = {}
    for key in sorted(single):
        r = single[key]
        t = r["roofline"]
        mf_s = r["model_flops_per_chip"] / 197e12
        frac = mf_s / max(t["bound_s"], 1e-30)
        doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
        worst.append((frac, key))
        lines.append(
            f"| {key[0]} | {key[1]} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"{t['dominant'].replace('_s', '')} | "
            f"{r['useful_flops_ratio']:.2f} | {frac:.4f} | "
            f"{LEVERS[t['dominant']]} |")
    worst.sort()
    lines.append("")
    lines.append(f"Dominant-term histogram: "
                 + ", ".join(f"{k.replace('_s','')}: {v}"
                             for k, v in sorted(doms.items())))
    lines.append("")
    lines.append("Worst roofline fractions (hillclimb candidates): "
                 + "; ".join(f"{a}×{s} ({f:.4f})"
                             for f, (a, s) in worst[:4]))
    return "\n".join(lines)


def _splice(text: str, tag: str, body: str) -> str:
    """Replace <!-- TAG --> or an existing BEGIN/END TAG region."""
    import re as _re

    begin, end = f"<!-- BEGIN {tag} -->", f"<!-- END {tag} -->"
    wrapped = f"{begin}\n{body}\n{end}"
    if begin in text:
        return _re.sub(_re.escape(begin) + r".*?" + _re.escape(end),
                       wrapped, text, flags=_re.S)
    return text.replace(f"<!-- {tag} -->", wrapped)


def main() -> None:
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = _splice(text, "DRYRUN_TABLE", dryrun_table())
    text = _splice(text, "ROOFLINE_TABLE", roofline_table())
    open(path, "w").write(text)
    print("EXPERIMENTS.md tables rendered "
          f"({len(_load('single'))} single-pod cells).")


if __name__ == "__main__":
    main()
