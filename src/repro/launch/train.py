"""Training driver: data-parallel + TP training with checkpoint/restart,
straggler reporting, and deterministic resume.

This is the end-to-end path the fault-tolerance story hangs off:

  * periodic checkpointing (step- and wall-clock-triggered) with atomic
    commit (checkpoint/ckpt.py);
  * ``--resume auto`` restores the latest valid manifest and re-places it
    under the *current* mesh's shardings — elastic restarts across
    different chip counts;
  * per-step wall time is logged; steps slower than ``straggler_factor x``
    the running median are flagged (on a multi-host cluster this feeds the
    host-replacement loop);
  * data order is a pure function of (seed, step), so replacing a host
    never drifts the global batch (data/pipeline.py).

On this CPU container it runs the reduced smoke configs; on a real cluster
the same file runs the FULL configs (the mesh/rules scale with
``jax.device_count()``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.configs.common import SMOKE_BATCH, SMOKE_SEQ
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.models import build
from repro.obs import cli as obs_cli
from repro.obs import profiling as _prof
from repro.optim import OptConfig
from repro.parallel.mesh_context import MeshContext, make_context
from repro.training import TrainConfig, init_train_state, make_train_step


def build_mesh_context(tp: int, mesh_arg: str | None = None) -> MeshContext:
    """The training MeshContext: ``--mesh data=2,model=2`` wins; otherwise
    the legacy ``--tp`` split of whatever devices exist."""
    if mesh_arg:
        return make_context(mesh_arg)
    dp = jax.device_count() // tp
    return make_context((("data", dp), ("model", tp)))


def build_mesh_and_rules(tp: int):
    """Deprecated spelling of :func:`build_mesh_context` (kept for older
    scripts); returns the context's (mesh, rules) pair."""
    ctx = build_mesh_context(tp)
    return ctx.mesh, ctx.rules


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--config", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=SMOKE_BATCH * 2)
    ap.add_argument("--seq", type=int, default=SMOKE_SEQ)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--mesh", default=None,
                    help="mesh axes as 'data=2,model=2' (multiplies to the "
                         "global device count); overrides --tp")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--ckpt-every-s", type=float, default=600.0)
    ap.add_argument("--keep-last", type=int, default=3,
                    help="committed checkpoints to keep (0 keeps all)")
    ap.add_argument("--resume", choices=("auto", "none"), default="auto")
    ap.add_argument("--straggler-factor", type=float, default=1.5)
    ap.add_argument("--straggler-report", default=None,
                    help="jsonl path for per-step timing records")
    ap.add_argument("--log-every", type=int, default=10)
    from repro.core import dispatch
    from repro.core import policy as kpolicy

    ap.add_argument("--policy", default=None,
                    help="KernelPolicy for the model's core ops and the "
                         "optimizer's global-norm reduce: a path label, "
                         "an op=path,op=path override list, or a JSON "
                         "object of policy fields")
    ap.add_argument("--tune", default=None,
                    help="per-op kernel tuning overrides layered on the "
                         "policy: op.knob=value pairs, e.g. "
                         "'ssd.q=64,attention.block_q=256'")
    ap.add_argument("--kernel-path", default=None, choices=dispatch.PATHS,
                    help="deprecated alias for --policy <path-label>")
    obs_cli.add_obs_args(ap)
    args = ap.parse_args()

    pol = kpolicy.policy_from_cli(args.policy, args.kernel_path,
                                  "deprecated:launch.train.kernel_path",
                                  tune_arg=args.tune)

    with obs_cli.obs_scope(args) as obs_sess:
        run(args, pol, obs_sess)


def run(args, pol, obs_sess=None) -> None:
    mod = configs.get(args.arch)
    cfg = mod.SMOKE if args.config == "smoke" else mod.FULL
    if pol is not None:
        cfg = dataclasses.replace(cfg, policy=pol)
    bundle = build(cfg)
    mesh_ctx = build_mesh_context(args.tp, args.mesh)
    opt_cfg = OptConfig(peak_lr=args.lr, warmup_steps=min(20, args.steps),
                        decay_steps=args.steps, policy=pol)
    train_cfg = TrainConfig(microbatches=args.microbatches)
    ckpt_writer = ckpt.AsyncCheckpointer(
        args.ckpt_dir, keep_last=args.keep_last or None)

    with mesh_ctx:
        state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg,
                                 train_cfg)
        step_fn = jax.jit(
            make_train_step(bundle, opt_cfg, train_cfg, mesh_ctx=mesh_ctx),
            donate_argnums=(0,))

        start = 0
        if args.resume == "auto":
            latest = ckpt.latest_step(args.ckpt_dir)
            if latest is not None:
                from repro.training import train_state_pspecs

                specs = train_state_pspecs(bundle, mesh_ctx.rules,
                                           train_cfg)
                shardings = jax.tree.map(mesh_ctx.named_sharding, specs)
                state = ckpt.restore(args.ckpt_dir, latest, state,
                                     shardings=shardings)
                start = latest
                print(f"resumed from step {latest}")

        data = SyntheticLMPipeline(DataConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch))
        data.skip_to(start)

        times: list[float] = []
        last_ckpt_t = time.time()
        for step in range(start, args.steps):
            batch = data.device_batch(step)
            if cfg.stub_tokens:
                batch["stub"] = jnp.zeros(
                    (args.batch, cfg.stub_tokens, cfg.stub_dim), cfg.dtype)
            if cfg.family == "encdec":
                batch = {"frames": jnp.zeros(
                    (args.batch, args.seq, cfg.d_model), cfg.dtype),
                    "tokens": batch["tokens"], "labels": batch["labels"]}
            t0 = time.time()
            with _prof.span("train/step"):
                state, metrics = step_fn(state, batch)
                jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            times.append(dt)
            if obs_sess is not None:
                obs_sess.histogram(
                    "repro_train_step_seconds",
                    "optimizer step wall time").observe(dt)
                obs_sess.gauge(
                    "repro_train_tokens_per_s",
                    "training throughput at the last step").set(
                    args.batch * args.seq / max(dt, 1e-9))
                obs_sess.emit("train_step", step=step, seconds=dt,
                              loss=float(metrics["loss"]))

            med = float(np.median(times[-50:]))
            straggle = len(times) > 5 and dt > args.straggler_factor * med
            if args.straggler_report:
                with open(args.straggler_report, "a") as f:
                    f.write(json.dumps({"step": step, "dt": dt,
                                        "median": med,
                                        "straggler": straggle}) + "\n")
            if straggle:
                print(f"[straggler] step {step}: {dt:.3f}s vs median "
                      f"{med:.3f}s")
            if step % args.log_every == 0 or step == args.steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:.3f}s")

            due_steps = (step + 1) % args.ckpt_every == 0
            due_time = time.time() - last_ckpt_t > args.ckpt_every_s
            if due_steps or due_time or step == args.steps - 1:
                # async: snapshots now, writes in the background; the next
                # save (or the final wait below) is the commit barrier
                ckpt_writer.save(step + 1, state)
                last_ckpt_t = time.time()
                print(f"checkpoint scheduled @ step {step + 1}")

        path = ckpt_writer.wait()
        print(f"checkpointed -> {path}")

    print(f"done: {args.steps - start} steps, "
          f"median step {np.median(times):.3f}s")


if __name__ == "__main__":
    main()
