"""Production meshes and the logical-axis rule tables for each.

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state — the dry-run sets
``xla_force_host_platform_device_count`` *before* any jax init and only then
calls in here.

Mesh axes:
  single-pod : (data=16, model=16)            = 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips; ``pod`` is an
               outer data-parallel axis whose gradient all-reduce crosses
               the DCN (slow link — see optim/compress.py).

The rule tables map the model code's logical dim names onto mesh axes.
Divisibility degradation (kv_heads=4 on a 16-way axis -> replicate) is
handled inside ``spec_for``; the table just states intent.
"""
from __future__ import annotations

import jax

from repro.parallel.compat import make_mesh
from repro.parallel.mesh_context import MeshContext
from repro.parallel.sharding import Rules

# Hardware constants (TPU v5e) used by the roofline analyser.
PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW = 50e9                     # bytes/s per link (~per axis direction)
VMEM_BYTES = 16 * 2 ** 20
HBM_BYTES = 16 * 2 ** 30


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_production_context(*, multi_pod: bool = False, fsdp: bool = True,
                            seq_shard: bool = False,
                            op_shard_axes=()) -> MeshContext:
    """The production mesh + rules as one activatable MeshContext."""
    mesh = make_production_mesh(multi_pod=multi_pod)
    return MeshContext(mesh=mesh,
                       rules=make_rules(mesh, fsdp=fsdp,
                                        seq_shard=seq_shard),
                       op_shard_axes=op_shard_axes)


def make_rules(mesh: jax.sharding.Mesh, *, fsdp: bool = True,
               seq_shard: bool = False) -> Rules:
    """Logical-name -> mesh-axis table for a production mesh.

    ``seq_shard`` additionally shards long sequence/cache dims over ``data``
    (sequence parallelism — the long_500k decode cells, where batch=1 leaves
    the data axis otherwise idle).
    """
    multi_pod = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    table = {
        # activations
        "batch": batch_axes,
        "vocab": "model",
        # attention params
        "heads": "model",
        "kv_heads": "model",
        # mlp / moe params
        "ff": "model",
        "e_ff": "model",
        "experts": "model",
        # MoE dispatch groups ride the batch axes (grouped dispatch keeps
        # all routing scatter/gather shard-local; see layers.py). The flat
        # (expert x capacity) slot dim rides the model axis. exp_cap
        # catches the residual data-axis sharding for the global impl.
        "moe_groups": batch_axes,
        "exp_slots": "model",
        "exp_cap": "data",
        # mamba params
        "inner": "model",
        "inner_all": "model",
        "ssm_heads": "model",
        # never TP-shard the residual width or the layer stack
        "embed": None,
        "layers": None,
        # decode cells shard the KV-cache sequence dim; spec_for drops any
        # axis already consumed by the tensor's batch dim, so this resolves
        # to "model" when batch occupies "data" (decode_32k) and to both
        # axes when batch=1 replicates (long_500k).
        "kv_seq": ("data", "model") if seq_shard else None,
    }
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return Rules(table=table, fsdp="data" if fsdp else None,
                 axis_sizes=sizes)


def make_smoke_mesh(n: int = 1) -> jax.sharding.Mesh:
    """Tiny mesh over whatever devices exist (tests / CPU examples)."""
    devs = jax.devices()[:n]
    import numpy as np

    return jax.sharding.Mesh(np.array(devs).reshape(-1), ("data",))
