"""Serving driver: batched generation with the continuous-batching engine
(or the wave baseline via --scheduler wave).

CPU demo: reduced configs, randomly initialised weights (or a checkpoint
produced by launch/train.py via --ckpt-dir) — the point is the serving
path: chunked prefill interleaved with decode over a ring KV cache, with
the model's softmax/RMSNorm/SSD all routing through the matmul-form
primitives. --arrival-rate spreads the synthetic requests as open-loop
Poisson arrivals instead of presenting them all at once.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.checkpoint import ckpt
from repro.models import build
from repro.models.common import init_params
from repro.obs import cli as obs_cli
from repro.serving import Request, ServeConfig, ServingEngine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--config", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--scheduler", choices=("continuous", "wave"),
                    default="continuous",
                    help="continuous batching (per-slot admission, ring "
                         "KV cache, chunked prefill) or the wave baseline")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens a prefilling slot consumes per "
                         "tick (continuous scheduler)")
    ap.add_argument("--cache", choices=("ring", "paged"), default="ring",
                    help="KV-cache layout (continuous scheduler): per-slot "
                         "ring buffers, or the paged block-table pool with "
                         "prompt-prefix sharing and copy-on-write")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="paged cache: total KV pool pages (default "
                         "(slots+1) x pages-per-slot)")
    ap.add_argument("--page-rows", type=int, default=None,
                    help="paged cache: rows per page — a power-of-two "
                         "multiple of the sublane tile (default "
                         "kernels/layout.KV_PAGE_ROWS)")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrival rate in requests/s "
                         "(0: all requests available immediately)")
    ap.add_argument("--seed", type=int, default=0,
                    help="sampling RNG seed (and synthetic request seed)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--mesh", default=None,
                    help="mesh axes as 'data=1,model=2' (multiplies to the "
                         "global device count): serve sharded — the ring "
                         "KV cache splits over the model axis and the "
                         "kernel policy resolves per-shard TuneSpecs")
    from repro.core import dispatch
    from repro.core import policy as kpolicy

    ap.add_argument("--policy", default=None,
                    help="KernelPolicy for every core op in the served "
                         "model: a path label, an op=path,op=path override "
                         "list, or a JSON object of policy fields "
                         "(default: the active policy)")
    ap.add_argument("--tune", default=None,
                    help="per-op kernel tuning overrides layered on the "
                         "policy: op.knob=value pairs, e.g. "
                         "'ssd.q=64,attention.block_q=256'")
    ap.add_argument("--kernel-path", default=None, choices=dispatch.PATHS,
                    help="deprecated alias for --policy <path-label>")
    obs_cli.add_obs_args(ap)
    args = ap.parse_args()

    pol = kpolicy.policy_from_cli(args.policy, args.kernel_path,
                                  "deprecated:launch.serve.kernel_path",
                                  tune_arg=args.tune)

    with obs_cli.obs_scope(args):
        run(args, pol)


def run(args, pol) -> None:
    mod = configs.get(args.arch)
    cfg = mod.SMOKE if args.config == "smoke" else mod.FULL
    bundle = build(cfg)
    mesh_ctx = None
    if args.mesh:
        from repro.parallel.mesh_context import make_context

        mesh_ctx = make_context(args.mesh)
        print(f"serving sharded over mesh {mesh_ctx.label()}")
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         cfg.dtype)
    if mesh_ctx is not None:
        from repro.models.common import partition_specs

        specs = partition_specs(bundle.params_pspec, rules=mesh_ctx.rules,
                                fsdp_ok=False)
        shardings = jax.tree.map(mesh_ctx.named_sharding, specs)
        params = jax.tree.map(jax.device_put, params, shardings)
    if args.ckpt_dir:
        latest = ckpt.latest_step(args.ckpt_dir)
        if latest is not None:
            state = ckpt.restore(
                args.ckpt_dir, latest, {"params": params},
                shardings=None if mesh_ctx is None
                else {"params": shardings})
            params = state["params"]
            print(f"loaded checkpoint step {latest}")

    engine = ServingEngine(bundle, params, ServeConfig(
        slots=args.slots, max_new=args.max_new, policy=pol,
        scheduler=args.scheduler, prefill_chunk=args.prefill_chunk,
        cache_kind=args.cache, pool_pages=args.pool_pages,
        page_rows=args.page_rows,
        seed=args.seed), mesh_ctx=mesh_ctx)
    rng = np.random.default_rng(args.seed)
    arrival = 0.0
    reqs = []
    for i in range(args.requests):
        if args.arrival_rate > 0:
            arrival += float(rng.exponential(1.0 / args.arrival_rate))
        reqs.append(Request(uid=i, prompt=rng.integers(
            3, cfg.vocab, size=rng.integers(4, args.prompt_len + 1),
            dtype=np.int32), arrival_s=arrival))

    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    n_tok = sum(len(r.tokens) for r in results)
    for r in results[:4]:
        print(f"req {r.uid}: prompt_len={r.prompt_len} -> "
              f"{len(r.tokens)} tokens: {r.tokens[:12]}")
    print(f"{len(results)} requests, {n_tok} tokens in {dt:.2f}s "
          f"({n_tok / max(dt, 1e-9):.1f} tok/s, "
          f"scheduler={engine.scheduler})")
    kv = engine.kv_stats()
    if kv is not None:
        print(f"paged KV pool: peak {kv.get('peak_pages_in_use', 0)}/"
              f"{kv.get('pages_total', 0)} pages, "
              f"{kv['shared_tokens']} prompt tokens prefix-shared, "
              f"{kv['cow_copies']} CoW copies, {kv['defers']} admissions "
              "deferred")
    if args.arrival_rate > 0:
        lats = [1e3 * (ts - r.arrival_s)
                for r in results for ts in r.token_s]
        if lats:
            print(f"open loop @ {args.arrival_rate:.1f} req/s: token "
                  f"latency p50={np.percentile(lats, 50):.1f}ms "
                  f"p99={np.percentile(lats, 99):.1f}ms")


if __name__ == "__main__":
    main()
