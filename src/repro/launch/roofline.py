"""Roofline report: aggregate the dry-run artifacts into the §Roofline table.

Reads ``artifacts/dryrun/single/*.json`` (the roofline table is single-pod
per the brief; multi-pod artifacts prove the pod axis shards) and emits a
markdown table with, per (arch x shape):

  compute_s    = HLO_FLOPs / (chips x 197 TFLOP/s)      [per-chip form]
  memory_s     = HLO_bytes / (chips x 819 GB/s)
  collective_s = collective_bytes / (chips x 50 GB/s)
  dominant term, MODEL_FLOPS/HLO_FLOPs ratio, and a one-line lever.

All three terms are computed from per-chip quantities (the SPMD module is
the per-device program), which is numerically identical to the brief's
global-quantity / (chips x peak) form.
"""
from __future__ import annotations

import argparse
import glob
import json
import os

LEVERS = {
    "compute_s": "raise MXU occupancy: fewer rematerialised dots, "
                 "larger fused matmul tiles",
    "memory_s": "cut HBM traffic: fuse attention softmax chain (Pallas "
                "flash kernel), bf16 intermediates, wider fusion",
    "collective_s": "cut collective bytes: reduce-scatter instead of "
                    "all-reduce+slice, overlap FSDP gathers, shrink "
                    "replicated KV/router traffic",
}


def load_records(art_dir: str, mesh: str = "single") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(art_dir, mesh, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(rec: dict) -> str:
    r = rec["roofline"]
    h = rec["hlo_analysis"]
    ratio = rec["useful_flops_ratio"]
    frac = {
        k: r[k] / max(r["bound_s"], 1e-30)
        for k in ("compute_s", "memory_s", "collective_s")
    }
    # roofline fraction: useful model compute time / bound time
    mf_s = rec["model_flops_per_chip"] / 197e12
    roofline_frac = mf_s / max(r["bound_s"], 1e-30)
    return (f"| {rec['arch']} | {rec['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant'].replace('_s', '')} | {ratio:.2f} | "
            f"{roofline_frac:.3f} |")


def main() -> None:
    ap = argparse.ArgumentParser()
    default_art = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "artifacts", "dryrun"))
    ap.add_argument("--artifacts", default=default_art)
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()

    recs = [r for r in load_records(args.artifacts, args.mesh)
            if r.get("status") == "ok"]
    print("| arch | shape | compute_s | memory_s | collective_s | dominant "
          "| MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for rec in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        print(fmt_row(rec))
    print()
    doms: dict[str, int] = {}
    worst = sorted(
        recs, key=lambda r: (r["model_flops_per_chip"] / 197e12)
        / max(r["roofline"]["bound_s"], 1e-30))
    for rec in recs:
        doms[rec["roofline"]["dominant"]] = doms.get(
            rec["roofline"]["dominant"], 0) + 1
    print(f"dominant-term histogram: {doms}")
    if worst:
        print("worst roofline fractions:")
        for rec in worst[:5]:
            r = rec["roofline"]
            mf_s = rec["model_flops_per_chip"] / 197e12
            print(f"  {rec['arch']:24s} {rec['shape']:12s} "
                  f"frac={mf_s / max(r['bound_s'], 1e-30):.4f} "
                  f"dom={r['dominant']} lever: {LEVERS[r['dominant']]}")


if __name__ == "__main__":
    main()
