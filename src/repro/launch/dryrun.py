import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder host devices stand in for 2 pods x 256 chips, the
full-size models are lowered from ``ShapeDtypeStruct`` stand-ins (zero
allocation), and a successful ``.compile()`` means GSPMD found a valid
collective schedule for every tensor in the program.

Per cell we record into ``artifacts/dryrun/<mesh>/<arch>__<shape>.json``:
  * memory_analysis()  -- per-chip argument/output/temp/peak bytes
  * cost_analysis()    -- XLA's own flops / bytes-accessed (loop bodies
                          counted once; see hlo_analysis for the fix)
  * hlo_analysis       -- loop-aware flops / HBM traffic / collective bytes
  * model_flops        -- 6 N D analytic (N_active for MoE)

Usage:
  python -m repro.launch.dryrun --all                 # every cell, 2 meshes
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all --mesh single   # roofline table pass
"""
import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.common import SHAPE_TABLE, make_cell
from repro.launch import mesh as mesh_lib
from repro.launch.hlo_analysis import analyse, roofline_terms
from repro.models import build
from repro.models.common import partition_specs, shape_structs
from repro.optim import OptConfig
from repro.parallel.sharding import spec_for, use_rules
from repro.training import (
    TrainConfig,
    make_serve_step,
    make_train_step,
)
from repro.training.train_lib import state_shape_structs, train_state_pspecs

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _opt_cfg(mod) -> OptConfig:
    import jax.numpy as jnp

    dt = jnp.bfloat16 if mod.OPT_STATE_DTYPE == "bfloat16" else jnp.float32
    return OptConfig(state_dtype=dt)


def _train_cfg(mod, microbatches: int = 1) -> TrainConfig:
    return TrainConfig(microbatches=microbatches,
                       optimizer=getattr(mod, "OPTIMIZER", "adamw"))


def _named(tree_specs, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape: str, mesh, rules, *,
               microbatches: int = 1):
    """-> (lowered, cell_info). Raises on sharding errors."""
    mod = configs.get(arch)
    cfg = mod.FULL
    bundle = build(cfg)
    cell = make_cell(cfg, shape)
    opt_cfg = _opt_cfg(mod)

    with use_rules(rules):
        batch_specs = {
            k: spec_for(cell.batch_specs[k].shape, cell.batch_logical[k],
                        rules=rules)
            for k in cell.batch_specs
        }
        batch_shardings = {k: NamedSharding(mesh, s)
                           for k, s in batch_specs.items()}

        if cell.kind == "train":
            tc = _train_cfg(mod, microbatches)
            step = make_train_step(bundle, opt_cfg, tc)
            state_sds = state_shape_structs(bundle, opt_cfg, tc)
            state_specs = train_state_pspecs(bundle, rules, tc)
            state_shardings = _named(state_specs, mesh)
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(state_shardings, batch_shardings),
                    out_shardings=(state_shardings, None),
                ).lower(state_sds, cell.batch_specs)
        elif cell.kind == "prefill":
            prefill_step, _ = make_serve_step(bundle)
            params_sds = shape_structs(bundle.params_pspec, cfg.dtype)
            params_specs = partition_specs(bundle.params_pspec, rules=rules,
                                           fsdp_ok=True)
            params_shardings = _named(params_specs, mesh)
            # pin the produced cache to the decode-side layout (seq-sharded)
            cache_pspec = bundle.cache_pspec(cell.batch, cell.seq)
            cache_specs = partition_specs(cache_pspec, rules=rules)
            cache_shardings = _named(cache_specs, mesh)
            with mesh:
                lowered = jax.jit(
                    prefill_step,
                    in_shardings=(params_shardings, batch_shardings),
                    out_shardings=(None, cache_shardings),
                ).lower(params_sds, cell.batch_specs)
        else:  # decode
            _, decode_step = make_serve_step(bundle)
            params_sds = shape_structs(bundle.params_pspec, cfg.dtype)
            params_specs = partition_specs(bundle.params_pspec, rules=rules,
                                           fsdp_ok=True)
            params_shardings = _named(params_specs, mesh)
            cache_pspec = bundle.cache_pspec(cell.cache_batch, cell.cache_len)
            cache_sds = shape_structs(cache_pspec, cfg.dtype)
            cache_specs = partition_specs(cache_pspec, rules=rules)
            cache_shardings = _named(cache_specs, mesh)
            with mesh:
                lowered = jax.jit(
                    decode_step,
                    in_shardings=(params_shardings, cache_shardings,
                                  batch_shardings),
                    out_shardings=(None, cache_shardings),
                ).lower(params_sds, cache_sds, cell.batch_specs)
    return lowered, {"bundle": bundle, "cell": cell}


def model_flops(bundle, cell) -> float:
    """6 N D analytic model flops for the cell (N_active for MoE)."""
    n = bundle.n_active_params
    if cell.kind == "train":
        tokens = cell.batch * cell.seq
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        tokens = cell.batch * cell.seq
        return 2.0 * n * tokens
    return 2.0 * n * cell.batch        # decode: one token per sequence


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: str, *,
             force: bool = False) -> dict:
    mod = configs.get(arch)
    if shape in mod.SKIPS:
        return {"arch": arch, "shape": shape, "mesh": mesh_name,
                "status": "skip", "reason": mod.SKIPS[shape]}
    out_path = os.path.join(out_dir, mesh_name, f"{arch}__{shape}.json")
    if os.path.exists(out_path) and not force:
        with open(out_path) as f:
            return json.load(f)

    multi = mesh_name == "multi"
    mesh = mesh_lib.make_production_mesh(multi_pod=multi)
    # serving cells shard the KV cache sequence dim (prefill writes the
    # cache that decode reads — both sides must agree on its layout)
    seq_shard = SHAPE_TABLE[shape][2] in ("decode", "prefill")
    rules = mesh_lib.make_rules(mesh, fsdp=True, seq_shard=seq_shard)

    t0 = time.time()
    lowered, info = lower_cell(arch, shape, mesh, rules)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    h = analyse(hlo)
    n_chips = mesh.devices.size
    terms = roofline_terms(
        h, peak_flops=mesh_lib.PEAK_FLOPS_BF16, hbm_bw=mesh_lib.HBM_BW,
        ici_bw=mesh_lib.ICI_BW)
    mf = model_flops(info["bundle"], info["cell"])

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name, "status": "ok",
        "n_chips": n_chips,
        "n_params": info["bundle"].n_params,
        "n_active_params": info["bundle"].n_active_params,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "peak_bytes": ma.peak_memory_in_bytes,
        },
        "cost_analysis": {
            "flops_once": ca.get("flops", 0.0),
            "bytes_accessed_once": ca.get("bytes accessed", 0.0),
        },
        "hlo_analysis": h,
        "roofline": terms,
        "model_flops_global": mf,
        "model_flops_per_chip": mf / n_chips,
        "useful_flops_ratio": (mf / n_chips) / max(h["flops"], 1.0),
    }
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def iter_cells():
    for arch in configs.all_arch_ids():
        mod = configs.get(arch)
        for shape in SHAPE_TABLE:
            yield arch, shape, (shape in mod.SKIPS)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=os.path.abspath(ARTIFACT_DIR))
    args = ap.parse_args()

    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    cells = []
    if args.all:
        cells = [(a, s) for a, s, _ in iter_cells()]
    elif args.arch and args.shape:
        cells = [(args.arch, args.shape)]
    else:
        ap.error("pass --all or both --arch and --shape")

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        for mesh_name in meshes:
            try:
                rec = run_cell(arch, shape, mesh_name, args.out,
                               force=args.force)
            except Exception:
                n_fail += 1
                print(f"FAIL  {arch:24s} {shape:12s} {mesh_name}")
                traceback.print_exc()
                continue
            if rec["status"] == "skip":
                n_skip += 1
                print(f"skip  {arch:24s} {shape:12s} {mesh_name:6s} "
                      f"({rec['reason'][:60]})")
                continue
            n_ok += 1
            r = rec["roofline"]
            print(f"ok    {arch:24s} {shape:12s} {mesh_name:6s} "
                  f"peak={rec['memory']['peak_bytes'] / 2**30:7.2f}GiB "
                  f"c={r['compute_s']:.2e}s m={r['memory_s']:.2e}s "
                  f"x={r['collective_s']:.2e}s dom={r['dominant']} "
                  f"[{rec['compile_s']:.0f}s compile]")
    print(f"\n{n_ok} ok, {n_skip} skip, {n_fail} fail")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
