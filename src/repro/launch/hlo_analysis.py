"""Loop-aware static analysis of compiled (post-SPMD) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body **once**, but our layer
stacks are ``lax.scan`` loops — a 94-layer model would be under-counted 94x.
This module re-derives the three roofline inputs from ``compiled.as_text()``
with execution multipliers propagated through the call graph:

  * ``flops``            dot/convolution (+1/elem elementwise, |in|/reduce)
  * ``memory_bytes``     HBM-traffic model: Σ (operands + result) bytes over
                         *materialising* ops — fusions count at the call
                         site only (their internals live in registers/VMEM),
                         which is exactly the fusion memory model XLA's own
                         cost analysis uses.
  * ``collective_bytes`` per collective kind. Convention (documented for
                         the roofline): bytes = per-device result size
                         (operand size for reduce-scatter), all-reduce
                         counted 2x (reduce-scatter + all-gather phases);
                         ring factor (n-1)/n is folded into the link
                         bandwidth constant.

Trip counts come from the ``backend_config known_trip_count`` that XLA
attaches to rolled loops; a while without one is counted once (and
reported in ``unknown_trip_whiles``).

The HLO here is the per-device SPMD module, so every figure is *per chip* —
matching the roofline denominators (chips x per-chip peak).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that don't touch HBM (control/aliasing/layout only)
NON_MATERIAL = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "partition-id",
    "replica-id", "iota", "copy-start", "copy-done", "domain",
    "opt-barrier", "add-dependency",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^(?:ROOT )?%([\w.\-]+) = (.+)$")
_PARAM_RE = re.compile(r"%?([\w.\-]+): (\([^)]*\)|\w+\[[\d,]*\](?:\{[\d,]*\})?)")


def _shape_bytes(type_str: str) -> int:
    """Bytes of one (possibly tuple) HLO type string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> list[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Instr:
    name: str
    opcode: str
    type_str: str
    body: str          # everything after the opcode
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    symbols: dict      # name -> type_str (params + defs)
    param_names: list = dataclasses.field(default_factory=list)

    @property
    def root(self):
        for i in self.instrs:
            if i.is_root:
                return i
        return self.instrs[-1] if self.instrs else None

    @property
    def defs(self):
        d = getattr(self, "_defs", None)
        if d is None:
            d = {i.name: i for i in self.instrs}
            self._defs = d
        return d


def parse_computations(hlo: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in hlo.splitlines():
        line = raw.strip()
        header = re.match(
            r"^(?:ENTRY )?%?([\w.\-]+) \((.*)\) -> .* \{$", line)
        if header:
            name, params = header.group(1), header.group(2)
            cur = Computation(name, [], {})
            for pname, ptype in _PARAM_RE.findall(params):
                cur.symbols[pname] = ptype
                cur.param_names.append(pname)
            comps[name] = cur
            continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        split = _split_type_opcode(rest)
        if split is None:
            continue
        type_str, opcode, body = split
        cur.symbols[name] = type_str
        cur.instrs.append(Instr(name, opcode, type_str, body,
                                is_root=line.startswith("ROOT ")))
    return comps


def _split_type_opcode(rest: str):
    """'<type> <opcode>(...' -> (type, opcode, 'opcode(...'). Tuple types may
    contain `/*index=N*/` comments, so parens are matched by depth."""
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_str = rest[:end + 1]
        after = rest[end + 1:].lstrip()
    else:
        m = re.match(r"([\w\[\],]+(?:\{[^}]*\})?)\s+", rest)
        if not m:
            return None
        type_str = m.group(1)
        after = rest[m.end():]
    om = re.match(r"([\w\-]+)\(", after)
    if not om:
        return None
    return type_str, om.group(1), after


def _trip_count(body: str) -> int | None:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', body)
    return int(m.group(1)) if m else None


def _callees(instr: Instr) -> list[tuple[str, str]]:
    """-> [(kind, computation-name)]; kind in {fusion, while_body,
    while_cond, apply, branch}."""
    out = []
    if instr.opcode == "fusion":
        m = re.search(r"calls=%([\w.\-]+)", instr.body)
        if m:
            out.append(("fusion", m.group(1)))
    elif instr.opcode == "while":
        mb = re.search(r"body=%([\w.\-]+)", instr.body)
        mc = re.search(r"condition=%([\w.\-]+)", instr.body)
        if mb:
            out.append(("while_body", mb.group(1)))
        if mc:
            out.append(("while_cond", mc.group(1)))
    elif instr.opcode == "conditional":
        for m in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                             r"(?:true|false)_computation=%([\w.\-]+))",
                             instr.body):
            names = m.group(1) or m.group(2) or ""
            for n in re.findall(r"%([\w.\-]+)", names):
                out.append(("branch", n))
    else:
        for m in re.finditer(r"(?:to_apply|comparator)=%([\w.\-]+)",
                             instr.body):
            out.append(("apply", m.group(1)))
    return out


def _operand_names(instr: Instr) -> list[str]:
    # operands are inside the first (...) of the body
    depth = 0
    start = instr.body.find("(")
    if start < 0:
        return []
    for i in range(start, len(instr.body)):
        if instr.body[i] == "(":
            depth += 1
        elif instr.body[i] == ")":
            depth -= 1
            if depth == 0:
                inner = instr.body[start + 1:i]
                return re.findall(r"%([\w.\-]+)", inner)
    return []


ELEMWISE_1 = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not",
    "exponential", "tanh", "log", "rsqrt", "sqrt", "power",
    "exponential-minus-one", "log-plus-one", "cosine", "sine", "floor",
    "ceil", "round-nearest-afz", "sign", "clamp", "remainder", "atan2",
    "logistic", "cbrt", "erf",
}


def _instr_flops(instr: Instr, comp: Computation) -> float:
    if instr.opcode == "dot":
        ops = _operand_names(instr)
        if not ops:
            return 0.0
        lhs_type = comp.symbols.get(ops[0], "")
        lhs_dims = _shape_dims(lhs_type)
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.body)
        k = 1
        if m and lhs_dims:
            for d in m.group(1).split(","):
                if d and int(d) < len(lhs_dims):
                    k *= lhs_dims[int(d)]
        return 2.0 * _shape_elems(instr.type_str) * k
    if instr.opcode == "convolution":
        ops = _operand_names(instr)
        rhs_dims = _shape_dims(comp.symbols.get(ops[1], "")) if len(ops) > 1 \
            else []
        m = re.search(r"dim_labels=\w+_(\w+)->", instr.body)
        k = 1
        if m and rhs_dims:
            labels = m.group(1)
            for i, ch in enumerate(labels):
                if ch != "o" and i < len(rhs_dims):   # all but output-feature
                    k *= rhs_dims[i]
        fgc = re.search(r"feature_group_count=(\d+)", instr.body)
        if fgc and "i" in (m.group(1) if m else ""):
            pass  # depthwise handled by i-dim == 1 in rhs
        return 2.0 * _shape_elems(instr.type_str) * k
    if instr.opcode in ELEMWISE_1 or instr.opcode == "convert":
        return float(_shape_elems(instr.type_str))
    if instr.opcode in ("reduce", "reduce-window"):
        ops = _operand_names(instr)
        if ops:
            return float(_shape_elems(comp.symbols.get(ops[0], "")))
    return 0.0


SLICING_OPS = {"slice", "dynamic-slice", "gather"}


def _written_bytes(instr: Instr, comp: Computation) -> int:
    """Bytes written by ``instr``; a dynamic-update-slice writes only the
    update region (the buffer is updated in place under XLA aliasing)."""
    if instr.opcode == "dynamic-update-slice":
        ops = _operand_names(instr)
        if len(ops) > 1 and ops[1] in comp.symbols:
            return _shape_bytes(comp.symbols[ops[1]])
    return _shape_bytes(instr.type_str)


_LOOKTHROUGH = {"convert", "bitcast", "copy"}


def _uses_of(callee: Computation, name: str):
    for ins in callee.instrs:
        if name in _operand_names(ins):
            yield ins


def _param_read_bytes(callee: Computation, pname: str, full_bytes: int,
                      _depth: int = 0) -> int:
    """HBM bytes read from fusion parameter ``pname``: if every use slices
    it, only the sliced regions stream in (this is how a scan body reads one
    layer of a stacked parameter — the fix for the 200x over-count of
    counting the full stacked buffer per iteration). A use that merely
    passes the buffer through to the root tuple (loop-carried state) is
    free — XLA aliases it in place; convert/bitcast chains around the
    carry (the CPU backend's double-buffered 'wide' loops) are looked
    through."""
    if _depth > 4:
        return full_bytes
    sliced = 0
    for ins in _uses_of(callee, pname):
        ops = _operand_names(ins)
        if ins.is_root and ins.opcode == "tuple":
            continue                               # pass-through carry
        if ins.opcode in SLICING_OPS and ops and ops[0] == pname:
            sliced += _shape_bytes(ins.type_str)
        elif ins.opcode == "dynamic-update-slice" and ops[0] == pname:
            # in-place update: the unmodified region is not read
            sliced += _written_bytes(ins, callee)
        elif ins.opcode in _LOOKTHROUGH:
            sliced += _param_read_bytes(callee, ins.name, full_bytes,
                                        _depth + 1)
            if sliced >= full_bytes:
                return full_bytes
        else:
            return full_bytes
    return sliced


def _fusion_written_bytes(callee: Computation) -> int:
    """Bytes a fusion writes: root-tuple elements that are raw parameter
    pass-throughs cost nothing (aliased); dynamic-update-slice elements
    cost their update region; everything else costs its full size."""
    root = callee.root
    if root is None:
        return 0
    pset = set(callee.param_names)

    def elem_bytes(opn: str, depth: int = 0) -> int:
        if opn in pset:
            return 0                               # aliased pass-through
        d = callee.defs.get(opn)
        if d is None:
            return _shape_bytes(callee.symbols.get(opn, ""))
        if d.opcode == "dynamic-update-slice":
            return _written_bytes(d, callee)
        if d.opcode in _LOOKTHROUGH and depth < 4:
            ops = _operand_names(d)
            if ops:
                return elem_bytes(ops[0], depth + 1)
        return _shape_bytes(callee.symbols.get(opn, ""))

    if root.opcode == "tuple":
        return sum(elem_bytes(opn) for opn in _operand_names(root))
    if root.opcode in _LOOKTHROUGH:
        ops = _operand_names(root)
        if ops:
            return elem_bytes(ops[0])
    return _written_bytes(root, callee)


def _instr_memory_bytes(instr: Instr, comp: Computation,
                        comps: dict) -> int:
    if instr.opcode in NON_MATERIAL:
        return 0
    if instr.opcode in SLICING_OPS:
        return 2 * _shape_bytes(instr.type_str)      # read slice + write
    if instr.opcode == "dynamic-update-slice":
        return 2 * _written_bytes(instr, comp)       # read update + write
    if instr.opcode == "fusion":
        m = re.search(r"calls=%([\w.\-]+)", instr.body)
        callee = comps.get(m.group(1)) if m else None
        if callee is None:
            return _shape_bytes(instr.type_str)
        total = _fusion_written_bytes(callee)
        for i, op in enumerate(_operand_names(instr)):
            full = _shape_bytes(comp.symbols.get(op, ""))
            if i < len(callee.param_names):
                total += _param_read_bytes(callee, callee.param_names[i],
                                           full)
            else:
                total += full
        return total
    total = _shape_bytes(instr.type_str)
    for op in _operand_names(instr):
        t = comp.symbols.get(op)
        if t:
            total += _shape_bytes(t)
    return total


def _collective_bytes(instr: Instr, comp: Computation) -> int:
    if instr.opcode == "all-reduce":
        return 2 * _shape_bytes(instr.type_str)
    if instr.opcode == "reduce-scatter":
        ops = _operand_names(instr)
        if ops and ops[0] in comp.symbols:
            return _shape_bytes(comp.symbols[ops[0]])
    return _shape_bytes(instr.type_str)


def analyse(hlo_text: str) -> dict:
    """-> {flops, memory_bytes, collective_bytes: {kind: bytes},
    collective_total, unknown_trip_whiles, n_collectives}.

    All values are per-device (the module is the SPMD per-device program).
    """
    comps = parse_computations(hlo_text)
    entry = None
    for line in hlo_text.splitlines():
        m = re.match(r"^ENTRY %?([\w.\-]+)", line.strip())
        if m:
            entry = m.group(1)
            break
    if entry is None or entry not in comps:
        raise ValueError("no ENTRY computation found")

    # propagate execution multipliers through the call graph
    mult: dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    # memory model: count HBM traffic only where buffers materialise
    material: dict[str, bool] = {entry: True}
    unknown_whiles = 0
    order = [entry]
    seen = {entry}
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps[cname]
        for instr in comp.instrs:
            for kind, callee in _callees(instr):
                if callee not in comps:
                    continue
                m = mult[cname]
                is_material = material.get(cname, False)
                if kind in ("while_body", "while_cond"):
                    tc = _trip_count(instr.body)
                    if tc is None:
                        tc = 1
                        if kind == "while_body":
                            unknown_whiles += 1
                    m *= tc
                    child_material = is_material
                elif kind == "fusion":
                    child_material = False     # internals live in VMEM/regs
                elif kind == "apply":
                    child_material = False
                else:                          # conditional branch
                    child_material = is_material
                mult[callee] += m
                material[callee] = material.get(callee, False) or \
                    child_material
                if callee not in seen:
                    seen.add(callee)
                    order.append(callee)

    flops = 0.0
    mem = 0.0
    coll: dict[str, float] = defaultdict(float)
    n_coll = 0
    for cname in order:
        comp = comps[cname]
        m = mult[cname]
        if m == 0:
            continue
        for instr in comp.instrs:
            flops += m * _instr_flops(instr, comp)
            if material.get(cname, False):
                mem += m * _instr_memory_bytes(instr, comp, comps)
            if instr.opcode in COLLECTIVES:
                coll[instr.opcode] += m * _collective_bytes(instr, comp)
                n_coll += int(m)

    return {
        "flops": flops,
        "memory_bytes": mem,
        "collective_bytes": dict(coll),
        "collective_total": sum(coll.values()),
        "n_collectives": n_coll,
        "unknown_trip_whiles": unknown_whiles,
    }


def roofline_terms(analysis: dict, *, peak_flops: float, hbm_bw: float,
                   ici_bw: float) -> dict:
    """Three per-chip roofline terms in seconds (+ dominant term)."""
    compute_s = analysis["flops"] / peak_flops
    memory_s = analysis["memory_bytes"] / hbm_bw
    collective_s = analysis["collective_total"] / ici_bw
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    terms["dominant"] = dom
    terms["bound_s"] = terms[dom]
    return terms


if __name__ == "__main__":
    import sys

    with open(sys.argv[1]) as f:
        print(json.dumps(analyse(f.read()), indent=2))
