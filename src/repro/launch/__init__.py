"""Launch layer: production meshes, the multi-pod dry-run, the roofline
analyser, and the train/serve drivers."""
