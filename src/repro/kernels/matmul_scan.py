"""MatMulScan: log-depth matmul-form scan (the ``tile_logdepth`` path).

Both existing tile paths serialize the inter-block carry — the TPU twin
threads it through a sequential grid dimension + VMEM scratch
(``tcu_scan.py``), the Triton twin through an in-kernel ``fori_loop`` —
so scan latency grows linearly in ``n / block``. MatMulScan (Zouzias &
McColl; the TCU-model follow-up to Dakkak et al.) removes that serial
chain: a radix-``s`` Brent-Kung scan whose upsweep and downsweep are
*only* batched matmuls against two constant ``s x s`` matrices:

  ``L_s`` — triangular ones. In this repo's row-vector layout it appears
  transposed as ``U_s`` (upper-triangular ones, the same constructor the
  linear kernels already build): ``t @ U_s`` is an inclusive scan of
  ``t``'s last axis, one MMA per tree node.
  ``B_s`` — the broadcast matrix (here a ``1 x s`` ones row): the
  downsweep replicates each node's exclusive carry across its children
  as ``carry[..., None] @ B_s`` — again a matmul, never a gather.

The weighted variant (``h_k = exp(logp_k) * h_{k-1} + t_k``) folds the
per-step decay into the upsweep operand: the triangular-ones matrix
becomes the 1-semiseparable ``exp(segsum(logp))`` mask — exactly the
form ``repro.core.distributed.weighted_exclusive_carry`` uses at the
mesh level and the SSD kernels use within a chunk — and the downsweep
carry is scaled by the within-group cumulative decay before the add.

Execution is split in two layers:

* The *local* (level-0) block scans run as Pallas kernels with a fully
  parallel grid — defined here for TPU (``repro.kernels.triton
  .matmul_scan`` holds the Triton twins). They are the linear kernels
  minus the carry machinery.
* The *tree combine* over per-block totals (:func:`tree_scan` /
  :func:`tree_weighted`) runs as ``O(log_radix nblocks)`` rounds of
  batched XLA ``dot_general``s against the constant matrices, shared by
  both backends' glue. XLA lowers these onto the MXU / tensor cores —
  the whole path is matmuls, with no serial dependence longer than the
  tree height.

``radix`` (tree branching factor) and ``fan_in`` (base-case width: a
remaining sequence this short is finished with one triangular matmul)
are ``KNOB_SCHEMA`` tuning knobs; their default values and sweep
candidates live in ``repro.kernels.layout`` like every other geometry
number.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import LANES, SUBLANES


# ---------------------------------------------------------------------------
# constant-matrix constructors (traceable — iota, no host constants)


def upper_tri_ones(t: int, dtype=jnp.float32) -> jax.Array:
    """``U_t`` (the row-vector transpose of the paper family's ``L_s``):
    upper-triangular ones including the diagonal. ``a @ U_t`` is a
    row-wise inclusive scan."""
    rows = jax.lax.broadcasted_iota(jnp.int32, (t, t), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (t, t), 1)
    return (rows <= cols).astype(dtype)


def broadcast_row(t: int, dtype=jnp.float32) -> jax.Array:
    """``B_t`` as a ``1 x t`` ones row: ``carry[..., None] @ B_t``
    replicates a per-group scalar across the group's ``t`` children —
    the downsweep broadcast, kept as a matmul."""
    return jnp.ones((1, t), dtype)


def segsum(log_a: jax.Array) -> jax.Array:
    """``out[..., i, j] = sum(log_a[..., j+1 : i+1])`` on the lower
    triangle (diagonal 0), ``-inf`` above it — so ``exp(segsum(log_a))``
    is the 1-semiseparable decay mask with exact zeros where ``j > i``.
    Mirrors ``repro.core.tiles.segsum`` (not imported: this module loads
    under ``repro.kernels`` before ``repro.core`` finishes importing)."""
    m = log_a.shape[-1]
    csum = jnp.cumsum(
        jnp.pad(log_a, [(0, 0)] * (log_a.ndim - 1) + [(1, 0)]), axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    rows = jax.lax.broadcasted_iota(jnp.int32, (m + 1, m + 1), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (m + 1, m + 1), 1)
    return jnp.where(rows >= cols, diff, -jnp.inf)[..., 1:, 1:]


def _shift_right(x: jax.Array, axis: int) -> jax.Array:
    """Inclusive -> exclusive along ``axis``: drop the last slot, prepend
    the combine identity (0 for both + and the weighted combine)."""
    axis = axis % x.ndim
    pad = [(0, 0)] * x.ndim
    pad[axis] = (1, 0)
    sl = [slice(None)] * x.ndim
    sl[axis] = slice(None, -1)
    return jnp.pad(x, pad)[tuple(sl)]


# ---------------------------------------------------------------------------
# the log-depth tree combine (pure XLA; shared by the TPU and GPU glue)


def tree_scan(t: jax.Array, *, radix: int, fan_in: int) -> jax.Array:
    """Inclusive prefix sum of ``t (..., m)`` in ``O(log_radix m)``
    rounds of batched matmuls against ``U_radix`` / ``B_radix``.

    Each level groups ``radix`` neighbours, scans every group with one
    batched ``@ U`` (upsweep), recurses on the group totals, and adds the
    recursion's exclusive carries back via ``carry @ B`` (downsweep). A
    sequence of at most ``fan_in`` is finished with a single triangular
    matmul — the base of the recursion.
    """
    radix = max(2, int(radix))
    fan_in = max(1, int(fan_in))
    t = t.astype(jnp.float32)
    m = t.shape[-1]
    if m <= fan_in:
        return jax.lax.dot_general(
            t, upper_tri_ones(m), (((t.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
    groups = -(-m // radix)
    pad = groups * radix - m
    if pad:  # zero-padding is the scan identity: the tail never leaks back
        t = jnp.pad(t, [(0, 0)] * (t.ndim - 1) + [(0, pad)])
    tg = t.reshape(*t.shape[:-1], groups, radix)
    local = jax.lax.dot_general(                       # upsweep: @ U_radix
        tg, upper_tri_ones(radix), (((tg.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    carry = tree_scan(local[..., -1], radix=radix, fan_in=fan_in)
    exc = _shift_right(carry, -1)
    local = local + jax.lax.dot_general(               # downsweep: @ B_radix
        exc[..., None], broadcast_row(radix),
        (((exc.ndim,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    return local.reshape(*local.shape[:-2], groups * radix)[..., :m]


def tree_weighted(logp: jax.Array, t: jax.Array, *, radix: int,
                  fan_in: int) -> jax.Array:
    """Weighted (decayed) inclusive scan in log depth.

    Solves ``h_k = exp(logp_k) * h_{k-1} + t_k`` for ``logp (..., m)``
    and ``t (..., m, F)`` (``F`` flat trailing features — 1 for the
    scalar scans, ``N*P`` for SSD chunk states), returning ``h`` of
    ``t``'s shape. Same tree as :func:`tree_scan` with the triangular
    ones replaced by the 1-semiseparable ``exp(segsum(logp))`` mask in
    the upsweep, and the downsweep carry scaled by the within-group
    cumulative decay (itself matmul-form: ``logp @ U``) before the add.
    Zero-padding the tail is the identity here too: ``logp = 0`` is
    decay 1 and ``t = 0`` adds nothing.
    """
    radix = max(2, int(radix))
    fan_in = max(1, int(fan_in))
    logp = logp.astype(jnp.float32)
    t = t.astype(jnp.float32)
    m = logp.shape[-1]
    if m <= fan_in:
        return jnp.matmul(jnp.exp(segsum(logp)), t)
    groups = -(-m // radix)
    pad = groups * radix - m
    if pad:
        logp = jnp.pad(logp, [(0, 0)] * (logp.ndim - 1) + [(0, pad)])
        t = jnp.pad(t, [(0, 0)] * (t.ndim - 2) + [(0, pad), (0, 0)])
    lg = logp.reshape(*logp.shape[:-1], groups, radix)
    tg = t.reshape(*t.shape[:-2], groups, radix, t.shape[-1])
    local = jnp.matmul(jnp.exp(segsum(lg)), tg)        # (..., g, radix, F)
    carry = tree_weighted(jnp.sum(lg, axis=-1), local[..., -1, :],
                          radix=radix, fan_in=fan_in)
    exc = _shift_right(carry, -2)                      # (..., g, F)
    cum = jax.lax.dot_general(                         # within-group Λ
        lg, upper_tri_ones(radix), (((lg.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    local = local + jnp.exp(cum)[..., None] * exc[..., None, :]
    return local.reshape(
        *local.shape[:-3], groups * radix, local.shape[-1])[..., :m, :]


# ---------------------------------------------------------------------------
# Pallas-TPU local kernels: the linear kernels minus the carry machinery,
# on a fully parallel grid


def _local_scan_kernel(x_ref, o_ref):
    a = x_ref[...]
    bn = a.shape[1]
    o_ref[...] = jax.lax.dot_general(
        a, upper_tri_ones(bn, a.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_n", "interpret"))
def matmul_local_scan(x: jax.Array, *, block_s: int, block_n: int,
                      interpret: bool = False) -> jax.Array:
    """Per-block inclusive scan: (s, n) -> (s, n) f32, every
    ``block_s x block_n`` block scanned independently (no inter-block
    carry — the tree combine adds it). Both grid dimensions are parallel.
    """
    s, n = x.shape
    if block_s % SUBLANES or block_n % LANES:
        raise ValueError(
            f"blocks {(block_s, block_n)} must be multiples of "
            f"{(SUBLANES, LANES)}")
    if n % block_n or s % block_s:
        raise ValueError(
            f"dims must be multiples of {(block_s, block_n)}, got {x.shape}")
    return pl.pallas_call(
        _local_scan_kernel,
        grid=(s // block_s, n // block_n),
        in_specs=[pl.BlockSpec((block_s, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_s, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="matmul_local_scan",
    )(x)


def _local_weighted_kernel(x_ref, lam_ref, o_ref, *, q: int):
    lam = lam_ref[...].astype(jnp.float32)             # (1, q)
    x = x_ref[...].astype(jnp.float32)                 # (1, q)
    # Λ = λ @ U (matmul-form cumulative log decay), then the
    # 1-semiseparable mask M[t, τ] = exp(Λ_t − Λ_τ) for τ ≤ t
    cum = jax.lax.dot_general(
        lam, upper_tri_ones(q), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (1, q)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    diff = cum[0][:, None] - cum[0][None, :]
    m = jnp.where(rows >= cols, jnp.exp(diff), 0.0)    # (q, q)
    # y_t = Σ_τ M[t, τ] x_τ, laid out (1, q): contract x's lane axis
    # against M's τ axis
    o_ref[...] = jax.lax.dot_general(
        x, m, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def matmul_local_weighted(x: jax.Array, lam: jax.Array, *, q: int,
                          interpret: bool = False) -> jax.Array:
    """Per-block weighted scan: x, lam (rows, n) -> (rows, n) f32 with
    ``h_t = exp(lam_t) h_{t-1} + x_t`` restarted at every ``q``-block
    boundary (the tree combine stitches blocks). Fully parallel grid."""
    rows, n = x.shape
    if q % LANES:
        raise ValueError(f"block q={q} must be a multiple of {LANES}")
    if n % q:
        raise ValueError(f"n={n} must be a multiple of q={q}")
    return pl.pallas_call(
        functools.partial(_local_weighted_kernel, q=q),
        grid=(rows, n // q),
        in_specs=[
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((1, q), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="matmul_local_weighted",
    )(x, lam)


def _local_ssd_kernel(xdt_ref, lam_ref, b_ref, c_ref, y_ref, s_ref, *,
                      q: int):
    xdt = xdt_ref[0].astype(jnp.float32)               # (q, P)
    lam = lam_ref[...].astype(jnp.float32)             # (1, q)
    bmat = b_ref[0].astype(jnp.float32)                # (q, N)
    cmat = c_ref[0].astype(jnp.float32)                # (q, N)

    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    cum = jax.lax.dot_general(
        lam, upper_tri_ones(q), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)            # (1, q)
    total = jnp.sum(lam)

    # Intra-chunk only: Y_local = ((C Bᵀ) ∘ M) @ (dt∘X); the inter-chunk
    # H term is added by the glue after the tree combine.
    diff = cum[0][:, None] - cum[0][None, :]
    m = jnp.where(rows >= cols, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[0] = jax.lax.dot_general(
        cb * m, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(y_ref.dtype)

    # Per-chunk state contribution S = (B ∘ w)ᵀ @ (dt∘X), w_τ = exp(Σλ − Λ_τ)
    bw = bmat * jnp.exp(total - cum[0])[:, None]
    s_ref[0] = jax.lax.dot_general(
        bw, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32).astype(s_ref.dtype)


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def matmul_local_ssd(
    xdt: jax.Array,     # (BH, L, P)  dt-weighted inputs, P % 128 == 0
    lam: jax.Array,     # (BH, L)     per-step log decay
    b: jax.Array,       # (BH, L, N)  N % 8 == 0
    c: jax.Array,       # (BH, L, N)
    *,
    q: int,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Carry-free SSD chunk pass on a fully parallel grid. Returns
    ``(y_local (BH, L, P), s (BH, nchunks*N, P))`` — the intra-chunk
    outputs and every chunk's state contribution; the glue tree-combines
    the states and adds the inter-chunk term."""
    bh, seqlen, hdim = xdt.shape
    nstate = b.shape[-1]
    if q % LANES:
        raise ValueError(f"chunk q={q} must be a multiple of {LANES}")
    if seqlen % q:
        raise ValueError(f"L={seqlen} must be a multiple of {q}")
    nchunks = seqlen // q
    return pl.pallas_call(
        functools.partial(_local_ssd_kernel, q=q),
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, q, hdim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
            pl.BlockSpec((1, q, nstate), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, nstate), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, hdim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, nstate, hdim), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seqlen, hdim), jnp.float32),
            jax.ShapeDtypeStruct((bh, nchunks * nstate, hdim), jnp.float32),
        ],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
        name="matmul_local_ssd",
    )(xdt, lam, b, c)
