"""Pallas TPU kernel: matmul-form segmented inclusive scan.

Paper mapping (Dakkak et al. ICS'19, Alg. 6 / Fig. 9), TPU-adapted:

* ``A @ U`` (U = upper-triangular ones) scans each row of a tile — one MXU
  pass scans ``block_s`` segments x ``block_n`` elements.
* The tile-to-tile carry ``S ← Broadcast(R[last])`` is one more matmul:
  ``carry = R @ E`` with ``E[n, m] = 1 iff n == last`` replicates the last
  column of R across all lanes (the paper's Broadcast(LastColumn(R)),
  Algorithm 6 line 11 / footnote 5).
* On the V100 the serial carry forced decoupled-lookback-style machinery at
  scale; TPU Pallas grids are sequential per core, so the carry is simply a
  VMEM scratch accumulator along the innermost grid dimension.

Layout: row-major ``x (s, n)``; grid (s/block_s, n/block_n) with chunks
innermost-sequential. The block geometry is caller-supplied (a resolved
``TuneSpec``); defaults live in ``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.layout import LANES, SUBLANES, default_tuning


def _scan_kernel(x_ref, o_ref, carry_ref, *, nchunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = x_ref[...]                                   # rows = segments
    bn = a.shape[1]
    rows = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (bn, bn), 1)
    u = (rows <= cols).astype(a.dtype)               # upper-triangular ones
    au = jax.lax.dot_general(
        a, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + carry_ref[...]
    o_ref[...] = au.astype(o_ref.dtype)

    @pl.when(j != nchunks - 1)
    def _carry():
        # Broadcast(LastColumn(R)): E has ones only in the last row.
        e = (rows == bn - 1).astype(jnp.float32)
        carry_ref[...] = jax.lax.dot_general(
            au, e, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_n", "interpret"))
def tcu_segmented_scan_tn(x: jax.Array, *, block_s: int | None = None,
                          block_n: int | None = None,
                          interpret: bool = False) -> jax.Array:
    """Inclusive scan along the last axis: (s, n) -> (s, n) in f32.

    ``s % block_s == 0`` and ``n % block_n == 0`` (wrapper pads);
    ``block_s`` must be a sublane multiple and ``block_n`` a lane
    multiple; rows are independent segments.
    """
    spec = default_tuning("tpu", "scan")
    block_s = block_s or spec["block_s"]
    block_n = block_n or spec["block_n"]
    s, n = x.shape
    if block_s % SUBLANES or block_n % LANES:
        raise ValueError(
            f"blocks {(block_s, block_n)} must be multiples of "
            f"{(SUBLANES, LANES)}")
    if n % block_n or s % block_s:
        raise ValueError(
            f"dims must be multiples of {(block_s, block_n)}, got "
            f"{x.shape}")
    nchunks = n // block_n
    return pl.pallas_call(
        functools.partial(_scan_kernel, nchunks=nchunks),
        grid=(s // block_s, nchunks),
        in_specs=[pl.BlockSpec((block_s, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_s, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_s, block_n), jnp.float32)],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tcu_segmented_scan",
    )(x)
