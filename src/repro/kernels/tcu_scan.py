"""Pallas TPU kernel: matmul-form segmented inclusive scan.

Paper mapping (Dakkak et al. ICS'19, Alg. 6 / Fig. 9), TPU-adapted:

* ``A @ U`` (U = upper-triangular ones) scans each row of a tile — one MXU
  pass scans 128 segments x 128 elements.
* The tile-to-tile carry ``S ← Broadcast(R[last])`` is one more matmul:
  ``carry = R @ E`` with ``E[n, m] = 1 iff n == last`` replicates the last
  column of R across all lanes (the paper's Broadcast(LastColumn(R)),
  Algorithm 6 line 11 / footnote 5).
* On the V100 the serial carry forced decoupled-lookback-style machinery at
  scale; TPU Pallas grids are sequential per core, so the carry is simply a
  VMEM scratch accumulator along the innermost grid dimension.

Layout: row-major ``x (s, n)``; block (128, 128); grid (s/128, n/128) with
chunks innermost-sequential.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

LANES = 128


def _scan_kernel(x_ref, o_ref, carry_ref, *, nchunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    a = x_ref[...]                                   # (128, 128) rows=segments
    rows = jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (LANES, LANES), 1)
    u = (rows <= cols).astype(a.dtype)               # upper-triangular ones
    au = jax.lax.dot_general(
        a, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    ) + carry_ref[...]
    o_ref[...] = au.astype(o_ref.dtype)

    @pl.when(j != nchunks - 1)
    def _carry():
        # Broadcast(LastColumn(R)): E has ones only in the last row.
        e = (rows == LANES - 1).astype(jnp.float32)
        carry_ref[...] = jax.lax.dot_general(
            au, e, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )


@functools.partial(jax.jit, static_argnames=("interpret",))
def tcu_segmented_scan_tn(x: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Inclusive scan along the last axis: (s, n) -> (s, n) in f32.

    Both dims must be multiples of 128 (wrapper pads); rows are independent
    segments.
    """
    s, n = x.shape
    if n % LANES or s % LANES:
        raise ValueError(f"dims must be multiples of {LANES}, got {x.shape}")
    nchunks = n // LANES
    return pl.pallas_call(
        functools.partial(_scan_kernel, nchunks=nchunks),
        grid=(s // LANES, nchunks),
        in_specs=[pl.BlockSpec((LANES, LANES), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((LANES, LANES), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((LANES, LANES), jnp.float32)],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tcu_segmented_scan",
    )(x)
