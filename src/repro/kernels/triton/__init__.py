"""Pallas-Triton (GPU) twins of every Pallas-TPU kernel in the parent
package — the paper's algorithms on the hardware the paper targeted.

Each kernel expresses segmented reduction/scan as chained tensor-core MMA
fragments (ones-vector reduction, upper-triangular-matmul scan) with
GPU-appropriate block shapes and grid schedules: CUDA grids are parallel,
so every sequential carry the TPU twins thread through a grid dimension +
VMEM scratch becomes an in-kernel ``fori_loop`` with register carries here.

The kernels register as the ``tile_gpu`` entries of the
``repro.kernels.backend`` op registry (see ``repro.kernels.ops``); the
generic ``tile`` path resolves to them on GPU hosts. On CPU the whole
subsystem is validated through Pallas interpret mode.

Import discipline: only ``repro.kernels.triton.compat`` may touch
``jax.experimental.pallas.triton`` (grep-guard enforced).
"""
from repro.kernels.triton.compat import available, compiler_params
from repro.kernels.triton.flash_attention import triton_flash_attention
from repro.kernels.triton.fused_rmsnorm import triton_fused_rmsnorm
from repro.kernels.triton.ssd_scan import triton_ssd_chunk_scan
from repro.kernels.triton.tcu_reduce import triton_segmented_reduce
from repro.kernels.triton.tcu_scan import triton_segmented_scan

__all__ = [
    "available",
    "compiler_params",
    "triton_flash_attention",
    "triton_fused_rmsnorm",
    "triton_segmented_reduce",
    "triton_segmented_scan",
    "triton_ssd_chunk_scan",
]
