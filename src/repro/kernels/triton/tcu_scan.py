"""Pallas-Triton kernel: matmul-form segmented inclusive scan (GPU twin of
``repro.kernels.tcu_scan``).

Paper mapping (Dakkak et al. ICS'19, Alg. 6), GPU-adapted:

* ``A @ U`` (U = upper-triangular ones) scans each fragment row — one MMA
  pass scans BLOCK_S segments x BLOCK_N elements.
* The tile-to-tile carry ``S <- Broadcast(R[last])`` stays one more matmul:
  ``carry = R @ E`` with E ones only in the last row replicates the last
  column of R across every lane (Algorithm 6 line 11 / footnote 5).
* On the V100 the paper needed decoupled-lookback machinery because the
  serial carry crosses thread blocks; here each program owns its whole
  segment rows, so the carry is a register tensor threaded through an
  in-kernel ``fori_loop`` over column chunks — CUDA grid dimensions are
  parallel and cannot carry state (unlike the TPU twin's sequential grid +
  VMEM scratch).

Grid: ``(S / block_s,)``; layout row-major ``x (s, n)``, rows = segments.
The block geometry and launch shape are caller-supplied (a resolved
``TuneSpec``); defaults live in ``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import default_tuning


def _scan_kernel(x_ref, o_ref, *, block_s: int, block_n: int, nchunks: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block_n, block_n), 1)
    u = (rows <= cols).astype(jnp.float32)       # upper-triangular ones
    e = (rows == block_n - 1).astype(jnp.float32)  # ones in the last row

    def body(k, carry):
        sl = (slice(None), pl.dslice(k * block_n, block_n))
        a = pl.load(x_ref, sl).astype(jnp.float32)
        au = jax.lax.dot_general(
            a, u, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) + carry
        pl.store(o_ref, sl, au)
        # Broadcast(LastColumn(R)) as R @ E — stays on the tensor core.
        return jax.lax.dot_general(
            au, e, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    jax.lax.fori_loop(
        0, nchunks, body, jnp.zeros((block_s, block_n), jnp.float32))


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_n", "num_warps",
                                    "num_stages", "interpret"))
def triton_segmented_scan(x: jax.Array, *, block_s: int | None = None,
                          block_n: int | None = None,
                          num_warps: int | None = None,
                          num_stages: int | None = None,
                          interpret: bool = False) -> jax.Array:
    """Inclusive scan along the last axis: (s, n) -> (s, n) f32.

    ``s % block_s == 0`` and ``n % block_n == 0`` (wrapper pads); rows are
    independent segments.
    """
    spec = default_tuning("gpu", "scan")
    block_s = block_s or spec["block_s"]
    block_n = block_n or spec["block_n"]
    s, n = x.shape
    if s % block_s or n % block_n:
        raise ValueError(
            f"dims must be multiples of {(block_s, block_n)}, got {x.shape}")
    return pl.pallas_call(
        functools.partial(_scan_kernel, block_s=block_s, block_n=block_n,
                          nchunks=n // block_n),
        grid=(s // block_s,),
        in_specs=[pl.BlockSpec((block_s, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_segmented_scan",
    )(x)
