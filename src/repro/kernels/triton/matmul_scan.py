"""Pallas-Triton kernels: MatMulScan local (level-0) block scans — the GPU
twins of ``repro.kernels.matmul_scan`` for the ``tile_logdepth`` path.

The linear Triton kernels thread the inter-block carry through an
in-kernel ``fori_loop`` (CUDA grids are parallel and cannot carry state),
so their depth is ``n / block``. The log-depth path deletes that loop
entirely: each program scans one block with a single triangular MMA and
emits its block total/state; the ``O(log_radix nblocks)`` tree combine
over those totals (``tree_scan`` / ``tree_weighted`` — pure batched XLA
matmuls against the constant ``U_s``/``B_s`` matrices) runs outside the
kernel and is shared with the TPU glue.

Single-row fragments (the weighted scan walks one decay row per program)
ride the same broadcast trick the linear SSD twin uses: replicate the row
to a 16-row fragment so ``tl.dot``'s ``M >= 16`` shape rule holds, then
collapse the identical rows without arithmetic.

Launch geometry is caller-supplied (a resolved ``TuneSpec``); defaults
live in ``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import MMA_TILE as TILE
from repro.kernels.layout import default_tuning
from repro.kernels.matmul_scan import upper_tri_ones


def _local_scan_kernel(x_ref, o_ref):
    a = x_ref[...].astype(jnp.float32)
    bn = a.shape[1]
    o_ref[...] = jax.lax.dot_general(
        a, upper_tri_ones(bn), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_n", "num_warps",
                                    "num_stages", "interpret"))
def triton_local_scan(x: jax.Array, *, block_s: int | None = None,
                      block_n: int | None = None,
                      num_warps: int | None = None,
                      num_stages: int | None = None,
                      interpret: bool = False) -> jax.Array:
    """Per-block inclusive scan: (s, n) -> (s, n) f32, every
    ``block_s x block_n`` block independent (no carry loop — the tree
    combine adds it). Grid is fully parallel in both dimensions."""
    spec = default_tuning("gpu", "scan")
    block_s = block_s or spec["block_s"]
    block_n = block_n or spec["block_n"]
    s, n = x.shape
    if s % block_s or n % block_n:
        raise ValueError(
            f"dims must be multiples of {(block_s, block_n)}, got {x.shape}")
    return pl.pallas_call(
        _local_scan_kernel,
        grid=(s // block_s, n // block_n),
        in_specs=[pl.BlockSpec((block_s, block_n), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block_s, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((s, n), jnp.float32),
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_local_scan",
    )(x)


def _local_weighted_kernel(x_ref, lam_ref, o_ref, *, q: int):
    x = x_ref[...].astype(jnp.float32)                   # (q,)
    lam = lam_ref[...].astype(jnp.float32)               # (q,)
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    u = (rows <= cols).astype(jnp.float32)

    # Λ = λ @ U on a 16-row fragment (rows identical, tl.dot needs M >= 16)
    lam16 = jnp.broadcast_to(lam[None, :], (TILE, q))
    cum16 = jax.lax.dot_general(
        lam16, u, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    cum = jnp.max(cum16, axis=0)                         # (q,)

    # M[t, τ] = exp(Λ_t − Λ_τ) for τ ≤ t; y_t = Σ_τ M[t, τ] x_τ on the
    # same replicated-fragment trick, collapsing identical rows after.
    diff = cum[:, None] - cum[None, :]
    m = jnp.where(rows >= cols, jnp.exp(diff), 0.0)      # (q, q)
    x16 = jnp.broadcast_to(x[None, :], (TILE, q))
    y16 = jax.lax.dot_general(
        x16, m, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)              # (16, q) identical
    o_ref[...] = jnp.max(y16, axis=0)


@functools.partial(jax.jit, static_argnames=("q", "num_warps", "num_stages",
                                             "interpret"))
def triton_local_weighted(x: jax.Array, lam: jax.Array, *,
                          q: int | None = None,
                          num_warps: int | None = None,
                          num_stages: int | None = None,
                          interpret: bool = False) -> jax.Array:
    """Per-block weighted scan: x, lam (rows, n) -> (rows, n) f32 with
    ``h_t = exp(lam_t) h_{t-1} + x_t`` restarted at every ``q``-block
    boundary. Fully parallel grid."""
    spec = default_tuning("gpu", "weighted_scan")
    q = q or spec["q"]
    rows, n = x.shape
    if n % q:
        raise ValueError(f"n={n} must be a multiple of q={q}")
    return pl.pallas_call(
        functools.partial(_local_weighted_kernel, q=q),
        grid=(rows, n // q),
        in_specs=[
            pl.BlockSpec((None, q), lambda i, j: (i, j)),
            pl.BlockSpec((None, q), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((None, q), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((rows, n), jnp.float32),
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_local_weighted",
    )(x, lam)


def _local_ssd_kernel(xdt_ref, lam_ref, b_ref, c_ref, y_ref, s_ref, *,
                      q: int):
    xdt = xdt_ref[...].astype(jnp.float32)               # (q, P)
    lam = lam_ref[...].astype(jnp.float32)               # (q,)
    bmat = b_ref[...].astype(jnp.float32)                # (q, N)
    cmat = c_ref[...].astype(jnp.float32)                # (q, N)

    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    u = (rows <= cols).astype(jnp.float32)

    lam16 = jnp.broadcast_to(lam[None, :], (TILE, q))
    cum16 = jax.lax.dot_general(
        lam16, u, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    cum = jnp.max(cum16, axis=0)                         # (q,)
    total = jnp.sum(lam)

    # Intra-chunk only: Y_local = ((C Bᵀ) ∘ M) @ (dt∘X); the inter-chunk
    # H term is added by the glue after the tree combine.
    diff = cum[:, None] - cum[None, :]
    m = jnp.where(rows >= cols, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y_ref[...] = jax.lax.dot_general(
        cb * m, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    # Per-chunk state contribution S = (B ∘ w)ᵀ @ (dt∘X), w_τ = exp(Σλ − Λ_τ)
    bw = bmat * jnp.exp(total - cum)[:, None]
    s_ref[...] = jax.lax.dot_general(
        bw, xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("q", "num_warps", "num_stages",
                                             "interpret"))
def triton_local_ssd(
    xdt: jax.Array,     # (BH, L, P)  dt-weighted inputs, P % 16 == 0
    lam: jax.Array,     # (BH, L)     per-step log decay
    b: jax.Array,       # (BH, L, N)  N % 16 == 0
    c: jax.Array,       # (BH, L, N)
    *,
    q: int | None = None,
    num_warps: int | None = None,
    num_stages: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Carry-free SSD chunk pass on a fully parallel grid. Returns
    ``(y_local (BH, L, P), s (BH, nchunks*N, P))``."""
    spec = default_tuning("gpu", "ssd")
    q = q or spec["q"]
    bh, seqlen, hdim = xdt.shape
    nstate = b.shape[-1]
    if seqlen % q:
        raise ValueError(f"L={seqlen} must be a multiple of {q}")
    if nstate % TILE or hdim % TILE:
        raise ValueError(
            f"N={nstate}, P={hdim} must be multiples of {TILE} (MMA shape)")
    nchunks = seqlen // q
    return pl.pallas_call(
        functools.partial(_local_ssd_kernel, q=q),
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((None, q, hdim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q), lambda i, j: (i, j)),
            pl.BlockSpec((None, q, nstate), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, q, nstate), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, q, hdim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((None, nstate, hdim), lambda i, j: (i, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seqlen, hdim), jnp.float32),
            jax.ShapeDtypeStruct((bh, nchunks * nstate, hdim), jnp.float32),
        ],
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_local_ssd",
    )(xdt, lam, b, c)
