"""Pallas-Triton kernel: RMSNorm with a matmul-form sum-of-squares (GPU twin
of ``repro.kernels.fused_rmsnorm``).

Same algebra as the TPU twin: the row reduction is fed through the tensor
core as ``(x∘x) @ 1`` with the all-ones RHS doubling as the lane broadcast
(every output lane holds the row's sum of squares, so no cross-lane shuffle
is needed before the elementwise normalisation — the effect the V100 paper
needed Listing-3 layout hacks for).

GPU restructure: a (128, 8192) f32 row block does not fit in a CTA's
registers, so the kernel makes two passes over the feature dim in
``block_d`` chunks — pass 1 accumulates the chained sum-of-squares MMA,
pass 2 re-reads x (L2-hot) and writes the normalised output. Unlike the TPU
twin, the feature dim may be zero-padded: the true ``d`` is a separate
static divisor, so Σx² over the padded row is exact.

Grid: ``(rows / row_block,)``. The block geometry and launch shape are
caller-supplied (a resolved ``TuneSpec``, clamped against the actual
feature dim by the glue — a ``block_d`` wider than the padded row shrinks
to fit instead of crashing); defaults live in ``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import MMA_TILE as TILE
from repro.kernels.layout import default_tuning


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d: int,
                    block_d: int, nchunks: int):
    ones = jnp.ones((block_d, TILE), jnp.float32)

    def ssq_body(k, acc):
        xx = pl.load(
            x_ref, (slice(None), pl.dslice(k * block_d, block_d))
        ).astype(jnp.float32)
        # (x∘x) @ 1 : matmul-form row reduction, lanes replicated
        return acc + jax.lax.dot_general(
            xx * xx, ones, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    ssq = jax.lax.fori_loop(
        0, nchunks, ssq_body,
        jnp.zeros((x_ref.shape[0], TILE), jnp.float32))
    # lanes are identical; collapse without arithmetic, divide by the TRUE d
    rstd = jax.lax.rsqrt(jnp.max(ssq, axis=1, keepdims=True) / d + eps)

    def norm_body(k, _):
        sl = (slice(None), pl.dslice(k * block_d, block_d))
        xx = pl.load(x_ref, sl).astype(jnp.float32)
        w = pl.load(w_ref, (slice(None), sl[1])).astype(jnp.float32)  # (1, BD)
        pl.store(o_ref, sl, (xx * rstd * w).astype(o_ref.dtype))
        return 0

    jax.lax.fori_loop(0, nchunks, norm_body, 0)


@functools.partial(jax.jit, static_argnames=("eps", "d", "block_r",
                                             "block_d", "num_warps",
                                             "num_stages", "interpret"))
def triton_fused_rmsnorm(
    x: jax.Array, w: jax.Array, *, eps: float = 1e-6, d: int | None = None,
    block_r: int | None = None, block_d: int | None = None,
    num_warps: int | None = None, num_stages: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """RMSNorm rows of ``x (rows, d_pad)`` by ``w (d_pad,)``.

    ``rows % block_r == 0`` and ``d_pad % block_d == 0`` (wrapper pads the
    feature dim with zeros and passes the true feature count as ``d``).
    """
    spec = default_tuning("gpu", "rmsnorm")
    block_r = block_r or spec["row_block"]
    block_d = block_d or spec["block_d"]
    rows, d_pad = x.shape
    if d is None:
        d = d_pad
    if rows % block_r or d_pad % block_d:
        raise ValueError(
            f"shape {x.shape} must tile {(block_r, block_d)}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d, block_d=block_d,
                          nchunks=d_pad // block_d),
        grid=(rows // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d_pad), lambda i: (i, 0)),
            pl.BlockSpec((1, d_pad), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, d_pad), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d_pad), x.dtype),
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_fused_rmsnorm",
    )(x, w.reshape(1, d_pad))
