"""Pallas-Triton kernel: Mamba-2 SSD chunked scan (GPU twin of
``repro.kernels.ssd_scan``) — the paper's scan, decay-weighted.

Same algebra as the TPU twin: intra-chunk ``(C Bᵀ ∘ M) @ X`` with
``M = exp(segsum(λ))`` a weighted lower-triangle (λ ≡ 0, N = P = 1 recovers
the paper's plain tile scan), and the chunk-state recurrence
``H_k = exp(Σλ)·H_{k-1} + S_k`` as the carry.

GPU restructure: the carry cannot ride a sequential grid dimension (CUDA
grids are parallel), so each program owns one folded (batch·head) row and
walks its chunks with an in-kernel ``fori_loop``, holding H (N, P) in
registers. The within-chunk cumulative decay Λ stays matmul-form (λ @ U),
broadcast to a 16-row fragment so the MMA shape is legal (tl.dot needs
M ≥ 16); all 16 result rows are identical and collapse without arithmetic.

Grid: ``(B·H,)``; the default chunk length (two tensor-core fragments)
lives in ``repro.kernels.layout`` — registers, not VMEM, bound the chunk
size here, and the caller supplies it (a resolved ``TuneSpec``) along
with the launch shape.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import MMA_TILE as TILE
from repro.kernels.layout import default_tuning


def _ssd_kernel(xdt_ref, lam_ref, b_ref, c_ref, y_ref, state_ref, *,
                q: int, nchunks: int, nstate: int, hdim: int):
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    u = (rows <= cols).astype(jnp.float32)

    def body(jc, h):
        tsl = pl.dslice(jc * q, q)
        xdt = pl.load(xdt_ref, (tsl, slice(None))).astype(jnp.float32)  # (Q,P)
        lam = pl.load(lam_ref, (tsl,)).astype(jnp.float32)              # (Q,)
        bmat = pl.load(b_ref, (tsl, slice(None))).astype(jnp.float32)   # (Q,N)
        cmat = pl.load(c_ref, (tsl, slice(None))).astype(jnp.float32)   # (Q,N)

        # Λ = λ @ U in matmul form, on a 16-row fragment (rows identical).
        lam16 = jnp.broadcast_to(lam[None, :], (TILE, q))
        cum16 = jax.lax.dot_general(
            lam16, u, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (16, Q)
        cum = jnp.max(cum16, axis=0)                         # (Q,)
        total = jnp.sum(lam)                                 # Σ_chunk λ

        # M[t, τ] = exp(Λ_t − Λ_τ) for τ ≤ t  (weighted L+I mask)
        diff = cum[:, None] - cum[None, :]
        m = jnp.where(rows >= cols, jnp.exp(diff), 0.0)      # (Q, Q)

        # Intra-chunk: Y = ((C Bᵀ) ∘ M) @ (dt∘X)
        cb = jax.lax.dot_general(
            cmat, bmat, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # (Q, Q)
        y = jax.lax.dot_general(
            cb * m, xdt, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (Q, P)

        # Inter-chunk: Y += (C ∘ exp(Λ)) @ H_prev
        y += jax.lax.dot_general(
            cmat * jnp.exp(cum)[:, None], h, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        pl.store(y_ref, (tsl, slice(None)), y)

        # State update: H = exp(Σλ)·H + (B ∘ w)ᵀ @ (dt∘X), w_τ = exp(Σλ − Λ_τ)
        bw = bmat * jnp.exp(total - cum)[:, None]            # (Q, N)
        s_new = jax.lax.dot_general(
            bw, xdt, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)              # (N, P)
        return jnp.exp(total) * h + s_new

    h = jax.lax.fori_loop(
        0, nchunks, body, jnp.zeros((nstate, hdim), jnp.float32))
    state_ref[...] = h


@functools.partial(jax.jit, static_argnames=("q", "num_warps", "num_stages",
                                             "interpret"))
def triton_ssd_chunk_scan(
    xdt: jax.Array,     # (BH, L, P)  dt-weighted inputs, P % 16 == 0 (padded)
    lam: jax.Array,     # (BH, L)     per-step log decay  a_h · dt
    b: jax.Array,       # (BH, L, N)  N % 16 == 0 (padded)
    c: jax.Array,       # (BH, L, N)
    *,
    q: int | None = None,
    num_warps: int | None = None,
    num_stages: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (BH, L, P) f32, final_state (BH, N, P))."""
    spec = default_tuning("gpu", "ssd")
    q = q or spec["q"]
    bh, seqlen, hdim = xdt.shape
    nstate = b.shape[-1]
    if seqlen % q:
        raise ValueError(f"L={seqlen} must be a multiple of {q}")
    if nstate % TILE or hdim % TILE:
        raise ValueError(
            f"N={nstate}, P={hdim} must be multiples of {TILE} (MMA shape)")
    nchunks = seqlen // q
    return pl.pallas_call(
        functools.partial(_ssd_kernel, q=q, nchunks=nchunks,
                          nstate=nstate, hdim=hdim),
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((None, seqlen, hdim), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, seqlen), lambda i: (i, 0)),
            pl.BlockSpec((None, seqlen, nstate), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, seqlen, nstate), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, seqlen, hdim), lambda i: (i, 0, 0)),
            pl.BlockSpec((None, nstate, hdim), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seqlen, hdim), jnp.float32),
            jax.ShapeDtypeStruct((bh, nstate, hdim), jnp.float32),
        ],
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_ssd_chunk_scan",
    )(xdt, lam, b, c)
