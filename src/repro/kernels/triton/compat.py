"""Version shim for the Pallas-Triton (GPU) lowering.

This is the ONLY module in the repo allowed to import
``jax.experimental.pallas.triton`` — the same discipline the raw
compiler-params guard enforces for ``pltpu`` (a grep-guard test checks it).
Like the TPU side, the class name drifts across JAX releases
(``TritonCompilerParams`` on 0.4.x, ``CompilerParams`` on newer trees), so
every Triton kernel builds its params through :func:`compiler_params` here
(usually via ``repro.kernels.backend.compiler_params(backend="gpu", ...)``).
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any


def _plgpu():
    from jax.experimental.pallas import triton as plgpu

    return plgpu


def available() -> bool:
    """True when this JAX ships the Pallas-Triton lowering at all."""
    try:
        _plgpu()
        return True
    except ImportError:
        return False


def compiler_params_cls() -> type:
    """The Pallas-Triton compiler-params class under whichever name the
    installed JAX uses (``CompilerParams`` preferred, ``TritonCompilerParams``
    on 0.4.x)."""
    plgpu = _plgpu()
    for name in ("CompilerParams", "TritonCompilerParams"):
        cls = getattr(plgpu, name, None)
        if cls is not None:
            return cls
    import jax

    raise RuntimeError(
        f"jax {jax.__version__}: no Pallas-Triton compiler-params class "
        "found; the version shim in repro.kernels.triton.compat needs a new "
        "spelling"
    )


def _accepted_fields(cls: type) -> set[str]:
    if dataclasses.is_dataclass(cls):
        return {f.name for f in dataclasses.fields(cls)}
    return set(inspect.signature(cls).parameters)


def compiler_params(**kwargs: Any):
    """Construct Triton compiler params portably, dropping fields the
    installed JAX doesn't know (including TPU-only knobs such as
    ``dimension_semantics`` — GPU grids are always parallel)."""
    cls = compiler_params_cls()
    fields = _accepted_fields(cls)
    return cls(**{k: v for k, v in kwargs.items() if k in fields})
