"""Padding/layout glue for the Pallas-Triton twins — the ``tile_gpu``
entries of the ``repro.kernels.backend`` op registry.

Mirrors the TPU glue in ``repro.kernels.ops`` with GPU tile multiples
(16-wide tensor-core MMA fragments instead of 128-lane MXU tiles) and GPU
layouts (row-major segment rows — no transposed LoadTile). Registration
happens in ``repro.kernels.ops`` next to the TPU entries; nothing here
imports that module (it imports us).

Every wrapper takes ``interpret=`` (True runs the kernel body through the
Pallas interpreter — how CI validates this subsystem on CPU; False
compiles through Triton and therefore requires a GPU — forcing
``path="tile_gpu"`` on a non-GPU host raises immediately rather than
failing inside the compiler) and ``tuning=`` (the resolved
``repro.core.policy.TuneSpec``; None falls back to the GPU defaults in
``repro.kernels.layout``). Block knobs are clamped against the actual
shape via :func:`repro.kernels.layout.fit_block` — a swept or
hand-written spec shrinks to fit a small/unaligned dim (or the wrapper
falls back to the oracle, the attention idiom) instead of crashing.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import backend, layout, ref
from repro.kernels.layout import MMA_TILE as TILE
from repro.kernels.layout import fit_block, nrows, pad_axis, ssd_fold, \
    ssd_unfold
from repro.kernels.matmul_scan import tree_scan, tree_weighted
from repro.kernels.triton.flash_attention import triton_flash_attention
from repro.kernels.triton.fused_rmsnorm import triton_fused_rmsnorm
from repro.kernels.triton.matmul_scan import (
    triton_local_scan,
    triton_local_ssd,
    triton_local_weighted,
)
from repro.kernels.triton.ssd_scan import triton_ssd_chunk_scan
from repro.kernels.triton.tcu_reduce import triton_segmented_reduce
from repro.kernels.triton.tcu_scan import triton_segmented_scan


def _require_gpu(interpret: bool, name: str) -> None:
    if not interpret and not backend.on_gpu():
        raise RuntimeError(
            f"{name}: path='tile_gpu' compiles through Pallas-Triton and "
            f"needs a GPU, but the active JAX backend is "
            f"{jax.default_backend()!r}; use path='interpret' for CPU "
            "validation, or the backend-agnostic path='tile' / 'auto'")


def _knob(tuning, key: str, op: str) -> int:
    """One GPU-geometry knob from the resolved TuneSpec (or the layout
    default when no spec reached this glue — direct/legacy callers)."""
    return layout.knob(tuning, key, "gpu", op)


def _launch(tuning, op: str) -> dict:
    """The Triton launch-shape knobs (``num_warps``/``num_stages``)."""
    return {"num_warps": _knob(tuning, "num_warps", op),
            "num_stages": _knob(tuning, "num_stages", op)}


# ---------------------------------------------------------------------------
# segmented reduce / scan


def reduce_tile_gpu(x: jax.Array, *, tuning=None,
                    interpret: bool = False) -> jax.Array:
    _require_gpu(interpret, "segmented_reduce")
    lead = x.shape[:-1]
    n = x.shape[-1]
    flat = x.reshape(-1, n)
    bs = fit_block(flat.shape[0], _knob(tuning, "block_s", "reduce"), TILE)
    bn = fit_block(n, _knob(tuning, "block_n", "reduce"), TILE)
    # row-major LoadTile: rows are segments; pad to the block grid
    xp = pad_axis(pad_axis(flat, 0, bs), 1, bn)
    out = triton_segmented_reduce(xp, block_s=bs, block_n=bn,
                                  interpret=interpret,
                                  **_launch(tuning, "reduce"))
    return out[: flat.shape[0]].reshape(lead)


def scan_tile_gpu(x: jax.Array, *, tuning=None,
                  interpret: bool = False) -> jax.Array:
    _require_gpu(interpret, "segmented_scan")
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = nrows(lead)
    bs = fit_block(rows, _knob(tuning, "block_s", "scan"), TILE)
    bn = fit_block(n, _knob(tuning, "block_n", "scan"), TILE)
    flat = pad_axis(pad_axis(x.reshape(-1, n), 0, bs), 1, bn)
    out = triton_segmented_scan(flat, block_s=bs, block_n=bn,
                                interpret=interpret,
                                **_launch(tuning, "scan"))
    return out[:rows, :n].reshape(*lead, n)


def scan_tile_logdepth_gpu(x: jax.Array, *, tuning=None,
                           interpret: bool = False) -> jax.Array:
    """Log-depth MatMulScan: carry-free local block scans (fully parallel
    grid, no ``fori_loop``) + the shared O(log_radix nblocks) tree combine
    of batched MMAs over block totals."""
    _require_gpu(interpret, "segmented_scan[tile_logdepth]")
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = nrows(lead)
    bs = fit_block(rows, _knob(tuning, "block_s", "scan"), TILE)
    bn = fit_block(n, _knob(tuning, "block_n", "scan"), TILE)
    flat = pad_axis(pad_axis(x.reshape(-1, n), 0, bs), 1, bn)
    local = triton_local_scan(flat, block_s=bs, block_n=bn,
                              interpret=interpret,
                              **_launch(tuning, "scan"))
    s_pad, n_pad = local.shape
    nchunks = n_pad // bn
    if nchunks > 1:
        carry = tree_scan(local[:, bn - 1::bn],
                          radix=_knob(tuning, "radix", "scan"),
                          fan_in=_knob(tuning, "fan_in", "scan"))
        exc = jnp.pad(carry, ((0, 0), (1, 0)))[:, :-1]
        local = (local.reshape(s_pad, nchunks, bn)
                 + exc[..., None]).reshape(s_pad, n_pad)
    return local[:rows, :n].reshape(*lead, n)


# ---------------------------------------------------------------------------
# weighted scan (the SSD kernel degenerated to N = P = 1, B = C = 1)


def weighted_scan_tile_gpu(x: jax.Array, log_a: jax.Array, *, tuning=None,
                           interpret: bool = False) -> jax.Array:
    _require_gpu(interpret, "weighted_scan")
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = nrows(lead)
    q = fit_block(n, _knob(tuning, "q", "weighted_scan"), TILE)
    xf = x.reshape(rows, n).astype(jnp.float32)
    la = log_a.reshape(rows, n).astype(jnp.float32)
    # state dim N=1 and head dim P=1, padded to one MMA fragment edge:
    # b = c = e_1 make the recurrence y_t = h_t = exp(la_t) h_{t-1} + x_t.
    xp = pad_axis(pad_axis(xf[..., None], 2, TILE), 1, q)
    lap = pad_axis(la, 1, q)       # pad with 0 ⇒ decay 1, input 0: harmless
    e1 = jnp.ones((rows, n, 1), jnp.float32)
    e1 = pad_axis(pad_axis(e1, 2, TILE), 1, q)
    y, _ = triton_ssd_chunk_scan(xp, lap, e1, e1, q=q, interpret=interpret,
                                 **_launch(tuning, "weighted_scan"))
    return y[:, :n, 0].reshape(*lead, n)


def weighted_scan_tile_logdepth_gpu(x: jax.Array, log_a: jax.Array, *,
                                    tuning=None,
                                    interpret: bool = False) -> jax.Array:
    """Log-depth weighted scan: per-block 1-semiseparable local passes +
    the decay-folded tree combine over block boundary states."""
    _require_gpu(interpret, "weighted_scan[tile_logdepth]")
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = nrows(lead)
    q = fit_block(n, _knob(tuning, "q", "weighted_scan"), TILE)
    xf = x.reshape(rows, n).astype(jnp.float32)
    la = log_a.reshape(rows, n).astype(jnp.float32)
    xp = pad_axis(xf, 1, q)
    lap = pad_axis(la, 1, q)       # pad with 0 ⇒ decay 1, input 0: harmless
    local = triton_local_weighted(xp, lap, q=q, interpret=interpret,
                                  **_launch(tuning, "weighted_scan"))
    nchunks = xp.shape[1] // q
    if nchunks > 1:
        lg = lap.reshape(rows, nchunks, q)
        carry = tree_weighted(
            jnp.sum(lg, axis=-1), local[:, q - 1::q, None],
            radix=_knob(tuning, "radix", "weighted_scan"),
            fan_in=_knob(tuning, "fan_in", "weighted_scan"))[..., 0]
        exc = jnp.pad(carry, ((0, 0), (1, 0)))[:, :-1]
        local = (local.reshape(rows, nchunks, q)
                 + jnp.exp(jnp.cumsum(lg, axis=-1)) * exc[..., None]
                 ).reshape(rows, -1)
    return local[:, :n].reshape(*lead, n)


# ---------------------------------------------------------------------------
# rmsnorm (forward only — ops.rmsnorm wraps every path in one custom VJP)


def rmsnorm_tile_gpu_fwd(x: jax.Array, w: jax.Array, eps: float,
                         interpret: bool, tuning=None) -> jax.Array:
    _require_gpu(interpret, "rmsnorm")
    lead, d = x.shape[:-1], x.shape[-1]
    rows = nrows(lead)
    br = fit_block(rows, _knob(tuning, "row_block", "rmsnorm"), TILE)
    # clamp block_d to the padded feature extent, then pad d to a multiple
    # of the fitted block: divisibility holds for ANY d (the fix for the
    # fixed-128 chunk crashing/padding-wasting lane-unaligned dims)
    bd = fit_block(d, _knob(tuning, "block_d", "rmsnorm"), TILE)
    flat = pad_axis(pad_axis(x.reshape(-1, d), 0, br), 1, bd)
    wp = pad_axis(w, 0, bd)
    out = triton_fused_rmsnorm(flat, wp, eps=eps, d=d, block_r=br,
                               block_d=bd, interpret=interpret,
                               **_launch(tuning, "rmsnorm"))
    return out[:rows, :d].reshape(*lead, d)


# ---------------------------------------------------------------------------
# SSD scan


def ssd_tile_gpu(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)    positive step sizes
    a: jax.Array,       # (H,)         negative decay rates
    b: jax.Array,       # (B, L, G, N)
    c: jax.Array,       # (B, L, G, N)
    *,
    return_state: bool = False,
    tuning=None,
    interpret: bool = False,
):
    _require_gpu(interpret, "ssd_scan")
    bsz, seqlen, nheads, hdim = x.shape
    nstate = b.shape[3]
    q = fit_block(seqlen, _knob(tuning, "q", "ssd"), TILE)
    xdt, lam, bb, cc = ssd_fold(x, dt, a, b, c)
    # pad P and N to the MMA fragment edge, L to the chunk length
    xdt = pad_axis(pad_axis(xdt, 2, TILE), 1, q)
    lam = pad_axis(lam, 1, q)
    bb = pad_axis(pad_axis(bb, 2, TILE), 1, q)
    cc = pad_axis(pad_axis(cc, 2, TILE), 1, q)
    y, state = triton_ssd_chunk_scan(xdt, lam, bb, cc, q=q,
                                     interpret=interpret,
                                     **_launch(tuning, "ssd"))
    return ssd_unfold(y, state, bsz=bsz, nheads=nheads, seqlen=seqlen,
                      hdim=hdim, nstate=nstate, out_dtype=x.dtype,
                      return_state=return_state)


def ssd_tile_logdepth_gpu(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)    positive step sizes
    a: jax.Array,       # (H,)         negative decay rates
    b: jax.Array,       # (B, L, G, N)
    c: jax.Array,       # (B, L, G, N)
    *,
    return_state: bool = False,
    tuning=None,
    interpret: bool = False,
):
    """Log-depth SSD: carry-free per-chunk passes emit (y_local, S_j);
    the chunk-state recurrence runs as the weighted tree combine and the
    inter-chunk term is one batched matmul per chunk."""
    _require_gpu(interpret, "ssd_scan[tile_logdepth]")
    bsz, seqlen, nheads, hdim = x.shape
    nstate = b.shape[3]
    q = fit_block(seqlen, _knob(tuning, "q", "ssd"), TILE)
    xdt, lam, bb, cc = ssd_fold(x, dt, a, b, c)
    xdt = pad_axis(pad_axis(xdt, 2, TILE), 1, q)
    lam = pad_axis(lam, 1, q)
    bb = pad_axis(pad_axis(bb, 2, TILE), 1, q)
    cc = pad_axis(pad_axis(cc, 2, TILE), 1, q)
    y, s = triton_local_ssd(xdt, lam, bb, cc, q=q, interpret=interpret,
                            **_launch(tuning, "ssd"))
    bh, l_pad, p_pad = xdt.shape
    n_pad = bb.shape[2]
    nchunks = l_pad // q
    lg = lam.reshape(bh, nchunks, q)
    # pad chunks have λ = 0 and S = 0: identity steps, H passes through
    h_inc = tree_weighted(
        jnp.sum(lg, axis=-1), s.reshape(bh, nchunks, n_pad * p_pad),
        radix=_knob(tuning, "radix", "ssd"),
        fan_in=_knob(tuning, "fan_in", "ssd"))
    h_exc = jnp.pad(h_inc, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    h_exc = h_exc.reshape(bh, nchunks, n_pad, p_pad)
    cdec = (cc.reshape(bh, nchunks, q, n_pad)
            * jnp.exp(jnp.cumsum(lg, axis=-1))[..., None])
    y = (y.reshape(bh, nchunks, q, p_pad)
         + jnp.einsum("bjqn,bjnp->bjqp", cdec, h_exc)
         ).reshape(bh, l_pad, p_pad)
    state = h_inc[:, -1].reshape(bh, n_pad, p_pad)
    return ssd_unfold(y, state, bsz=bsz, nheads=nheads, seqlen=seqlen,
                      hdim=hdim, nstate=nstate, out_dtype=x.dtype,
                      return_state=return_state)


# ---------------------------------------------------------------------------
# attention


def attention_tile_gpu(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, tuning=None, interpret: bool = False,
) -> jax.Array:
    _require_gpu(interpret, "attention")
    lq, lk, d = q.shape[2], k.shape[2], q.shape[3]
    bq = fit_block(lq, _knob(tuning, "block_q", "attention"), TILE)
    bk = fit_block(lk, _knob(tuning, "block_k", "attention"), TILE)
    if lq % bq or lk % bk or d % TILE:  # kernel is block-strict -> oracle
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    return triton_flash_attention(q, k, v, causal=causal, window=window,
                                  scale=scale, block_q=bq, block_k=bk,
                                  interpret=interpret,
                                  **_launch(tuning, "attention"))
