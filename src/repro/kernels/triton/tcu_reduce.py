"""Pallas-Triton kernel: matmul-form segmented reduction (GPU twin of
``repro.kernels.tcu_reduce``).

Paper mapping (Dakkak et al. ICS'19, Alg. 3), GPU-adapted per the
tensor-core reduction follow-ups (arXiv:1903.03640, arXiv:2001.05585):

* The paper loads tiles column-major so 16 segments fill the 16 rows of a
  WMMA fragment and one ``P @ A`` pass reduces all of them. On the GPU we
  keep the natural row-major layout (rows = segments, coalesced loads) and
  put the ones vector on the *right*: ``A @ 1`` sums each fragment row —
  the transpose of the paper's P-matrix trick, same MMA work.
* The work-efficient chained accumulation ``V_i = A_i·1 + V_{i-1}`` is an
  in-kernel ``fori_loop`` over column chunks with the accumulator in
  registers. CUDA grids have no sequential-dimension semantics (unlike TPU
  Pallas grids), so the carry cannot live in a grid-walked scratch buffer —
  every chained MMA happens inside one program.
* The ones RHS is ``(BLOCK_N, 16)``: 16 lanes is the tensor-core fragment
  edge, and replicating the row sums across all 16 output lanes costs
  nothing while keeping every ``jnp.dot`` shape MMA-legal (tl.dot needs
  M, N, K >= 16).

Grid: ``(S / block_s,)`` — segment blocks parallel across CTAs. The block
geometry and launch shape (``num_warps``/``num_stages``) are
caller-supplied (a resolved ``TuneSpec``); defaults live in
``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import MMA_TILE as TILE
from repro.kernels.layout import default_tuning


def _reduce_kernel(x_ref, o_ref, *, block_s: int, block_n: int, nchunks: int):
    ones = jnp.ones((block_n, TILE), jnp.float32)

    def body(k, acc):
        a = pl.load(x_ref, (slice(None), pl.dslice(k * block_n, block_n)))
        # A @ 1 : every output lane holds the row (segment) sums.
        return acc + jax.lax.dot_general(
            a.astype(jnp.float32), ones, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    acc = jax.lax.fori_loop(
        0, nchunks, body, jnp.zeros((block_s, TILE), jnp.float32))
    # all TILE lanes are identical; max-collapse is a shuffle, not arithmetic
    o_ref[...] = jnp.max(acc, axis=1)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_n", "num_warps",
                                    "num_stages", "interpret"))
def triton_segmented_reduce(x: jax.Array, *, block_s: int | None = None,
                            block_n: int | None = None,
                            num_warps: int | None = None,
                            num_stages: int | None = None,
                            interpret: bool = False) -> jax.Array:
    """Reduce rows of ``x``: (s, n) -> (s,) f32. Rows are independent
    segments; ``s % block_s == 0`` and ``n % block_n == 0`` (wrapper pads).
    """
    spec = default_tuning("gpu", "reduce")
    block_s = block_s or spec["block_s"]
    block_n = block_n or spec["block_n"]
    s, n = x.shape
    if s % block_s or n % block_n:
        raise ValueError(
            f"dims must be multiples of {(block_s, block_n)}, got {x.shape}")
    return pl.pallas_call(
        functools.partial(_reduce_kernel, block_s=block_s, block_n=block_n,
                          nchunks=n // block_n),
        grid=(s // block_s,),
        in_specs=[pl.BlockSpec((block_s, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_s,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((s,), jnp.float32),
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_segmented_reduce",
    )(x)
