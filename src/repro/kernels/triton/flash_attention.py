"""Pallas-Triton kernel: blocked (flash) attention with GQA + sliding window
(GPU twin of ``repro.kernels.flash_attention``).

Same online-softmax algebra as the TPU twin — the denominator update
``l += rowsum(exp(S − m))`` rides the tensor core as ``p @ 1`` (the paper's
P-matrix reduction); only the row-max stays a vector reduction (max has no
matmul form).

GPU restructure: the TPU twin walks kv blocks along an innermost
*sequential* grid dimension with VMEM scratch carries; CUDA grids are
parallel, so here each program owns one (batch, q-head, q-block) and walks
the kv blocks with an in-kernel ``fori_loop``, carrying ``(m, l, acc)`` in
registers. Block-level causal/window skipping becomes loop-bound
arithmetic: the loop runs ``[lo, hi)`` where ``hi`` clips fully-future kv
blocks (causal) and ``lo`` clips fully-expired ones (sliding window) —
the same work-skipping as the TPU twin's ``pl.when`` visibility test.

Grid: ``(B, Hq, Lq/BLOCK_Q)``; GQA via the k/v index maps (q head h reads
kv head ``h // rep``), no repeated-KV materialisation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import MMA_TILE as TILE
from repro.kernels.layout import default_tuning

NEG_INF = float(-1e30)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, scale: float, causal: bool,
                  window: int | None, bq: int, bk: int, nk: int, offs: int):
    iq = pl.program_id(2)
    q = q_ref[...].astype(jnp.float32)               # (BQ, D)
    q_lo = iq * bq + offs                            # q rows in k coordinates
    q_hi = q_lo + bq - 1

    # block-granular visibility as loop bounds (TPU twin: pl.when per block)
    hi = jnp.minimum(nk, q_hi // bk + 1) if causal else nk
    lo = jnp.maximum(0, (q_lo - window + 1) // bk) if window is not None \
        else 0

    def body(jk, carry):
        m_prev, l_prev, acc = carry
        ksl = (pl.dslice(jk * bk, bk), slice(None))
        k = pl.load(k_ref, ksl).astype(jnp.float32)  # (BK, D)
        v = pl.load(v_ref, ksl).astype(jnp.float32)  # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                    # (BQ, BK)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = jk * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))      # (BQ,)
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)[:, None]               # (BQ, 1)
        # l update: rowsum(p) in matmul form (p @ 1) — paper's P-reduction.
        ones = jnp.ones((bk, TILE), jnp.float32)
        psum = jax.lax.dot_general(
            p, ones, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)               # (BQ, TILE)
        l_new = corr * l_prev + psum
        acc = corr * acc + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq, TILE), jnp.float32)
    acc0 = jnp.zeros((bq, q_ref.shape[-1]), jnp.float32)
    _, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))

    l1 = jnp.max(l, axis=1, keepdims=True)           # lanes identical
    safe = jnp.where(l1 > 0.0, l1, 1.0)
    o_ref[...] = (acc / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "num_warps", "num_stages", "interpret"),
)
def triton_flash_attention(
    q: jax.Array,       # (B, Hq, Lq, D)
    k: jax.Array,       # (B, Hkv, Lk, D)
    v: jax.Array,       # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    num_warps: int | None = None,
    num_stages: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    spec = default_tuning("gpu", "attention")
    block_q = block_q or spec["block_q"]
    block_k = block_k or spec["block_k"]
    bsz, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    rep = hq // hkv
    if lq % block_q or lk % block_k:
        raise ValueError(f"seq lens {(lq, lk)} must tile {(block_q, block_k)}")
    if d % TILE:
        raise ValueError(f"head dim {d} must be a multiple of {TILE}")
    scale_v = scale if scale is not None else 1.0 / (d ** 0.5)
    nk = lk // block_k
    offs = lk - lq  # align sequence ends (prefill: 0; decode chunks: >0)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale_v, causal=causal, window=window,
            bq=block_q, bk=block_k, nk=nk, offs=offs,
        ),
        grid=(bsz, hq, lq // block_q),
        in_specs=[
            pl.BlockSpec((None, None, block_q, d),
                         lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((None, None, lk, d),
                         lambda b, h, i, rep=rep: (b, h // rep, 0, 0)),
            pl.BlockSpec((None, None, lk, d),
                         lambda b, h, i, rep=rep: (b, h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d),
                               lambda b, h, i: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hq, lq, d), q.dtype),
        compiler_params=backend.compiler_params(
            backend="gpu",
            num_warps=num_warps or spec["num_warps"],
            num_stages=num_stages or spec["num_stages"]),
        interpret=interpret,
        name="triton_flash_attention",
    )(q, k, v)
