"""Pallas TPU kernel: work-efficient matmul-form segmented reduction.

Paper mapping (Dakkak et al. ICS'19, Alg. 3 / Fig. 7), TPU-adapted:

* The paper loads tiles **column-major** so 16 segments occupy the 16 rows of
  a WMMA fragment and one ``P @ A`` reduces all of them. Our analogue: the
  wrapper feeds the kernel ``x`` transposed to ``(n, s)`` so one VMEM block
  holds ``block_n`` elements (sublanes) x ``block_s`` segments (lanes) and
  one ``P_8 @ A`` MXU pass reduces a whole lane-row of segments at once.
* The paper's work-efficient trick — accumulate ``V_i = P·A_i + V_{i-1}``
  across tiles, one matmul each, collapsing only at the end — is the
  sequential innermost grid dimension with a VMEM scratch accumulator.
* The f32 scratch is (8, block_s): the live data is the paper's "first row
  of V"; 8 sublanes is the f32 minimum tile. The redundant 7 rows cost
  nothing (the MXU streams M=8 in one pass) — reduction stays memory-bound,
  which is the paper's central observation.

Grid: ``(S/block_s, N/block_n)`` — segments parallel, chunks sequential
(innermost). The block geometry is caller-supplied (a resolved
``TuneSpec``); defaults live in ``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.layout import LANES, SUBLANES, default_tuning


def _reduce_kernel(x_ref, o_ref, acc_ref, *, nchunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_ref[...]                                   # (block_n, block_s)
    # P @ A with P = ones in row 0: realised as an (8, block_n) ones LHS —
    # every result row holds the column sums; row 0 is the paper's V row.
    p = jnp.ones((SUBLANES, a.shape[0]), a.dtype)
    acc_ref[...] += jax.lax.dot_general(
        p, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == nchunks - 1)
    def _store():
        o_ref[...] = acc_ref[0, :].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_n", "interpret"))
def tcu_segmented_reduce_tn(xt: jax.Array, *, block_s: int | None = None,
                            block_n: int | None = None,
                            interpret: bool = False) -> jax.Array:
    """Reduce columns of ``xt``: (n, s) -> (s,). ``s % block_s == 0`` and
    ``n % block_n == 0`` (wrapper pads); ``block_s`` must be a lane
    multiple and ``block_n`` a sublane multiple.

    ``xt`` is the transposed segment matrix (the paper's col-major
    LoadTile).
    """
    spec = default_tuning("tpu", "reduce")
    block_s = block_s or spec["block_s"]
    block_n = block_n or spec["block_n"]
    n, s = xt.shape
    if block_s % LANES or block_n % SUBLANES:
        raise ValueError(
            f"blocks {(block_s, block_n)} must be multiples of "
            f"{(LANES, SUBLANES)}")
    if n % block_n or s % block_s:
        raise ValueError(
            f"dims must be multiples of {(block_n, block_s)}, got "
            f"{xt.shape}")
    nchunks = n // block_n
    return pl.pallas_call(
        functools.partial(_reduce_kernel, nchunks=nchunks),
        grid=(s // block_s, nchunks),
        in_specs=[pl.BlockSpec((block_n, block_s), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((block_s,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((s,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((SUBLANES, block_s), jnp.float32)],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tcu_segmented_reduce",
    )(xt)
