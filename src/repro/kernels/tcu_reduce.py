"""Pallas TPU kernel: work-efficient matmul-form segmented reduction.

Paper mapping (Dakkak et al. ICS'19, Alg. 3 / Fig. 7), TPU-adapted:

* The paper loads tiles **column-major** so 16 segments occupy the 16 rows of
  a WMMA fragment and one ``P @ A`` reduces all of them. Our analogue: the
  wrapper feeds the kernel ``x`` transposed to ``(n, s)`` so one VMEM block
  holds 128 elements (sublanes) x 128 segments (lanes) and one
  ``P_8 @ A`` MXU pass reduces 128 segments at once.
* The paper's work-efficient trick — accumulate ``V_i = P·A_i + V_{i-1}``
  across tiles, one matmul each, collapsing only at the end — is the
  sequential innermost grid dimension with a VMEM scratch accumulator.
* The f32 scratch is (8, 128): the live data is the paper's "first row of V";
  8 sublanes is the f32 minimum tile. The redundant 7 rows cost nothing
  (the MXU streams M=8 in one pass) — reduction stays memory-bound, which is
  the paper's central observation.

Grid: ``(S/128, N/128)`` — segments parallel, chunks sequential (innermost).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend

LANES = 128
SUBLANES = 8


def _reduce_kernel(x_ref, o_ref, acc_ref, *, nchunks: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = x_ref[...]                                   # (128, 128) = [n, s]
    # P @ A with P = ones in row 0: realised as an (8,128) ones LHS — every
    # result row holds the column sums; row 0 is the paper's V row.
    p = jnp.ones((SUBLANES, LANES), a.dtype)
    acc_ref[...] += jax.lax.dot_general(
        p, a, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(j == nchunks - 1)
    def _store():
        o_ref[...] = acc_ref[0, :].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def tcu_segmented_reduce_tn(xt: jax.Array, *, interpret: bool = False) -> jax.Array:
    """Reduce columns of ``xt``: (n, s) -> (s,). Both dims multiples of 128.

    ``xt`` is the transposed segment matrix (the paper's col-major LoadTile).
    """
    n, s = xt.shape
    if n % LANES or s % LANES:
        raise ValueError(f"dims must be multiples of {LANES}, got {xt.shape}")
    nchunks = n // LANES
    return pl.pallas_call(
        functools.partial(_reduce_kernel, nchunks=nchunks),
        grid=(s // LANES, nchunks),
        in_specs=[pl.BlockSpec((LANES, LANES), lambda i, j: (j, i))],
        out_specs=pl.BlockSpec((LANES,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((s,), jnp.float32),
        scratch_shapes=[pltpu.VMEM((SUBLANES, LANES), jnp.float32)],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="tcu_segmented_reduce",
    )(xt)
