"""Public jit'd wrappers for the Pallas kernels.

Dispatch policy: on TPU backends the Pallas kernels run natively; everywhere
else (this CPU container, tests) the pure-jnp references in ``ref.py`` are
used, unless ``interpret=True`` forces the kernel body through the Pallas
interpreter (how the kernels are validated on CPU). Wrappers own all
padding/layout glue so kernels stay shape-strict and MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention as _flash_kernel
from repro.kernels.fused_rmsnorm import fused_rmsnorm as _rmsnorm_kernel
from repro.kernels.ssd_scan import ssd_chunk_scan as _ssd_kernel
from repro.kernels.tcu_reduce import tcu_segmented_reduce_tn as _reduce_kernel
from repro.kernels.tcu_scan import tcu_segmented_scan_tn as _scan_kernel

LANES = 128


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _use_kernel(force: bool | None) -> tuple[bool, bool]:
    """-> (use_pallas, interpret)."""
    if force is None:
        return on_tpu(), False
    return bool(force), not on_tpu()


def _pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    rem = (-x.shape[axis]) % multiple
    if not rem:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def segmented_reduce(x: jax.Array, *, use_pallas: bool | None = None) -> jax.Array:
    """Sum over the last axis of ``x (..., n)`` -> f32 ``(...,)``."""
    use, interp = _use_kernel(use_pallas)
    if not use:
        return ref.segmented_reduce_ref(x)
    lead = x.shape[:-1]
    n = x.shape[-1]
    flat = x.reshape(-1, n)
    # col-major LoadTile: feed the kernel x^T, pad both dims to 128
    xt = _pad_axis(_pad_axis(flat.T, 0, LANES), 1, LANES)
    out = _reduce_kernel(xt, interpret=interp)
    return out[: flat.shape[0]].reshape(lead)


def segmented_scan(x: jax.Array, *, use_pallas: bool | None = None) -> jax.Array:
    """Inclusive prefix-sum over the last axis -> f32, same shape."""
    use, interp = _use_kernel(use_pallas)
    if not use:
        return ref.segmented_scan_ref(x)
    lead = x.shape[:-1]
    n = x.shape[-1]
    flat = _pad_axis(_pad_axis(x.reshape(-1, n), 0, LANES), 1, LANES)
    out = _scan_kernel(flat, interpret=interp)
    rows = int(jnp.prod(jnp.array(lead))) if lead else 1
    return out[:rows, :n].reshape(*lead, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def _rmsnorm_fwd_dispatch(x, w, eps, impl):
    use, interp = impl
    if not use:
        return ref.rmsnorm_ref(x, w, eps=eps)
    lead, d = x.shape[:-1], x.shape[-1]
    flat = _pad_axis(x.reshape(-1, d), 0, 128)
    out = _rmsnorm_kernel(flat, w, eps=eps, interpret=interp)
    rows = 1
    for s in lead:
        rows *= s
    return out[:rows].reshape(*lead, d)


def _rmsnorm_vjp_fwd(x, w, eps, impl):
    return _rmsnorm_fwd_dispatch(x, w, eps, impl), (x, w)


def _rmsnorm_vjp_bwd(eps, impl, res, g):
    # backward through the reference formulation (numerically identical)
    x, w = res
    _, vjp = jax.vjp(lambda xx, ww: ref.rmsnorm_ref(xx, ww, eps=eps), x, w)
    return vjp(g)


_rmsnorm_fwd_dispatch.defvjp(_rmsnorm_vjp_fwd, _rmsnorm_vjp_bwd)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            use_pallas: bool | None = None) -> jax.Array:
    """RMSNorm over the last axis (differentiable; Pallas fwd on TPU)."""
    return _rmsnorm_fwd_dispatch(x, w, eps, _use_kernel(use_pallas))


def ssd_scan(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)    positive step sizes
    a: jax.Array,       # (H,)         negative decay rates
    b: jax.Array,       # (B, L, G, N)
    c: jax.Array,       # (B, L, G, N)
    *,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Mamba-2 SSD scan -> (B, L, H, P) in the input dtype."""
    use, interp = _use_kernel(use_pallas)
    if not use:
        return ref.ssd_scan_ref(x, dt, a, b, c)
    bsz, seqlen, nheads, hdim = x.shape
    ngroups, nstate = b.shape[2], b.shape[3]
    rep = nheads // ngroups
    # fold (B, H) and broadcast groups; pad P (lane dim) and L to 128
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xdt = jnp.moveaxis(xdt, 2, 1).reshape(bsz * nheads, seqlen, hdim)
    lam = (dt.astype(jnp.float32) * a.astype(jnp.float32))
    lam = jnp.moveaxis(lam, 2, 1).reshape(bsz * nheads, seqlen)
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    bb = jnp.moveaxis(bb, 2, 1).reshape(bsz * nheads, seqlen, nstate)
    cc = jnp.moveaxis(cc, 2, 1).reshape(bsz * nheads, seqlen, nstate)
    xdt = _pad_axis(_pad_axis(xdt, 2, LANES), 1, LANES)
    lam = _pad_axis(lam, 1, LANES)
    bb = _pad_axis(_pad_axis(bb, 2, 8), 1, LANES)
    cc = _pad_axis(_pad_axis(cc, 2, 8), 1, LANES)
    y, _ = _ssd_kernel(xdt, lam, bb, cc, interpret=interp)
    y = y[:, :seqlen, :hdim].reshape(bsz, nheads, seqlen, hdim)
    return jnp.moveaxis(y, 1, 2).astype(x.dtype)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, use_pallas: bool | None = None,
) -> jax.Array:
    """Multi-head attention (B, Hq, Lq, D) x (B, Hkv, Lk, D) -> (B, Hq, Lq, D)."""
    use, interp = _use_kernel(use_pallas)
    lq, lk = q.shape[2], k.shape[2]
    if not use or lq % 128 or lk % 128:
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    return _flash_kernel(q, k, v, causal=causal, window=window, scale=scale,
                         interpret=interp)
