"""Public wrappers for the Pallas kernels, dispatched through
``repro.kernels.backend``.

Every op registers its path entries with :func:`backend.register_op`: the
*tile* entry is the padding/layout glue in this module feeding the
shape-strict, MXU-aligned Pallas-TPU kernel (native on TPU, interpret mode
on CPU); the *tile_gpu* entry is the Pallas-Triton twin's glue
(``repro.kernels.triton.ops``, native on GPU); the scan family also
registers *tile_logdepth* entries per backend (carry-free local kernels +
the ``matmul_scan`` tree combine); the *fused* entry is the pure-jnp
oracle in ``ref.py``. The execution path is chosen
per call (``policy=`` / ``path=`` / legacy ``use_pallas=``) or by the
active ``repro.core.policy.KernelPolicy`` (whose process default follows
``REPRO_KERNEL_PATH``) — see the backend module docstring for precedence;
the stable public façade over these ops is ``repro.ops``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import backend, layout, ref
from repro.kernels.backend import pallas_op
from repro.kernels.layout import LANES, SUBLANES
from repro.kernels.layout import nrows as _nrows
from repro.kernels.layout import pad_axis as _pad_axis
from repro.kernels.layout import ssd_fold, ssd_unfold

if backend.has_pallas_tpu():
    from repro.kernels import matmul_scan as _mm_scan
    from repro.kernels.flash_attention import flash_attention as _flash_kernel
    from repro.kernels.fused_rmsnorm import fused_rmsnorm as _rmsnorm_kernel
    from repro.kernels.ssd_scan import ssd_chunk_scan as _ssd_kernel
    from repro.kernels.tcu_reduce import (
        tcu_segmented_reduce_tn as _reduce_kernel)
    from repro.kernels.tcu_scan import tcu_segmented_scan_tn as _scan_kernel
else:  # pragma: no cover — JAX without the Pallas-TPU lowering
    _flash_kernel = _rmsnorm_kernel = _ssd_kernel = None
    _reduce_kernel = _scan_kernel = _mm_scan = None

if backend.has_pallas_triton():
    from repro.kernels.triton import ops as triton_ops
else:  # pragma: no cover — JAX without the Pallas-Triton lowering
    triton_ops = None


def _require_pallas(kernel, name: str):
    if kernel is None:
        raise RuntimeError(
            f"{name}: this JAX build has no Pallas-TPU lowering; only the "
            "fused path is available (path='fused')")
    return kernel


def _gpu_entry(fn_name: str):
    """The Triton glue entry, or None when this JAX has no Pallas-Triton."""
    return getattr(triton_ops, fn_name) if triton_ops is not None else None


def _knob(tuning, key: str, op: str) -> int:
    """One TPU-geometry knob from the resolved TuneSpec (or the layout
    default when no spec reached this glue — direct/legacy callers)."""
    return layout.knob(tuning, key, "tpu", op)


on_tpu = backend.on_tpu  # re-exported; historical home of this probe


# ---------------------------------------------------------------------------
# segmented reduce


def _reduce_tile(x: jax.Array, *, tuning=None,
                 interpret: bool = False) -> jax.Array:
    lead = x.shape[:-1]
    n = x.shape[-1]
    flat = x.reshape(-1, n)
    # spec geometry, clamped against the shape: segments ride the lanes,
    # elements the sublanes of the transposed LoadTile
    bs = layout.fit_block(flat.shape[0], _knob(tuning, "block_s", "reduce"),
                          LANES)
    bn = layout.fit_block(n, _knob(tuning, "block_n", "reduce"), SUBLANES)
    # col-major LoadTile: feed the kernel x^T, pad both dims to the blocks
    xt = _pad_axis(_pad_axis(flat.T, 0, bn), 1, bs)
    out = _require_pallas(_reduce_kernel, "segmented_reduce")(
        xt, block_s=bs, block_n=bn, interpret=interpret)
    return out[: flat.shape[0]].reshape(lead)


def segmented_reduce(x: jax.Array, *, policy=None, path: str | None = None,
                     use_pallas: bool | None = None) -> jax.Array:
    """Sum over the last axis of ``x (..., n)`` -> f32 ``(...,)``."""
    return pallas_op("segmented_reduce", x, policy=policy, path=path,
                     use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# segmented scan


def _scan_tile(x: jax.Array, *, tuning=None,
               interpret: bool = False) -> jax.Array:
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = _nrows(lead)
    bs = layout.fit_block(rows, _knob(tuning, "block_s", "scan"), SUBLANES)
    bn = layout.fit_block(n, _knob(tuning, "block_n", "scan"), LANES)
    flat = _pad_axis(_pad_axis(x.reshape(-1, n), 0, bs), 1, bn)
    out = _require_pallas(_scan_kernel, "segmented_scan")(
        flat, block_s=bs, block_n=bn, interpret=interpret)
    return out[:rows, :n].reshape(*lead, n)


def _scan_tile_logdepth(x: jax.Array, *, tuning=None,
                        interpret: bool = False) -> jax.Array:
    """Log-depth MatMulScan: carry-free local block scans (fully parallel
    Pallas grid) + an O(log_radix nblocks) tree combine of batched MMAs
    over the block totals (``repro.kernels.matmul_scan``)."""
    mm = _require_pallas(_mm_scan, "segmented_scan[tile_logdepth]")
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = _nrows(lead)
    bs = layout.fit_block(rows, _knob(tuning, "block_s", "scan"), SUBLANES)
    bn = layout.fit_block(n, _knob(tuning, "block_n", "scan"), LANES)
    flat = _pad_axis(_pad_axis(x.reshape(-1, n), 0, bs), 1, bn)
    local = mm.matmul_local_scan(flat, block_s=bs, block_n=bn,
                                 interpret=interpret)
    s_pad, n_pad = local.shape
    nchunks = n_pad // bn
    if nchunks > 1:
        totals = local[:, bn - 1::bn]                    # (s_pad, nchunks)
        carry = mm.tree_scan(totals,
                             radix=_knob(tuning, "radix", "scan"),
                             fan_in=_knob(tuning, "fan_in", "scan"))
        exc = jnp.pad(carry, ((0, 0), (1, 0)))[:, :-1]   # exclusive
        local = (local.reshape(s_pad, nchunks, bn)
                 + exc[..., None]).reshape(s_pad, n_pad)
    return local[:rows, :n].reshape(*lead, n)


def segmented_scan(x: jax.Array, *, policy=None, path: str | None = None,
                   use_pallas: bool | None = None) -> jax.Array:
    """Inclusive prefix-sum over the last axis -> f32, same shape."""
    return pallas_op("segmented_scan", x, policy=policy, path=path,
                     use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# weighted scan (the SSD kernel degenerated to N = P = 1, B = C = 1)


def _weighted_scan_tile(x: jax.Array, log_a: jax.Array, *, tuning=None,
                        interpret: bool = False) -> jax.Array:
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = _nrows(lead)
    q = layout.fit_block(n, _knob(tuning, "q", "weighted_scan"), LANES)
    xf = x.reshape(rows, n).astype(jnp.float32)
    la = log_a.reshape(rows, n).astype(jnp.float32)
    # state dim N=1 (pad to 8) and head dim P=1 (pad to 128): h is scalar,
    # b = c = e_1 make the recurrence y_t = h_t = exp(la_t) h_{t-1} + x_t.
    xp = _pad_axis(_pad_axis(xf[..., None], 2, LANES), 1, q)
    lap = _pad_axis(la, 1, q)      # pad with 0 ⇒ decay 1, input 0: harmless
    e1 = jnp.ones((rows, n, 1), jnp.float32)
    e1 = _pad_axis(_pad_axis(e1, 2, SUBLANES), 1, q)
    y, _ = _require_pallas(_ssd_kernel, "weighted_scan")(
        xp, lap, e1, e1, q=q, interpret=interpret)
    return y[:, :n, 0].reshape(*lead, n)


def _weighted_scan_tile_logdepth(x: jax.Array, log_a: jax.Array, *,
                                 tuning=None,
                                 interpret: bool = False) -> jax.Array:
    """Log-depth weighted scan: per-block 1-semiseparable local passes +
    a decay-folded tree combine over the block boundary states."""
    mm = _require_pallas(_mm_scan, "weighted_scan[tile_logdepth]")
    lead = x.shape[:-1]
    n = x.shape[-1]
    rows = _nrows(lead)
    q = layout.fit_block(n, _knob(tuning, "q", "weighted_scan"), LANES)
    xf = x.reshape(rows, n).astype(jnp.float32)
    la = log_a.reshape(rows, n).astype(jnp.float32)
    xp = _pad_axis(xf, 1, q)
    lap = _pad_axis(la, 1, q)      # pad with 0 ⇒ decay 1, input 0: harmless
    local = mm.matmul_local_weighted(xp, lap, q=q, interpret=interpret)
    nchunks = xp.shape[1] // q
    if nchunks > 1:
        lg = lap.reshape(rows, nchunks, q)
        # block boundary recurrence H_j = exp(Σλ_j)·H_{j-1} + h_j[last]
        carry = mm.tree_weighted(
            jnp.sum(lg, axis=-1), local[:, q - 1::q, None],
            radix=_knob(tuning, "radix", "weighted_scan"),
            fan_in=_knob(tuning, "fan_in", "weighted_scan"))[..., 0]
        exc = jnp.pad(carry, ((0, 0), (1, 0)))[:, :-1]   # (rows, nchunks)
        local = (local.reshape(rows, nchunks, q)
                 + jnp.exp(jnp.cumsum(lg, axis=-1)) * exc[..., None]
                 ).reshape(rows, -1)
    return local[:, :n].reshape(*lead, n)


def weighted_scan(x: jax.Array, log_a: jax.Array, *, policy=None,
                  path: str | None = None,
                  use_pallas: bool | None = None) -> jax.Array:
    """Decayed scan ``y_i = exp(log_a_i) * y_{i-1} + x_i`` -> f32."""
    return pallas_op("weighted_scan", x, log_a, policy=policy, path=path,
                     use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# rmsnorm (differentiable: all paths share one custom VJP)


def _rmsnorm_tile_fwd(x, w, eps, interpret, tuning):
    lead, d = x.shape[:-1], x.shape[-1]
    if d % LANES:  # kernel is lane-strict; unaligned d -> oracle (the
        return ref.rmsnorm_ref(x, w, eps=eps)  # same idiom as attention)
    rb = layout.fit_block(_nrows(lead), _knob(tuning, "row_block", "rmsnorm"),
                          SUBLANES)
    flat = _pad_axis(x.reshape(-1, d), 0, rb)
    out = _require_pallas(_rmsnorm_kernel, "rmsnorm")(
        flat, w, eps=eps, row_block=rb, interpret=interpret)
    return out[: _nrows(lead)].reshape(*lead, d)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 3, 4))
def _rmsnorm_dispatch(kind, x, w, eps, tuning):
    if kind == "fused":
        return ref.rmsnorm_ref(x, w, eps=eps)
    if kind == "tile_gpu":
        return triton_ops.rmsnorm_tile_gpu_fwd(x, w, eps, False, tuning)
    return _rmsnorm_tile_fwd(x, w, eps, kind == "interpret", tuning)


def _rmsnorm_vjp_fwd(kind, x, w, eps, tuning):
    return _rmsnorm_dispatch(kind, x, w, eps, tuning), (x, w)


def _rmsnorm_vjp_bwd(kind, eps, tuning, res, g):
    # backward through the reference formulation (numerically identical)
    x, w = res
    _, vjp = jax.vjp(lambda xx, ww: ref.rmsnorm_ref(xx, ww, eps=eps), x, w)
    return vjp(g)


_rmsnorm_dispatch.defvjp(_rmsnorm_vjp_fwd, _rmsnorm_vjp_bwd)


def _rmsnorm_tile(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                  tuning=None, interpret: bool = False) -> jax.Array:
    return _rmsnorm_dispatch("interpret" if interpret else "tile", x, w,
                             eps, tuning)


def _rmsnorm_tile_gpu(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
                      tuning=None, interpret: bool = False) -> jax.Array:
    if interpret:  # interpret validation runs outside the VJP wrapper too
        return triton_ops.rmsnorm_tile_gpu_fwd(x, w, eps, True, tuning)
    return _rmsnorm_dispatch("tile_gpu", x, w, eps, tuning)


def _rmsnorm_fused(x: jax.Array, w: jax.Array, *,
                   eps: float = 1e-6) -> jax.Array:
    return _rmsnorm_dispatch("fused", x, w, eps, None)


def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            policy=None, path: str | None = None,
            use_pallas: bool | None = None) -> jax.Array:
    """RMSNorm over the last axis (differentiable; Pallas fwd on TPU/GPU)."""
    return pallas_op("rmsnorm", x, w, eps=eps, policy=policy, path=path,
                     use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# SSD scan


def _ssd_tile(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)    positive step sizes
    a: jax.Array,       # (H,)         negative decay rates
    b: jax.Array,       # (B, L, G, N)
    c: jax.Array,       # (B, L, G, N)
    *,
    return_state: bool = False,
    tuning=None,
    interpret: bool = False,
):
    bsz, seqlen, nheads, hdim = x.shape
    nstate = b.shape[3]
    q = layout.fit_block(seqlen, _knob(tuning, "q", "ssd"), LANES)
    # fold (B, H) and broadcast groups; pad P (lane dim) to 128, L to q
    xdt, lam, bb, cc = ssd_fold(x, dt, a, b, c)
    xdt = _pad_axis(_pad_axis(xdt, 2, LANES), 1, q)
    lam = _pad_axis(lam, 1, q)
    bb = _pad_axis(_pad_axis(bb, 2, SUBLANES), 1, q)
    cc = _pad_axis(_pad_axis(cc, 2, SUBLANES), 1, q)
    y, state = _require_pallas(_ssd_kernel, "ssd_scan")(
        xdt, lam, bb, cc, q=q, interpret=interpret)
    # kernel state is (B*H, N_pad, P_pad); zero-padding of b/x keeps the
    # valid block exact — slice and match ssd_chunked's (B, H, P, N)
    return ssd_unfold(y, state, bsz=bsz, nheads=nheads, seqlen=seqlen,
                      hdim=hdim, nstate=nstate, out_dtype=x.dtype,
                      return_state=return_state)


def _ssd_tile_logdepth(
    x: jax.Array,       # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)    positive step sizes
    a: jax.Array,       # (H,)         negative decay rates
    b: jax.Array,       # (B, L, G, N)
    c: jax.Array,       # (B, L, G, N)
    *,
    return_state: bool = False,
    tuning=None,
    interpret: bool = False,
):
    """Log-depth SSD: carry-free per-chunk passes emit (y_local, S_j);
    the chunk-state recurrence ``H_j = exp(Σλ_j)·H_{j-1} + S_j`` runs as
    the weighted tree combine and the inter-chunk term
    ``(C ∘ exp(Λ)) @ H_{j-1}`` is one batched matmul per chunk."""
    mm = _require_pallas(_mm_scan, "ssd_scan[tile_logdepth]")
    bsz, seqlen, nheads, hdim = x.shape
    nstate = b.shape[3]
    q = layout.fit_block(seqlen, _knob(tuning, "q", "ssd"), LANES)
    xdt, lam, bb, cc = ssd_fold(x, dt, a, b, c)
    xdt = _pad_axis(_pad_axis(xdt, 2, LANES), 1, q)
    lam = _pad_axis(lam, 1, q)
    bb = _pad_axis(_pad_axis(bb, 2, SUBLANES), 1, q)
    cc = _pad_axis(_pad_axis(cc, 2, SUBLANES), 1, q)
    y, s = mm.matmul_local_ssd(xdt, lam, bb, cc, q=q, interpret=interpret)
    bh, l_pad, p_pad = xdt.shape
    n_pad = bb.shape[2]
    nchunks = l_pad // q
    lg = lam.reshape(bh, nchunks, q)
    # pad chunks have λ = 0 and S = 0: identity steps, H passes through
    h_inc = mm.tree_weighted(
        jnp.sum(lg, axis=-1), s.reshape(bh, nchunks, n_pad * p_pad),
        radix=_knob(tuning, "radix", "ssd"),
        fan_in=_knob(tuning, "fan_in", "ssd"))
    h_exc = jnp.pad(h_inc, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    h_exc = h_exc.reshape(bh, nchunks, n_pad, p_pad)
    cdec = (cc.reshape(bh, nchunks, q, n_pad)
            * jnp.exp(jnp.cumsum(lg, axis=-1))[..., None])
    y = (y.reshape(bh, nchunks, q, p_pad)
         + jnp.einsum("bjqn,bjnp->bjqp", cdec, h_exc)
         ).reshape(bh, l_pad, p_pad)
    state = h_inc[:, -1].reshape(bh, n_pad, p_pad)
    return ssd_unfold(y, state, bsz=bsz, nheads=nheads, seqlen=seqlen,
                      hdim=hdim, nstate=nstate, out_dtype=x.dtype,
                      return_state=return_state)


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, *, policy=None, path: str | None = None,
             use_pallas: bool | None = None, return_state: bool = False):
    """Mamba-2 SSD scan -> (B, L, H, P) in the input dtype; with
    ``return_state=True`` also the final state (B, H, P, N) f32."""
    return pallas_op("ssd_scan", x, dt, a, b, c, policy=policy, path=path,
                     use_pallas=use_pallas, return_state=return_state)


# ---------------------------------------------------------------------------
# attention


def _attention_tile(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, tuning=None, interpret: bool = False,
) -> jax.Array:
    lq, lk = q.shape[2], k.shape[2]
    # block_q rides the sublanes (the kernel accepts any 8-multiple);
    # block_k is the lane dim of the score tile and stays a 128-multiple
    bq = layout.fit_block(lq, _knob(tuning, "block_q", "attention"),
                          SUBLANES)
    bk = layout.fit_block(lk, _knob(tuning, "block_k", "attention"), LANES)
    if lq % bq or lk % bk:  # kernel is block-strict; unaligned -> oracle
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                       scale=scale)
    return _require_pallas(_flash_kernel, "attention")(
        q, k, v, causal=causal, window=window, scale=scale,
        block_q=bq, block_k=bk, interpret=interpret)


def attention(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    scale: float | None = None, policy=None, path: str | None = None,
    use_pallas: bool | None = None,
) -> jax.Array:
    """Multi-head attention (B, Hq, Lq, D) x (B, Hkv, Lk, D) -> (B, Hq, Lq, D)."""
    return pallas_op("attention", q, k, v, causal=causal, window=window,
                     scale=scale, policy=policy, path=path,
                     use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# registry


def _diff_via_ref(kernel_fn, ref_fn):
    """Make a kernel entry differentiable: backward through the oracle.

    ``pallas_call`` has no JVP rule in interpret mode (and only partial
    autodiff support natively), so a train step that reaches a kernel
    path would crash. Every kernel agrees with its ``ref.py`` twin to
    tolerance (the dispatch-agreement tests), so the same trick rmsnorm
    already uses generalises: run the kernel forward, differentiate the
    reference formulation (numerically identical) backward. ``kwargs``
    are static per call and must be accepted by both twins —
    ``interpret``/``tuning`` steer only the kernel side (geometry changes
    how the kernel runs, never what it computes, so the oracle backward
    stays numerically identical).
    """
    if kernel_fn is None:
        return None

    @functools.wraps(kernel_fn)
    def wrapped(*args, interpret=False, tuning=None, **kwargs):
        run = jax.custom_vjp(
            lambda *arrs: kernel_fn(*arrs, interpret=interpret,
                                    tuning=tuning, **kwargs))

        def fwd(*arrs):
            return run(*arrs), arrs

        def bwd(res, g):
            _, vjp = jax.vjp(lambda *a: ref_fn(*a, **kwargs), *res)
            return vjp(g)

        run.defvjp(fwd, bwd)
        return run(*args)

    return wrapped


backend.register_op("segmented_reduce",
                    tile=_diff_via_ref(_reduce_tile,
                                       ref.segmented_reduce_ref),
                    fused=ref.segmented_reduce_ref,
                    tile_gpu=_diff_via_ref(_gpu_entry("reduce_tile_gpu"),
                                           ref.segmented_reduce_ref))
backend.register_op("segmented_scan",
                    tile=_diff_via_ref(_scan_tile, ref.segmented_scan_ref),
                    fused=ref.segmented_scan_ref,
                    tile_gpu=_diff_via_ref(_gpu_entry("scan_tile_gpu"),
                                           ref.segmented_scan_ref),
                    tile_logdepth=_diff_via_ref(_scan_tile_logdepth,
                                                ref.segmented_scan_ref),
                    tile_logdepth_gpu=_diff_via_ref(
                        _gpu_entry("scan_tile_logdepth_gpu"),
                        ref.segmented_scan_ref))
backend.register_op("weighted_scan",
                    tile=_diff_via_ref(_weighted_scan_tile,
                                       ref.weighted_scan_ref),
                    fused=ref.weighted_scan_ref,
                    tile_gpu=_diff_via_ref(
                        _gpu_entry("weighted_scan_tile_gpu"),
                        ref.weighted_scan_ref),
                    tile_logdepth=_diff_via_ref(
                        _weighted_scan_tile_logdepth,
                        ref.weighted_scan_ref),
                    tile_logdepth_gpu=_diff_via_ref(
                        _gpu_entry("weighted_scan_tile_logdepth_gpu"),
                        ref.weighted_scan_ref))
# rmsnorm carries its own custom VJP (all paths share it) — no wrapper
backend.register_op("rmsnorm", tile=_rmsnorm_tile, fused=_rmsnorm_fused,
                    tile_gpu=(_rmsnorm_tile_gpu if triton_ops is not None
                              else None))
backend.register_op("ssd_scan",
                    tile=_diff_via_ref(_ssd_tile, ref.ssd_scan_ref),
                    fused=ref.ssd_scan_ref,
                    tile_gpu=_diff_via_ref(_gpu_entry("ssd_tile_gpu"),
                                           ref.ssd_scan_ref),
                    tile_logdepth=_diff_via_ref(_ssd_tile_logdepth,
                                                ref.ssd_scan_ref),
                    tile_logdepth_gpu=_diff_via_ref(
                        _gpu_entry("ssd_tile_logdepth_gpu"),
                        ref.ssd_scan_ref))
backend.register_op("attention",
                    tile=_diff_via_ref(_attention_tile,
                                       ref.flash_attention_ref),
                    fused=ref.flash_attention_ref,
                    tile_gpu=_diff_via_ref(_gpu_entry("attention_tile_gpu"),
                                           ref.flash_attention_ref))
