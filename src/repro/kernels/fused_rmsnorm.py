"""Pallas TPU kernel: RMSNorm with a matmul-form Σx² reduction.

This is the paper's future-work suggestion ("computation of variance in
batch norm") applied to the norm all ten assigned archs actually use. The
row reduction Σx² is fed through the MXU as ``(x∘x) @ 1`` — a P-matrix
reduction with the all-ones RHS doubling as the lane broadcast (every output
lane holds the sum, so no cross-lane shuffle is needed for the subsequent
elementwise normalisation; the V100 version needed Listing-3 layout hacks
for the same effect).

Grid: rows/row_block; the full feature dim lives in one VMEM block
(d ≤ 8192 ⇒ ≤ 4 MiB f32 per block, well under the 16 MiB VMEM budget).
``row_block`` is caller-supplied (a resolved ``TuneSpec``); the default
lives in ``repro.kernels.layout``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend
from repro.kernels.layout import LANES, SUBLANES, default_tuning


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)               # (row_block, d)
    ones = jnp.ones((d, LANES), jnp.float32)
    # (x∘x) @ 1 : every lane of ssq holds Σ_d x²  (matmul-form reduce+bcast)
    ssq = jax.lax.dot_general(
        x * x, ones, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (row_block, 128)
    rstd = jax.lax.rsqrt(ssq[:, :1] / d + eps)       # (row_block, 1)
    w = w_ref[...].astype(jnp.float32)               # (1, d)
    o_ref[...] = (x * rstd * w).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "row_block", "interpret"))
def fused_rmsnorm(
    x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
    row_block: int | None = None, interpret: bool = False
) -> jax.Array:
    """RMSNorm rows of ``x (rows, d)`` by ``w (d,)``; ``rows % row_block
    == 0`` (wrapper pads) and ``d`` a lane multiple."""
    row_block = row_block or default_tuning("tpu", "rmsnorm")["row_block"]
    rows, d = x.shape
    if row_block % SUBLANES:
        raise ValueError(
            f"row_block {row_block} must be a multiple of {SUBLANES}")
    if rows % row_block or d % LANES:
        raise ValueError(f"shape {x.shape} must tile {(row_block, LANES)}")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=(rows // row_block,),
        in_specs=[
            pl.BlockSpec((row_block, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((row_block, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=backend.compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fused_rmsnorm",
    )(x, w.reshape(1, d))
