"""Pallas TPU kernel: RMSNorm with a matmul-form Σx² reduction.

This is the paper's future-work suggestion ("computation of variance in
batch norm") applied to the norm all ten assigned archs actually use. The
row reduction Σx² is fed through the MXU as ``(x∘x) @ 1`` — a P-matrix
reduction with the all-ones RHS doubling as the lane broadcast (every output
lane holds the sum, so no cross-lane shuffle is needed for the subsequent
elementwise normalisation; the V100 version needed Listing-3 layout hacks
for the same effect).

Grid: rows/128; the full feature dim lives in one VMEM block
(d ≤ 8192 ⇒ ≤ 4 MiB f32 per block, well under the 16 MiB VMEM budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import backend

LANES = 128
ROW_BLOCK = 128


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, d: int):
    x = x_ref[...].astype(jnp.float32)               # (ROW_BLOCK, d)
    ones = jnp.ones((d, LANES), jnp.float32)
    # (x∘x) @ 1 : every lane of ssq holds Σ_d x²  (matmul-form reduce+bcast)
    ssq = jax.lax.dot_general(
        x * x, ones, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # (ROW_BLOCK, 128)
    rstd = jax.lax.rsqrt(ssq[:, :1] / d + eps)       # (ROW_BLOCK, 1)
    w = w_ref[...].astype(jnp.float32)               # (1, d)
    o_ref[...] = (x * rstd * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "interpret"))
def fused_rmsnorm(
    x: jax.Array, w: jax.Array, *, eps: float = 1e-6, interpret: bool = False
) -> jax.Array:
    """RMSNorm rows of ``x (rows, d)`` by ``w (d,)``; rows % 128 == 0."""
    rows, d = x.shape
    if rows % ROW_BLOCK or d % LANES:
        raise ValueError(f"shape {x.shape} must tile (128, 128)")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, d=d),
        grid=(rows // ROW_BLOCK,),
        in_specs=[
            pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROW_BLOCK, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=backend.compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
        name="fused_rmsnorm",
    )(x, w.reshape(1, d))
