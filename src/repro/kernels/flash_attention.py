"""Pallas TPU kernel: blocked (flash) attention with GQA + sliding window.

Used for the 32k prefill shapes. Connection to the paper: the online-softmax
denominator ``ℓ += rowsum(exp(S − m))`` is a matmul-form reduction
(``p @ 1``, the paper's P-matrix trick), so the only VPU reduction left in
the inner loop is the row-max (max has no matmul form — the paper's
formulation is sum-only, see DESIGN §2).

Grid: ``(B, Hq, Lq/BQ, Lk/BK)``, kv blocks innermost-sequential. GQA is
handled by the k/v index maps (q head h reads kv head ``h // rep``) — no
repeated-KV materialisation. Fully-masked kv blocks are skipped at block
granularity (causal and sliding-window bounds).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.layout import LANES, SUBLANES, default_tuning

NEG_INF = float(-1e30)


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: int | None,
                  bq: int, bk: int, nk: int, offs: int):
    jk = pl.program_id(3)
    iq = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # block-level visibility: q rows span [iq*bq, iq*bq+bq) (+offs in k space)
    q_lo = iq * bq + offs
    q_hi = q_lo + bq - 1
    k_lo = jk * bk
    k_hi = k_lo + bk - 1
    visible = jnp.bool_(True)
    if causal:
        visible &= k_lo <= q_hi
    if window is not None:
        visible &= k_hi > q_lo - window

    @pl.when(visible)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)          # (BQ, D)
        k = k_ref[0, 0].astype(jnp.float32)          # (BK, D)
        v = v_ref[0, 0].astype(jnp.float32)          # (BK, D)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale                                     # (BQ, BK)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]                          # (BQ,)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])               # (BQ, BK)
        corr = jnp.exp(m_prev - m_new)                # (BQ,)
        # ℓ update: rowsum(p) in matmul form (p @ 1) — paper's P-reduction.
        ones = jnp.ones((bk, LANES), jnp.float32)
        psum = jax.lax.dot_general(
            p, ones, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )                                             # (BQ, 128) replicated
        l_ref[...] = corr[:, None] * l_ref[...] + psum
        acc_ref[...] = corr[:, None] * acc_ref[...] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)

    @pl.when(jk == nk - 1)
    def _store():
        l = l_ref[:, :1]
        safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k",
                     "interpret"),
)
def flash_attention(
    q: jax.Array,       # (B, Hq, Lq, D)
    k: jax.Array,       # (B, Hkv, Lk, D)
    v: jax.Array,       # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int | None = None,
    block_k: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    spec = default_tuning("tpu", "attention")
    block_q = block_q or spec["block_q"]
    block_k = block_k or spec["block_k"]
    bsz, hq, lq, d = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    rep = hq // hkv
    if block_q % SUBLANES or block_k % LANES:
        raise ValueError(
            f"blocks {(block_q, block_k)} must be multiples of "
            f"{(SUBLANES, LANES)}")
    if lq % block_q or lk % block_k:
        raise ValueError(f"seq lens {(lq, lk)} must tile {(block_q, block_k)}")
    scale_v = scale if scale is not None else 1.0 / (d ** 0.5)
    nk = lk // block_k
    offs = lk - lq  # align sequence ends (prefill: 0; decode chunks: >0)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, scale=scale_v, causal=causal, window=window,
            bq=block_q, bk=block_k, nk=nk, offs=offs,
        ),
        grid=(bsz, hq, lq // block_q, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, hq, lq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
        name="flash_attention",
    )(q, k, v)
