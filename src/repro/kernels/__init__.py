"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; the public
entry points (with CPU fallback + interpret-mode validation) live in
``ops.py``:

  tcu_reduce.py       matmul-form segmented reduction   (paper §4)
  tcu_scan.py         matmul-form segmented scan        (paper §5)
  fused_rmsnorm.py    RMSNorm with MXU Σx²              (paper §8 future work)
  ssd_scan.py         Mamba-2 SSD = weighted tile scan  (beyond-paper)
  flash_attention.py  blocked attention, matmul-form ℓ  (beyond-paper)
"""
from repro.kernels.ops import (
    attention,
    rmsnorm,
    segmented_reduce,
    segmented_scan,
    ssd_scan,
)

__all__ = [
    "attention",
    "rmsnorm",
    "segmented_reduce",
    "segmented_scan",
    "ssd_scan",
]
