"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel module pairs with a pure-jnp oracle in ``ref.py``; the public
entry points live in ``ops.py`` and route through the version-shimmed
dispatch layer in ``backend.py`` (fused XLA vs Pallas tile vs interpret
mode, selected by the active ``repro.core.policy.KernelPolicy`` — per
call via ``policy=``/``path=``, or process-wide; the stable façade is
``repro.ops``):

  backend.py          version shim + capability probes + pallas_op dispatch
  tcu_reduce.py       matmul-form segmented reduction   (paper §4)
  tcu_scan.py         matmul-form segmented scan        (paper §5)
  matmul_scan.py      log-depth MatMulScan: carry-free local kernels +
                      O(log) tree combine (``tile_logdepth``; beyond-paper)
  fused_rmsnorm.py    RMSNorm with MXU Σx²              (paper §8 future work)
  ssd_scan.py         Mamba-2 SSD = weighted tile scan  (beyond-paper)
  flash_attention.py  blocked attention, matmul-form ℓ  (beyond-paper)
  layout.py           shared padding/fold glue + the ONLY home of kernel
                      geometry numbers (TuneSpec defaults / sweep
                      candidates; grep-guard enforced)
  triton/             Pallas-Triton (GPU) twins of all five kernels,
                      registered as the ``tile_gpu`` entries
"""
from repro.kernels import backend
from repro.kernels.backend import (
    available_ops,
    compiler_params,
    pallas_op,
)
from repro.kernels.ops import (
    attention,
    rmsnorm,
    segmented_reduce,
    segmented_scan,
    ssd_scan,
    weighted_scan,
)

__all__ = [
    "attention",
    "available_ops",
    "backend",
    "compiler_params",
    "pallas_op",
    "rmsnorm",
    "segmented_reduce",
    "segmented_scan",
    "ssd_scan",
    "weighted_scan",
]
