"""Pallas TPU kernel: Mamba-2 SSD chunked scan = the paper's scan, weighted.

The SSD ("state-space duality") computation is exactly the generalisation of
Dakkak et al.'s matmul-form scan from ones-triangles to decay-weighted
triangles:

* paper ``A @ U`` (intra-tile scan)   →  ``(C Bᵀ ∘ M) @ X`` with
  ``M = exp(segsum(λ))`` a *weighted* lower-triangular mask (λ = a·dt);
  with λ ≡ 0, N = P = 1, B = C = 1 this degenerates to the paper's tile scan.
* paper ``S ← Broadcast(R[last])`` (tile carry) → the chunk state recurrence
  ``H_k = exp(Σλ)·H_{k-1} + S_k`` carried in VMEM scratch along the
  sequential chunk grid dimension.
* paper grid-level scan-then-propagate → `repro.core.dist_weighted_scan`
  for sequence-parallel execution across devices (long_500k cells).

The within-chunk cumulative decay Λ is itself computed in matmul form
(``λ @ U``), so every reduction/scan in this kernel routes through the MXU.

Grid: ``(B·H, L/q)`` with chunks innermost-sequential; carry scratch (N, P)
f32 per (batch, head). The chunk length ``q`` is caller-supplied (a
resolved ``TuneSpec``; the default — one MXU edge — lives in
``repro.kernels.layout``). Second output: final state (for prefill →
decode handoff in serving).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import backend
from repro.kernels.layout import LANES, default_tuning


def _ssd_kernel(xdt_ref, lam_ref, b_ref, c_ref, y_ref, state_ref, h_ref,
                *, nchunks: int, q: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xdt = xdt_ref[0].astype(jnp.float32)             # (q, P)  dt-weighted input
    lam = lam_ref[...].astype(jnp.float32)           # (1, q)  log decays
    bmat = b_ref[0].astype(jnp.float32)              # (q, N)
    cmat = c_ref[0].astype(jnp.float32)              # (q, N)

    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    u = (rows <= cols).astype(jnp.float32)
    # Λ = λ @ U : inclusive cumulative log-decay, matmul-form (paper's A·U).
    cum = jax.lax.dot_general(
        lam, u, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (1, q)
    total = jnp.sum(lam)                             # Σ_chunk λ (scalar)

    # M[t, τ] = exp(Λ_t − Λ_τ) for τ ≤ t  (weighted L+I mask)
    diff = cum[0][:, None] - cum[0][None, :]
    m = jnp.where(rows >= cols, jnp.exp(diff), 0.0)  # (q, q)

    # Intra-chunk: Y = ((C Bᵀ) ∘ M) @ (dt∘X)
    cb = jax.lax.dot_general(
        cmat, bmat, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (q, q)
    y = jax.lax.dot_general(
        cb * m, xdt, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (q, P)

    # Inter-chunk: Y += (C ∘ exp(Λ)) @ H_prev
    cdec = cmat * jnp.exp(cum[0])[:, None]           # (q, N)
    y += jax.lax.dot_general(
        cdec, h_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    y_ref[0] = y.astype(y_ref.dtype)

    # State update: H = exp(Σλ)·H + (B ∘ w)ᵀ @ (dt∘X),  w_τ = exp(Σλ − Λ_τ)
    w = jnp.exp(total - cum[0])                      # (q,)
    bw = bmat * w[:, None]                           # (q, N)
    s_new = jax.lax.dot_general(
        bw, xdt, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                                # (N, P)
    h_ref[...] = jnp.exp(total) * h_ref[...] + s_new

    @pl.when(j == nchunks - 1)
    def _emit_state():
        state_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("q", "interpret"))
def ssd_chunk_scan(
    xdt: jax.Array,     # (BH, L, P)  dt-weighted inputs, P % 128 == 0 (padded)
    lam: jax.Array,     # (BH, L)     per-step log decay  a_h · dt
    b: jax.Array,       # (BH, L, N)  N % 8 == 0
    c: jax.Array,       # (BH, L, N)
    *,
    q: int | None = None,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan. Returns (y (BH, L, P) f32, final_state (BH, N, P)).

    ``q`` is the chunk length (a lane multiple; ``L % q == 0`` — the
    wrapper pads).
    """
    q = q or default_tuning("tpu", "ssd")["q"]
    bh, seqlen, hdim = xdt.shape
    nstate = b.shape[-1]
    if q % LANES:
        raise ValueError(f"chunk q={q} must be a multiple of {LANES}")
    if seqlen % q:
        raise ValueError(f"L={seqlen} must be a multiple of {q}")
    nchunks = seqlen // q
    return pl.pallas_call(
        functools.partial(_ssd_kernel, nchunks=nchunks, q=q),
        grid=(bh, nchunks),
        in_specs=[
            pl.BlockSpec((1, q, hdim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q), lambda i, j: (i, j)),
            pl.BlockSpec((1, q, nstate), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, q, nstate), lambda i, j: (i, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, hdim), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, nstate, hdim), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seqlen, hdim), jnp.float32),
            jax.ShapeDtypeStruct((bh, nstate, hdim), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((nstate, hdim), jnp.float32)],
        compiler_params=backend.compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
        name="ssd_chunk_scan",
    )(xdt, lam, b, c)
