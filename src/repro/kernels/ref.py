"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernels are validated against (interpret mode
on CPU, real MXU on TPU) and the XLA fallback the models use on non-TPU
backends. Keep them boring and obviously correct.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segmented_reduce_ref(x: jax.Array) -> jax.Array:
    """Sum over the last axis, f32 accumulation. x: (..., n) -> (...,)."""
    return jnp.sum(x.astype(jnp.float32), axis=-1)


def segmented_scan_ref(x: jax.Array) -> jax.Array:
    """Inclusive prefix-sum over the last axis, f32 accumulation."""
    return jnp.cumsum(x.astype(jnp.float32), axis=-1)


def weighted_scan_ref(x: jax.Array, log_a: jax.Array) -> jax.Array:
    """Decayed scan ``y_i = exp(log_a_i) * y_{i-1} + x_i`` along the last
    axis, f32 accumulation. Oracle for the weighted-scan tile path (the SSD
    kernel with N = P = 1, B = C = 1)."""
    a = jnp.exp(log_a.astype(jnp.float32))
    x = x.astype(jnp.float32)

    def combine(left, right):
        a_l, y_l = left
        a_r, y_r = right
        return a_l * a_r, y_r + a_r * y_l

    _, y = jax.lax.associative_scan(combine, (a, x), axis=-1)
    return y


def rmsnorm_ref(x: jax.Array, w: jax.Array, *, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over the last axis: x * rsqrt(mean(x^2) + eps) * w."""
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def ssd_scan_ref(
    x: jax.Array,       # (B, L, H, P)   inputs (already dt-weighted or raw)
    dt: jax.Array,      # (B, L, H)      softplus'd step sizes, > 0
    a: jax.Array,       # (H,)           negative state decay rates (A = -exp(A_log))
    b: jax.Array,       # (B, L, G, N)   input projections (G groups broadcast over H)
    c: jax.Array,       # (B, L, G, N)   output projections
    *,
    return_state: bool = False,
):
    """Sequential reference of the Mamba-2 SSD recurrence.

    state_{t} = exp(a * dt_t) * state_{t-1} + dt_t * b_t x_t^T
    y_t       = c_t . state_t
    Shapes follow Mamba-2: H heads, P head-dim, N state-dim, G kv-like groups
    with H % G == 0 (heads within a group share B/C). With
    ``return_state=True`` also returns the final state (B, H, P, N) f32.
    """
    bsz, seqlen, nheads, hdim = x.shape
    ngroups, nstate = b.shape[2], b.shape[3]
    rep = nheads // ngroups
    bf = jnp.repeat(b, rep, axis=2).astype(jnp.float32)      # (B, L, H, N)
    cf = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(dtf * a.astype(jnp.float32))             # (B, L, H)

    def step(state, inp):
        xt, bt, ct, dt_t, dec = inp                           # (B,H,P),(B,H,N)...
        state = dec[..., None, None] * state + (
            dt_t[..., None, None] * bt[..., None, :] * xt[..., :, None]
        )                                                     # (B, H, P, N)
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    state0 = jnp.zeros((bsz, nheads, hdim, nstate), jnp.float32)
    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(bf, 1, 0),
        jnp.moveaxis(cf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(decay, 1, 0),
    )
    h_last, ys = jax.lax.scan(step, state0, xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)                # (B, L, H, P)
    return (y, h_last) if return_state else y


def flash_attention_ref(
    q: jax.Array,       # (B, Hq, Lq, D)
    k: jax.Array,       # (B, Hkv, Lk, D)
    v: jax.Array,       # (B, Hkv, Lk, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
) -> jax.Array:
    """Plain softmax attention with GQA head-group broadcast and optional
    sliding window. Oracle for kernels/flash_attention.py."""
    bq, hq, lq, d = q.shape
    hkv = k.shape[1]
    rep = hq // hkv
    kf = jnp.repeat(k, rep, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, rep, axis=1).astype(jnp.float32)
    qf = q.astype(jnp.float32)
    s = scale if scale is not None else 1.0 / (d ** 0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", qf, kf) * s
    lk = k.shape[2]
    qpos = jnp.arange(lq)[:, None] + (lk - lq)   # align ends (decode-friendly)
    kpos = jnp.arange(lk)[None, :]
    mask = jnp.ones((lq, lk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)
