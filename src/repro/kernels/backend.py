"""Version shim + multi-backend dispatch layer for every Pallas kernel in
the repo.

Why this exists: the Pallas private surfaces rename things across JAX
releases (``pltpu.TPUCompilerParams`` on 0.4.x became ``pltpu.CompilerParams``
on 0.5+, same drift on the Triton side, field sets move too). Hard-coding
one spelling in each kernel broke all of them at once; this module is the
single place that knows which JAX is installed and which accelerator is
active. Kernels call :func:`compiler_params` instead of touching
``pltpu``/``plgpu`` classes, and the public wrappers register with
:func:`register_op` so every call site picks its execution path through one
switch:

  ``fused``      the XLA reference path (``repro.kernels.ref`` /
                 ``repro.core``) — default off-accelerator
  ``tile``       the explicit Pallas tile kernel for *this host's* backend:
                 resolves to ``tile_tpu`` on TPU, ``tile_gpu`` on GPU
                 (Pallas-Triton), and downgrades to ``interpret`` elsewhere
                 with a one-time warning (there is nothing to compile for)
  ``tile_tpu``   force the Pallas-TPU kernel — raises off-TPU
  ``tile_gpu``   force the Pallas-Triton kernel — raises off-GPU
  ``interpret``  the Pallas kernel body through the interpreter — how the
                 kernels are validated on CPU
  ``auto``       ``tile`` on TPU/GPU, ``fused`` otherwise

Selection precedence: per-call ``path=`` kwarg > per-call legacy
``use_pallas=`` bool > ``REPRO_KERNEL_PATH`` env var > ``auto``. Passing
both ``path=`` and ``use_pallas=`` with conflicting values warns and honours
``path=``. ``auto`` consults the measured per-shape crossover table in
``repro.core.autotune`` (keyed by backend — a GPU-measured table never
steers a CPU/TPU host) when the call shape is known, falling back to the
static choice (tile on TPU/GPU, fused elsewhere) otherwise or when
``REPRO_AUTOTUNE=off``. ``auto`` never selects a ``tile_*`` label the host
cannot lower natively.
"""
from __future__ import annotations

import dataclasses
import inspect
import os
import warnings
from typing import Any, Callable

import jax

ENV_PATH = "REPRO_KERNEL_PATH"
PATHS = ("auto", "fused", "tile", "tile_tpu", "tile_gpu", "interpret")


# ---------------------------------------------------------------------------
# capability probes


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def on_gpu() -> bool:
    """True when the default JAX backend is a GPU (CUDA or ROCm)."""
    return jax.default_backend() in ("gpu", "cuda", "rocm")


def has_pallas_tpu() -> bool:
    """True when this JAX ships the Pallas-TPU lowering at all."""
    try:
        from jax.experimental.pallas import tpu as _  # noqa: F401
        return True
    except ImportError:
        return False


def has_pallas_triton() -> bool:
    """True when this JAX ships the Pallas-Triton (GPU) lowering at all."""
    try:
        from repro.kernels.triton import compat
    except ImportError:  # pragma: no cover — broken install
        return False
    return compat.available()


def native_tile_backend() -> str | None:
    """The concrete tile path this host lowers natively, or None."""
    if on_tpu() and has_pallas_tpu():
        return "tile_tpu"
    if on_gpu() and has_pallas_triton():
        return "tile_gpu"
    return None


# ---------------------------------------------------------------------------
# compiler-params shim


def compiler_params_cls() -> type:
    """The Pallas-TPU compiler-params class under whichever name this JAX
    uses (``CompilerParams`` on 0.5+, ``TPUCompilerParams`` on 0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise RuntimeError(
        f"jax {jax.__version__}: no Pallas-TPU compiler-params class found; "
        "the version shim in repro.kernels.backend needs a new spelling"
    )


def _accepted_fields(cls: type) -> set[str]:
    if dataclasses.is_dataclass(cls):
        return {f.name for f in dataclasses.fields(cls)}
    return set(inspect.signature(cls).parameters)


def compiler_params(backend: str = "tpu", **kwargs: Any):
    """Construct compiler params portably for either Pallas backend.

    ``backend="tpu"`` (default) builds the Pallas-TPU params;
    ``backend="gpu"`` defers to the Triton shim in
    ``repro.kernels.triton.compat`` (the only module allowed to import
    ``jax.experimental.pallas.triton``). Fields unknown to the installed
    JAX (the field sets drift between releases) are dropped rather than
    raising, so kernels can request newer knobs without pinning a JAX
    version.
    """
    if backend in ("gpu", "triton"):
        from repro.kernels.triton import compat

        return compat.compiler_params(**kwargs)
    if backend != "tpu":
        raise ValueError(
            f"unknown compiler-params backend {backend!r}; "
            "expected 'tpu' or 'gpu'")
    cls = compiler_params_cls()
    fields = _accepted_fields(cls)
    if "dimension_semantics" in kwargs and kwargs["dimension_semantics"]:
        kwargs["dimension_semantics"] = tuple(kwargs["dimension_semantics"])
    return cls(**{k: v for k, v in kwargs.items() if k in fields})


# ---------------------------------------------------------------------------
# path resolution


# algorithm-level contenders that only repro.core.dispatch understands; the
# env var is shared process-wide, so kernel-level call sites must tolerate
# them (their nearest kernel-level equivalent is the fused XLA path)
_DISPATCH_ONLY = ("baseline", "xla_tile")

_TILE_DOWNGRADE_WARNED = False


def _warn_tile_downgrade() -> None:
    """One-time notice that the generic ``tile`` label fell back to the
    interpreter — silent interpreter execution looks like a hang at real
    sizes, so say so once per process."""
    global _TILE_DOWNGRADE_WARNED
    if _TILE_DOWNGRADE_WARNED:
        return
    _TILE_DOWNGRADE_WARNED = True
    warnings.warn(
        f"path='tile' has no native Pallas lowering on the "
        f"{jax.default_backend()!r} backend (tile_tpu needs a TPU, tile_gpu "
        "a GPU with Pallas-Triton); running the kernel body through the "
        "Pallas interpreter instead. Pass path='interpret' explicitly to "
        "silence this one-time warning.",
        UserWarning, stacklevel=5)


def resolve_path(path: str | None = None, *,
                 use_pallas: bool | None = None,
                 op: str | None = None, n: int | None = None,
                 dtype: Any = None) -> str:
    """Resolve a concrete execution path:
    ``fused`` | ``tile_tpu`` | ``tile_gpu`` | ``interpret``.

    ``path`` is the explicit per-call choice; ``use_pallas`` is the legacy
    bool (True → kernel, False → fused, None → unspecified); with neither,
    ``$REPRO_KERNEL_PATH`` applies, then ``auto``. When both are passed
    with conflicting values, ``path=`` wins and a ``UserWarning`` is
    emitted (``path='interpret'`` with ``use_pallas=True`` is *not* a
    conflict — interpret runs the same kernel body).

    The generic ``tile`` resolves per backend (TPU kernel on TPU, Triton
    kernel on GPU, interpreter + one-time warning elsewhere); the explicit
    ``tile_tpu``/``tile_gpu`` labels raise a clear error on the wrong host.

    ``op``/``n``/``dtype`` describe the call shape; with them, ``auto``
    consults the measured, backend-keyed crossover table
    (``repro.core.autotune``) instead of the static backend check.
    """
    if use_pallas is not None:
        implied = "tile" if use_pallas else "fused"
        if path is None:
            path = implied
        elif (use_pallas and path == "fused") or \
                (not use_pallas and path in ("tile", "tile_tpu", "tile_gpu",
                                             "interpret")):
            warnings.warn(
                f"conflicting path={path!r} and use_pallas={use_pallas}; "
                "path= takes precedence (use_pallas= is legacy)",
                UserWarning, stacklevel=3)
    if path is None:
        path = os.environ.get(ENV_PATH, "").strip().lower() or "auto"
        if path in _DISPATCH_ONLY:
            path = "fused"
    if path not in PATHS:
        raise ValueError(f"unknown kernel path {path!r}; expected one of {PATHS}")
    native = native_tile_backend()
    if path == "auto":
        choice = None
        if op is not None and n is not None:
            from repro.core import autotune  # deferred: autotune imports us

            choice = autotune.choose(
                op, n, dtype,
                candidates=("fused", "tile", "tile_tpu", "tile_gpu",
                            "interpret"),
                level="kernel")
            # auto must never force a tile backend this host can't lower
            if choice in ("tile_tpu", "tile_gpu") and choice != native:
                choice = None
        path = choice or ("tile" if native else "fused")
    if path == "tile":
        if native is None:
            _warn_tile_downgrade()
            return "interpret"  # nothing to compile the tile kernel for
        return native
    if path == "tile_tpu" and native != "tile_tpu":
        raise RuntimeError(
            "path='tile_tpu' requires a TPU host with the Pallas-TPU "
            f"lowering (active backend: {jax.default_backend()!r}); use "
            "path='interpret' for CPU validation or path='tile' for "
            "backend-appropriate selection")
    if path == "tile_gpu" and native != "tile_gpu":
        raise RuntimeError(
            "path='tile_gpu' requires a GPU host with the Pallas-Triton "
            f"lowering (active backend: {jax.default_backend()!r}); use "
            "path='interpret' for CPU validation or path='tile' for "
            "backend-appropriate selection")
    return path


# ---------------------------------------------------------------------------
# op registry — the single pallas_call front door


@dataclasses.dataclass(frozen=True)
class PallasOp:
    """One kernel family: the Pallas tile entries per backend (each must
    accept an ``interpret=`` kwarg) and the fused-XLA reference twin.

    ``tile`` is the Pallas-TPU entry (also the body the ``interpret`` path
    runs); ``tile_gpu`` the Pallas-Triton twin, or None while a family has
    no GPU kernel yet.
    """

    name: str
    tile: Callable[..., Any]
    fused: Callable[..., Any]
    tile_gpu: Callable[..., Any] | None = None


_REGISTRY: dict[str, PallasOp] = {}


def register_op(name: str, *, tile: Callable[..., Any],
                fused: Callable[..., Any],
                tile_gpu: Callable[..., Any] | None = None) -> PallasOp:
    op = PallasOp(name=name, tile=tile, fused=fused, tile_gpu=tile_gpu)
    _REGISTRY[name] = op
    return op


def get_op(name: str) -> PallasOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no Pallas op {name!r} registered; known: {available_ops()}"
        ) from None


def available_ops() -> list[str]:
    return sorted(_REGISTRY)


# ops whose first argument's trailing dim IS the segment size the autotune
# table buckets by; for the rest (attention: head dim, ssd_scan: different
# op key at the dispatch level) auto stays static rather than consulting
# the wrong bucket
_SIZE_IS_LAST_DIM = ("segmented_reduce", "segmented_scan", "weighted_scan")


def pallas_op(name: str, *args: Any, path: str | None = None,
              use_pallas: bool | None = None, **kwargs: Any) -> Any:
    """Run a registered op through the path switch (see module docstring).

    For the reduction/scan family the first array argument's trailing
    dimension is the op's segment size, enabling shape-aware ``auto``.
    """
    op = get_op(name)
    n = dt = None
    if name in _SIZE_IS_LAST_DIM:
        for a in args:
            if hasattr(a, "shape") and getattr(a, "ndim", 0) >= 1:
                n, dt = a.shape[-1], a.dtype
                break
    p = resolve_path(path, use_pallas=use_pallas, op=name, n=n, dtype=dt)
    if p == "fused":
        return op.fused(*args, **kwargs)
    if p == "tile_gpu":
        if op.tile_gpu is None:
            raise RuntimeError(
                f"{name}: no Pallas-Triton (GPU) kernel registered for this "
                "op; use path='tile_tpu', 'interpret', or 'fused'")
        return op.tile_gpu(*args, interpret=False, **kwargs)
    return op.tile(*args, interpret=(p == "interpret"), **kwargs)
