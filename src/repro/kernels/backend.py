"""Version shim + dispatch layer for every Pallas kernel in the repo.

Why this exists: the Pallas-TPU private surface renames things across JAX
releases (``pltpu.TPUCompilerParams`` on 0.4.x became ``pltpu.CompilerParams``
on 0.5+, field sets drift too). Hard-coding one spelling in each kernel broke
all of them at once; this module is the single place that knows which JAX is
installed. Kernels call :func:`compiler_params` instead of touching ``pltpu``
classes, and the public wrappers register with :func:`register_op` so every
call site picks its execution path through one switch:

  ``fused``      the XLA reference path (``repro.kernels.ref`` /
                 ``repro.core``) — default off-TPU
  ``tile``       the explicit Pallas tile kernel — native on TPU, silently
                 downgraded to ``interpret`` elsewhere (there is no TPU to
                 compile for)
  ``interpret``  the Pallas kernel body through the interpreter — how the
                 kernels are validated on CPU
  ``auto``       ``tile`` on TPU, ``fused`` otherwise

Selection precedence: per-call ``path=`` kwarg > per-call legacy
``use_pallas=`` bool > ``REPRO_KERNEL_PATH`` env var > ``auto``. Passing
both ``path=`` and ``use_pallas=`` with conflicting values warns and honours
``path=``. ``auto`` consults the measured per-shape crossover table in
``repro.core.autotune`` when the call shape is known, falling back to the
static choice (tile on TPU, fused elsewhere) otherwise or when
``REPRO_AUTOTUNE=off``.
"""
from __future__ import annotations

import dataclasses
import inspect
import os
import warnings
from typing import Any, Callable

import jax

ENV_PATH = "REPRO_KERNEL_PATH"
PATHS = ("auto", "fused", "tile", "interpret")


# ---------------------------------------------------------------------------
# capability probes


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def has_pallas_tpu() -> bool:
    """True when this JAX ships the Pallas-TPU lowering at all."""
    try:
        from jax.experimental.pallas import tpu as _  # noqa: F401
        return True
    except ImportError:
        return False


# ---------------------------------------------------------------------------
# compiler-params shim


def compiler_params_cls() -> type:
    """The Pallas-TPU compiler-params class under whichever name this JAX
    uses (``CompilerParams`` on 0.5+, ``TPUCompilerParams`` on 0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise RuntimeError(
        f"jax {jax.__version__}: no Pallas-TPU compiler-params class found; "
        "the version shim in repro.kernels.backend needs a new spelling"
    )


def _accepted_fields(cls: type) -> set[str]:
    if dataclasses.is_dataclass(cls):
        return {f.name for f in dataclasses.fields(cls)}
    return set(inspect.signature(cls).parameters)


def compiler_params(**kwargs: Any):
    """Construct compiler params portably.

    Fields unknown to the installed JAX (the field set drifts between
    releases) are dropped rather than raising, so kernels can request newer
    knobs without pinning a JAX version.
    """
    cls = compiler_params_cls()
    fields = _accepted_fields(cls)
    if "dimension_semantics" in kwargs and kwargs["dimension_semantics"]:
        kwargs["dimension_semantics"] = tuple(kwargs["dimension_semantics"])
    return cls(**{k: v for k, v in kwargs.items() if k in fields})


# ---------------------------------------------------------------------------
# path resolution


# algorithm-level contenders that only repro.core.dispatch understands; the
# env var is shared process-wide, so kernel-level call sites must tolerate
# them (their nearest kernel-level equivalent is the fused XLA path)
_DISPATCH_ONLY = ("baseline", "xla_tile")


def resolve_path(path: str | None = None, *,
                 use_pallas: bool | None = None,
                 op: str | None = None, n: int | None = None,
                 dtype: Any = None) -> str:
    """Resolve a concrete execution path: ``fused`` | ``tile`` | ``interpret``.

    ``path`` is the explicit per-call choice; ``use_pallas`` is the legacy
    bool (True → kernel, False → fused, None → unspecified); with neither,
    ``$REPRO_KERNEL_PATH`` applies, then ``auto``. When both are passed
    with conflicting values, ``path=`` wins and a ``UserWarning`` is
    emitted (``path='interpret'`` with ``use_pallas=True`` is *not* a
    conflict — interpret runs the same kernel body).

    ``op``/``n``/``dtype`` describe the call shape; with them, ``auto``
    consults the measured crossover table (``repro.core.autotune``)
    instead of the static TPU check.
    """
    if use_pallas is not None:
        implied = "tile" if use_pallas else "fused"
        if path is None:
            path = implied
        elif (use_pallas and path == "fused") or \
                (not use_pallas and path in ("tile", "interpret")):
            warnings.warn(
                f"conflicting path={path!r} and use_pallas={use_pallas}; "
                "path= takes precedence (use_pallas= is legacy)",
                UserWarning, stacklevel=3)
    if path is None:
        path = os.environ.get(ENV_PATH, "").strip().lower() or "auto"
        if path in _DISPATCH_ONLY:
            path = "fused"
    if path not in PATHS:
        raise ValueError(f"unknown kernel path {path!r}; expected one of {PATHS}")
    if path == "auto":
        choice = None
        if op is not None and n is not None:
            from repro.core import autotune  # deferred: autotune imports us

            choice = autotune.choose(op, n, dtype,
                                     candidates=("fused", "tile", "interpret"),
                                     level="kernel")
        path = choice or ("tile" if on_tpu() and has_pallas_tpu() else "fused")
    if path == "tile" and not on_tpu():
        path = "interpret"  # nothing to compile the tile kernel for
    return path


# ---------------------------------------------------------------------------
# op registry — the single pallas_call front door


@dataclasses.dataclass(frozen=True)
class PallasOp:
    """One kernel family: the Pallas tile entry (must accept an
    ``interpret=`` kwarg) and its fused-XLA reference twin."""

    name: str
    tile: Callable[..., Any]
    fused: Callable[..., Any]


_REGISTRY: dict[str, PallasOp] = {}


def register_op(name: str, *, tile: Callable[..., Any],
                fused: Callable[..., Any]) -> PallasOp:
    op = PallasOp(name=name, tile=tile, fused=fused)
    _REGISTRY[name] = op
    return op


def get_op(name: str) -> PallasOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no Pallas op {name!r} registered; known: {available_ops()}"
        ) from None


def available_ops() -> list[str]:
    return sorted(_REGISTRY)


# ops whose first argument's trailing dim IS the segment size the autotune
# table buckets by; for the rest (attention: head dim, ssd_scan: different
# op key at the dispatch level) auto stays static rather than consulting
# the wrong bucket
_SIZE_IS_LAST_DIM = ("segmented_reduce", "segmented_scan", "weighted_scan")


def pallas_op(name: str, *args: Any, path: str | None = None,
              use_pallas: bool | None = None, **kwargs: Any) -> Any:
    """Run a registered op through the path switch (see module docstring).

    For the reduction/scan family the first array argument's trailing
    dimension is the op's segment size, enabling shape-aware ``auto``.
    """
    op = get_op(name)
    n = dt = None
    if name in _SIZE_IS_LAST_DIM:
        for a in args:
            if hasattr(a, "shape") and getattr(a, "ndim", 0) >= 1:
                n, dt = a.shape[-1], a.dtype
                break
    p = resolve_path(path, use_pallas=use_pallas, op=name, n=n, dtype=dt)
    if p == "fused":
        return op.fused(*args, **kwargs)
    return op.tile(*args, interpret=(p == "interpret"), **kwargs)
