"""Version shim + multi-backend dispatch layer for every Pallas kernel in
the repo.

Why this exists: the Pallas private surfaces rename things across JAX
releases (``pltpu.TPUCompilerParams`` on 0.4.x became ``pltpu.CompilerParams``
on 0.5+, same drift on the Triton side, field sets move too). Hard-coding
one spelling in each kernel broke all of them at once; this module is the
single place that knows which JAX is installed and which accelerator is
active. Kernels call :func:`compiler_params` instead of touching
``pltpu``/``plgpu`` classes, and the public wrappers register with
:func:`register_op` so every call site picks its execution path through one
switch:

  ``fused``      the XLA reference path (``repro.kernels.ref`` /
                 ``repro.core``) — default off-accelerator
  ``tile``       the explicit Pallas tile kernel for *this host's* backend:
                 resolves to ``tile_tpu`` on TPU, ``tile_gpu`` on GPU
                 (Pallas-Triton), and downgrades to ``interpret`` elsewhere
                 with a one-time warning (there is nothing to compile for)
  ``tile_tpu``   force the Pallas-TPU kernel — raises off-TPU
  ``tile_gpu``   force the Pallas-Triton kernel — raises off-GPU
  ``tile_logdepth``  the log-depth MatMulScan contender (scan family):
                 the host backend's carry-free local block kernels + an
                 O(log) XLA tree combine; off-accelerator the local
                 kernels run through the interpreter (the label survives)
  ``interpret``  the Pallas kernel body through the interpreter — how the
                 kernels are validated on CPU
  ``auto``       ``tile`` on TPU/GPU, ``fused`` otherwise

Which path runs is decided by the active :class:`repro.core.policy.
KernelPolicy` — the single resolution algorithm for the whole repo. This
module keeps the registry, the capability probes, and the compiler-params
shim; selection state (path, per-op overrides, backend preference,
autotune mode, env-var parsing) lives entirely in ``repro.core.policy``.
Precedence: per-call ``path=`` kwarg > per-call legacy ``use_pallas=``
bool > per-call / active ``policy`` (whose process default is built from
``REPRO_KERNEL_PATH`` and friends) > ``auto``. Passing both ``path=`` and
``use_pallas=`` with conflicting values warns and honours ``path=``.
``auto`` consults the measured per-shape crossover table in
``repro.core.autotune`` (keyed by backend — a GPU-measured table never
steers a CPU/TPU host) when the call shape is known, falling back to the
static choice (tile on TPU/GPU, fused elsewhere) otherwise or when the
policy disables autotuning. ``auto`` never selects a ``tile_*`` label the
host cannot lower natively.

Selection also carries *tuning*: the resolution result is a
``ResolvedPath`` whose ``.tuning`` is the per-op
:class:`~repro.core.policy.TuneSpec` (layout defaults < autotune table's
swept winner < policy ``op_tuning``); :func:`pallas_op` hands it to the
tile entries as ``tuning=`` so every kernel's block/chunk/warp geometry
is data, not constants.
"""
from __future__ import annotations

import dataclasses
import inspect
import warnings
from typing import Any, Callable

import jax

from repro.obs import runtime as _obs

# the env var's *name*; it is parsed only by repro.core.policy
ENV_PATH = "REPRO_KERNEL_PATH"
PATHS = ("auto", "fused", "tile", "tile_tpu", "tile_gpu", "tile_logdepth",
         "interpret")


# ---------------------------------------------------------------------------
# capability probes


def on_tpu() -> bool:
    """True when the default JAX backend is a TPU."""
    return jax.default_backend() == "tpu"


def on_gpu() -> bool:
    """True when the default JAX backend is a GPU (CUDA or ROCm)."""
    return jax.default_backend() in ("gpu", "cuda", "rocm")


def has_pallas_tpu() -> bool:
    """True when this JAX ships the Pallas-TPU lowering at all."""
    try:
        from jax.experimental.pallas import tpu as _  # noqa: F401
        return True
    except ImportError:
        return False


def has_pallas_triton() -> bool:
    """True when this JAX ships the Pallas-Triton (GPU) lowering at all."""
    try:
        from repro.kernels.triton import compat
    except ImportError:  # pragma: no cover — broken install
        return False
    return compat.available()


def native_tile_backend() -> str | None:
    """The concrete tile path this host lowers natively, or None."""
    if on_tpu() and has_pallas_tpu():
        return "tile_tpu"
    if on_gpu() and has_pallas_triton():
        return "tile_gpu"
    return None


# ---------------------------------------------------------------------------
# compiler-params shim


def compiler_params_cls() -> type:
    """The Pallas-TPU compiler-params class under whichever name this JAX
    uses (``CompilerParams`` on 0.5+, ``TPUCompilerParams`` on 0.4.x)."""
    from jax.experimental.pallas import tpu as pltpu

    for name in ("CompilerParams", "TPUCompilerParams"):
        cls = getattr(pltpu, name, None)
        if cls is not None:
            return cls
    raise RuntimeError(
        f"jax {jax.__version__}: no Pallas-TPU compiler-params class found; "
        "the version shim in repro.kernels.backend needs a new spelling"
    )


def _accepted_fields(cls: type) -> set[str]:
    if dataclasses.is_dataclass(cls):
        return {f.name for f in dataclasses.fields(cls)}
    return set(inspect.signature(cls).parameters)


def compiler_params(backend: str = "tpu", **kwargs: Any):
    """Construct compiler params portably for either Pallas backend.

    ``backend="tpu"`` (default) builds the Pallas-TPU params;
    ``backend="gpu"`` defers to the Triton shim in
    ``repro.kernels.triton.compat`` (the only module allowed to import
    ``jax.experimental.pallas.triton``). Fields unknown to the installed
    JAX (the field sets drift between releases) are dropped rather than
    raising, so kernels can request newer knobs without pinning a JAX
    version.
    """
    if backend in ("gpu", "triton"):
        from repro.kernels.triton import compat

        return compat.compiler_params(**kwargs)
    if backend != "tpu":
        raise ValueError(
            f"unknown compiler-params backend {backend!r}; "
            "expected 'tpu' or 'gpu'")
    cls = compiler_params_cls()
    fields = _accepted_fields(cls)
    if "dimension_semantics" in kwargs and kwargs["dimension_semantics"]:
        kwargs["dimension_semantics"] = tuple(kwargs["dimension_semantics"])
    return cls(**{k: v for k, v in kwargs.items() if k in fields})


# ---------------------------------------------------------------------------
# path resolution — repro.core.policy owns the one resolve implementation
# in the repo; this module only folds the legacy use_pallas bool into a
# label before handing the call to it


def _merge_use_pallas(path: str | None,
                      use_pallas: bool | None) -> str | None:
    """Fold the legacy ``use_pallas`` bool into an explicit path label.

    True → ``tile``, False → ``fused``, None → unspecified. When both
    ``path=`` and ``use_pallas=`` are passed with conflicting values,
    ``path=`` wins and a ``UserWarning`` is emitted (``path='interpret'``
    with ``use_pallas=True`` is *not* a conflict — interpret runs the same
    kernel body).
    """
    if use_pallas is None:
        return path
    implied = "tile" if use_pallas else "fused"
    if path is None:
        return implied
    if (use_pallas and path == "fused") or \
            (not use_pallas and path in ("tile", "tile_tpu", "tile_gpu",
                                         "interpret")):
        warnings.warn(
            f"conflicting path={path!r} and use_pallas={use_pallas}; "
            "path= takes precedence (use_pallas= is legacy)",
            UserWarning, stacklevel=4)
    return path


# ---------------------------------------------------------------------------
# op registry — the single pallas_call front door


@dataclasses.dataclass(frozen=True)
class PallasOp:
    """One kernel family: the Pallas tile entries per backend (each must
    accept ``interpret=`` and — when the family has tuning knobs —
    ``tuning=`` kwargs) and the fused-XLA reference twin.

    ``tile`` is the Pallas-TPU entry (also the body the ``interpret`` path
    runs); ``tile_gpu`` the Pallas-Triton twin, or None while a family has
    no GPU kernel yet. ``tile_logdepth``/``tile_logdepth_gpu`` are the
    log-depth MatMulScan contenders per backend (scan family only; None
    elsewhere) — each must accept ``interpret=`` like the linear entries,
    which is how the label survives off-accelerator with interpreted
    local kernels. ``knobs`` declares the family's tuning-knob schema
    (from ``repro.core.policy.KNOB_SCHEMA``, keyed by the canonical op
    name); the default and sweep-candidate knob *values* live in
    ``repro.kernels.layout`` and are exposed here per backend so autotune
    and callers interrogate the registry, not the kernel files.
    """

    name: str
    tile: Callable[..., Any]
    fused: Callable[..., Any]
    tile_gpu: Callable[..., Any] | None = None
    tile_logdepth: Callable[..., Any] | None = None
    tile_logdepth_gpu: Callable[..., Any] | None = None
    knobs: tuple = ()

    def _canonical(self) -> str:
        from repro.core import policy as kpolicy

        return kpolicy.OP_ALIASES.get(self.name, self.name)

    def default_tuning(self, backend: str = "tpu") -> dict:
        """Default knob values for this family on ``backend``."""
        from repro.kernels import layout

        return layout.default_tuning(backend, self._canonical())

    def candidate_tuning(self, backend: str = "tpu") -> list[dict]:
        """The candidate specs the autotune sweep times for this family."""
        from repro.kernels import layout

        return layout.candidate_tuning(backend, self._canonical())


_REGISTRY: dict[str, PallasOp] = {}


def register_op(name: str, *, tile: Callable[..., Any],
                fused: Callable[..., Any],
                tile_gpu: Callable[..., Any] | None = None,
                tile_logdepth: Callable[..., Any] | None = None,
                tile_logdepth_gpu: Callable[..., Any] | None = None
                ) -> PallasOp:
    from repro.core import policy as kpolicy  # deferred: avoids a cycle

    canon = kpolicy.OP_ALIASES.get(name, name)
    op = PallasOp(name=name, tile=tile, fused=fused, tile_gpu=tile_gpu,
                  tile_logdepth=tile_logdepth,
                  tile_logdepth_gpu=tile_logdepth_gpu,
                  knobs=tuple(kpolicy.KNOB_SCHEMA.get(canon, ())))
    _REGISTRY[name] = op
    return op


def get_op(name: str) -> PallasOp:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no Pallas op {name!r} registered; known: {available_ops()}"
        ) from None


def available_ops() -> list[str]:
    return sorted(_REGISTRY)


def _call_shape(name: str, args: tuple) -> tuple:
    """The (size, dtype) the autotune table buckets ``name`` by, extracted
    from the call's first array argument — the same quantity the dispatch
    layer passes for its level (reduction family: trailing segment size;
    rmsnorm: feature dim; attention: query length, kernel layout
    (B, H, L, D); ssd_scan: sequence length, (B, L, H, P)). Returns
    (None, None) when no shape context is extractable — resolution then
    stays static and table tuning keeps the layout defaults.
    """
    a = next((x for x in args
              if hasattr(x, "shape") and getattr(x, "ndim", 0) >= 1), None)
    if a is None:
        return None, None
    if name in ("segmented_reduce", "segmented_scan", "weighted_scan",
                "rmsnorm"):
        return a.shape[-1], a.dtype
    if name == "attention" and a.ndim >= 3:
        return a.shape[2], a.dtype
    if name == "ssd_scan" and a.ndim >= 2:
        return a.shape[1], a.dtype
    return None, None


def _emit_invoke(name: str, n, dt, p) -> None:
    """One ``kernel_invoke`` event + counter per registry execution (only
    called when an obs session is active)."""
    sess = _obs.ACTIVE
    if sess is None:
        return
    from repro.core import autotune  # deferred: imports us

    tuning = getattr(p, "tuning", None)
    sess.emit("kernel_invoke", op=name,
              n=(int(n) if n is not None else None),
              dtype=(autotune.dtype_tag(dt) if dt is not None else None),
              path=str(p),
              tuning=(tuning.as_dict() if tuning is not None else None))
    sess.counter(
        "repro_kernel_invocations_total",
        "kernel-registry executions by op/path").inc(op=name, path=str(p))


def pallas_op(name: str, *args: Any, policy: Any = None,
              path: str | None = None,
              use_pallas: bool | None = None, **kwargs: Any) -> Any:
    """Run a registered op through the policy switch (see module
    docstring).

    ``policy`` is a :class:`repro.core.policy.KernelPolicy` (or string
    shorthand; None = the active policy); ``path``/``use_pallas`` are the
    per-call legacy spellings and beat the policy. Every family extracts
    its bucket size from the call (see :func:`_call_shape`), enabling
    shape-aware ``auto`` AND shape-bucketed table tuning. The resolved
    :class:`~repro.core.policy.TuneSpec` rides the resolution result and
    is handed to the tile entries as ``tuning=`` (families that declare
    knobs); the fused XLA twin has no geometry and never sees it.
    """
    from repro.core import policy as kpolicy

    op = get_op(name)
    n, dt = _call_shape(name, args)
    path = _merge_use_pallas(path, use_pallas)
    p = kpolicy.as_policy(policy).resolve(op=name, n=n, dtype=dt,
                                          level="kernel", explicit=path)
    if _obs.ACTIVE is not None:   # off by default; one global load
        _emit_invoke(name, n, dt, p)
    if p == "fused":
        return op.fused(*args, **kwargs)
    if op.knobs:
        kwargs["tuning"] = getattr(p, "tuning", None)
    if p == "tile_gpu":
        if op.tile_gpu is None:
            raise RuntimeError(
                f"{name}: no Pallas-Triton (GPU) kernel registered for this "
                "op; use path='tile_tpu', 'interpret', or 'fused'")
        return op.tile_gpu(*args, interpret=False, **kwargs)
    if p == "tile_logdepth":
        native = native_tile_backend()
        fn = op.tile_logdepth_gpu if native == "tile_gpu" \
            else op.tile_logdepth
        if fn is None:
            raise RuntimeError(
                f"{name}: no log-depth MatMulScan kernel registered for "
                "this op (tile_logdepth covers the scan family: scan, "
                "weighted_scan, ssd); use path='tile' or 'fused'")
        # off-accelerator the local block kernels run interpreted; the
        # tree combine is plain XLA either way
        return fn(*args, interpret=(native is None), **kwargs)
    return op.tile(*args, interpret=(p == "interpret"), **kwargs)
