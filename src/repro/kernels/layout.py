"""Shared layout/padding glue for the kernel wrappers.

Both kernel backends (the Pallas-TPU twins in this package and the
Pallas-Triton twins in ``repro.kernels.triton``) wrap the same shape-strict
kernels in the same way: flatten leading dims, zero-pad to the backend's
tile multiples, run, slice the valid block back out. The padding algebra is
backend-independent — only the multiples differ (128-lane MXU tiles vs
16-wide tensor-core MMA fragments) — so it lives here once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    rem = (-x.shape[axis]) % multiple
    if not rem:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def nrows(lead: tuple[int, ...]) -> int:
    """Product of the leading (batch-like) dims a wrapper flattens away."""
    rows = 1
    for s in lead:
        rows *= s
    return rows


def ssd_fold(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array):
    """Model layout -> kernel layout for the SSD chunk-scan kernels.

    Folds ``(B, H)`` into one grid axis, broadcasts the G kv-like groups
    over the H heads, and pre-weights the inputs: ``xdt = dt * x``,
    ``lam = dt * a``. Returns ``(xdt (BH, L, P), lam (BH, L),
    bb (BH, L, N), cc (BH, L, N))`` in f32, unpadded — the caller applies
    its backend's tile-multiple padding (zero-padding is harmless: lam = 0
    means decay 1 and input 0).
    """
    bsz, seqlen, nheads, hdim = x.shape
    ngroups, nstate = b.shape[2], b.shape[3]
    rep = nheads // ngroups
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xdt = jnp.moveaxis(xdt, 2, 1).reshape(bsz * nheads, seqlen, hdim)
    lam = (dt.astype(jnp.float32) * a.astype(jnp.float32))
    lam = jnp.moveaxis(lam, 2, 1).reshape(bsz * nheads, seqlen)
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    bb = jnp.moveaxis(bb, 2, 1).reshape(bsz * nheads, seqlen, nstate)
    cc = jnp.moveaxis(cc, 2, 1).reshape(bsz * nheads, seqlen, nstate)
    return xdt, lam, bb, cc


def ssd_unfold(y: jax.Array, state: jax.Array, *, bsz: int, nheads: int,
               seqlen: int, hdim: int, nstate: int, out_dtype,
               return_state: bool):
    """Kernel layout back to model layout; slices padding off.

    ``y`` is (BH, L_pad, P_pad), ``state`` (BH, N_pad, P_pad); the
    zero-padding of b/x keeps the valid state block exact, so slicing is
    enough. Returns ``y (B, L, H, P)`` (cast to ``out_dtype``) and, when
    requested, the final state ``(B, H, P, N)`` f32 (matching
    ``ssd_chunked``).
    """
    y = y[:, :seqlen, :hdim].reshape(bsz, nheads, seqlen, hdim)
    y = jnp.moveaxis(y, 1, 2).astype(out_dtype)
    if not return_state:
        return y
    st = state[:, :nstate, :hdim].reshape(bsz, nheads, nstate, hdim)
    return y, jnp.swapaxes(st, -1, -2)
