"""Shared layout/padding glue + the single home for kernel geometry.

Both kernel backends (the Pallas-TPU twins in this package and the
Pallas-Triton twins in ``repro.kernels.triton``) wrap the same shape-strict
kernels in the same way: flatten leading dims, zero-pad to the backend's
tile multiples, run, slice the valid block back out. The padding algebra is
backend-independent — only the multiples differ (128-lane MXU tiles vs
16-wide tensor-core MMA fragments) — so it lives here once.

Since the TuneSpec refactor this module is also the ONLY place allowed to
spell out block/chunk/warp numbers (a grep-guard test bans literal geometry
constants in every other kernel file):

* :data:`LANES` / :data:`SUBLANES` / :data:`MMA_TILE` — *hardware*
  constants (MXU lane count, f32 sublane tile, tensor-core fragment edge).
  These are facts about the silicon, not tuning knobs.
* :data:`DEFAULT_TUNING` — the per-(backend, op) default knob values the
  kernels ran with before geometry became caller-supplied. Consumed by
  ``repro.core.policy.KernelPolicy.tuning_for`` as the base layer every
  resolved :class:`~repro.core.policy.TuneSpec` starts from.
* :data:`CANDIDATE_TUNING` — the candidate specs ``python -m
  repro.core.autotune --write`` sweeps per op (>= 2 each; the winning spec
  is persisted in the v3 table).
* :func:`fit_block` — clamp a caller-supplied block size to the hardware
  multiple and the (padded) extent of the axis it tiles, so a swept or
  hand-written spec can never crash a kernel on a small or unaligned shape
  (it shrinks to fit instead).

The knob *names* are validated against ``repro.core.policy.KNOB_SCHEMA``
(the policy layer owns validation, the way ``op_paths`` validates against
``KNOWN_OPS``); this module owns the *values*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Hardware constants (not tuning knobs):
LANES = 128      # TPU MXU/VPU lane count — the systolic-array edge
SUBLANES = 8     # TPU f32 sublane tile (min second-to-last dim)
MMA_TILE = 16    # GPU tensor-core MMA fragment edge (WMMA 16x16x16)

# KV-cache page height for the paged serving pool (serving/kvpool.py):
# a power-of-two multiple of SUBLANES so a page is a whole number of
# sublane tiles and divides every pow2-bucketized ring capacity. Like
# the constants above this is geometry, so it lives here and nowhere
# else (callers import it; the grep-guard bans literal copies).
KV_PAGE_ROWS = 2 * SUBLANES

# Per-(backend, op) default tuning — the values the kernels hard-coded
# before the TuneSpec refactor. Keys must stay within
# repro.core.policy.KNOB_SCHEMA (test-enforced). The "tpu" section also
# covers the interpret path (it runs the Pallas-TPU kernel body).
DEFAULT_TUNING = {
    "tpu": {
        "reduce": {"block_s": 128, "block_n": 128},
        "scan": {"block_s": 128, "block_n": 128,
                 "radix": 16, "fan_in": 16},
        "weighted_scan": {"q": 128, "radix": 16, "fan_in": 16},
        "rmsnorm": {"row_block": 128},
        "attention": {"block_q": 128, "block_k": 128},
        "ssd": {"q": 128, "radix": 16, "fan_in": 16},
        "ragged_reduce": {},
        "ragged_scan": {},
    },
    "gpu": {
        "reduce": {"block_s": 32, "block_n": 64,
                   "num_warps": 4, "num_stages": 2},
        "scan": {"block_s": 32, "block_n": 64, "radix": 16, "fan_in": 16,
                 "num_warps": 4, "num_stages": 2},
        "weighted_scan": {"q": 64, "radix": 16, "fan_in": 16,
                          "num_warps": 4, "num_stages": 2},
        "rmsnorm": {"row_block": 16, "block_d": 128,
                    "num_warps": 8, "num_stages": 2},
        "attention": {"block_q": 64, "block_k": 64,
                      "num_warps": 4, "num_stages": 2},
        "ssd": {"q": 64, "radix": 16, "fan_in": 16,
                "num_warps": 4, "num_stages": 2},
        "ragged_reduce": {},
        "ragged_scan": {},
    },
}

# Candidate specs the autotune sweep times per op (the first entry is the
# default geometry so the sweep always covers the status quo). Ragged ops
# have no Pallas kernel yet, hence no candidates.
CANDIDATE_TUNING = {
    "tpu": {
        "reduce": ({"block_s": 128, "block_n": 128},
                   {"block_s": 128, "block_n": 256},
                   {"block_s": 256, "block_n": 128}),
        "scan": ({"block_s": 128, "block_n": 128},
                 {"block_s": 128, "block_n": 256}),
        "weighted_scan": ({"q": 128}, {"q": 256}),
        "rmsnorm": ({"row_block": 128}, {"row_block": 256}),
        "attention": ({"block_q": 128, "block_k": 128},
                      {"block_q": 128, "block_k": 256}),
        "ssd": ({"q": 128}, {"q": 256}),
    },
    "gpu": {
        "reduce": ({"block_s": 32, "block_n": 64,
                    "num_warps": 4, "num_stages": 2},
                   {"block_s": 64, "block_n": 64,
                    "num_warps": 4, "num_stages": 2},
                   {"block_s": 32, "block_n": 128,
                    "num_warps": 8, "num_stages": 3}),
        "scan": ({"block_s": 32, "block_n": 64,
                  "num_warps": 4, "num_stages": 2},
                 {"block_s": 16, "block_n": 128,
                  "num_warps": 8, "num_stages": 2}),
        "weighted_scan": ({"q": 64, "num_warps": 4, "num_stages": 2},
                          {"q": 128, "num_warps": 4, "num_stages": 2}),
        "rmsnorm": ({"row_block": 16, "block_d": 128,
                     "num_warps": 8, "num_stages": 2},
                    {"row_block": 32, "block_d": 64,
                     "num_warps": 4, "num_stages": 2}),
        "attention": ({"block_q": 64, "block_k": 64,
                       "num_warps": 4, "num_stages": 2},
                      {"block_q": 128, "block_k": 64,
                       "num_warps": 8, "num_stages": 2}),
        "ssd": ({"q": 64, "num_warps": 4, "num_stages": 2},
                {"q": 128, "num_warps": 4, "num_stages": 2}),
    },
}


# Candidate specs for the log-depth MatMulScan contender (its own table:
# the linear sweep's clamp-dedupe compares executed dicts, and mixing
# radix/fan_in into CANDIDATE_TUNING would make identical linear
# geometries look distinct and get timed as phantoms). radix is the tree
# branching factor, fan_in the base-case width finished with one
# triangular matmul — both sized around the MMA fragment edge.
LOGDEPTH_CANDIDATE_TUNING = {
    "tpu": {
        "scan": ({"block_s": 128, "block_n": 128,
                  "radix": 16, "fan_in": 16},
                 {"block_s": 128, "block_n": 128,
                  "radix": 16, "fan_in": 64}),
        "weighted_scan": ({"q": 128, "radix": 16, "fan_in": 16},
                          {"q": 128, "radix": 32, "fan_in": 32}),
        "ssd": ({"q": 128, "radix": 16, "fan_in": 16},
                {"q": 128, "radix": 32, "fan_in": 32}),
    },
    "gpu": {
        "scan": ({"block_s": 32, "block_n": 64, "radix": 16, "fan_in": 16,
                  "num_warps": 4, "num_stages": 2},
                 {"block_s": 32, "block_n": 64, "radix": 16, "fan_in": 64,
                  "num_warps": 4, "num_stages": 2}),
        "weighted_scan": ({"q": 64, "radix": 16, "fan_in": 16,
                           "num_warps": 4, "num_stages": 2},
                          {"q": 64, "radix": 32, "fan_in": 32,
                           "num_warps": 4, "num_stages": 2}),
        "ssd": ({"q": 64, "radix": 16, "fan_in": 16,
                 "num_warps": 4, "num_stages": 2},
                {"q": 64, "radix": 32, "fan_in": 32,
                 "num_warps": 4, "num_stages": 2}),
    },
}


def default_tuning(backend: str, op: str) -> dict:
    """The default knob values for ``op`` on ``backend`` (a fresh dict)."""
    return dict(DEFAULT_TUNING.get(backend, {}).get(op, {}))


def candidate_tuning(backend: str, op: str) -> list[dict]:
    """The sweepable candidate specs for ``op`` on ``backend``."""
    return [dict(c) for c in CANDIDATE_TUNING.get(backend, {}).get(op, ())]


def logdepth_candidate_tuning(backend: str, op: str) -> list[dict]:
    """The sweepable candidate specs for ``op``'s ``tile_logdepth``
    contender on ``backend`` (empty for families without one)."""
    return [dict(c)
            for c in LOGDEPTH_CANDIDATE_TUNING.get(backend, {}).get(op, ())]


# Which hardware multiple each clampable block knob carries, split by the
# call-shape axis it tiles: "n" knobs tile the very axis the autotune
# table buckets by (segment size / chunk length / feature dim) and can be
# clamped as soon as n is known — at resolve time, so the reported
# TuneSpec IS the geometry that runs; "rows" knobs tile the flattened
# batch axis only the glue sees and are clamped there. Attention's blocks
# tile two sequence axes that may differ (decode), so only the glue
# clamps them.
N_AXIS_KNOBS = {
    "tpu": {"reduce": {"block_n": SUBLANES}, "scan": {"block_n": LANES},
            "weighted_scan": {"q": LANES}, "ssd": {"q": LANES}},
    "gpu": {"reduce": {"block_n": MMA_TILE}, "scan": {"block_n": MMA_TILE},
            "weighted_scan": {"q": MMA_TILE}, "ssd": {"q": MMA_TILE},
            "rmsnorm": {"block_d": MMA_TILE}},
}
ROW_AXIS_KNOBS = {
    "tpu": {"reduce": {"block_s": LANES}, "scan": {"block_s": SUBLANES},
            "rmsnorm": {"row_block": SUBLANES}},
    "gpu": {"reduce": {"block_s": MMA_TILE}, "scan": {"block_s": MMA_TILE},
            "rmsnorm": {"row_block": MMA_TILE}},
}


def clamp_spec(backend: str, op: str, knobs: dict, *,
               n: int | None = None, rows: int | None = None) -> dict:
    """Clamp block knobs against the known call shape (see
    :data:`N_AXIS_KNOBS`/:data:`ROW_AXIS_KNOBS`); unknown extents pass
    the knob through unchanged. Used by ``KernelPolicy.tuning_for`` (n
    only) so the resolved spec reports what actually runs, and by the
    autotune sweep (n and rows) so candidates that collapse onto the same
    executed geometry are deduplicated instead of timed as phantoms."""
    out = dict(knobs)
    for ext, table in ((n, N_AXIS_KNOBS), (rows, ROW_AXIS_KNOBS)):
        if ext is None:
            continue
        for knob, mult in table.get(backend, {}).get(op, {}).items():
            if knob in out:
                out[knob] = fit_block(ext, out[knob], mult)
    return out


def fit_block(size: int, block: int, multiple: int) -> int:
    """Clamp a caller-supplied block size against the axis it tiles.

    Rounds ``block`` down to the hardware ``multiple`` (never below it) and
    caps it at the padded extent of ``size``, so a swept/hand-written spec
    cannot request a block the shape can't supply: the wrapper then pads
    the axis to a multiple of the fitted block and divisibility holds by
    construction.
    """
    b = max(multiple, (int(block) // multiple) * multiple)
    ext = -(-max(int(size), 1) // multiple) * multiple
    return min(b, ext)


def knob(tuning, key: str, backend: str, op: str) -> int:
    """One knob value from a TuneSpec-or-None, else the backend default.

    ``tuning`` is anything with ``.get`` (a ``TuneSpec`` or a plain dict);
    None falls through to :func:`default_tuning` — how direct kernel-glue
    callers that predate the policy plumbing keep working.
    """
    if tuning is not None:
        v = tuning.get(key)
        if v is not None:
            return int(v)
    return int(DEFAULT_TUNING[backend][op][key])


def pad_axis(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    """Zero-pad ``axis`` of ``x`` up to the next multiple of ``multiple``."""
    rem = (-x.shape[axis]) % multiple
    if not rem:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def nrows(lead: tuple[int, ...]) -> int:
    """Product of the leading (batch-like) dims a wrapper flattens away."""
    rows = 1
    for s in lead:
        rows *= s
    return rows


def ssd_fold(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array):
    """Model layout -> kernel layout for the SSD chunk-scan kernels.

    Folds ``(B, H)`` into one grid axis, broadcasts the G kv-like groups
    over the H heads, and pre-weights the inputs: ``xdt = dt * x``,
    ``lam = dt * a``. Returns ``(xdt (BH, L, P), lam (BH, L),
    bb (BH, L, N), cc (BH, L, N))`` in f32, unpadded — the caller applies
    its backend's tile-multiple padding (zero-padding is harmless: lam = 0
    means decay 1 and input 0).
    """
    bsz, seqlen, nheads, hdim = x.shape
    ngroups, nstate = b.shape[2], b.shape[3]
    rep = nheads // ngroups
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    xdt = jnp.moveaxis(xdt, 2, 1).reshape(bsz * nheads, seqlen, hdim)
    lam = (dt.astype(jnp.float32) * a.astype(jnp.float32))
    lam = jnp.moveaxis(lam, 2, 1).reshape(bsz * nheads, seqlen)
    bb = jnp.repeat(b, rep, axis=2).astype(jnp.float32)
    cc = jnp.repeat(c, rep, axis=2).astype(jnp.float32)
    bb = jnp.moveaxis(bb, 2, 1).reshape(bsz * nheads, seqlen, nstate)
    cc = jnp.moveaxis(cc, 2, 1).reshape(bsz * nheads, seqlen, nstate)
    return xdt, lam, bb, cc


def ssd_unfold(y: jax.Array, state: jax.Array, *, bsz: int, nheads: int,
               seqlen: int, hdim: int, nstate: int, out_dtype,
               return_state: bool):
    """Kernel layout back to model layout; slices padding off.

    ``y`` is (BH, L_pad, P_pad), ``state`` (BH, N_pad, P_pad); the
    zero-padding of b/x keeps the valid state block exact, so slicing is
    enough. Returns ``y (B, L, H, P)`` (cast to ``out_dtype``) and, when
    requested, the final state ``(B, H, P, N)`` f32 (matching
    ``ssd_chunked``).
    """
    y = y[:, :seqlen, :hdim].reshape(bsz, nheads, seqlen, hdim)
    y = jnp.moveaxis(y, 1, 2).astype(out_dtype)
    if not return_state:
        return y
    st = state[:, :nstate, :hdim].reshape(bsz, nheads, nstate, hdim)
    return y, jnp.swapaxes(st, -1, -2)
