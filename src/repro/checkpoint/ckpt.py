"""Sharded, atomic, mesh-shape-independent checkpointing — async by default.

Layout:  <dir>/step_<N>/host_<i>.npz  +  <dir>/step_<N>/manifest.json

* Each host writes only its addressable shards (leaf key -> list of
  (global-index, data) entries), so no device->host all-gather is needed.
* **Async save** (:class:`AsyncCheckpointer`): ``save()`` snapshots the
  addressable shards to host memory (a copy, so donated/overwritten device
  buffers can't corrupt the file) and returns; write + fsync + rename run
  on a background thread. The *next* ``save()`` (or an explicit
  :meth:`~AsyncCheckpointer.wait`) is the barrier — it joins the previous
  write and re-raises any I/O error, so the step loop overlaps exactly one
  checkpoint with compute and can never stack unbounded dirty state.
* Commit is atomic: write into ``step_<N>.tmp``, fsync, rename. A crash
  mid-write never corrupts the latest valid checkpoint; ``latest_step``
  ignores ``.tmp`` dirs, and the next save sweeps stale ``.tmp`` dirs a
  crash left behind. **Multi-host commit**: every host writes
  ``host_<i>.npz`` into the shared tmp dir (via a ``.part`` rename so a
  half-written file is never counted); host 0 renames to the final name
  only once all ``n_hosts`` host files exist, and the other hosts block
  until the rename lands — a checkpoint either has every host's shards or
  is not visible at all.
* Restore is **elastic**: shards are reassembled into global host arrays
  and re-placed under whatever sharding the *new* mesh prescribes — resume
  on 256 chips after checkpointing on 512 (or vice versa) just works,
  including across process counts (placement goes through
  ``jax.make_array_from_callback``, which only touches addressable
  devices).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time

import jax
import numpy as np

from repro.obs import profiling as _prof
from repro.obs import runtime as _obs


def _flat_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def _snapshot(tree):
    """Copy this host's addressable shards to host memory (sync phase)."""
    shards: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for key, leaf in _flat_with_paths(tree):
        leaf = jax.numpy.asarray(leaf)
        meta[key] = {"shape": list(leaf.shape), "dtype": str(leaf.dtype)}
        for i, s in enumerate(leaf.addressable_shards):
            start = [idx.start or 0 for idx in s.index] if s.index else []
            arr = np.array(s.data)  # copy: the device buffer may be reused
            shards[f"{key}||{i}||{','.join(map(str, start))}"] = (
                arr.view(np.uint16) if arr.dtype == jax.numpy.bfloat16
                else arr)
            meta[key].setdefault("bf16", arr.dtype == jax.numpy.bfloat16)
    return shards, meta


def _sweep_stale_tmp(directory: str, current_step: int) -> None:
    """Remove ``step_*.tmp`` dirs a crashed run left behind (never the
    current step's — in a multi-host save other hosts may be writing it)."""
    if not os.path.isdir(directory):
        return
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)\.tmp", d)
        if m and int(m.group(1)) != current_step:
            shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def _committed_steps(directory: str) -> list[int]:
    if not os.path.isdir(directory):
        return []
    return sorted(
        int(m.group(1)) for d in os.listdir(directory)
        if (m := re.fullmatch(r"step_(\d+)", d))
        and os.path.exists(os.path.join(directory, d, "manifest.json")))


class AsyncCheckpointer:
    """Overlap checkpoint I/O with the step loop; at most one in flight.

    ``save(step, tree)`` returns after the host-memory snapshot;
    ``wait()`` blocks until the write is committed (and re-raises any
    background error). Calling ``save`` again waits for the previous write
    first — that is the barrier contract the training loop relies on.

    ``keep_last=N`` garbage-collects older committed ``step_*`` dirs after
    each commit (host 0 only); None keeps everything.
    """

    def __init__(self, directory: str, *, keep_last: int | None = None,
                 poll_s: float = 0.05, timeout_s: float = 600.0,
                 _pre_commit=None):
        if keep_last is not None and keep_last < 1:
            raise ValueError(f"keep_last must be >= 1, got {keep_last}")
        self.directory = str(directory)
        self.keep_last = keep_last
        self.poll_s = poll_s
        self.timeout_s = timeout_s
        self._pre_commit = _pre_commit  # test hook: runs before commit
        self._thread: threading.Thread | None = None
        self._error: BaseException | None = None
        self._committed: str | None = None

    def save(self, step: int, tree) -> None:
        """Snapshot ``tree`` and schedule the write; returns immediately.

        Blocks only on the *previous* save's completion (the barrier) and
        on the device->host copy of this host's addressable shards.
        """
        self.wait()
        _sweep_stale_tmp(self.directory, step)
        # the session is captured HERE and handed to the writer thread:
        # the background write must land in the session that was active
        # when the save was issued, even if the scope closes meanwhile
        sess = _obs.ACTIVE
        t_snap = time.perf_counter() if sess is not None else 0.0
        with _prof.span("ckpt/snapshot"):
            shards, meta = _snapshot(tree)
        if sess is not None:
            dur = time.perf_counter() - t_snap
            sess.histogram(
                "repro_ckpt_snapshot_seconds",
                "device->host shard snapshot (blocks the step loop)"
            ).observe(dur)
            sess.emit("ckpt", phase="snapshot", step=int(step), seconds=dur)
        host = jax.process_index()
        n_hosts = jax.process_count()
        self._thread = threading.Thread(
            target=self._write, name=f"ckpt-step{step}",
            args=(step, shards, meta, host, n_hosts, sess), daemon=True)
        self._thread.start()

    def wait(self) -> str | None:
        """Block until the in-flight save (if any) is committed; returns
        the last committed path. Re-raises a background write error."""
        t, self._thread = self._thread, None
        if t is not None:
            sess = _obs.ACTIVE
            if sess is not None:
                t_join = time.perf_counter()
                t.join()
                dur = time.perf_counter() - t_join
                sess.histogram(
                    "repro_ckpt_commit_barrier_seconds",
                    "time the step loop blocked joining the in-flight "
                    "checkpoint write").observe(dur)
                sess.emit("ckpt", phase="commit_barrier", seconds=dur)
            else:
                t.join()
        if self._error is not None:
            err, self._error = self._error, None
            raise err
        return self._committed

    def last_committed(self) -> str | None:
        """The last committed path (does not block; None if the first save
        is still in flight or never happened)."""
        return self._committed

    # -- background phase ---------------------------------------------------

    def _write(self, step, shards, meta, host, n_hosts, sess=None):
        t_w = time.perf_counter()
        try:
            self._committed = self._write_inner(
                step, shards, meta, host, n_hosts)
        except BaseException as e:  # surfaced by the next wait()/save()
            self._error = e
            return
        if sess is not None:   # the session captured at save() time —
            # this thread records into it even after the scope moved on
            dur = time.perf_counter() - t_w
            sess.histogram(
                "repro_ckpt_write_seconds",
                "background write+fsync+commit duration").observe(dur)
            sess.emit("ckpt", phase="write", step=int(step), seconds=dur)

    def _write_inner(self, step, shards, meta, host, n_hosts) -> str:
        final = os.path.join(self.directory, f"step_{step}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)

        # never let a half-written npz count toward the commit quorum
        part = os.path.join(tmp, f"host_{host}.npz.part")
        with open(part, "wb") as f:  # np.savez would append ".npz" to a path
            np.savez(f, **shards)
        os.replace(part, os.path.join(tmp, f"host_{host}.npz"))
        if host == 0:
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "leaves": meta,
                           "n_hosts": n_hosts}, f)
        if self._pre_commit is not None:
            self._pre_commit()

        deadline = time.monotonic() + self.timeout_s
        if host == 0:
            # commit only once every host's shards are on disk
            while True:
                have = sum(
                    os.path.exists(os.path.join(tmp, f"host_{i}.npz"))
                    for i in range(n_hosts))
                if have == n_hosts:
                    break
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"checkpoint step {step}: only {have}/{n_hosts} "
                        f"host files after {self.timeout_s}s")
                time.sleep(self.poll_s)
            fd = os.open(tmp, os.O_RDONLY)
            os.fsync(fd)
            os.close(fd)
            if os.path.exists(final):  # re-save of an existing step
                shutil.rmtree(final)
            os.replace(tmp, final)
            if self.keep_last is not None:
                for old in _committed_steps(self.directory)[:-self.keep_last]:
                    shutil.rmtree(
                        os.path.join(self.directory, f"step_{old}"),
                        ignore_errors=True)
        else:
            # the rename is host 0's; block until it lands
            while os.path.exists(tmp) or not os.path.exists(
                    os.path.join(final, "manifest.json")):
                if time.monotonic() > deadline:
                    raise TimeoutError(
                        f"checkpoint step {step}: host 0 did not commit "
                        f"within {self.timeout_s}s")
                time.sleep(self.poll_s)
        return final


def save(directory: str, step: int, tree, *,
         keep_last: int | None = None) -> str:
    """Synchronous save (write + commit before returning); returns the
    committed path. The async form is :class:`AsyncCheckpointer`."""
    ckpt = AsyncCheckpointer(directory, keep_last=keep_last)
    ckpt.save(step, tree)
    return ckpt.wait()


def latest_step(directory: str) -> int | None:
    steps = _committed_steps(directory)
    return steps[-1] if steps else None


def restore(directory: str, step: int, target_tree, shardings=None):
    """Rebuild ``target_tree``-shaped pytree from the checkpoint, placed
    under ``shardings`` (same treedef) or replicated if None."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    # gather shards from every host file present
    assembled: dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(path)):
        if not fname.endswith(".npz"):
            continue
        with np.load(os.path.join(path, fname)) as z:
            for skey in z.files:
                key, _, start_s = skey.split("||")
                info = manifest["leaves"][key]
                if key not in assembled:
                    dt = np.uint16 if info.get("bf16") else np.dtype(
                        info["dtype"])
                    assembled[key] = np.zeros(info["shape"], dt)
                data = z[skey]
                start = ([int(x) for x in start_s.split(",")]
                         if start_s else [])
                idx = tuple(slice(st, st + sh)
                            for st, sh in zip(start, data.shape))
                assembled[key][idx if idx else ...] = data

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (pathk, leaf), shd in zip(flat, shard_flat):
        key = jax.tree_util.keystr(pathk)
        arr = assembled[key]
        info = manifest["leaves"][key]
        if info.get("bf16"):
            arr = arr.view(jax.numpy.bfloat16)  # ml_dtypes view, zero-copy
        if shd is not None:
            # placement touches only addressable devices, so elastic
            # restore works across process counts and mesh shapes
            out.append(jax.make_array_from_callback(
                tuple(info["shape"]), shd,
                lambda idx, a=arr: a[idx]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
