"""Sharded, atomic, mesh-shape-independent checkpointing.

Layout:  <dir>/step_<N>/host_<i>.npz  +  <dir>/step_<N>/manifest.json

* Each host writes only its addressable shards (leaf key -> list of
  (global-index, data) entries), so no device->host all-gather is needed.
* Commit is atomic: write into ``step_<N>.tmp``, fsync, rename. A crash
  mid-write never corrupts the latest valid checkpoint; ``latest_step``
  ignores ``.tmp`` dirs.
* Restore is **elastic**: shards are reassembled into global host arrays
  and re-placed under whatever sharding the *new* mesh prescribes — resume
  on 256 chips after checkpointing on 512 (or vice versa) just works.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _flat_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(directory: str, step: int, tree) -> str:
    """Write checkpoint for ``step``; returns the committed path."""
    final = os.path.join(directory, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    shards: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for key, leaf in _flat_with_paths(tree):
        leaf = jax.numpy.asarray(leaf)
        meta[key] = {"shape": list(leaf.shape), "dtype": str(leaf.dtype)}
        for i, s in enumerate(leaf.addressable_shards):
            start = [idx.start or 0 for idx in s.index] if s.index else []
            arr = np.asarray(s.data)
            shards[f"{key}||{i}||{','.join(map(str, start))}"] = (
                arr.view(np.uint16) if arr.dtype == jax.numpy.bfloat16
                else arr)
            meta[key].setdefault("bf16", arr.dtype == jax.numpy.bfloat16)

    host = jax.process_index()
    np.savez(os.path.join(tmp, f"host_{host}.npz"), **shards)
    if host == 0:
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "leaves": meta,
                       "n_hosts": jax.process_count()}, f)
    # commit: fsync dir entries then atomic rename
    fd = os.open(tmp, os.O_RDONLY)
    os.fsync(fd)
    os.close(fd)
    if os.path.exists(final):          # re-save of an existing step
        import shutil

        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for d in os.listdir(directory)
             if (m := re.fullmatch(r"step_(\d+)", d))
             and os.path.exists(os.path.join(directory, d, "manifest.json"))]
    return max(steps) if steps else None


def restore(directory: str, step: int, target_tree, shardings=None):
    """Rebuild ``target_tree``-shaped pytree from the checkpoint, placed
    under ``shardings`` (same treedef) or replicated if None."""
    path = os.path.join(directory, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)

    # gather shards from every host file present
    assembled: dict[str, np.ndarray] = {}
    for fname in sorted(os.listdir(path)):
        if not fname.endswith(".npz"):
            continue
        with np.load(os.path.join(path, fname)) as z:
            for skey in z.files:
                key, _, start_s = skey.split("||")
                info = manifest["leaves"][key]
                if key not in assembled:
                    dt = np.uint16 if info.get("bf16") else np.dtype(
                        info["dtype"])
                    assembled[key] = np.zeros(info["shape"], dt)
                data = z[skey]
                start = ([int(x) for x in start_s.split(",")]
                         if start_s else [])
                idx = tuple(slice(st, st + sh)
                            for st, sh in zip(start, data.shape))
                assembled[key][idx if idx else ...] = data

    flat, treedef = jax.tree_util.tree_flatten_with_path(target_tree)
    shard_flat = (jax.tree.leaves(shardings)
                  if shardings is not None else [None] * len(flat))
    out = []
    for (pathk, leaf), shd in zip(flat, shard_flat):
        key = jax.tree_util.keystr(pathk)
        arr = assembled[key]
        info = manifest["leaves"][key]
        if info.get("bf16"):
            arr = arr.view(np.uint16)
            jarr = jax.numpy.asarray(arr).view(jax.numpy.bfloat16)
        else:
            jarr = jax.numpy.asarray(arr)
        out.append(jax.device_put(jarr, shd) if shd is not None else jarr)
    return jax.tree_util.tree_unflatten(treedef, out)
