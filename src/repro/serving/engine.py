"""Batched serving engine: wave-batched prefill + batched greedy/sampled
decode over a fixed slot grid.

Design (TPU-adapted):
  * a fixed number of decode *slots* (the jit'd prefill/decode steps each
    have one static shape — no recompile churn);
  * requests are admitted in waves of up to ``slots``; prompts are
    left-padded to the wave's prompt length so the whole wave shares the
    cache position counter (the cache pytree carries one scalar ``pos``);
  * every engine tick decodes all live slots in one batched call — the TCU
    reduce/scan primitives inside the model (softmax, RMSNorm, SSD) do the
    per-token math;
  * finished sequences are masked (their sampled tokens ignored) until the
    wave retires.

For the multi-chip case the cache pytree is sharded with the same logical
rules as the dry-run decode cells; the engine code is sharding-agnostic.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as kpolicy
from repro.core.policy import KernelPolicy
from repro.models.common import init_params
from repro.models.lm import Bundle
from repro.training.train_lib import make_serve_step

_SEQ_CACHE_KEYS = ("k", "v", "self_k", "self_v")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4                  # concurrent sequences (static batch)
    max_new: int = 32               # decode budget per wave
    eos_token: int = 2
    greedy: bool = True
    temperature: float = 1.0
    # explicit KernelPolicy for every core op in the served model
    # (attention, SSD, MoE); strings auto-coerce. None keeps the bundle's
    # own setting (usually the active policy); a value rebuilds the
    # bundle with the policy baked into the jitted prefill/decode steps —
    # no env-var reliance.
    policy: KernelPolicy | None = None
    # deprecated spelling of ``policy`` (a bare path label); warns once
    kernel_path: dataclasses.InitVar[str | None] = None

    def __post_init__(self, kernel_path):
        object.__setattr__(self, "policy", kpolicy.coerce_config_policy(
            self.policy, kernel_path, "ServeConfig"))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32


@dataclasses.dataclass
class Result:
    uid: int
    tokens: list                    # generated ids (up to EOS)
    prompt_len: int


def _pad_cache_seq(cache, extra: int):
    """Grow the sequence axis of every KV leaf by ``extra`` slots."""
    def pad(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        if name in _SEQ_CACHE_KEYS and hasattr(leaf, "ndim") and \
                leaf.ndim >= 3:
            pw = [(0, 0)] * leaf.ndim
            pw[2] = (0, extra)      # (L, B, S, H, D): S is axis 2
            return jnp.pad(leaf, pw)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, cache)


class ServingEngine:
    """Wave-batched engine over a Bundle: ``run(requests)`` drains a list,
    ``serve_wave`` handles one admitted wave."""

    def __init__(self, bundle: Bundle, params, cfg: ServeConfig):
        # compare the WHOLE policy, not a path string: an autotune-mode or
        # per-op-override change must invalidate the cached bundle too
        # (its jitted prefill/decode steps baked the old choices in)
        if cfg.policy is not None and bundle.cfg.policy != cfg.policy:
            from repro.models import build  # lazy: engine is model-agnostic

            bundle = build(dataclasses.replace(
                bundle.cfg, policy=cfg.policy))
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        prefill, decode = make_serve_step(bundle)
        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode)
        self._rng = jax.random.PRNGKey(0)
        self.queue: deque[Request] = deque()
        self.results: list[Result] = []

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if self.cfg.greedy:
            return np.asarray(jnp.argmax(logits[:, -1], axis=-1))
        self._rng, sub = jax.random.split(self._rng)
        return np.asarray(jax.random.categorical(
            sub, logits[:, -1] / self.cfg.temperature))

    def serve_wave(self, wave: list[Request]) -> list[Result]:
        nb = self.cfg.slots
        plen = max(len(r.prompt) for r in wave)
        tokens = np.zeros((nb, plen), np.int32)
        for i, r in enumerate(wave):                # left-pad prompts
            tokens[i, plen - len(r.prompt):] = r.prompt
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)})
        cache = _pad_cache_seq(cache, self.cfg.max_new)
        nxt = self._sample(logits)

        out = [[int(nxt[i])] for i in range(nb)]
        done = np.array([int(nxt[i]) == self.cfg.eos_token
                         for i in range(nb)])
        for _ in range(self.cfg.max_new - 1):
            if done[:len(wave)].all():
                break
            step_tok = jnp.asarray(nxt.reshape(nb, 1), jnp.int32)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": step_tok})
            nxt = self._sample(logits)
            for i in range(nb):
                if not done[i]:
                    out[i].append(int(nxt[i]))
                    done[i] |= int(nxt[i]) == self.cfg.eos_token
        results = []
        for i, r in enumerate(wave):
            toks = out[i]
            if self.cfg.eos_token in toks:
                toks = toks[:toks.index(self.cfg.eos_token)]
            results.append(Result(uid=r.uid, tokens=toks,
                                  prompt_len=len(r.prompt)))
        return results

    def run(self, requests: list[Request]) -> list[Result]:
        for r in requests:
            self.submit(r)
        while self.queue:
            wave = [self.queue.popleft()
                    for _ in range(min(self.cfg.slots, len(self.queue)))]
            while len(wave) < self.cfg.slots:   # pad wave with dummies
                wave.append(wave[-1])
            uids = set()
            res = []
            for r in self.serve_wave(wave):
                if r.uid not in uids:
                    uids.add(r.uid)
                    res.append(r)
            self.results.extend(res)
        return sorted(self.results, key=lambda r: r.uid)


def demo_engine(bundle: Bundle, *, slots: int = 4, max_new: int = 16,
                seed: int = 0,
                policy: "KernelPolicy | str | None" = None) -> ServingEngine:
    params = init_params(jax.random.PRNGKey(seed), bundle.params_pspec,
                         bundle.cfg.dtype)
    return ServingEngine(bundle, params, ServeConfig(slots=slots,
                                                     max_new=max_new,
                                                     policy=policy))
