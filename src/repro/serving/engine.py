"""Serving engines over a fixed slot grid: continuous batching (default)
with the legacy wave-batched scheduler kept as a measurable baseline.

Continuous scheduler (TPU-adapted):
  * a fixed number of decode *slots*; a finished slot is refilled from the
    queue on the next tick — no wave barrier, so one long sequence never
    strands the other slots;
  * the KV cache is a ring buffer with a per-slot position counter: slot b
    writes token t at row ``(pos[b] + t) % capacity`` and attends the
    ``min(pos[b] + t + 1, capacity)`` valid rows, so slots stop sharing one
    scalar ``pos`` and stop paying for the wave-max prompt (sequences
    longer than the capacity degrade to sliding-window attention instead
    of failing);
  * prefill is chunked and interleaved with decode: every tick issues ONE
    jitted block step of shape (slots, T) where T is ``prefill_chunk``
    while any slot is consuming its prompt and 1 otherwise; per-slot
    ``n_valid`` lets prefilling slots swallow up to T prompt tokens while
    decoding slots ride along with a single token — admission never stalls
    decode;
  * jitted steps live in a module-level cache keyed by the bundle's model
    config (which embeds the whole ``KernelPolicy`` — hashable since
    PR 4/5), and the cache capacity is bucketized to powers of two, so the
    decode-step compile count over a mixed-length workload is bounded by
    2 x #capacity-buckets (the T=chunk and T=1 shapes), not by the number
    of distinct request lengths.

Wave scheduler (baseline, ``ServeConfig(scheduler="wave")``): requests are
admitted in waves of up to ``slots``; prompts are left-padded to the
wave's prompt length (one scalar cache ``pos``); the wave retires only
when every member finishes.  Kept as the contender row in
``benchmarks/serving_bench.py`` — the continuous win is a checked-in
number, not a claim.

Both schedulers share the slot/result bookkeeping and the sampling RNG
(seeded from ``ServeConfig.seed``).  Encoder-decoder bundles have no
block-decode step; asking them for the continuous scheduler warns and
falls back to wave.

For the multi-chip case pass a :class:`~repro.parallel.mesh_context.
MeshContext`: the ring cache is then allocated *sharded* under the
context's rules (``kv_heads`` -> the model axis, so each host holds only
its KV shards), the block step activates the context (kernel policy
resolves per-shard TuneSpecs) and pins logits replicated, and in
multi-host runs the engine switches to lockstep admission — every host
is fed the same request stream and admits queue-order, because each
block step is one SPMD collective program that all hosts must enter with
identical shapes. Admission/eviction bookkeeping itself stays host-local.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policy as kpolicy
from repro.core.policy import KernelPolicy
from repro.models.common import init_params
from repro.models.lm import Bundle
from repro.obs import profiling as _prof
from repro.obs import runtime as _obs
from repro.training.train_lib import make_block_serve_step, make_serve_step

_SEQ_CACHE_KEYS = ("k", "v", "self_k", "self_v")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    slots: int = 4                  # concurrent sequences (static batch)
    max_new: int = 32               # decode budget per request (default)
    eos_token: int = 2
    greedy: bool = True
    temperature: float = 1.0
    scheduler: str = "continuous"   # continuous | wave
    prefill_chunk: int = 16         # prompt tokens consumed per tick/slot
    max_context: int | None = None  # cap on ring-cache capacity (rows)
    # KV-cache layout for the continuous scheduler: "ring" keeps the
    # per-slot ring buffers (PR 6 baseline); "paged" switches to the
    # block-table page pool with prefix sharing and copy-on-write
    # (serving/kvpool.py) — admission allocates pages lazily, shared
    # prompt prefixes map to the same physical pages, and pool pressure
    # defers admission instead of crashing.
    cache_kind: str = "ring"        # ring | paged
    page_rows: int | None = None    # page height (None: layout.KV_PAGE_ROWS)
    pool_pages: int | None = None   # KV pool size (None: (slots+1) pages/slot)
    state_pages: int | None = None  # SSM snapshot pool size (None: 2*slots)
    prefix_sharing: bool = True     # trie-share prompt prefixes (paged only)
    seed: int = 0                   # sampling RNG seed
    trace_ring: int = 4096          # admit/finish events kept in memory
    #   (the engine's trace is a bounded ring — a long-running service
    #   must not grow a per-event python list without bound; the full
    #   stream is available via repro.obs's JSON-lines sink)
    # explicit KernelPolicy for every core op in the served model
    # (attention, SSD, MoE); strings auto-coerce. None keeps the bundle's
    # own setting (usually the active policy); a value rebuilds the
    # bundle with the policy baked into the jitted prefill/decode steps —
    # no env-var reliance.
    policy: KernelPolicy | None = None
    # deprecated spelling of ``policy`` (a bare path label); warns once
    kernel_path: dataclasses.InitVar[str | None] = None

    def __post_init__(self, kernel_path):
        if self.scheduler not in ("continuous", "wave"):
            raise ValueError(
                f"scheduler must be 'continuous' or 'wave', "
                f"got {self.scheduler!r}")
        if self.cache_kind not in ("ring", "paged"):
            raise ValueError(
                f"cache_kind must be 'ring' or 'paged', "
                f"got {self.cache_kind!r}")
        if self.cache_kind == "paged" and self.scheduler == "wave":
            raise ValueError(
                "the paged KV cache requires the continuous scheduler "
                "(wave batching shares one scalar position counter)")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        if self.trace_ring < 1:
            raise ValueError("trace_ring must be >= 1")
        object.__setattr__(self, "policy", kpolicy.coerce_config_policy(
            self.policy, kernel_path, "ServeConfig"))


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new: int | None = None      # per-request budget (None: cfg.max_new)
    arrival_s: float = 0.0          # open-loop arrival offset from run()


@dataclasses.dataclass
class Result:
    uid: int
    tokens: list                    # generated ids (up to EOS)
    prompt_len: int
    arrival_s: float = 0.0
    first_token_s: float | None = None   # emission time of first token
    finish_s: float | None = None        # emission time of last token
    token_s: list = dataclasses.field(default_factory=list)
    admitted_tick: int = -1
    finish_tick: int = -1


@dataclasses.dataclass
class _Slot:
    """One row of the continuous-batching slot grid."""
    free: bool = True
    req: Request | None = None
    ppos: int = 0                   # prompt tokens consumed so far
    budget: int = 0
    last: int = 0                   # last sampled token (decode input)
    result: Result | None = None


def _pad_cache_seq(cache, extra: int):
    """Grow the sequence axis of every KV leaf by ``extra`` slots."""
    def pad(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        if name in _SEQ_CACHE_KEYS and hasattr(leaf, "ndim") and \
                leaf.ndim >= 3:
            pw = [(0, 0)] * leaf.ndim
            pw[2] = (0, extra)      # (L, B, S, H, D): S is axis 2
            return jnp.pad(leaf, pw)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, cache)


def _bucket(n: int) -> int:
    """Next power of two >= n (floor 16) — the ring-capacity buckets that
    bound the jit compile count across mixed-length workloads."""
    return max(16, 1 << (max(int(n), 1) - 1).bit_length())


# ---------------------------------------------------------------------------
# module-level jit compile cache
#
# Keyed by the bundle's frozen ModelConfig, which embeds the whole
# KernelPolicy (path, autotune mode, per-op overrides, op_tuning) — two
# engines serving the same config share compiled steps, and any policy
# change (including a tuning-only change) keys a fresh entry exactly as
# the bundle-rebuild check invalidates the bundle.

_STEP_CACHE: dict = {}


def clear_compile_cache() -> None:
    """Drop every cached jitted serving step (tests / memory pressure)."""
    _STEP_CACHE.clear()


def _steps_for(bundle: Bundle, mesh_ctx=None) -> dict:
    key = (bundle.cfg, None if mesh_ctx is None else mesh_ctx.key())
    entry = _STEP_CACHE.get(key)
    if _obs.ACTIVE is not None:
        _obs.ACTIVE.counter(
            "repro_serving_compile_cache_total",
            "serving step-cache lookups by result").inc(
            result=("hit" if entry is not None else "miss"))
    if entry is None:
        prefill, decode = make_serve_step(bundle)
        block = make_block_serve_step(bundle, mesh_ctx=mesh_ctx)
        paged = make_block_serve_step(bundle, mesh_ctx=mesh_ctx, paged=True)
        entry = {"prefill": jax.jit(prefill), "decode": jax.jit(decode),
                 "block": None if block is None else jax.jit(block),
                 "block_paged": None if paged is None else jax.jit(paged)}
        _STEP_CACHE[key] = entry
    return entry


class ServingEngine:
    """Serving engine over a Bundle: ``run(requests)`` drains a list with
    the configured scheduler; each call returns only that call's results
    (``self.results`` keeps the full history)."""

    def __init__(self, bundle: Bundle, params, cfg: ServeConfig,
                 mesh_ctx=None):
        # compare the WHOLE policy, not a path string: an autotune-mode or
        # per-op-override change must invalidate the cached bundle too
        # (its jitted prefill/decode steps baked the old choices in)
        if cfg.policy is not None and bundle.cfg.policy != cfg.policy:
            from repro.models import build  # lazy: engine is model-agnostic

            bundle = build(dataclasses.replace(
                bundle.cfg, policy=cfg.policy))
        self.bundle = bundle
        self.cfg = cfg
        self.params = params
        self.mesh_ctx = mesh_ctx
        # multi-host serving runs every host through the same tick
        # sequence (SPMD: each block step is a collective program). The
        # hosts must therefore make identical admission decisions, so
        # wall-clock arrival gating is disabled — every host is fed the
        # same request stream and admits it queue-order ("lockstep").
        # Admission/eviction bookkeeping itself stays host-local python.
        self._lockstep = mesh_ctx is not None and jax.process_count() > 1
        if self._lockstep and cfg.scheduler == "wave":
            raise ValueError(
                "multi-host serving requires the continuous scheduler "
                "(wave admission depends on per-host wall clocks)")
        steps = _steps_for(bundle, mesh_ctx)
        self._prefill = steps["prefill"]
        self._decode = steps["decode"]
        self._block = steps["block"]
        self._block_paged = steps["block_paged"]
        self.scheduler = cfg.scheduler
        self.cache_kind = cfg.cache_kind
        if self.scheduler == "continuous" and self._block is None:
            warnings.warn(
                "bundle has no block-decode step (encoder-decoder); "
                "falling back to the wave scheduler", stacklevel=2)
            self.scheduler = "wave"
            if self.cache_kind == "paged":
                warnings.warn(
                    "paged KV cache requires the continuous scheduler; "
                    "ignoring cache_kind='paged'", stacklevel=2)
                self.cache_kind = "ring"
        self._rng = jax.random.PRNGKey(cfg.seed)
        self.queue: deque[Request] = deque()
        self.results: list[Result] = []
        # admit/finish events (tick, uid): a bounded ring, not a list — a
        # long-running service must not grow per-event state without bound
        self._trace: deque[dict] = deque(maxlen=cfg.trace_ring)
        self.ticks = 0                  # block steps issued (continuous)
        self._cache = None              # continuous ring cache (reused)
        self._capacity = None
        self._kv = None                 # PagedKVManager (cache_kind=paged)
        self._overflow_warned = False   # max_context degrade: warn once

    # -- shared plumbing ----------------------------------------------------

    @property
    def trace(self) -> list[dict]:
        """The retained admit/finish events, oldest first (bounded by
        ``ServeConfig.trace_ring``; the unbounded stream goes to the obs
        event sink when a session is active)."""
        return list(self._trace)

    def _trace_event(self, tick: int, event: str, uid: int,
                     slot: int, **extra) -> None:
        ev = {"tick": tick, "event": event, "uid": uid, "slot": slot,
              **extra}
        self._trace.append(ev)
        sess = _obs.ACTIVE
        if sess is not None:
            sess.emit("serving", **ev)
            sess.counter(
                "repro_serving_requests_total",
                "request lifecycle events by type").inc(event=event)

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def compile_stats(self) -> dict:
        """Compiled-shape counts of the jitted serving steps (None when a
        step was never traced / does not exist)."""
        def size(fn):
            if fn is None or not hasattr(fn, "_cache_size"):
                return None
            return fn._cache_size()

        return {"prefill": size(self._prefill),
                "decode": size(self._decode),
                "block": size(self._block),
                "block_paged": size(self._block_paged)}

    def kv_stats(self) -> dict | None:
        """Paged-pool occupancy/sharing counters (None under the ring
        cache): pages in use / free / shared, peak in use, CoW copies,
        defers, trie entries — see ``PagedKVManager.stats``."""
        return None if self._kv is None else self._kv.stats()

    def _budget(self, req: Request) -> int:
        return self.cfg.max_new if req.max_new is None else req.max_new

    def _fetch(self, arr: jax.Array) -> np.ndarray:
        """Device array -> host np. Multi-host arrays are not fully
        addressable; the block step pins its outputs replicated, so this
        host's shard 0 IS the global value."""
        if self._lockstep:
            return np.asarray(arr.addressable_data(0))
        return np.asarray(arr)

    def _to_device(self, arr: np.ndarray) -> jax.Array:
        """Host np -> device array; in multi-host, a *global* replicated
        array (every host passes the same value — lockstep invariant)."""
        if self._lockstep:
            sharding = jax.sharding.NamedSharding(
                self.mesh_ctx.mesh, jax.sharding.PartitionSpec())
            return jax.make_array_from_process_local_data(
                sharding, np.asarray(arr))
        return jnp.asarray(arr)

    def _sample(self, logits: jax.Array) -> np.ndarray:
        if logits.ndim == 3:            # wave decode emits (B, T, V)
            logits = logits[:, -1]
        if self.cfg.greedy:
            return self._fetch(jnp.argmax(logits, axis=-1))
        self._rng, sub = jax.random.split(self._rng)
        return self._fetch(jax.random.categorical(
            sub, logits / self.cfg.temperature))

    def run(self, requests: list[Request]) -> list[Result]:
        t0 = time.perf_counter()
        for r in sorted(requests, key=lambda r: r.arrival_s):
            self.submit(r)
        if self.scheduler == "continuous":
            out = self._run_continuous(t0)
        else:
            out = self._run_wave(t0)
        self.results.extend(out)        # full history; return is per-call
        return sorted(out, key=lambda r: r.uid)

    # -- continuous scheduler ----------------------------------------------

    def _ensure_cache(self) -> None:
        need = max((len(r.prompt) + self._budget(r) for r in self.queue),
                   default=16)
        cap = _bucket(need)
        if self.cfg.max_context is not None:
            cap = min(cap, _bucket(self.cfg.max_context))
        pspec_kwargs: dict = {}
        if self.cache_kind == "paged":
            from repro.kernels.layout import KV_PAGE_ROWS
            from repro.serving import kvpool

            rows = self.cfg.page_rows or KV_PAGE_ROWS
            kvpool.validate_page_rows(rows)
            swa = self.bundle.cfg.swa_window
            if swa:
                # page granularity: the sliding window rounds UP to a
                # whole page (a paged slot keeps >= swa rows, never fewer)
                cap = min(cap, -(-swa // rows) * rows)
            cap = max(cap, rows)
            mp = cap // rows
            pool_pages = self.cfg.pool_pages or (self.cfg.slots + 1) * mp
            state_pages = self.cfg.state_pages or 2 * self.cfg.slots
            pspec_kwargs = {"kind": "paged", "pool_pages": pool_pages,
                            "page_rows": rows, "state_pages": state_pages}
            if self._kv is None or self._capacity != cap or \
                    self._kv.kv is not None and \
                    self._kv.kv.n_pages != pool_pages:
                self._kv = kvpool.PagedKVManager(
                    slots=self.cfg.slots, page_rows=rows, maxpages=mp,
                    pool_pages=pool_pages,
                    family=self.bundle.cfg.family,
                    state_pages=state_pages,
                    sharing=self.cfg.prefix_sharing)
        if self._cache is None or self._capacity != cap:
            pspec_tree = self.bundle.cache_pspec(self.cfg.slots, cap,
                                                 per_slot_pos=True,
                                                 **pspec_kwargs)
            ctx = self.mesh_ctx
            if ctx is not None and ctx.mesh is not None:
                # sharded ring cache: build under jit with out_shardings
                # from the context's rules (kv_heads -> model axis), so
                # each host only allocates its addressable KV shards
                from repro.models.common import partition_specs

                specs = partition_specs(pspec_tree, rules=ctx.rules,
                                        fsdp_ok=False)
                shardings = jax.tree.map(ctx.named_sharding, specs)
                self._cache = jax.jit(
                    lambda: init_params(jax.random.PRNGKey(0), pspec_tree,
                                        self.bundle.cfg.dtype),
                    out_shardings=shardings)()
            else:
                self._cache = init_params(
                    jax.random.PRNGKey(0), pspec_tree,
                    self.bundle.cfg.dtype)
            self._capacity = cap

    def _run_continuous(self, t0: float) -> list[Result]:
        nb = self.cfg.slots
        self._ensure_cache()
        chunk = min(self.cfg.prefill_chunk, self._capacity)
        slots = [_Slot() for _ in range(nb)]
        out: list[Result] = []

        while True:
            sess = _obs.ACTIVE       # per-tick: sessions can open mid-run
            now = time.perf_counter() - t0
            tick_start = t0 + now    # same clock read; no cost when off
            cur = self.ticks
            # admission: refill every free slot from the arrived queue
            # (lockstep mode ignores arrival clocks — see __init__).
            # Paged mode admits head-of-line only: a deferred request
            # blocks later ones (FIFO; skipping ahead would starve it).
            reset = np.zeros(nb, bool)
            blocked = False
            for i, s in enumerate(slots):
                if blocked or not s.free or not self.queue:
                    continue
                if not (self._lockstep or self.queue[0].arrival_s <= now):
                    continue
                req = self.queue[0]
                budget = self._budget(req)
                start = 0
                if self._kv is not None:
                    got = self._kv.admit(i, req.prompt, budget,
                                         uid=req.uid)
                    if got is None:     # pool pressure: defer admission
                        blocked = True
                        continue
                    start = got
                need = len(req.prompt) + budget
                if need > self._capacity:
                    # capacity saturated at max_context: the slot degrades
                    # to sliding-window attention (ring/paged overwrite
                    # their oldest rows). Correct for SWA models, lossy
                    # for full-attention ones — say so, don't be silent.
                    if not self._overflow_warned:
                        warnings.warn(
                            f"request uid={req.uid} needs {need} cache "
                            f"rows but capacity is {self._capacity} "
                            f"(max_context={self.cfg.max_context}); "
                            "oldest rows will be overwritten — degrading "
                            "to sliding-window attention. Further "
                            "overflows are traced, not warned.",
                            stacklevel=2)
                        self._overflow_warned = True
                    self._trace_event(cur, "swa_degrade", req.uid, i,
                                      need=need, capacity=self._capacity)
                self.queue.popleft()
                slots[i] = s = _Slot(
                    free=False, req=req, budget=budget,
                    result=Result(uid=req.uid, tokens=[],
                                  prompt_len=len(req.prompt),
                                  arrival_s=req.arrival_s,
                                  admitted_tick=cur))
                s.ppos = start          # trie-shared prefix tokens skipped
                reset[i] = True
                self._trace_event(cur, "admit", req.uid, i, start=start)
            active = [i for i, s in enumerate(slots) if not s.free]
            if not active:
                if blocked:
                    req = self.queue[0]
                    raise RuntimeError(
                        f"paged KV pool cannot admit request "
                        f"uid={req.uid} (prompt {len(req.prompt)} + "
                        f"budget {self._budget(req)}) even with every "
                        "slot idle — raise ServeConfig.pool_pages")
                if not self.queue:
                    break
                wait = self.queue[0].arrival_s - now
                if wait > 0 and not self._lockstep:
                    time.sleep(min(wait, 0.01))
                continue

            # one block step: T = chunk while anyone prefills, else 1
            any_prefill = any(slots[i].ppos < len(slots[i].req.prompt)
                              for i in active)
            t_len = chunk if any_prefill else 1
            if sess is not None:
                t_adm = time.perf_counter()
                sess.gauge("repro_serving_queue_depth",
                           "requests waiting for a slot").set(
                    len(self.queue))
                sess.gauge("repro_serving_slot_occupancy",
                           "fraction of decode slots busy").set(
                    len(active) / nb)
            tokens = np.zeros((nb, t_len), np.int32)
            n_valid = np.zeros(nb, np.int32)
            for i in active:
                s = slots[i]
                plen = len(s.req.prompt)
                if s.ppos < plen:
                    take = min(t_len, plen - s.ppos)
                    tokens[i, :take] = s.req.prompt[s.ppos:s.ppos + take]
                    n_valid[i] = take
                else:
                    tokens[i, 0] = s.last
                    n_valid[i] = 1
            if self._kv is not None:
                page_np = self._kv.plan_tick(
                    {i: int(n_valid[i]) for i in active})
                page = {k: self._to_device(v)
                        for k, v in page_np.items()}
                with _prof.span("serving/block_step"):
                    logits, self._cache = self._block_paged(
                        self.params, self._cache, self._to_device(tokens),
                        self._to_device(n_valid), self._to_device(reset),
                        page)
            else:
                with _prof.span("serving/block_step"):
                    logits, self._cache = self._block(
                        self.params, self._cache, self._to_device(tokens),
                        self._to_device(n_valid), self._to_device(reset))
            if sess is not None:
                t_step = time.perf_counter()
            nxt = self._sample(logits)
            now = time.perf_counter() - t0
            self.ticks = cur + 1

            for i in active:
                s = slots[i]
                if self._kv is not None:
                    self._kv.advance(i, int(n_valid[i]))
                plen = len(s.req.prompt)
                if s.ppos < plen:
                    s.ppos += int(n_valid[i])
                    if self._kv is not None and s.ppos >= plen:
                        self._kv.mark_prefilled(i)
                    if s.ppos < plen:
                        continue        # mid-prefill: logits are interim
                # this tick produced a real token for slot i
                tok = int(nxt[i])
                s.last = tok
                res = s.result
                if res.first_token_s is None:
                    res.first_token_s = now
                    if sess is not None:
                        sess.histogram(
                            "repro_serving_ttft_seconds",
                            "request arrival to first token").observe(
                            max(0.0, now - res.arrival_s))
                finished = tok == self.cfg.eos_token
                if not finished:
                    if sess is not None and res.token_s:
                        sess.histogram(
                            "repro_serving_token_latency_seconds",
                            "gap between consecutive emitted tokens"
                        ).observe(max(0.0, now - res.token_s[-1]))
                    res.tokens.append(tok)
                    res.token_s.append(now)
                    if sess is not None:
                        sess.counter("repro_serving_tokens_total",
                                     "decode tokens emitted").inc()
                    finished = len(res.tokens) >= s.budget
                if finished:
                    res.finish_s = now
                    res.finish_tick = cur
                    self._trace_event(cur, "finish", res.uid, i)
                    out.append(res)
                    if self._kv is not None:
                        self._kv.release(i)   # pages back to the pool
                    slots[i] = _Slot()  # freed; refilled next tick

            if self._kv is not None:
                self._kv.end_tick()
                if sess is not None:
                    self._kv.emit_gauges()
            if sess is not None:
                # contiguous boundaries: the four phase durations sum to
                # the tick wall time exactly (tested to float tolerance)
                t_end = time.perf_counter()
                ph = sess.histogram(
                    "repro_serving_tick_phase_seconds",
                    "per-tick phase wall time (phases sum to the tick)")
                ph.observe(t_adm - tick_start, phase="admission")
                ph.observe(t_step - t_adm,
                           phase=("prefill" if any_prefill else "decode"))
                ph.observe((t0 + now) - t_step, phase="sample")
                ph.observe(t_end - (t0 + now), phase="bookkeep")
                sess.histogram(
                    "repro_serving_tick_seconds",
                    "block-step tick wall time").observe(t_end - tick_start)
        return out

    # -- wave scheduler (baseline) ------------------------------------------

    def serve_wave(self, wave: list[Request],
                   t0: float | None = None) -> list[Result]:
        if t0 is None:
            t0 = time.perf_counter()
        nb = self.cfg.slots
        live = len(wave)
        budgets = [self._budget(r) for r in wave]
        wave_budget = max(budgets)
        plen = max(len(r.prompt) for r in wave)
        tokens = np.zeros((nb, plen), np.int32)
        for i, r in enumerate(wave):                # left-pad prompts
            tokens[i, plen - len(r.prompt):] = r.prompt
        logits, cache = self._prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)})
        cache = _pad_cache_seq(cache, wave_budget)
        nxt = self._sample(logits)
        now = time.perf_counter() - t0

        out = [[int(nxt[i])] for i in range(live)]
        times = [[now] for _ in range(live)]
        # padding rows beyond the wave are done from the start: they are
        # never sampled into results and never keep the wave alive
        done = np.ones(nb, bool)
        for i in range(live):
            done[i] = (int(nxt[i]) == self.cfg.eos_token
                       or budgets[i] <= 1)
        for _ in range(wave_budget - 1):
            if done.all():
                break
            step_tok = jnp.asarray(nxt.reshape(nb, 1), jnp.int32)
            logits, cache = self._decode(self.params, cache,
                                         {"tokens": step_tok})
            nxt = self._sample(logits)
            now = time.perf_counter() - t0
            for i in range(live):
                if not done[i]:
                    out[i].append(int(nxt[i]))
                    times[i].append(now)
                    done[i] = (int(nxt[i]) == self.cfg.eos_token
                               or len(out[i]) >= budgets[i])
        results = []
        for i, r in enumerate(wave):
            toks, ts = out[i], times[i]
            if self.cfg.eos_token in toks:
                cut = toks.index(self.cfg.eos_token)
                toks, ts = toks[:cut], ts[:cut]
            results.append(Result(
                uid=r.uid, tokens=toks, prompt_len=len(r.prompt),
                arrival_s=r.arrival_s, first_token_s=times[i][0],
                finish_s=times[i][-1], token_s=ts))
        return results

    def _run_wave(self, t0: float) -> list[Result]:
        out: list[Result] = []
        while self.queue:
            now = time.perf_counter() - t0
            wave: list[Request] = []
            while self.queue and len(wave) < self.cfg.slots and \
                    self.queue[0].arrival_s <= now:
                wave.append(self.queue.popleft())
            if not wave:                # open loop: wait for next arrival
                wait = self.queue[0].arrival_s - now
                if wait > 0:
                    time.sleep(min(wait, 0.01))
                continue
            out.extend(self.serve_wave(wave, t0))
        return out


def demo_engine(bundle: Bundle, *, slots: int = 4, max_new: int = 16,
                seed: int = 0, scheduler: str = "continuous",
                prefill_chunk: int = 16,
                policy: "KernelPolicy | str | None" = None) -> ServingEngine:
    params = init_params(jax.random.PRNGKey(seed), bundle.params_pspec,
                         bundle.cfg.dtype)
    return ServingEngine(bundle, params, ServeConfig(
        slots=slots, max_new=max_new, seed=seed, scheduler=scheduler,
        prefill_chunk=prefill_chunk, policy=policy))
