"""Paged KV-cache pool: block tables, prefix sharing, copy-on-write.

The continuous engine's ring cache (PR 6) dedicates every slot a full
pow2-bucketized context even when most requests are short or share a long
system prompt. This module is the host-side memory manager for the paged
alternative (``ServeConfig(cache_kind="paged")``): the device holds ONE
preallocated pool of fixed-size KV pages per cache family, and each slot
owns a *block table* mapping its logical pages ``(pos // R) % maxpages``
to physical pool pages. Everything here is plain python/numpy bookkeeping
— the device-side gather/scatter lives in ``models/layers.py``
(``attn_decode_paged``) and stays one jitted block step.

Page geometry comes from ``kernels/layout.py`` (``KV_PAGE_ROWS``, a
power-of-two multiple of the sublane tile); no literal geometry constants
appear here — the grep-guard that polices the kernels applies in spirit.

Three cooperating pieces:

* :class:`PagePool` — a free-list allocator with per-page refcounts.
  ``alloc`` pops a page at refcount 1; ``decref`` returns it to the free
  list at 0. ``defer_free=True`` parks freed pages in limbo until
  ``flush()`` (the SSM snapshot pool: a snapshot freed at tick start may
  still be read by this tick's block step, so its page must not be
  rewritten until the next tick).
* :class:`PrefixTrie` — prompt prefixes at page granularity. Full-page
  edges are keyed by their R-token tuple; a node's *partial* entries hold
  a sub-page tail (< R tokens). Entries reference the physical page
  holding those rows (refcounted: the trie is a sharer like any slot) and
  optionally an SSM state-snapshot page valid at exactly that boundary.
  ``match`` returns the deepest shareable boundary; ``evict`` reclaims
  least-recently-used leaves under pool pressure.
* :class:`PagedKVManager` — the engine-facing facade. Admission matches
  the trie, maps shared pages into the slot's block table (incref), and
  *reserves* the worst-case number of new pages the request can touch —
  if free + evictable pages cannot cover the reservation the admission
  is **deferred** (back-pressure instead of crashing). Pages are
  allocated lazily by ``plan_tick`` as the slot's writes reach them;
  writing a page with refcount > 1 triggers **copy-on-write** (a fresh
  page plus a device-side page-gather entry, so divergence never
  corrupts a sharer). Prompt pages are registered back into the trie
  when prefill completes, so later requests share them until eviction.

Sharing semantics per family:

* attention (dense/moe/vlm + the hybrid shared block): any common prefix
  shares its full pages, plus the longest common sub-page run of the
  first divergent page (that page CoWs on the sharer's first write).
* SSM state (ssm/hybrid): a state snapshot is only valid at exactly the
  boundary it was captured, so sharing requires the sharer's prompt to
  extend the *whole* registered prompt. Snapshots are captured at the
  first tick after prefill completes (device state then equals
  state-after-prompt) into the snapshot pool.

Observability (``repro.obs``): gauges ``repro_kvpool_pages{state=...}``
(in_use / free / shared), ``repro_kvpool_share_ratio``,
``repro_kvpool_cow_copies``, ``repro_kvpool_peak_pages_in_use``; events
``kv_alloc`` / ``kv_evict`` / ``kv_cow`` / ``kv_defer``; counters
``repro_kvpool_cow_total`` / ``repro_kvpool_defer_total``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np

from repro.kernels.layout import KV_PAGE_ROWS, SUBLANES
from repro.obs import runtime as _obs

# families with a paged attention KV pool / with SSM conv+state snapshots
KV_FAMILIES = ("dense", "vlm", "moe", "hybrid")
STATE_FAMILIES = ("ssm", "hybrid")


def validate_page_rows(rows: int) -> int:
    """Page height must be a power-of-two multiple of the sublane tile so
    pages divide every pow2-bucketized capacity (``engine._bucket``)."""
    if rows < SUBLANES or rows % SUBLANES or rows & (rows - 1):
        raise ValueError(
            f"page_rows must be a power-of-two multiple of {SUBLANES}, "
            f"got {rows}")
    return rows


# ---------------------------------------------------------------------------
# page pool


class PagePool:
    """Free-list page allocator with per-page refcounts."""

    def __init__(self, n_pages: int, *, defer_free: bool = False):
        if n_pages < 1:
            raise ValueError(f"pool needs >= 1 page, got {n_pages}")
        self.n_pages = n_pages
        self._free = list(range(n_pages - 1, -1, -1))   # stack: pops page 0
        self._ref = [0] * n_pages
        self._limbo: list[int] = []                     # freed, unflushed
        self._defer = defer_free
        self.peak_in_use = 0
        self.total_allocs = 0

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return self.n_pages - len(self._free) - len(self._limbo)

    def shared_count(self) -> int:
        return sum(1 for r in self._ref if r > 1)

    def refcount(self, pid: int) -> int:
        return self._ref[pid]

    def alloc(self) -> int | None:
        """Pop a free page at refcount 1; None when exhausted."""
        if not self._free:
            return None
        pid = self._free.pop()
        self._ref[pid] = 1
        self.total_allocs += 1
        self.peak_in_use = max(self.peak_in_use, self.in_use)
        return pid

    def incref(self, pid: int) -> None:
        assert self._ref[pid] > 0, f"incref of free page {pid}"
        self._ref[pid] += 1

    def decref(self, pid: int) -> bool:
        """Drop one reference; True when the page returned to the free
        list (or limbo, under ``defer_free``)."""
        assert self._ref[pid] > 0, f"decref of free page {pid}"
        self._ref[pid] -= 1
        if self._ref[pid]:
            return False
        (self._limbo if self._defer else self._free).append(pid)
        return True

    def flush(self) -> None:
        """Make limbo pages allocatable (end of tick: no in-flight device
        read can still target them)."""
        self._free.extend(self._limbo)
        self._limbo.clear()


# ---------------------------------------------------------------------------
# prefix trie


class _Entry:
    """One stored page of prompt tokens hanging off a trie node.

    ``tokens`` has exactly ``page_rows`` entries for a full-page edge
    (then ``child`` is the next node) or fewer for a partial tail.
    ``kv_page`` is the physical pool page holding those rows (None for
    pure-SSM families); ``state_page`` is a snapshot valid after the
    entry's last token (None when only KV is shared)."""

    __slots__ = ("tokens", "kv_page", "state_page", "child", "last_used")

    def __init__(self, tokens, kv_page, state_page, child=None):
        self.tokens = tokens
        self.kv_page = kv_page
        self.state_page = state_page
        self.child = child
        self.last_used = 0


class _Node:
    __slots__ = ("children", "partials")

    def __init__(self):
        self.children: dict[tuple, _Entry] = {}   # full-page edges
        self.partials: list[_Entry] = []          # sub-page tails


@dataclasses.dataclass
class Match:
    """Result of a trie lookup: the shareable prefix for one prompt."""
    length: int = 0                       # shared tokens (slot start pos)
    kv_pages: list = dataclasses.field(default_factory=list)  # (pid, rows)
    state_page: int | None = None         # snapshot at exactly `length`


class PrefixTrie:
    def __init__(self, page_rows: int):
        self.page_rows = page_rows
        self.root = _Node()
        self._clock = 0                   # LRU ticks (match/register bump)
        self.n_entries = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, tokens: tuple, *, need_state: bool,
              max_len: int) -> Match:
        """Deepest shareable boundary for ``tokens``, capped at
        ``max_len`` (the engine passes ``plen - 1`` so an admitted sharer
        always has at least one prompt token left to process — the
        next-token logits come from that token's forward pass).

        ``need_state=False`` (attention-only): every full-page edge is a
        boundary, plus the longest common sub-page run of one partial.
        ``need_state=True`` (ssm/hybrid): only boundaries carrying a
        state snapshot qualify, and a partial entry must match *in full*
        (a snapshot is valid at exactly its capture length)."""
        r = self.page_rows
        now = self._tick()
        node, i = self.root, 0
        chain: list[_Entry] = []
        best = Match()

        def candidate(length, tail_entry=None, tail_rows=0):
            kv = [(e.kv_page, r) for e in chain]
            st = None
            if tail_entry is not None:
                if tail_entry.kv_page is not None:
                    kv.append((tail_entry.kv_page, tail_rows))
                st = tail_entry.state_page
            elif chain:
                st = chain[-1].state_page
            if need_state and st is None:
                return
            if any(p is None for p, _ in kv):
                kv = []                   # pure-SSM: no pages to map
            best.length = length
            best.kv_pages = kv
            best.state_page = st

        while i + r <= max_len:
            ent = node.children.get(tuple(tokens[i:i + r]))
            if ent is None:
                break
            ent.last_used = now
            chain.append(ent)
            i += r
            candidate(i)
            node = ent.child

        # partial tails hanging off the deepest matched node
        rem = tokens[i:]
        for ent in node.partials:
            et = ent.tokens
            if need_state:
                # full-entry prefix match only, boundary within max_len
                if (i + len(et) <= max_len and len(et) <= len(rem)
                        and tuple(rem[:len(et)]) == tuple(et)):
                    ent.last_used = now
                    if i + len(et) > best.length:
                        candidate(i + len(et), ent, len(et))
            else:
                lcp = 0
                limit = min(len(et), len(rem), max_len - i)
                while lcp < limit and et[lcp] == rem[lcp]:
                    lcp += 1
                if lcp > 0 and i + lcp > best.length:
                    ent.last_used = now
                    candidate(i + lcp, ent, lcp)
        return best

    def has_state_at(self, tokens: tuple) -> bool:
        """True when a snapshot for exactly ``tokens`` is registered."""
        r = self.page_rows
        node, i = self.root, 0
        while i + r <= len(tokens):
            ent = node.children.get(tuple(tokens[i:i + r]))
            if ent is None:
                return False
            if i + r == len(tokens):
                return ent.state_page is not None
            node, i = ent.child, i + r
        rem = tuple(tokens[i:])
        return any(tuple(e.tokens) == rem and e.state_page is not None
                   for e in node.partials)

    def register(self, tokens: tuple, kv_pages, state_page, pool,
                 *, tail_rows: int) -> tuple[int, bool]:
        """Insert a prompt's page chain. ``kv_pages[j]`` holds tokens
        ``[j*R, (j+1)*R)`` (None entries for pure-SSM); the last entry may
        be a partial tail of ``tail_rows`` rows. The trie increfs every
        KV page it stores (it is a sharer). Pre-existing edges keep their
        pages (first writer wins — identical content by determinism).
        Returns (pages newly referenced, whether the tail/state landed)."""
        r = self.page_rows
        now = self._tick()
        node, i, j, newly = self.root, 0, 0, 0
        while i + r <= len(tokens):
            key = tuple(tokens[i:i + r])
            ent = node.children.get(key)
            if ent is None:
                pid = kv_pages[j] if kv_pages else None
                if pid is not None:
                    pool.incref(pid)
                    newly += 1
                ent = _Entry(key, pid, None, _Node())
                node.children[key] = ent
                self.n_entries += 1
            ent.last_used = now
            is_last = i + r == len(tokens)
            if is_last and state_page is not None and ent.state_page is None:
                ent.state_page = state_page
                state_page = None         # consumed
            node, i, j = ent.child, i + r, j + 1
        rem = tuple(tokens[i:])
        if rem:
            for ent in node.partials:
                if tuple(ent.tokens) == rem:
                    ent.last_used = now
                    if state_page is not None and ent.state_page is None:
                        ent.state_page = state_page
                        state_page = None
                    return newly, state_page is None
            pid = kv_pages[j] if kv_pages and j < len(kv_pages) else None
            if pid is not None:
                pool.incref(pid)
                newly += 1
            ent = _Entry(rem, pid, state_page, None)
            ent.last_used = now
            node.partials.append(ent)
            self.n_entries += 1
            state_page = None
        return newly, state_page is None

    # -- eviction ----------------------------------------------------------

    def _leaves(self):
        """(parent-node, key-or-entry) pairs for every evictable entry: a
        full-page edge whose subtree is empty, or any partial tail."""
        out = []

        def walk(node):
            for key, ent in node.children.items():
                if ent.child.children or ent.child.partials:
                    walk(ent.child)
                else:
                    out.append((node, key, ent))
            for ent in node.partials:
                out.append((node, None, ent))

        walk(self.root)
        return out

    def evict(self, pool, state_pool, *, need_kv: int = 0,
              need_state: int = 0, protect=()) -> tuple[int, int]:
        """Drop LRU leaves until ``need_kv`` KV pages / ``need_state``
        snapshot pages came back to their free lists (a decref only frees
        at refcount 0 — pages a live slot still maps are merely
        un-shared). ``protect`` entries (ids) are skipped: an admission
        must not evict the prefix it just matched. Returns pages freed."""
        freed_kv = freed_state = 0
        sess = _obs.ACTIVE
        while freed_kv < need_kv or freed_state < need_state:
            leaves = [(n, k, e) for n, k, e in self._leaves()
                      if id(e) not in protect]
            if not leaves:
                break
            node, key, ent = min(leaves, key=lambda t: t[2].last_used)
            if key is None:
                node.partials.remove(ent)
            else:
                del node.children[key]
            self.n_entries -= 1
            if ent.kv_page is not None and pool.decref(ent.kv_page):
                freed_kv += 1
            if ent.state_page is not None and \
                    state_pool.decref(ent.state_page):
                freed_state += 1
            if sess is not None:
                sess.emit("kv_evict", tokens=len(ent.tokens),
                          kv_page=ent.kv_page, state_page=ent.state_page)
        return freed_kv, freed_state


# ---------------------------------------------------------------------------
# manager


@dataclasses.dataclass
class _SlotRec:
    uid: int
    prompt: tuple
    budget: int
    start: int                       # shared tokens skipped at admission
    pos: int                         # device-side absolute position
    reserved: int                    # worst-case pages not yet allocated
    load_state: int = -1             # snapshot to load at the reset tick
    pending_capture: bool = False    # snapshot state-after-prompt next tick
    prefilled: bool = False


class PagedKVManager:
    """Host-side authority for one engine's paged caches: block tables,
    reservations, lazy allocation, CoW planning, trie registration."""

    def __init__(self, *, slots: int, page_rows: int, maxpages: int,
                 pool_pages: int, family: str, state_pages: int = 0,
                 sharing: bool = True):
        validate_page_rows(page_rows)
        self.slots = slots
        self.page_rows = page_rows
        self.maxpages = maxpages
        self.family = family
        self.has_kv = family in KV_FAMILIES
        self.has_state = family in STATE_FAMILIES
        self.sharing = sharing
        self.kv = PagePool(pool_pages) if self.has_kv else None
        self.state = (PagePool(state_pages, defer_free=True)
                      if self.has_state and state_pages > 0 else None)
        self.trie = PrefixTrie(page_rows)
        self.tables = np.full((slots, maxpages), -1, np.int32)
        self._reset_pos = np.zeros(slots, np.int32)
        self._recs: list[_SlotRec | None] = [None] * slots
        self._outstanding = 0            # sum of live reservations
        self.stats_counters = {"cow_copies": 0, "defers": 0, "allocs": 0,
                               "evictions": 0, "shared_tokens": 0,
                               "snapshots": 0}

    # -- admission ---------------------------------------------------------

    def _pages_needed(self, plen: int, budget: int, shared_len: int) -> int:
        """Worst-case NEW pages a request can touch: every page up to its
        last written position, minus full shared pages it never rewrites
        — unless it wraps the table, where every entry gets recycled (and
        shared entries CoW), so the bound is the whole table."""
        end = -(-(plen + budget) // self.page_rows)     # ceil
        if end > self.maxpages:
            return self.maxpages
        return max(0, end - shared_len // self.page_rows)

    def admit(self, slot: int, prompt, budget: int, *,
              uid: int = -1) -> int | None:
        """Try to admit a request into ``slot``. On success maps shared
        prefix pages into the block table, reserves worst-case new pages,
        and returns the start position (shared tokens to skip). Returns
        None when the pool cannot guarantee the reservation even after
        eviction — the engine defers the admission (back-pressure)."""
        assert self._recs[slot] is None, f"slot {slot} busy"
        tok = tuple(int(t) for t in prompt)
        plen = len(tok)
        m = Match()
        if self.sharing and plen > 1:
            m = self.trie.match(tok, need_state=self.has_state,
                                max_len=plen - 1)
        needed = 0
        if self.has_kv:
            needed = self._pages_needed(plen, budget, m.length)
            headroom = self.kv.free_count - self._outstanding
            if needed > headroom:
                protect = {id(e) for e in self._match_entries(m)}
                self.trie.evict(self.kv, self.state or _NULL_POOL,
                                need_kv=needed - headroom, protect=protect)
                self.stats_counters["evictions"] += 1
                headroom = self.kv.free_count - self._outstanding
            if needed > headroom:
                self.stats_counters["defers"] += 1
                sess = _obs.ACTIVE
                if sess is not None:
                    sess.emit("kv_defer", uid=uid, slot=slot,
                              needed=needed, free=self.kv.free_count,
                              outstanding=self._outstanding)
                    sess.counter("repro_kvpool_defer_total",
                                 "admissions deferred on pool pressure"
                                 ).inc()
                return None
            for idx, (pid, _rows) in enumerate(m.kv_pages):
                self.kv.incref(pid)
                self.tables[slot, idx] = pid
            self._outstanding += needed
        self._recs[slot] = _SlotRec(
            uid=uid, prompt=tok, budget=budget, start=m.length,
            pos=m.length, reserved=needed,
            load_state=(m.state_page if m.state_page is not None else -1))
        self._reset_pos[slot] = m.length
        self.stats_counters["shared_tokens"] += m.length
        return m.length

    def _match_entries(self, m: Match):
        """Entries whose pages a Match maps (eviction protection)."""
        # cheap re-walk is avoided: protect by page id via a refcount
        # argument — pages in m are about to be increfed, but during
        # admit's evict they are still at trie-only refcount. Walk the
        # trie for entries holding those pages instead.
        pids = {pid for pid, _ in m.kv_pages}
        if m.state_page is not None:
            pids.add(("s", m.state_page))
        out = []

        def walk(node):
            for ent in list(node.children.values()) + node.partials:
                if ent.kv_page in pids or ("s", ent.state_page) in pids:
                    out.append(ent)
                if ent.child is not None:
                    walk(ent.child)

        if pids:
            walk(self.trie.root)
        return out

    # -- per-tick planning -------------------------------------------------

    def _alloc_kv(self, rec: _SlotRec, slot: int, why: str) -> int:
        pid = self.kv.alloc()
        if pid is None:
            self.trie.evict(self.kv, self.state or _NULL_POOL, need_kv=1)
            pid = self.kv.alloc()
        if pid is None:
            raise RuntimeError(
                "KV page pool exhausted despite reservations — "
                f"pool_pages={self.kv.n_pages} cannot cover the active "
                "slots (raise ServeConfig.pool_pages)")
        if rec.reserved > 0:
            rec.reserved -= 1
            self._outstanding -= 1
        self.stats_counters["allocs"] += 1
        sess = _obs.ACTIVE
        if sess is not None:
            sess.emit("kv_alloc", slot=slot, uid=rec.uid, page=pid,
                      why=why)
        return pid

    def plan_tick(self, takes: dict[int, int]) -> dict[str, np.ndarray]:
        """Plan one block step: lazily allocate the pages each slot's
        ``take`` tokens will write, CoW any shared page about to be
        written, and schedule SSM snapshot captures/loads. Returns the
        page-table inputs for the jitted paged block step."""
        out: dict[str, np.ndarray] = {
            "reset_pos": self._reset_pos.copy()}
        r, mp = self.page_rows, self.maxpages
        sess = _obs.ACTIVE
        if self.has_kv:
            copy = np.arange(self.kv.n_pages, dtype=np.int32)
            for slot, take in takes.items():
                rec = self._recs[slot]
                if rec is None or take <= 0:
                    continue
                first, last = rec.pos, rec.pos + take - 1
                for lp in range(first // r, last // r + 1):
                    li = lp % mp
                    pid = int(self.tables[slot, li])
                    if pid < 0:
                        self.tables[slot, li] = self._alloc_kv(
                            rec, slot, "new")
                    elif self.kv.refcount(pid) > 1:
                        # first divergent write into a shared page:
                        # copy-on-write — sharers keep the original
                        new = self._alloc_kv(rec, slot, "cow")
                        copy[new] = pid
                        self.kv.decref(pid)
                        self.tables[slot, li] = new
                        self.stats_counters["cow_copies"] += 1
                        if sess is not None:
                            sess.emit("kv_cow", slot=slot, uid=rec.uid,
                                      src=pid, dst=new)
                            sess.counter(
                                "repro_kvpool_cow_total",
                                "copy-on-write page copies").inc()
                    # else: sole owner — append/ring-overwrite in place
            out["tables"] = np.maximum(self.tables, 0)
            out["kv_copy"] = copy
        if self.has_state:
            save = np.full(self.slots, -1, np.int32)
            load = np.full(self.slots, -1, np.int32)
            for slot in takes:
                rec = self._recs[slot]
                if rec is None:
                    continue
                if rec.load_state >= 0:
                    load[slot] = rec.load_state   # consumed at reset tick
                    rec.load_state = -1
                if rec.pending_capture:
                    rec.pending_capture = False
                    if rec.pos == len(rec.prompt):  # device state is
                        sp = self._capture(rec, slot)  # state-after-prompt
                        if sp is not None:
                            save[slot] = sp
            out["snap_save"] = save
            out["snap_load"] = load
        return out

    def _capture(self, rec: _SlotRec, slot: int) -> int | None:
        """Allocate a snapshot page and register the prompt (KV chain +
        state) in the trie. None = skipped (dup / no room / wrapped)."""
        if not self.sharing or self.state is None:
            return None
        plen = len(rec.prompt)
        if plen > self.maxpages * self.page_rows:
            return None                   # prompt itself wrapped the table
        if self.trie.has_state_at(rec.prompt):
            return None                   # first writer already landed
        if self.has_kv and self.kv.free_count - self._outstanding < 1:
            return None   # registering the tail makes the owner's next
            #               append CoW it; without headroom, skip
        sp = self.state.alloc()
        if sp is None:
            self.trie.evict(self.kv or _NULL_POOL, self.state,
                            need_state=1)
            sp = self.state.alloc()
        if sp is None:
            return None
        kv_pages = None
        tail = plen % self.page_rows or self.page_rows
        if self.has_kv:
            n_pg = -(-plen // self.page_rows)
            kv_pages = [int(self.tables[slot, j % self.maxpages])
                        for j in range(n_pg)]
        _, landed = self.trie.register(rec.prompt, kv_pages, sp, self.kv,
                                       tail_rows=tail)
        if not landed:                    # raced a dup: return the page
            self.state.decref(sp)
            return None
        if self.has_kv and plen % self.page_rows:
            rec.reserved += 1             # owner CoWs its tail next write
            self._outstanding += 1
        self.stats_counters["snapshots"] += 1
        return sp

    # -- bookkeeping -------------------------------------------------------

    def advance(self, slot: int, consumed: int) -> None:
        rec = self._recs[slot]
        if rec is not None:
            rec.pos += consumed

    def mark_prefilled(self, slot: int) -> None:
        """Engine callback when a slot's prompt is fully consumed (end of
        the tick): attention-only families register the prompt's pages
        now; stateful families schedule a snapshot capture for the next
        tick (device state then equals state-after-prompt)."""
        rec = self._recs[slot]
        if rec is None or rec.prefilled or not self.sharing:
            return
        rec.prefilled = True
        if self.has_state:
            rec.pending_capture = True    # registration rides the capture
            return
        plen = len(rec.prompt)
        if plen < 2 or plen > self.maxpages * self.page_rows:
            return
        tail = plen % self.page_rows
        if tail and self.kv.free_count - self._outstanding < 1:
            # registering the partial tail forces the owner to CoW it on
            # its next append; without headroom register full pages only
            full = plen - tail
            if full:
                pages = [int(self.tables[slot, j % self.maxpages])
                         for j in range(full // self.page_rows)]
                self.trie.register(rec.prompt[:full], pages, None, self.kv,
                                   tail_rows=self.page_rows)
            return
        n_pg = -(-plen // self.page_rows)
        pages = [int(self.tables[slot, j % self.maxpages])
                 for j in range(n_pg)]
        self.trie.register(rec.prompt, pages, None, self.kv,
                           tail_rows=tail or self.page_rows)
        if tail:
            rec.reserved += 1
            self._outstanding += 1

    def release(self, slot: int) -> None:
        """Slot finished: return its block-table references (pages the
        trie still shares stay alive) and drop the unused reservation."""
        rec = self._recs[slot]
        if rec is None:
            return
        if self.has_kv:
            for li in range(self.maxpages):
                pid = int(self.tables[slot, li])
                if pid >= 0:
                    self.kv.decref(pid)
            self.tables[slot, :] = -1
        self._outstanding -= rec.reserved
        self._recs[slot] = None

    def end_tick(self) -> None:
        """Post-step hook: limbo snapshot pages become allocatable (no
        in-flight read can target them any more)."""
        if self.state is not None:
            self.state.flush()

    # -- observability -----------------------------------------------------

    def stats(self) -> dict[str, Any]:
        out = dict(self.stats_counters)
        out["trie_entries"] = self.trie.n_entries
        if self.kv is not None:
            out.update(
                pages_total=self.kv.n_pages, pages_in_use=self.kv.in_use,
                pages_free=self.kv.free_count,
                pages_shared=self.kv.shared_count(),
                peak_pages_in_use=self.kv.peak_in_use,
                share_ratio=round(
                    self.kv.shared_count() / max(self.kv.in_use, 1), 4))
        if self.state is not None:
            out.update(state_pages_total=self.state.n_pages,
                       state_pages_in_use=self.state.in_use,
                       peak_state_pages_in_use=self.state.peak_in_use)
        return out

    def emit_gauges(self) -> None:
        sess = _obs.ACTIVE
        if sess is None or self.kv is None:
            return
        g = sess.gauge("repro_kvpool_pages", "KV pool pages by state")
        g.set(self.kv.in_use, state="in_use")
        g.set(self.kv.free_count, state="free")
        g.set(self.kv.shared_count(), state="shared")
        sess.gauge("repro_kvpool_share_ratio",
                   "shared / in-use KV pages").set(
            self.kv.shared_count() / max(self.kv.in_use, 1))
        sess.gauge("repro_kvpool_cow_copies",
                   "cumulative copy-on-write page copies").set(
            self.stats_counters["cow_copies"])
        sess.gauge("repro_kvpool_peak_pages_in_use",
                   "high-water mark of KV pages in use").set(
            self.kv.peak_in_use)


class _NullPool:
    """Stand-in for an absent pool so trie eviction can decref blindly."""

    def decref(self, pid):
        return False


_NULL_POOL = _NullPool()
