from repro.serving.engine import (
    Request,
    Result,
    ServeConfig,
    ServingEngine,
    clear_compile_cache,
    demo_engine,
)

__all__ = ["Request", "Result", "ServeConfig", "ServingEngine",
           "clear_compile_cache", "demo_engine"]
