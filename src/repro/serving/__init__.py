from repro.serving.engine import (
    Request,
    Result,
    ServeConfig,
    ServingEngine,
    clear_compile_cache,
    demo_engine,
)
from repro.serving.kvpool import PagedKVManager, PagePool, PrefixTrie

__all__ = ["Request", "Result", "ServeConfig", "ServingEngine",
           "clear_compile_cache", "demo_engine",
           "PagePool", "PrefixTrie", "PagedKVManager"]
