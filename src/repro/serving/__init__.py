from repro.serving.engine import (
    Request,
    Result,
    ServeConfig,
    ServingEngine,
    demo_engine,
)

__all__ = ["Request", "Result", "ServeConfig", "ServingEngine",
           "demo_engine"]
