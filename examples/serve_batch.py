"""Batched serving example: continuous batching over the engine's slot
grid (or the wave baseline via --scheduler wave).

Run:  PYTHONPATH=src python examples/serve_batch.py [--arch mamba2-1.3b]

Serves a reduced-config model with batched requests: chunked prefill
interleaved with decode ticks over a ring KV cache with per-slot
positions; a finished slot is refilled from the queue on the next tick.
Works for every assigned architecture family (dense KV cache, MoE, SSM
state cache, hybrid; enc-dec falls back to the wave scheduler).
--arrival-rate turns the request list into open-loop Poisson arrivals.
"""
import argparse
import time

import numpy as np

from repro import configs
from repro.models import build
from repro.serving.engine import Request, demo_engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    choices=configs.all_arch_ids())
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--scheduler", choices=("continuous", "wave"),
                    default="continuous")
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="open-loop Poisson arrivals in requests/s "
                         "(0: closed loop)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--policy", default=None,
                    help="KernelPolicy for every core op in the served "
                         "model: a path label, an op=path override list "
                         "(dotted keys tune kernel geometry), or JSON")
    args = ap.parse_args()

    mod = configs.get(args.arch)
    bundle = build(mod.SMOKE)
    engine = demo_engine(bundle, slots=args.slots, max_new=args.max_new,
                         seed=args.seed, scheduler=args.scheduler,
                         prefill_chunk=args.prefill_chunk,
                         policy=args.policy)

    rng = np.random.default_rng(args.seed)
    arrival = 0.0
    reqs = []
    for i in range(args.requests):
        if args.arrival_rate > 0:
            arrival += float(rng.exponential(1.0 / args.arrival_rate))
        reqs.append(Request(uid=i, prompt=rng.integers(
            3, mod.SMOKE.vocab, size=int(rng.integers(8, 24)),
            dtype=np.int32), arrival_s=arrival))

    t0 = time.time()
    results = engine.run(reqs)
    dt = time.time() - t0
    total = sum(len(r.tokens) for r in results)
    for r in results:
        print(f"req {r.uid}: prompt={r.prompt_len} tokens "
              f"-> {r.tokens[:10]}{'...' if len(r.tokens) > 10 else ''}")
    print(f"\n{len(results)} requests, {total} new tokens, {dt:.2f}s "
          f"({total / max(dt, 1e-9):.1f} tok/s on CPU, "
          f"scheduler={engine.scheduler})")


if __name__ == "__main__":
    main()
