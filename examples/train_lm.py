"""End-to-end driver: train a ~100M-param llama-family model for a few
hundred steps on synthetic data, with checkpointing and resume.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]

This is the (b)-deliverable end-to-end example: real config -> data
pipeline -> jit'd train step (all reductions in matmul form) -> optimizer
-> checkpoint/resume. On a TPU cluster the same loop runs the FULL configs
via launch/train.py.
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLMPipeline
from repro.models import build
from repro.models.layers import ModelConfig
from repro.ops import KernelPolicy
from repro.optim import OptConfig
from repro.training import TrainConfig, init_train_state, make_train_step

# ~100M params: 12L, d=768, llama-style
CFG_100M = ModelConfig(
    name="llama-100m", family="dense",
    n_layers=12, d_model=768, vocab=32000,
    n_heads=12, n_kv_heads=4, d_ff=2048, head_dim=64,
    tie_embeddings=True, dtype=jax.numpy.float32, remat_policy="off",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--policy", default=None,
                    help="KernelPolicy for the model's core ops: a path "
                         "label, an op=path,op=path override list (dotted "
                         "keys tune kernel geometry, e.g. 'ssd.q=64'), or "
                         "a JSON object of policy fields")
    args = ap.parse_args()

    cfg = CFG_100M
    if args.policy is not None:
        cfg = dataclasses.replace(cfg,
                                  policy=KernelPolicy.from_spec(args.policy))
    bundle = build(cfg)
    print(f"model: {bundle.n_params / 1e6:.1f}M params")
    opt_cfg = OptConfig(peak_lr=6e-4, warmup_steps=30,
                        decay_steps=args.steps, policy=cfg.policy)
    state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
    step_fn = jax.jit(make_train_step(bundle, opt_cfg),
                      donate_argnums=(0,))

    start = ckpt.latest_step(args.ckpt_dir) or 0
    if start:
        state = ckpt.restore(args.ckpt_dir, start, state)
        print(f"resumed from step {start}")

    data = SyntheticLMPipeline(DataConfig(
        vocab=CFG_100M.vocab, seq_len=args.seq, global_batch=args.batch))

    losses = []
    t0 = time.time()
    for step in range(start, args.steps):
        batch = data.device_batch(step)
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        if step % 20 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq * (step - start + 1) / (
                time.time() - t0)
            print(f"step {step:4d} loss {losses[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({tok_s / 1e3:.1f}k tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, step + 1, state)

    ckpt.save(args.ckpt_dir, args.steps, state)
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f} "
          f"(synthetic data: memorisation curve)")


if __name__ == "__main__":
    main()
