"""Quickstart: the paper's primitives and where they live in the framework.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.core as core
from repro.kernels import ops


def main() -> None:
    rng = jax.random.PRNGKey(0)

    # ---- 1. the paper's reduction: P @ A tile algebra --------------------
    x = jax.random.normal(rng, (1 << 20,))
    total_tile = core.tcu_reduce(x, formulation="tile")    # paper-faithful
    total_fused = core.tcu_reduce(x)                       # beyond-paper
    print(f"reduce: tile={float(total_tile):.3f} "
          f"fused={float(total_fused):.3f} "
          f"numpy={float(np.sum(np.asarray(x))):.3f}")

    # ---- 2. the paper's scan: A U + (L A) 1 ------------------------------
    v = jax.random.normal(jax.random.fold_in(rng, 1), (100_000,))
    s = core.tcu_scan(v)
    print(f"scan: max|err| vs cumsum = "
          f"{float(jnp.max(jnp.abs(s - jnp.cumsum(v)))):.2e}")

    # ---- 3. segmented forms (the 100x regime: many small segments) -------
    segs = jax.random.normal(jax.random.fold_in(rng, 2), (4096, 16))
    print(f"segmented reduce of 4096 x 16: {core.tcu_segmented_reduce(segs).shape}")

    # ---- 4. the weighted generalisation = Mamba-2's SSD ------------------
    la = -jax.random.uniform(jax.random.fold_in(rng, 3), (1000,))
    w = core.tcu_weighted_scan(v[:1000], la)
    print(f"weighted scan (y_i = a_i y_(i-1) + x_i): {w.shape}")

    # ---- 5. Pallas TPU kernels, validated on CPU via interpret mode ------
    xt = jax.random.normal(rng, (8, 1000), jnp.bfloat16)
    k_out = ops.segmented_reduce(xt, use_pallas=True)   # interpret on CPU
    print(f"pallas kernel vs oracle: "
          f"{np.allclose(k_out, np.asarray(xt, np.float32).sum(-1), atol=1)}")

    # ---- 6. a model layer consuming the primitive ------------------------
    w_norm = jnp.ones((512,))
    h = jax.random.normal(rng, (4, 512))
    print(f"fused rmsnorm (paper's batch-norm-variance future work): "
          f"{ops.rmsnorm(h, w_norm).shape}")


if __name__ == "__main__":
    main()
