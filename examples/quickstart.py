"""Quickstart: the paper's primitives through the stable ``repro.ops``
facade — every op takes ``policy=`` (which formulation runs) and the
policy can carry ``op_tuning`` (how the kernel runs).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

import repro.ops as ops
from repro.ops import KernelPolicy, using_policy


def main() -> None:
    rng = jax.random.PRNGKey(0)

    # ---- 1. the paper's reduction: P @ A tile algebra --------------------
    x = jax.random.normal(rng, (1 << 20,))
    total_tile = ops.reduce(x, policy="xla_tile")          # paper-faithful
    total_fused = ops.reduce(x, policy="fused")            # beyond-paper
    print(f"reduce: tile={float(total_tile):.3f} "
          f"fused={float(total_fused):.3f} "
          f"numpy={float(np.sum(np.asarray(x))):.3f}")

    # ---- 2. the paper's scan: A U + (L A) 1 ------------------------------
    v = jax.random.normal(jax.random.fold_in(rng, 1), (100_000,))
    s = ops.scan(v, policy="fused")
    print(f"scan: max|err| vs cumsum = "
          f"{float(jnp.max(jnp.abs(s - jnp.cumsum(v)))):.2e}")

    # ---- 3. segmented forms (the 100x regime: many small segments) -------
    segs = jax.random.normal(jax.random.fold_in(rng, 2), (4096, 16))
    print(f"segmented reduce of 4096 x 16: {ops.reduce(segs).shape} "
          "(policy=None -> the active policy's auto choice)")

    # ---- 4. the weighted generalisation = Mamba-2's SSD ------------------
    la = -jax.random.uniform(jax.random.fold_in(rng, 3), (1000,))
    w = ops.weighted_scan(v[:1000], la)
    print(f"weighted scan (y_i = a_i y_(i-1) + x_i): {w.shape}")

    # ---- 5. Pallas kernels, validated on CPU via interpret mode ----------
    xt = jax.random.normal(rng, (8, 1000), jnp.bfloat16)
    k_out = ops.reduce(xt, policy="interpret")      # kernel body on CPU
    print(f"pallas kernel vs oracle: "
          f"{np.allclose(k_out, np.asarray(xt, np.float32).sum(-1), atol=1)}")

    # ---- 6. a model layer consuming the primitive ------------------------
    w_norm = jnp.ones((512,))
    h = jax.random.normal(rng, (4, 512))
    print(f"fused rmsnorm (paper's batch-norm-variance future work): "
          f"{ops.rmsnorm(h, w_norm).shape}")

    # ---- 7. tuning is policy too: override the kernel geometry -----------
    tuned = KernelPolicy(path="interpret",
                         op_tuning={"scan": {"block_n": 256}})
    with using_policy(tuned):
        spec = ops.get_policy().resolve(op="scan", n=1000).tuning
        s2 = ops.scan(jnp.ones((8, 1000)))
    print(f"tuned scan ran with {spec.label()}: "
          f"last prefix = {float(s2[0, -1]):.0f} (want 1000)")


if __name__ == "__main__":
    main()
