"""Sequence-parallel SSD: the paper's grid-level scan across devices.

Run:  PYTHONPATH=src python examples/ssd_long_context.py

Demonstrates the long_500k story at example scale: a Mamba-2 SSD layer's
sequence dimension is sharded over a device mesh; each device computes its
chunk with the matmul-form weighted scan, and the cross-device carry is the
paper's scan-then-propagate (repro.ops.dist_weighted_scan) — three
triangular-matmul 'kernels' at tile, core, and mesh level.

Uses 4 fake host devices (set before jax import) — the same code shards
over the `data` axis of a real pod.
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax                                                      # noqa: E402
import jax.numpy as jnp                                         # noqa: E402
import numpy as np                                              # noqa: E402
from jax.sharding import PartitionSpec as P                     # noqa: E402

from repro.ops import dist_weighted_scan, weighted_scan         # noqa: E402
from repro.parallel.compat import make_mesh, shard_map          # noqa: E402


def main() -> None:
    mesh = make_mesh((4,), ("data",))
    seq = 1 << 16                      # 65k at example scale; 500k on pod
    x = jax.random.normal(jax.random.PRNGKey(0), (2, seq))
    log_a = -jax.random.uniform(jax.random.PRNGKey(1), (2, seq)) * 0.01

    def seq_parallel(xl, ll):
        return dist_weighted_scan(xl, ll, "data")

    sp = jax.jit(shard_map(
        seq_parallel, mesh=mesh,
        in_specs=(P(None, "data"), P(None, "data")),
        out_specs=P(None, "data")))

    got = sp(x, log_a)
    # single-device reference through the public facade (fused matmul form)
    want = weighted_scan(x, log_a, policy="fused")
    err = float(jnp.max(jnp.abs(got - want)))
    print(f"sequence-parallel SSD scan over 4 devices, seq={seq}")
    print(f"max |seq-parallel - single-device| = {err:.2e}")
    assert err < 1e-2
    print("OK: the grid-level carry (paper Sec 5.3) is exact")


if __name__ == "__main__":
    main()
