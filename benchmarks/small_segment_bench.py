"""Paper Figure 11: warp/block-level reduction and scan at small segment
sizes (2^4..2^13) — the regime where the paper reports up to 100x.

The V100 contrast was TCU-fragment ops vs shuffle loops; the TPU-native
contrast is one MXU matmul per 128 segments vs XLA's per-segment vector
reduction. We report both wall time and the HLO dot/VPU flop split — the
structural evidence that the work moved onto the matrix unit. Timed rows
carry median/IQR plus the roofline pair and land in
``BENCH_small_segments.json``.
"""
from __future__ import annotations

import jax

from benchmarks.common import (bandwidth_model, elems_per_sec, hlo_op_mix,
                               print_csv, select_paths, time_stats,
                               tuning_label, write_bench_json)

N_SEGMENTS = 4096

# row name -> (op, dispatch path); the tile rows are the explicit Pallas
# kernels (TPU or Triton per host) and drop out via select_paths where no
# native lowering exists
CONTENDERS = {
    "tcu_reduce": ("reduce", "xla_tile"),
    "base_reduce": ("reduce", "baseline"),
    "auto_reduce": ("reduce", "auto"),
    "tile_reduce": ("reduce", "tile"),
    "tcu_scan": ("scan", "fused"),
    "base_scan": ("scan", "baseline"),
    "auto_scan": ("scan", "auto"),
    "tile_scan": ("scan", "tile"),
    "logdepth_scan": ("scan", "tile_logdepth"),
}


def run() -> tuple[list, list]:
    from repro.core import dispatch

    keep = select_paths({k: v[1] for k, v in CONTENDERS.items()})
    rows, mix_rows = [], []
    for log_seg in range(4, 14):
        seg = 1 << log_seg
        x = jax.random.normal(jax.random.PRNGKey(1), (N_SEGMENTS, seg))
        ops = {"reduce": dispatch.reduce, "scan": dispatch.scan}
        cases = {
            # the "auto" rows pass policy=None (the ambient policy), so a
            # run.py --policy op=path override steers exactly them
            name: (lambda a, o=op, p=path: ops[o](
                a, policy=(None if p == "auto" else p)))
            for name, (op, path) in CONTENDERS.items() if name in keep
        }
        for name, fn in cases.items():
            st = time_stats(jax.jit(fn), x)
            t = st["median_s"]
            op, path = CONTENDERS[name]
            # reduce: read all, write one per segment; scan: read+write all
            bytes_moved = (x.size + N_SEGMENTS if op == "reduce"
                           else 2 * x.size) * x.dtype.itemsize
            rows.append({
                "algo": name, "segment_size": seg,
                "us_per_call": round(t * 1e6, 1),
                "iqr_us": round(st["iqr_s"] * 1e6, 1),
                "iters": st["iters"], "warmup": st["warmup"],
                "belems_s": round(elems_per_sec(x.size, t) / 1e9, 3),
                "tuning": tuning_label(path, op, seg, x.dtype),
                **bandwidth_model(bytes_moved, t),
            })
        for name in ("tcu_reduce", "base_reduce"):
            mix = hlo_op_mix(cases[name], x)
            mix_rows.append([name, seg, f"{mix['dot_flops']:.3g}",
                             f"{mix['vpu_flops']:.3g}"])
    return rows, mix_rows


def main() -> None:
    rows, mix_rows = run()
    cols = ["algo", "segment_size", "us_per_call", "iqr_us", "belems_s",
            "achieved_gbps", "pct_peak", "tuning"]
    print_csv("fig11_small_segments", cols,
              [[r[c] for c in cols] for r in rows])
    print_csv("fig11_alu_mix", ["algo", "segment_size", "dot_flops",
                                "vpu_flops"], mix_rows)
    write_bench_json("small_segments", rows, {"n_segments": N_SEGMENTS})


if __name__ == "__main__":
    main()
