"""Paper Figure 10: segmented reduction throughput vs segment size.

Fixed-size input (2^24 elements on this CPU host; the paper used 2^30 on a
V100), segment size swept over powers of two. Contenders are the dispatch
layer's paths (repro.core.dispatch — one switch, no ad-hoc imports):

  * ``tcu_tile``    — path="xla_tile": the paper-faithful tile algebra
  * ``tcu_fused``   — path="fused": the beyond-paper fused matmul form
  * ``baseline``    — path="baseline": jnp.sum (XLA's native vector
    reduction = the CUB stand-in)
  * ``tile_kernel`` — path="tile": the explicit Pallas kernel (Pallas-TPU
    on TPU, Pallas-Triton on GPU); skipped on hosts with no native
    lowering (see ``common.select_paths`` / ``run.py --backend``)

Derived columns: ``belems_s`` = billions of elements per second (the
paper's y-axis) and the roofline pair ``gbps``/``pct_peak`` — reduction is
bandwidth-bound, so achieved bytes/s against the host's peak is the
cross-machine-comparable number (see ``common.bandwidth_model``). Each
timed row reports the median with IQR over ``iters`` post-warmup calls and
lands in ``BENCH_segmented_reduce.json``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (bandwidth_model, elems_per_sec, print_csv,
                               select_paths, time_stats, tuning_label,
                               write_bench_json)

TOTAL = 1 << 22

CONTENDERS = {
    "tcu_tile": "xla_tile",
    "tcu_fused": "fused",
    "baseline_sum": "baseline",
    "tile_kernel": "tile",
}


def run(total: int = TOTAL) -> list[dict]:
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (total,), jnp.float32)
    paths = select_paths(CONTENDERS)
    for log_seg in range(4, 19, 4):
        seg = 1 << log_seg
        segs = total // seg
        xs = x.reshape(segs, seg)

        from repro.core import dispatch

        fns = {
            name: jax.jit(lambda a, p=p: dispatch.reduce(a, policy=p))
            for name, p in paths.items()
        }
        # minimal traffic: read every element, write one total per segment
        bytes_moved = (total + segs) * xs.dtype.itemsize
        for name, fn in fns.items():
            st = time_stats(fn, xs)
            t = st["median_s"]
            rows.append({
                "algo": name, "segment_size": seg, "n_segments": segs,
                "us_per_call": round(t * 1e6, 1),
                "iqr_us": round(st["iqr_s"] * 1e6, 1),
                "iters": st["iters"], "warmup": st["warmup"],
                "belems_s": round(elems_per_sec(total, t) / 1e9, 3),
                "tuning": tuning_label(paths[name], "reduce", seg,
                                       xs.dtype),
                **bandwidth_model(bytes_moved, t),
            })
    return rows


def main() -> None:
    rows = run()
    cols = ["algo", "segment_size", "n_segments", "us_per_call", "iqr_us",
            "belems_s", "achieved_gbps", "pct_peak", "tuning"]
    print_csv("fig10_segmented_reduce", cols,
              [[r[c] for c in cols] for r in rows])
    write_bench_json("segmented_reduce", rows, {"total_elems": TOTAL})


if __name__ == "__main__":
    main()
