"""Paper Figure 10: segmented reduction throughput vs segment size.

Fixed-size input (2^24 elements on this CPU host; the paper used 2^30 on a
V100), segment size swept over powers of two. Contenders are the dispatch
layer's paths (repro.core.dispatch — one switch, no ad-hoc imports):

  * ``tcu_tile``    — path="xla_tile": the paper-faithful tile algebra
  * ``tcu_fused``   — path="fused": the beyond-paper fused matmul form
  * ``baseline``    — path="baseline": jnp.sum (XLA's native vector
    reduction = the CUB stand-in)
  * ``tile_kernel`` — path="tile": the explicit Pallas kernel (Pallas-TPU
    on TPU, Pallas-Triton on GPU); skipped on hosts with no native
    lowering (see ``common.select_paths`` / ``run.py --backend``)

Derived column ``belems_s`` = billions of half-precision-equivalent elements
per second (the paper's y-axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (elems_per_sec, print_csv, select_paths,
                               time_fn, tuning_label)

TOTAL = 1 << 22

CONTENDERS = {
    "tcu_tile": "xla_tile",
    "tcu_fused": "fused",
    "baseline_sum": "baseline",
    "tile_kernel": "tile",
}


def run(total: int = TOTAL) -> list:
    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (total,), jnp.float32)
    paths = select_paths(CONTENDERS)
    for log_seg in range(4, 19, 4):
        seg = 1 << log_seg
        segs = total // seg
        xs = x.reshape(segs, seg)

        from repro.core import dispatch

        fns = {
            name: jax.jit(lambda a, p=p: dispatch.reduce(a, policy=p))
            for name, p in paths.items()
        }
        for name, fn in fns.items():
            t = time_fn(fn, xs)
            rows.append([name, seg, segs, f"{t * 1e6:.1f}",
                         f"{elems_per_sec(total, t) / 1e9:.3f}",
                         tuning_label(paths[name], "reduce", seg, xs.dtype)])
    return rows


def main() -> None:
    rows = run()
    print_csv("fig10_segmented_reduce",
              ["algo", "segment_size", "n_segments", "us_per_call",
               "belems_s", "tuning"], rows)


if __name__ == "__main__":
    main()
