"""Paper §6.3 power proxy: ALU mix of matmul-form vs vector-form collectives.

The paper measured 7.4-22.3% lower power with NVPROF, attributing it to the
FP16/INT ALUs idling while the TCU does the work. Power is not measurable
on this host, so we report the *structural* proxy from the compiled HLO:
what fraction of executed flops are dot-form (MXU-eligible, the efficient
unit) vs elementwise/reduce (VPU) for each formulation — plus HBM traffic
(the other power driver). The matmul form should show ~all flops on the
dot side and no increase in memory traffic.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import hlo_op_mix, print_csv


def run() -> list:
    from repro.core import dispatch

    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (512, 4096))
    cases = {
        "reduce_tcu_tile": lambda a: dispatch.reduce(a, policy="xla_tile"),
        "reduce_vector": lambda a: dispatch.reduce(a, policy="baseline"),
        "scan_tcu": lambda a: dispatch.scan(a, policy="fused"),
        "scan_vector": lambda a: dispatch.scan(a, policy="baseline"),
        "rmsnorm_tcu": lambda a: a * jax.lax.rsqrt(
            dispatch.reduce(a * a, policy="fused")[..., None] / a.shape[-1]
            + 1e-6),
        "rmsnorm_vector": lambda a: a * jax.lax.rsqrt(
            jnp.mean(a * a, axis=-1, keepdims=True) + 1e-6),
    }
    for name, fn in cases.items():
        mix = hlo_op_mix(fn, x)
        tot = max(mix["total_flops"], 1.0)
        rows.append([name, f"{mix['dot_flops']:.4g}",
                     f"{mix['vpu_flops']:.4g}",
                     f"{mix['dot_flops'] / tot:.3f}",
                     f"{mix['memory_bytes']:.4g}"])
    return rows


def main() -> None:
    print_csv("sec6_3_alu_mix_power_proxy",
              ["case", "dot_flops", "vpu_flops", "mxu_fraction",
               "hbm_bytes"], run())


if __name__ == "__main__":
    main()
