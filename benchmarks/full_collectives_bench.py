"""Paper Figures 13/14: full (grid-level) reduction and scan vs input size.

The device-level composition (tile scan -> tile-totals scan -> carry add,
repro.core.tcu_scan's recursion) against XLA's native sum/cumsum, over
input sizes 2^16..2^24. All contenders via repro.core.dispatch paths.
Rows carry median/IQR and the roofline pair (reduce: n reads + 1 write;
scan: n reads + n writes) and land in ``BENCH_full_collectives.json``.
"""
from __future__ import annotations

import jax

from benchmarks.common import (bandwidth_model, elems_per_sec, print_csv,
                               time_stats, write_bench_json)


def run() -> list[dict]:
    from repro.core import dispatch

    rows = []
    for log_n in range(16, 25, 2):
        n = 1 << log_n
        x = jax.random.normal(jax.random.PRNGKey(2), (n,))
        cases = {
            "tcu_full_reduce": lambda a: dispatch.reduce(a, policy="xla_tile"),
            "base_full_reduce": lambda a: dispatch.reduce(a, policy="baseline"),
            "tcu_full_scan": lambda a: dispatch.scan(a, policy="fused"),
            "base_full_scan": lambda a: dispatch.scan(a, policy="baseline"),
        }
        for name, fn in cases.items():
            st = time_stats(jax.jit(fn), x)
            t = st["median_s"]
            bytes_moved = ((n + 1) if name.endswith("reduce")
                           else 2 * n) * x.dtype.itemsize
            rows.append({
                "algo": name, "n": n,
                "us_per_call": round(t * 1e6, 1),
                "iqr_us": round(st["iqr_s"] * 1e6, 1),
                "iters": st["iters"], "warmup": st["warmup"],
                "belems_s": round(elems_per_sec(n, t) / 1e9, 3),
                **bandwidth_model(bytes_moved, t),
            })
    return rows


def main() -> None:
    rows = run()
    cols = ["algo", "n", "us_per_call", "iqr_us", "belems_s",
            "achieved_gbps", "pct_peak"]
    print_csv("fig13_14_full_reduce_scan", cols,
              [[r[c] for c in cols] for r in rows])
    write_bench_json("full_collectives", rows)


if __name__ == "__main__":
    main()
