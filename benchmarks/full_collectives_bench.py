"""Paper Figures 13/14: full (grid-level) reduction and scan vs input size.

The device-level composition (tile scan -> tile-totals scan -> carry add,
repro.core.tcu_scan's recursion) against XLA's native sum/cumsum, over
input sizes 2^16..2^24. All contenders via repro.core.dispatch paths.
"""
from __future__ import annotations

import jax

from benchmarks.common import elems_per_sec, print_csv, time_fn


def run() -> list:
    from repro.core import dispatch

    rows = []
    for log_n in range(16, 25, 2):
        n = 1 << log_n
        x = jax.random.normal(jax.random.PRNGKey(2), (n,))
        cases = {
            "tcu_full_reduce": lambda a: dispatch.reduce(a, policy="xla_tile"),
            "base_full_reduce": lambda a: dispatch.reduce(a, policy="baseline"),
            "tcu_full_scan": lambda a: dispatch.scan(a, policy="fused"),
            "base_full_scan": lambda a: dispatch.scan(a, policy="baseline"),
        }
        for name, fn in cases.items():
            t = time_fn(jax.jit(fn), x)
            rows.append([name, n, f"{t * 1e6:.1f}",
                         f"{elems_per_sec(n, t) / 1e9:.3f}"])
    return rows


def main() -> None:
    print_csv("fig13_14_full_reduce_scan",
              ["algo", "n", "us_per_call", "belems_s"], run())


if __name__ == "__main__":
    main()
