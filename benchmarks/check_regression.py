"""Diff two ``BENCH_*.json`` files and exit nonzero on regression.

Rows are keyed by their identifying fields — scheduler/contender, policy,
cache kind, workload, offered load, op/backend/band/dtype — whichever of
them a row carries; metric fields are compared with a relative tolerance.
Throughput-like metrics regress when the candidate drops below
``baseline * (1 - tol)``; latency-like metrics regress when it rises
above ``baseline * (1 + tol)``. Keys present in only one file are
reported but are not failures (benchmarks grow contenders), unless
``--require-keys`` is set.

This is the ROADMAP perf-trajectory gate's comparison engine: CI runs the
serving bench and diffs it against the checked-in ``BENCH_serving.json``,
and the segmented-scan kernel bench against the checked-in
``BENCH_segmented_scan.json`` (keyed by contender row + segment size).
CPU-container timings are noisy, so the CI legs pass a generous
tolerance — the gate's job until real-hardware rows land is catching
collapses (a scheduler stall, an accidental recompile per tick), not
single-digit-percent drift.

Usage:
    python benchmarks/check_regression.py BASELINE.json CANDIDATE.json \
        [--tol 0.25] [--metrics throughput_tok_s,p99_ms] [--require-keys]
"""
from __future__ import annotations

import argparse
import json
import sys

# identity fields, in display order (a row is keyed by those it carries)
KEY_FIELDS = ("bench", "scheduler", "contender", "name", "algo", "workload",
              "cache_kind", "policy", "offered_load", "op", "backend",
              "band", "dtype", "shape", "n", "segment_size", "n_segments",
              "seq_len", "mesh", "process_count")

# metric direction: regression = lower for these ...
HIGHER_BETTER = ("throughput_tok_s", "achieved_gbps", "pct_peak",
                 "gflops", "tokens_per_s", "belems_s", "ktok_s")
# ... and higher for these
LOWER_BETTER = ("p50_ms", "p99_ms", "p25_ms", "p75_ms", "iqr_ms",
                "median_us", "mean_us", "makespan_s", "peak_pages_in_use",
                "us_per_call", "iqr_us", "ms_per_call")


def row_key(row: dict) -> tuple:
    return tuple((f, row[f]) for f in KEY_FIELDS if f in row)


def load_rows(path: str) -> dict[tuple, dict]:
    with open(path) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    out = {}
    for row in rows:
        key = row_key(row)
        if key in out:
            raise SystemExit(f"{path}: duplicate row key {key}")
        out[key] = row
    return out


def compare(base: dict[tuple, dict], cand: dict[tuple, dict], *,
            tol: float, metrics: tuple[str, ...] | None = None):
    """-> (regressions, improvements, missing, added); each regression is
    (key, metric, baseline, candidate, limit)."""
    regressions, improvements = [], []
    missing = [k for k in base if k not in cand]
    added = [k for k in cand if k not in base]
    for key, brow in base.items():
        crow = cand.get(key)
        if crow is None:
            continue
        for metric, worse_is_lower in (
                [(m, True) for m in HIGHER_BETTER]
                + [(m, False) for m in LOWER_BETTER]):
            if metrics is not None and metric not in metrics:
                continue
            b, c = brow.get(metric), crow.get(metric)
            if not isinstance(b, (int, float)) or \
                    not isinstance(c, (int, float)) or \
                    isinstance(b, bool) or isinstance(c, bool):
                continue
            if worse_is_lower:
                limit = b * (1.0 - tol)
                if c < limit:
                    regressions.append((key, metric, b, c, limit))
                elif c > b * (1.0 + tol):
                    improvements.append((key, metric, b, c))
            else:
                limit = b * (1.0 + tol)
                if c > limit:
                    regressions.append((key, metric, b, c, limit))
                elif c < b * (1.0 - tol):
                    improvements.append((key, metric, b, c))
    return regressions, improvements, missing, added


def _fmt_key(key: tuple) -> str:
    return ",".join(f"{f}={v}" for f, v in key)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", help="reference BENCH_*.json")
    ap.add_argument("candidate", help="freshly measured BENCH_*.json")
    ap.add_argument("--tol", type=float, default=0.25,
                    help="relative tolerance (0.25 = 25%% headroom)")
    ap.add_argument("--metrics", default=None,
                    help="comma list restricting the compared metrics "
                         "(default: every known metric both rows carry)")
    ap.add_argument("--require-keys", action="store_true",
                    help="fail when a baseline row is missing from the "
                         "candidate (schema gate, not just perf)")
    args = ap.parse_args(argv)

    base = load_rows(args.baseline)
    cand = load_rows(args.candidate)
    metrics = tuple(args.metrics.split(",")) if args.metrics else None
    regs, imps, missing, added = compare(base, cand, tol=args.tol,
                                         metrics=metrics)

    for key, metric, b, c, limit in regs:
        print(f"REGRESSION {metric}: {b} -> {c} (limit {limit:.4g}) "
              f"[{_fmt_key(key)}]")
    for key, metric, b, c in imps:
        print(f"improvement {metric}: {b} -> {c} [{_fmt_key(key)}]")
    for key in missing:
        print(f"missing from candidate: [{_fmt_key(key)}]")
    for key in added:
        print(f"new in candidate: [{_fmt_key(key)}]")
    print(f"# compared {len(base)} baseline rows vs {len(cand)} candidate "
          f"rows at tol={args.tol}: {len(regs)} regressions, "
          f"{len(imps)} improvements, {len(missing)} missing, "
          f"{len(added)} added")
    if regs or (args.require_keys and missing):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
