"""Beyond-paper table: open-loop serving — continuous batching vs the wave
baseline on one synthetic Poisson workload.

The paper's small-segment reduce/scan primitives do the per-token math of
a decode step (softmax, RMSNorm, SSD); whether they stay busy is a
scheduling question. This benchmark drives both schedulers with the same
open-loop arrival trace — mixed prompt/output lengths with one
deliberately long sequence near the front — and reports throughput and
per-token completion latency (emission minus request arrival). The wave
scheduler strands short requests behind the long sequence's wave barrier;
the continuous scheduler refills each slot as it frees, so the p99 gap is
the checked-in number the refactor is judged by.

A second contender drives a *shared-prefix* workload (every request
extends one long system prompt) through the continuous scheduler under
both KV-cache layouts: the per-slot ring baseline and the paged
block-table pool (``cache_kind="paged"``, serving/kvpool.py) whose prefix
trie maps the common pages once and copy-on-writes on divergence. Every
row records ``cache_kind`` and ``peak_pages_in_use`` (from the
``repro_kvpool_peak_pages_in_use`` obs gauge when a session is active),
so the memory win — peak pages strictly below N x full-context — is a
checked-in number.

Writes ``BENCH_serving.json`` (one row per scheduler x offered load,
plus the shared-prefix cache rows) and prints the usual CSV block.
``--budget tiny`` is the CI smoke shape.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

try:
    from benchmarks.common import bandwidth_model, print_csv
except ModuleNotFoundError:     # run as a script: sys.path[0] is
    import os                   # benchmarks/, not the repo root
    import sys

    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.common import bandwidth_model, print_csv

BUDGETS = {
    # n_req, slots, short max_new range, long max_new, prefill_chunk, loads
    "tiny": dict(n_req=8, slots=2, short=(3, 7), long_new=24,
                 prefill_chunk=8, loads=(8.0,),
                 prefix_len=40, tail=4, prefix_new=6, prefix_ctx=64),
    "full": dict(n_req=24, slots=4, short=(4, 12), long_new=48,
                 prefill_chunk=16, loads=(4.0, 16.0),
                 prefix_len=96, tail=8, prefix_new=12, prefix_ctx=128),
}


def make_workload(n_req, rate, vocab, *, short, long_new, seed=0):
    """Poisson arrivals at ``rate`` req/s; prompts 4-24 tokens; short
    decode budgets except request 1, which is deliberately long (the wave
    barrier the continuous scheduler must not inherit)."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n_req):
        t += float(rng.exponential(1.0 / rate))
        max_new = long_new if i == 1 else int(rng.integers(*short))
        prompt = rng.integers(3, vocab, size=int(rng.integers(4, 24)),
                              dtype=np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new=max_new,
                            arrival_s=t))
    return reqs


def make_shared_prefix_workload(n_req, rate, vocab, *, prefix_len, tail,
                                max_new, seed=0):
    """Poisson arrivals where every prompt extends ONE ``prefix_len``-token
    system prompt with a short random tail — the paged cache's prefix trie
    maps the common pages once; the ring baseline re-prefills them per
    slot."""
    from repro.serving import Request

    rng = np.random.default_rng(seed)
    prefix = rng.integers(3, vocab, size=prefix_len, dtype=np.int32)
    reqs, t = [], 0.0
    for i in range(n_req):
        t += float(rng.exponential(1.0 / rate))
        prompt = np.concatenate(
            [prefix, rng.integers(3, vocab, size=tail, dtype=np.int32)]
        ).astype(np.int32)
        reqs.append(Request(uid=i, prompt=prompt, max_new=max_new,
                            arrival_s=t))
    return reqs


def _metrics(results):
    lats = [1e3 * (ts - r.arrival_s) for r in results for ts in r.token_s]
    total = sum(len(r.tokens) for r in results)
    makespan = (max(r.finish_s for r in results)
                - min(r.arrival_s for r in results))
    p25, p50, p75 = (float(x) for x in np.percentile(lats, (25, 50, 75)))
    return {
        "throughput_tok_s": round(total / max(makespan, 1e-9), 2),
        "p50_ms": round(p50, 2),
        "p99_ms": round(float(np.percentile(lats, 99)), 2),
        "p25_ms": round(p25, 2),
        "p75_ms": round(p75, 2),
        "iqr_ms": round(p75 - p25, 2),
        "total_tokens": total,
        "makespan_s": round(makespan, 4),
    }


def run(budget: str = "tiny", arch: str = "llama3.2-1b",
        policy=None, mesh_ctx=None) -> list[dict]:
    import jax

    from repro import configs
    from repro.models import build
    from repro.models.common import init_params
    from repro.serving import ServeConfig, ServingEngine

    shape = BUDGETS[budget]
    mod = configs.get(arch)
    cfg = mod.SMOKE
    bundle = build(cfg)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         cfg.dtype)
    # roofline proxy: a decode step streams the whole parameter set once
    # per generated token, which dominates traffic at batch sizes this
    # small — so bytes ~= param_bytes * total_tokens over the makespan
    param_bytes = sum(p.size * p.dtype.itemsize
                      for p in jax.tree.leaves(params))

    # sharded rows stay comparable to single-host history: every row
    # records the process count and the mesh shape it ran under
    mesh_label = "none" if mesh_ctx is None else mesh_ctx.label()

    def peak_pages(eng):
        """Peak pages-in-use: prefer the obs gauge (the number dashboards
        see), fall back to the engine's pool stats; None for ring."""
        from repro.obs import runtime as _obs

        kv = eng.kv_stats()
        if kv is None:
            return None
        if _obs.ACTIVE is not None:
            v = _obs.ACTIVE.gauge("repro_kvpool_peak_pages_in_use").value()
            if v is not None:
                return int(v)
        return kv.get("peak_pages_in_use", 0)

    def measure(eng, wl, base_row):
        eng.run(wl())                   # warmup: compiles out of the
        results = eng.run(wl())         # measured pass
        pol = eng.bundle.cfg.policy
        row = {"policy": "default" if pol is None else pol.label(),
               "n_req": shape["n_req"], "slots": shape["slots"],
               "arch": arch, "process_count": jax.process_count(),
               "mesh": mesh_label, "warmup_runs": 1, "measured_runs": 1,
               **base_row}
        row.update(_metrics(results))
        row.update(bandwidth_model(
            param_bytes * row["total_tokens"], row["makespan_s"]))
        row["peak_pages_in_use"] = peak_pages(eng)
        return row

    rows = []
    for rate in shape["loads"]:
        for sched in ("wave", "continuous"):
            if sched == "wave" and mesh_ctx is not None \
                    and jax.process_count() > 1:
                continue        # wave admission is per-host wall clock
            eng = ServingEngine(bundle, params, ServeConfig(
                slots=shape["slots"], max_new=16, eos_token=-1,
                scheduler=sched, prefill_chunk=shape["prefill_chunk"],
                policy=policy), mesh_ctx=mesh_ctx)
            wl = lambda: make_workload(
                shape["n_req"], rate, cfg.vocab,
                short=shape["short"], long_new=shape["long_new"])
            row = measure(eng, wl, {"scheduler": sched,
                                    "offered_load": rate,
                                    "workload": "mixed",
                                    "cache_kind": "ring"})
            if sched == "continuous":
                row["compiled_block_shapes"] = \
                    eng.compile_stats()["block"]
            rows.append(row)

        # shared-prefix contender: ring vs paged under the continuous
        # scheduler — the paged pool maps the common prompt pages once
        for kind in ("ring", "paged"):
            eng = ServingEngine(bundle, params, ServeConfig(
                slots=shape["slots"], max_new=shape["prefix_new"],
                eos_token=-1, scheduler="continuous",
                prefill_chunk=shape["prefill_chunk"],
                max_context=shape["prefix_ctx"], cache_kind=kind,
                policy=policy), mesh_ctx=mesh_ctx)
            wl = lambda: make_shared_prefix_workload(
                shape["n_req"], rate, cfg.vocab,
                prefix_len=shape["prefix_len"], tail=shape["tail"],
                max_new=shape["prefix_new"])
            row = measure(eng, wl, {"scheduler": "continuous",
                                    "offered_load": rate,
                                    "workload": "shared_prefix",
                                    "cache_kind": kind})
            if kind == "paged":
                kv = eng.kv_stats()
                row["pool_pages"] = kv["pages_total"]
                row["shared_prompt_tokens"] = kv["shared_tokens"]
                row["cow_copies"] = kv["cow_copies"]
            rows.append(row)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--budget", choices=tuple(BUDGETS), default="tiny")
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--mesh", default=None,
                    help="serve sharded: mesh axes as 'data=1,model=2' "
                         "(must multiply to the device count)")
    from repro.obs import cli as obs_cli

    obs_cli.add_obs_args(ap)
    args = ap.parse_args(argv if argv is not None else [])

    mesh_ctx = None
    if args.mesh:
        from repro.parallel.mesh_context import make_context

        mesh_ctx = make_context(args.mesh)
    # the obs scope opens before run(): the warmup pass is where the
    # engine compiles, so trace-time resolution events need it active
    with obs_cli.obs_scope(args):
        rows = run(args.budget, args.arch, mesh_ctx=mesh_ctx)
    cols = ["scheduler", "workload", "cache_kind", "offered_load",
            "throughput_tok_s", "p50_ms", "p99_ms", "iqr_ms",
            "achieved_gbps", "pct_peak", "total_tokens",
            "peak_pages_in_use"]
    print_csv("serving_open_loop",
              cols, [[r[c] for c in cols] for r in rows])
    with open(args.out, "w") as f:
        json.dump({"bench": "serving_open_loop", "budget": args.budget,
                   "arch": args.arch, "rows": rows}, f, indent=2)
    print(f"# wrote {args.out} ({len(rows)} rows)")


if __name__ == "__main__":
    import sys

    main(sys.argv[1:])
