"""Beyond-paper table: SSD (Mamba-2) chunked scan — the paper's weighted
scan at model scale — vs the sequential recurrence, over sequence length.

The chunked form is O(L/Q) matmul passes (all MXU work); the sequential
form is O(L) vector steps. This is the integration point that makes the
paper's technique land in two assigned architectures (mamba2, zamba2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (elems_per_sec, print_csv, select_paths,
                               time_fn, tuning_label)

CONTENDERS = {
    "ssd_chunked_matmul": "fused",
    "ssd_sequential": "baseline",
    "ssd_tile_kernel": "tile",   # Pallas kernel (TPU/Triton); skipped off-accelerator
}


def run() -> list:
    from repro.core import dispatch

    paths = select_paths(CONTENDERS)
    rows = []
    b, h, p, g, n = 2, 4, 64, 1, 64
    for log_l in (9, 11, 13):
        L = 1 << log_l
        ks = jax.random.split(jax.random.PRNGKey(log_l), 5)
        x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
        a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, L, g, n)) / jnp.sqrt(float(n))
        cc = jax.random.normal(ks[4], (b, L, g, n)) / jnp.sqrt(float(n))

        toks = b * L
        for name, path in paths.items():
            fn = jax.jit(lambda *t, p=path: dispatch.ssd(*t, policy=p))
            t1 = time_fn(fn, x, dt, a, bb, cc, iters=3)
            rows.append([name, L, f"{t1 * 1e3:.2f}",
                         f"{elems_per_sec(toks, t1) / 1e3:.1f}",
                         tuning_label(path, "ssd", L, x.dtype)])
    return rows


def main() -> None:
    print_csv("ssd_weighted_scan", ["algo", "seq_len", "ms_per_call",
                                    "ktok_s", "tuning"], run())


if __name__ == "__main__":
    main()
