"""Beyond-paper table: SSD (Mamba-2) chunked scan — the paper's weighted
scan at model scale — vs the sequential recurrence, over sequence length.

The chunked form is O(L/Q) matmul passes (all MXU work); the sequential
form is O(L) vector steps. This is the integration point that makes the
paper's technique land in two assigned architectures (mamba2, zamba2).
Rows carry median/IQR plus the roofline pair (operand reads + output
write) and land in ``BENCH_ssd.json``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (bandwidth_model, elems_per_sec, print_csv,
                               select_paths, time_stats, tuning_label,
                               write_bench_json)

CONTENDERS = {
    "ssd_chunked_matmul": "fused",
    "ssd_sequential": "baseline",
    "ssd_tile_kernel": "tile",   # Pallas kernel (TPU/Triton); skipped off-accelerator
    "ssd_logdepth_kernel": "tile_logdepth",  # log-depth MatMulScan glue
}


def run() -> list[dict]:
    from repro.core import dispatch

    paths = select_paths(CONTENDERS)
    rows = []
    b, h, p, g, n = 2, 4, 64, 1, 64
    for log_l in (9, 11, 13):
        L = 1 << log_l
        ks = jax.random.split(jax.random.PRNGKey(log_l), 5)
        x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
        a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (b, L, g, n)) / jnp.sqrt(float(n))
        cc = jax.random.normal(ks[4], (b, L, g, n)) / jnp.sqrt(float(n))

        toks = b * L
        # operand reads (x, dt, B, C) + output write (same shape as x)
        bytes_moved = (2 * x.size + dt.size + bb.size
                       + cc.size) * x.dtype.itemsize
        for name, path in paths.items():
            fn = jax.jit(lambda *t, p=path: dispatch.ssd(*t, policy=p))
            st = time_stats(fn, x, dt, a, bb, cc, iters=3)
            t1 = st["median_s"]
            rows.append({
                "algo": name, "seq_len": L,
                "ms_per_call": round(t1 * 1e3, 2),
                "iqr_ms": round(st["iqr_s"] * 1e3, 2),
                "iters": st["iters"], "warmup": st["warmup"],
                "ktok_s": round(elems_per_sec(toks, t1) / 1e3, 1),
                "tuning": tuning_label(path, "ssd", L, x.dtype),
                **bandwidth_model(bytes_moved, t1),
            })
    return rows


def main() -> None:
    rows = run()
    cols = ["algo", "seq_len", "ms_per_call", "iqr_ms", "ktok_s",
            "achieved_gbps", "pct_peak", "tuning"]
    print_csv("ssd_weighted_scan", cols,
              [[r[c] for c in cols] for r in rows])
    write_bench_json("ssd", rows)


if __name__ == "__main__":
    main()
