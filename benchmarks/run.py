"""Run every benchmark (one per paper table/figure) and print CSV blocks.

  python -m benchmarks.run            # all
  python -m benchmarks.run fig10      # substring filter
"""
from __future__ import annotations

import importlib
import sys
import time

BENCHES = [
    ("fig2_3_gemm_gemv", "benchmarks.gemm_bench"),
    ("fig10_segmented_reduce", "benchmarks.segmented_reduce_bench"),
    ("fig11_small_segments", "benchmarks.small_segment_bench"),
    ("fig12_segmented_scan", "benchmarks.segmented_scan_bench"),
    ("fig13_14_full_reduce_scan", "benchmarks.full_collectives_bench"),
    ("sec6_3_alu_mix_power_proxy", "benchmarks.alu_mix_bench"),
    ("ssd_weighted_scan", "benchmarks.ssd_bench"),
]


def main() -> None:
    pat = sys.argv[1] if len(sys.argv) > 1 else ""
    t0 = time.time()
    ran = 0
    for name, module in BENCHES:
        if pat and pat not in name:
            continue
        m = importlib.import_module(module)
        t = time.time()
        m.main()
        print(f"# [{name}] {time.time() - t:.1f}s")
        ran += 1
    print(f"\n# {ran} benchmarks in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
