"""Run every benchmark (one per paper table/figure) and print CSV blocks.

  python -m benchmarks.run                  # all
  python -m benchmarks.run fig10            # substring filter
  python -m benchmarks.run --backend gpu    # keep only this backend's
                                            # tile contenders; rows whose
                                            # path can't resolve on this
                                            # host are skipped, not fatal
  python -m benchmarks.run --policy reduce=tile,scan=baseline
                                            # pin per-op choices for the
                                            # sweep's "auto" rows (JSON
                                            # policy objects work too);
                                            # --kernel-path <label> is the
                                            # deprecated spelling of
                                            # --policy <label>
  python -m benchmarks.run --tune ssd.q=64  # override kernel geometry for
                                            # the tile contender rows (the
                                            # tuning= column shows what ran)
"""
from __future__ import annotations

import argparse
import importlib
import time

from benchmarks import common

BENCHES = [
    ("fig2_3_gemm_gemv", "benchmarks.gemm_bench"),
    ("fig10_segmented_reduce", "benchmarks.segmented_reduce_bench"),
    ("fig11_small_segments", "benchmarks.small_segment_bench"),
    ("fig12_segmented_scan", "benchmarks.segmented_scan_bench"),
    ("fig13_14_full_reduce_scan", "benchmarks.full_collectives_bench"),
    ("sec6_3_alu_mix_power_proxy", "benchmarks.alu_mix_bench"),
    ("ssd_weighted_scan", "benchmarks.ssd_bench"),
    ("serving_open_loop", "benchmarks.serving_bench"),
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("filter", nargs="?", default="",
                    help="substring filter on benchmark names")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "cpu", "gpu", "tpu"),
                    help="which backend's kernel contenders to include; "
                         "paths unresolvable on the current host are "
                         "skipped with a note instead of crashing")
    ap.add_argument("--policy", default=None,
                    help="KernelPolicy the sweep runs under: a path "
                         "label, an op=path,op=path override list (pins "
                         "per-op choices for the auto rows), or a JSON "
                         "object of policy fields")
    ap.add_argument("--tune", default=None,
                    help="per-op kernel tuning overrides layered on the "
                         "policy: op.knob=value pairs, e.g. "
                         "'ssd.q=64,reduce.block_n=256' (shown in each "
                         "benchmark's tuning= column)")
    ap.add_argument("--kernel-path", default=None,
                    help="deprecated alias for --policy <path-label>")
    ap.add_argument("--json-dir", default=".",
                    help="directory the BENCH_<name>.json row files land "
                         "in (created if missing)")
    from repro.obs import cli as obs_cli

    obs_cli.add_obs_args(ap)
    args = ap.parse_args(argv)
    common.set_bench_backend(args.backend)
    common.set_bench_json_dir(args.json_dir)

    from repro.core import policy as kpolicy

    pol = kpolicy.policy_from_cli(args.policy, args.kernel_path,
                                  "deprecated:benchmarks.run.kernel_path",
                                  tune_arg=args.tune)
    if pol is not None:
        kpolicy.set_policy(pol)

    with obs_cli.obs_scope(args):
        t0 = time.time()
        ran = 0
        for name, module in BENCHES:
            if args.filter and args.filter not in name:
                continue
            m = importlib.import_module(module)
            t = time.time()
            m.main()
            print(f"# [{name}] {time.time() - t:.1f}s")
            ran += 1
        print(f"\n# {ran} benchmarks in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
