"""Run every benchmark (one per paper table/figure) and print CSV blocks.

  python -m benchmarks.run                  # all
  python -m benchmarks.run fig10            # substring filter
  python -m benchmarks.run --backend gpu    # keep only this backend's
                                            # tile contenders; rows whose
                                            # path can't resolve on this
                                            # host are skipped, not fatal
"""
from __future__ import annotations

import argparse
import importlib
import time

from benchmarks import common

BENCHES = [
    ("fig2_3_gemm_gemv", "benchmarks.gemm_bench"),
    ("fig10_segmented_reduce", "benchmarks.segmented_reduce_bench"),
    ("fig11_small_segments", "benchmarks.small_segment_bench"),
    ("fig12_segmented_scan", "benchmarks.segmented_scan_bench"),
    ("fig13_14_full_reduce_scan", "benchmarks.full_collectives_bench"),
    ("sec6_3_alu_mix_power_proxy", "benchmarks.alu_mix_bench"),
    ("ssd_weighted_scan", "benchmarks.ssd_bench"),
]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("filter", nargs="?", default="",
                    help="substring filter on benchmark names")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "cpu", "gpu", "tpu"),
                    help="which backend's kernel contenders to include; "
                         "paths unresolvable on the current host are "
                         "skipped with a note instead of crashing")
    args = ap.parse_args(argv)
    common.set_bench_backend(args.backend)

    t0 = time.time()
    ran = 0
    for name, module in BENCHES:
        if args.filter and args.filter not in name:
            continue
        m = importlib.import_module(module)
        t = time.time()
        m.main()
        print(f"# [{name}] {time.time() - t:.1f}s")
        ran += 1
    print(f"\n# {ran} benchmarks in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
