"""Paper Figure 2/3: GEMM / GEMV throughput, matmul-unit vs vector path.

On the V100 the paper contrasted cuBLAS-with-TCU vs without; the TPU-native
analogue contrasts an MXU-shaped bf16 matmul (dims multiples of 128,
f32 accumulation) against the same computation forced through a vector
formulation (explicit multiply + sum — what the model code would do if the
reduction were NOT expressed as a matmul). GEMV = the paper's 'wasteful but
still winning' case: (M,K)x(K,128) with only one useful output column.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import print_csv, time_fn


def run() -> list:
    rows = []
    for m, n, k in ((256, 256, 256), (1024, 1024, 1024),
                    (2048, 2048, 2048)):
        a = jax.random.normal(jax.random.PRNGKey(0), (m, k),
                              jnp.bfloat16)
        b = jax.random.normal(jax.random.PRNGKey(1), (k, n), jnp.bfloat16)

        mm = jax.jit(lambda x, y: jax.lax.dot_general(
            x, y, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32))
        vec = jax.jit(lambda x, y: jnp.sum(
            x[:, :, None].astype(jnp.float32)
            * y[None, :, :].astype(jnp.float32), axis=1))
        flops = 2 * m * n * k
        cases = [("gemm_mxu", mm)]
        if m <= 512:          # vector form materialises (M,K,N) — cap it
            cases.append(("gemm_vector", vec))
        for name, fn in cases:
            t = time_fn(fn, a, b)
            rows.append([name, f"{m}x{n}x{k}", f"{t * 1e6:.1f}",
                         f"{flops / t / 1e9:.2f}"])

        # GEMV via a K=128-padded GEMM (the paper's HGEMV trick)
        v = jax.random.normal(jax.random.PRNGKey(2), (k, 1), jnp.bfloat16)
        vp = jnp.pad(v, ((0, 0), (0, 127)))
        gemv_pad = jax.jit(lambda x, y: jax.lax.dot_general(
            x, y, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[:, :1])
        gemv_vec = jax.jit(lambda x, y: jnp.einsum(
            "mk,ko->mo", x.astype(jnp.float32), y.astype(jnp.float32)))
        t1 = time_fn(gemv_pad, a, vp)
        t2 = time_fn(gemv_vec, a, v)
        gflops = 2 * m * k
        rows.append(["gemv_padded_gemm", f"{m}x1x{k}", f"{t1 * 1e6:.1f}",
                     f"{gflops / t1 / 1e9:.2f}"])
        rows.append(["gemv_vector", f"{m}x1x{k}", f"{t2 * 1e6:.1f}",
                     f"{gflops / t2 / 1e9:.2f}"])
    return rows


def main() -> None:
    print_csv("fig2_3_gemm_gemv", ["algo", "shape", "us_per_call",
                                   "gflops"], run())


if __name__ == "__main__":
    main()
