"""Benchmark harness plumbing: wall-clock timing of jit'd callables on this
CPU host plus derived model-level metrics.

Wall-clock numbers on a CPU container do not reproduce the paper's V100
throughput; what they DO establish (and what each benchmark asserts) is the
*shape* of the paper's claims: matmul-form vs element-form op counts, the
bandwidth-boundedness of reduction/scan, and the HLO-level ALU-mix proxy
for the power results. Every benchmark prints a CSV block
``name,<cols>`` followed by rows, and is one-to-one with a paper figure.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

# Which backend's contender set this run wants: "auto" = whatever the host
# resolves natively. Set by ``benchmarks.run --backend``.
BENCH_BACKEND = "auto"

# Where each benchmark's BENCH_<name>.json lands ("." = cwd). Set by
# ``benchmarks.run --json-dir`` so a sweep collects its machine-readable
# rows in one place for CI artifact upload.
BENCH_JSON_DIR = "."


def set_bench_backend(backend: str) -> None:
    global BENCH_BACKEND
    BENCH_BACKEND = backend


def set_bench_json_dir(directory: str) -> None:
    global BENCH_JSON_DIR
    BENCH_JSON_DIR = directory


def write_bench_json(bench: str, rows: list, meta: dict | None = None) -> str:
    """Persist one benchmark's rows as ``BENCH_<bench>.json`` under
    :data:`BENCH_JSON_DIR`. ``rows`` is a list of dicts with a stable
    per-benchmark schema (CI checks the serving one); ``meta`` merges into
    the top level alongside ``bench``/``rows``."""
    os.makedirs(BENCH_JSON_DIR, exist_ok=True)
    path = os.path.join(BENCH_JSON_DIR, f"BENCH_{bench}.json")
    with open(path, "w") as f:
        json.dump({"bench": bench, **(meta or {}), "rows": rows}, f,
                  indent=2)
    print(f"# wrote {path} ({len(rows)} rows)")
    return path


def select_paths(labels: dict[str, str]) -> dict[str, str]:
    """Filter contender rows to dispatch paths resolvable on this host.

    ``labels`` maps row name -> ``repro.core.dispatch`` path label. Rows
    that cannot run here are skipped with a printed note instead of
    crashing the sweep: labels that raise on resolution (``tile_gpu`` on a
    CPU host), labels for a backend other than the one ``--backend``
    requested, and the generic ``tile`` when it would silently downgrade
    to the Pallas interpreter (orders of magnitude slower than anything it
    would be compared against — a downgraded row is noise, not data).
    """
    import dataclasses

    from repro.core import policy as kpolicy

    # probe under interpret_fallback="silent": resolution only, nothing
    # runs, and the one-time downgrade warning stays unconsumed for a
    # later genuine path="tile" execution
    probe = dataclasses.replace(kpolicy.get_policy(),
                                interpret_fallback="silent")
    out = {}
    for name, path in labels.items():
        try:
            resolved = probe.resolve(explicit=path)
        except (RuntimeError, ValueError):
            print(f"# skip {name}: path={path!r} unresolvable on this host "
                  f"(backend={jax.default_backend()})")
            continue
        if BENCH_BACKEND != "auto" and resolved in ("tile_tpu", "tile_gpu") \
                and resolved != f"tile_{BENCH_BACKEND}":
            print(f"# skip {name}: path={path!r} resolves to {resolved!r}, "
                  f"not in the requested --backend {BENCH_BACKEND} "
                  "contender set")
            continue
        if resolved == "interpret" and path != "interpret":
            print(f"# skip {name}: path={path!r} downgrades to the Pallas "
                  "interpreter here (no native lowering)")
            continue
        if resolved == "tile_logdepth":
            # the label survives resolution even off-accelerator (only its
            # local block kernels drop to the interpreter), so the
            # downgrade is detected by re-probing under the strict policy
            try:
                dataclasses.replace(
                    probe, interpret_fallback="error").resolve(explicit=path)
            except RuntimeError:
                print(f"# skip {name}: path={path!r} runs its local block "
                      "kernels through the Pallas interpreter here (no "
                      "native lowering)")
                continue
        out[name] = path
    return out


def tuning_label(path: str, op: str, n: int | None = None,
                 dtype=None) -> str:
    """The TuneSpec the active policy resolves for one contender row.

    Compact ``"knob=value;..."`` form for the benchmark's ``tuning=``
    column; ``"-"`` for rows whose path runs no Pallas kernel (the XLA
    forms have no block geometry) or cannot resolve on this host. This is
    the same resolution pass the kernel call will make — including the
    bucket-axis clamp — so the segment-axis knobs shown are the geometry
    that ran (row-axis knobs can still shrink inside the glue when the
    batch is smaller than the block).
    """
    import dataclasses

    from repro.core import policy as kpolicy

    probe = dataclasses.replace(kpolicy.get_policy(),
                                interpret_fallback="silent")
    try:
        # the "auto" rows execute with policy=None (ambient resolution),
        # so their label must probe the same way — an explicit "auto"
        # would ignore the active policy's path/op_paths
        resolved = probe.resolve(op=op, n=n, dtype=dtype,
                                 explicit=None if path == "auto" else path)
    except (RuntimeError, ValueError):
        return "-"
    if resolved not in ("tile_tpu", "tile_gpu", "tile_logdepth",
                        "interpret"):
        return "-"
    spec = resolved.tuning
    return spec.label() if spec is not None else "-"


def time_stats(fn, *args, iters: int = 5, warmup: int = 2) -> dict:
    """Wall-clock statistics per call of an already-jit'd fn.

    The ``warmup`` calls run first and are *discarded* — they absorb the
    jit compile and any first-touch allocation, so the measured ``iters``
    time steady state only. Reports the median with the interquartile
    range (p25/p75) rather than a bare mean: serving-container wall
    clocks have heavy-tailed noise, and every bench row records the
    ``iters``/``warmup`` that produced it so two runs are comparable.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    p25, p50, p75 = (float(x) for x in np.percentile(ts, (25, 50, 75)))
    return {"median_s": p50, "p25_s": p25, "p75_s": p75,
            "iqr_s": p75 - p25, "iters": iters, "warmup": warmup}


def time_fn(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall seconds per call of an already-jit'd fn (compile and
    warmup discarded — see :func:`time_stats`)."""
    return time_stats(fn, *args, iters=iters, warmup=warmup)["median_s"]


def elems_per_sec(n_elems: int, seconds: float) -> float:
    return n_elems / max(seconds, 1e-12)


# ---------------------------------------------------------------------------
# bandwidth / roofline model
#
# Reduction and scan are bandwidth-bound (the paper's premise): the useful
# work per element is O(1), so the honest cross-machine metric is achieved
# memory bandwidth against the host's peak, not raw wall clock. The peaks
# below are deliberately round defaults per backend class; a real
# measurement host overrides with REPRO_PEAK_GBPS (note: NOT one of the
# policy env vars — those are parsed only by repro.core.policy).

DEFAULT_PEAK_GBPS = {"cpu": 50.0, "gpu": 900.0, "tpu": 1200.0}
ENV_PEAK_GBPS = "REPRO_PEAK_GBPS"


def peak_gbps() -> float:
    """This host's assumed peak memory bandwidth in GB/s:
    ``$REPRO_PEAK_GBPS`` if set, else a per-backend-class default."""
    env = os.environ.get(ENV_PEAK_GBPS, "").strip()
    if env:
        return float(env)
    b = jax.default_backend()
    b = "gpu" if b in ("cuda", "rocm") else b
    return DEFAULT_PEAK_GBPS.get(b, DEFAULT_PEAK_GBPS["cpu"])


def bandwidth_model(bytes_moved: int, seconds: float) -> dict:
    """Roofline annotation for one timed kernel call: achieved GB/s for
    ``bytes_moved`` (the op's minimal read+write traffic) against this
    host's :func:`peak_gbps`."""
    peak = peak_gbps()
    achieved = bytes_moved / max(seconds, 1e-12) / 1e9
    return {"bytes_moved": int(bytes_moved),
            "achieved_gbps": round(achieved, 4),
            "peak_gbps": peak,
            "pct_peak": round(100.0 * achieved / peak, 3)}


def hlo_op_mix(fn, *args) -> dict:
    """Loop-aware op-mix from the compiled HLO (the paper's §6.3 proxy:
    count matmul-form vs vector-ALU work)."""
    import sys
    sys.path.insert(0, "src")
    from repro.launch.hlo_analysis import (ELEMWISE_1, _instr_flops,
                                           parse_computations, analyse)

    compiled = jax.jit(fn).lower(*args).compile()
    txt = compiled.as_text()
    h = analyse(txt)
    comps = parse_computations(txt)
    dot_flops = 0.0
    vpu_flops = 0.0
    for comp in comps.values():
        for instr in comp.instrs:
            f = _instr_flops(instr, comp)
            if instr.opcode in ("dot", "convolution"):
                dot_flops += f
            else:
                vpu_flops += f
    return {"total_flops": h["flops"], "dot_flops": dot_flops,
            "vpu_flops": vpu_flops, "memory_bytes": h["memory_bytes"]}


def print_csv(name: str, cols: list, rows: list) -> None:
    print(f"\n# {name}")
    print(",".join(cols))
    for row in rows:
        print(",".join(str(x) for x in row))
