"""Paper Figure 12: segmented scan throughput vs segment size.

Contenders (one switch, repro.core.dispatch): the matmul-form scan
(path="fused") vs XLA's native ``jnp.cumsum`` (path="baseline", the Thrust
stand-in) vs the explicit Pallas kernel (path="tile" — TPU or Triton,
skipped where no native lowering exists). Fixed 2^22-element input.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (elems_per_sec, print_csv, select_paths,
                               time_fn, tuning_label)

TOTAL = 1 << 22

CONTENDERS = {
    "tcu_scan": "fused",
    "baseline_cumsum": "baseline",
    "tile_kernel": "tile",
}


def run(total: int = TOTAL) -> list:
    from repro.core import dispatch

    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (total,), jnp.float32)
    paths = select_paths(CONTENDERS)
    for log_seg in range(4, 19, 2):
        seg = 1 << log_seg
        segs = total // seg
        xs = x.reshape(segs, seg)
        fns = {
            name: jax.jit(lambda a, p=p: dispatch.scan(a, policy=p))
            for name, p in paths.items()
        }
        for name, fn in fns.items():
            t = time_fn(fn, xs)
            rows.append([name, seg, segs, f"{t * 1e6:.1f}",
                         f"{elems_per_sec(total, t) / 1e9:.3f}",
                         tuning_label(paths[name], "scan", seg, xs.dtype)])
    return rows


def main() -> None:
    print_csv("fig12_segmented_scan",
              ["algo", "segment_size", "n_segments", "us_per_call",
               "belems_s", "tuning"], run())


if __name__ == "__main__":
    main()
