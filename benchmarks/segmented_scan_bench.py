"""Paper Figure 12: segmented scan throughput vs segment size.

Contenders (one switch, repro.core.dispatch): the matmul-form scan
(path="fused") vs XLA's native ``jnp.cumsum`` (path="baseline", the Thrust
stand-in) vs the explicit Pallas kernel (path="tile") vs the log-depth
MatMulScan kernel (path="tile_logdepth") — the Pallas rows are skipped
where no native lowering exists. Fixed 2^22-element input.

Scan reads and writes every element, so the minimal-traffic roofline model
is 2x the input bytes; each row carries the median/IQR over ``iters``
post-warmup calls and lands in ``BENCH_segmented_scan.json``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import (bandwidth_model, elems_per_sec, print_csv,
                               select_paths, time_stats, tuning_label,
                               write_bench_json)

TOTAL = 1 << 22

CONTENDERS = {
    "tcu_scan": "fused",
    "baseline_cumsum": "baseline",
    "tile_kernel": "tile",
    "logdepth_kernel": "tile_logdepth",
}


def run(total: int = TOTAL) -> list[dict]:
    from repro.core import dispatch

    rows = []
    x = jax.random.normal(jax.random.PRNGKey(0), (total,), jnp.float32)
    paths = select_paths(CONTENDERS)
    for log_seg in range(4, 19, 2):
        seg = 1 << log_seg
        segs = total // seg
        xs = x.reshape(segs, seg)
        fns = {
            name: jax.jit(lambda a, p=p: dispatch.scan(a, policy=p))
            for name, p in paths.items()
        }
        # scan writes a prefix per element: read all + write all
        bytes_moved = 2 * total * xs.dtype.itemsize
        for name, fn in fns.items():
            st = time_stats(fn, xs)
            t = st["median_s"]
            rows.append({
                "algo": name, "segment_size": seg, "n_segments": segs,
                "us_per_call": round(t * 1e6, 1),
                "iqr_us": round(st["iqr_s"] * 1e6, 1),
                "iters": st["iters"], "warmup": st["warmup"],
                "belems_s": round(elems_per_sec(total, t) / 1e9, 3),
                "tuning": tuning_label(paths[name], "scan", seg, xs.dtype),
                **bandwidth_model(bytes_moved, t),
            })
    return rows


def main() -> None:
    rows = run()
    cols = ["algo", "segment_size", "n_segments", "us_per_call", "iqr_us",
            "belems_s", "achieved_gbps", "pct_peak", "tuning"]
    print_csv("fig12_segmented_scan", cols,
              [[r[c] for c in cols] for r in rows])
    write_bench_json("segmented_scan", rows, {"total_elems": TOTAL})


if __name__ == "__main__":
    main()
