"""Training-substrate tests: optimizer math, grad accumulation equivalence,
gradient compression unbiasedness, loss goes down end-to-end."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.common import smoke_batch
from repro.models import build
from repro.optim import (
    OptConfig,
    adamw_update,
    global_norm,
    init_opt_state,
    lr_at,
    stochastic_round_bf16,
)
from repro.optim.adafactor import adafactor_update, init_adafactor_state
from repro.optim.compress import compress_grads
from repro.training import TrainConfig, init_train_state, make_train_step


def test_lr_schedule():
    cfg = OptConfig(peak_lr=1e-3, warmup_steps=10, decay_steps=100,
                    min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.int32(0))) == 0.0
    assert np.isclose(float(lr_at(cfg, jnp.int32(10))), 1e-3)
    assert np.isclose(float(lr_at(cfg, jnp.int32(100))), 1e-4, rtol=1e-3)
    mid = float(lr_at(cfg, jnp.int32(55)))
    assert 1e-4 < mid < 1e-3


def test_global_norm_matmul_form():
    tree = {"a": jnp.ones((7, 11)), "b": -2.0 * jnp.ones((5,))}
    want = np.sqrt(7 * 11 * 1.0 + 5 * 4.0)
    np.testing.assert_allclose(float(global_norm(tree)), want, rtol=1e-5)


def test_adamw_scalar_reference():
    """One AdamW step on a scalar against the textbook update."""
    cfg = OptConfig(peak_lr=1e-1, warmup_steps=0, decay_steps=10**9,
                    b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                    clip_norm=1e9)
    p = {"w": jnp.float32(2.0)}
    g = {"w": jnp.float32(0.5)}
    state = init_opt_state(p, cfg)
    new_p, state, _ = adamw_update(g, state, p, cfg)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    update = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    np.testing.assert_allclose(float(new_p["w"]), 2.0 - 0.1 * update,
                               rtol=1e-5)


def test_adamw_weight_decay_decoupled():
    cfg = OptConfig(peak_lr=1e-1, warmup_steps=0, decay_steps=10**9,
                    weight_decay=0.1, clip_norm=1e9)
    p = {"w": jnp.float32(1.0)}
    g = {"w": jnp.float32(0.0)}
    state = init_opt_state(p, cfg)
    new_p, _, _ = adamw_update(g, state, p, cfg)
    # zero grad: only decay acts -> w - lr * wd * w
    np.testing.assert_allclose(float(new_p["w"]), 1.0 - 0.1 * 0.1 * 1.0,
                               rtol=1e-5)


def test_grad_clipping():
    cfg = OptConfig(peak_lr=0.0, clip_norm=1.0)
    p = {"w": jnp.ones((100,))}
    g = {"w": 10.0 * jnp.ones((100,))}
    state = init_opt_state(p, cfg)
    _, _, metrics = adamw_update(g, state, p, cfg)
    np.testing.assert_allclose(float(metrics["grad_norm"]), 100.0, rtol=1e-4)


def test_adafactor_memory_factored():
    p = {"w": jnp.ones((16, 32)), "b": jnp.ones((8,))}
    cfg = OptConfig()
    st = init_adafactor_state(p, cfg)
    assert st["v"]["w"]["vr"].shape == (16,)
    assert st["v"]["w"]["vc"].shape == (32,)
    assert st["v"]["b"]["v"].shape == (8,)
    g = {"w": 0.1 * jnp.ones((16, 32)), "b": 0.1 * jnp.ones((8,))}
    new_p, st2, m = adafactor_update(g, st, p, cfg)
    assert np.isfinite(float(m["grad_norm"]))
    assert bool(jnp.all(new_p["w"] < p["w"]))    # positive grad -> decrease


def test_stochastic_round_unbiased():
    x = jnp.full((20000,), 1.0 + 2.0 ** -9)      # exactly between bf16 steps
    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    means = [float(jnp.mean(stochastic_round_bf16(x, k).astype(jnp.float32)))
             for k in keys]
    np.testing.assert_allclose(np.mean(means), 1.0 + 2.0 ** -9, rtol=1e-4)


def test_compress_error_feedback_closes():
    """grads + error_buffer must telescope: q_t + e_t == g_t + e_{t-1}."""
    g = {"w": jnp.float32(1.0) + jnp.arange(100, dtype=jnp.float32) * 1e-4}
    q, e = compress_grads(g, None, jax.random.PRNGKey(0))
    recon = q["w"].astype(jnp.float32) + e["w"]
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g["w"]),
                               rtol=1e-6)


def test_grad_accumulation_equivalence():
    """microbatches=2 must match microbatches=1 (mean-of-means)."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=100)
    batch = smoke_batch(mod.SMOKE)

    outs = {}
    for nmb in (1, 2):
        tc = TrainConfig(microbatches=nmb)
        state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg, tc)
        step = jax.jit(make_train_step(bundle, opt_cfg, tc))
        new_state, metrics = step(state, batch)
        outs[nmb] = (float(metrics["loss"]),
                     jax.tree.leaves(new_state["params"]))
    np.testing.assert_allclose(outs[1][0], outs[2][0], rtol=1e-5)
    for a, b in zip(outs[1][1], outs[2][1]):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-3, atol=1e-5)


def test_loss_decreases_20_steps():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    opt_cfg = OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=40)
    state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
    step = jax.jit(make_train_step(bundle, opt_cfg))
    batch = smoke_batch(mod.SMOKE)
    losses = []
    for _ in range(20):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_compressed_training_still_learns():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    opt_cfg = OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=40)
    tc = TrainConfig(compress_grads=True)
    state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg, tc)
    assert "err" in state
    step = jax.jit(make_train_step(bundle, opt_cfg, tc))
    batch = smoke_batch(mod.SMOKE)
    losses = []
    for _ in range(15):
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses
