"""Host-side unit tests for the paged KV-cache subsystem
(serving/kvpool.py): page pool refcounts and limbo, prefix-trie
match/register/evict, manager admission with reservations, deferral,
CoW planning, and release accounting — plus the check_regression
comparison engine the CI serving gate runs on. No device work here;
the device-exactness tests live in test_serving.py."""
import numpy as np
import pytest

from repro.kernels.layout import KV_PAGE_ROWS, SUBLANES
from repro.serving import PagedKVManager, PagePool, PrefixTrie
from repro.serving.kvpool import validate_page_rows

R = KV_PAGE_ROWS


# ---------------------------------------------------------------------------
# geometry


def test_page_rows_come_from_layout():
    """KV_PAGE_ROWS is owned by kernels/layout.py and must satisfy its own
    validator: a power-of-two multiple of the sublane tile."""
    assert validate_page_rows(KV_PAGE_ROWS) == KV_PAGE_ROWS
    assert KV_PAGE_ROWS % SUBLANES == 0
    for bad in (0, SUBLANES - 1, SUBLANES * 3, SUBLANES + 1):
        with pytest.raises(ValueError, match="power-of-two"):
            validate_page_rows(bad)


# ---------------------------------------------------------------------------
# page pool


def test_pool_alloc_free_refcount():
    pool = PagePool(3)
    a, b = pool.alloc(), pool.alloc()
    assert (a, b) == (0, 1)               # deterministic: page 0 first
    assert pool.in_use == 2 and pool.free_count == 1
    pool.incref(a)
    assert pool.refcount(a) == 2 and pool.shared_count() == 1
    assert not pool.decref(a)             # still referenced
    assert pool.decref(a)                 # now free again
    assert pool.free_count == 2
    c, d = pool.alloc(), pool.alloc()
    assert c is not None and d is not None
    assert pool.alloc() is None           # exhausted -> None, not raise
    assert pool.peak_in_use == 3
    pool.decref(b)
    with pytest.raises(AssertionError):
        pool.decref(b)                    # double free is a bug


def test_pool_defer_free_limbo():
    """defer_free pools park freed pages in limbo until flush(): a
    snapshot freed this tick may still be read by this tick's block step,
    so its page must not be reallocated before end_tick."""
    pool = PagePool(1, defer_free=True)
    a = pool.alloc()
    assert pool.decref(a)
    assert pool.alloc() is None           # in limbo, not allocatable
    pool.flush()
    assert pool.alloc() == a


# ---------------------------------------------------------------------------
# prefix trie


def _toks(n, seed=0):
    rng = np.random.default_rng(seed)
    return tuple(int(t) for t in rng.integers(3, 250, size=n))


def test_trie_match_register_full_and_partial():
    pool = PagePool(8)
    trie = PrefixTrie(R)
    prompt = _toks(2 * R + 3)
    pages = [pool.alloc() for _ in range(3)]
    trie.register(prompt, pages, None, pool, tail_rows=3)
    # the trie increfs what it stores: owner release must not free them
    assert all(pool.refcount(p) == 2 for p in pages)

    # identical prompt, capped at plen-1: both full pages + a 2-row lcp
    # of the partial tail
    m = trie.match(prompt, need_state=False, max_len=len(prompt) - 1)
    assert m.length == 2 * R + 2
    assert m.kv_pages == [(pages[0], R), (pages[1], R), (pages[2], 2)]

    # divergence inside page 1: only page 0 shared (full pages are
    # all-or-nothing boundaries; sub-page runs only match on the tail)
    div = prompt[:R + 1] + (255,) + prompt[R + 2:]
    m = trie.match(div, need_state=False, max_len=len(div) - 1)
    assert m.length == R and m.kv_pages == [(pages[0], R)]

    # need_state: no snapshot registered anywhere -> no match at all
    m = trie.match(prompt, need_state=True, max_len=len(prompt) - 1)
    assert m.length == 0 and m.state_page is None


def test_trie_state_requires_exact_boundary():
    """A snapshot is valid only at exactly its capture length: sharers
    must extend the whole registered prompt, and a partial entry matches
    in full or not at all."""
    kv_pool, st_pool = PagePool(4), PagePool(2, defer_free=True)
    trie = PrefixTrie(R)
    prompt = _toks(R + 5)
    pages = [kv_pool.alloc(), kv_pool.alloc()]
    sp = st_pool.alloc()
    trie.register(prompt, pages, sp, kv_pool, tail_rows=5)
    assert trie.has_state_at(prompt)

    # extension of the whole prompt: state boundary at plen
    ext = prompt + _toks(4, seed=9)
    m = trie.match(ext, need_state=True, max_len=len(ext) - 1)
    assert m.length == len(prompt) and m.state_page == sp

    # diverging inside the partial tail: no full-entry match -> nothing
    div = prompt[:-1] + (255, 7)
    m = trie.match(div, need_state=True, max_len=len(div) - 1)
    assert m.length == 0
    # ... though attention-only matching still shares the lcp
    m = trie.match(div, need_state=False, max_len=len(div) - 1)
    assert m.length == R + 4


def test_trie_register_first_writer_wins():
    pool = PagePool(8)
    trie = PrefixTrie(R)
    prompt = _toks(R)
    a = pool.alloc()
    trie.register(prompt, [a], None, pool, tail_rows=R)
    b = pool.alloc()
    newly, _ = trie.register(prompt, [b], None, pool, tail_rows=R)
    assert newly == 0                     # duplicate: b not referenced
    assert pool.refcount(a) == 2 and pool.refcount(b) == 1
    m = trie.match(prompt + (9,), need_state=False, max_len=R)
    assert m.kv_pages == [(a, R)]


def test_trie_evict_lru_respects_protection():
    pool = PagePool(4)
    trie = PrefixTrie(R)
    old, new = _toks(R, seed=1), _toks(R, seed=2)
    p_old, p_new = pool.alloc(), pool.alloc()
    trie.register(old, [p_old], None, pool, tail_rows=R)
    trie.register(new, [p_new], None, pool, tail_rows=R)
    pool.decref(p_old), pool.decref(p_new)    # owners released
    # protect the LRU entry: eviction must take the newer one instead
    ent = trie.root.children[old]
    freed, _ = trie.evict(pool, PagePool(1), need_kv=1,
                          protect={id(ent)})
    assert freed == 1
    assert old in trie.root.children          # protected entry survives
    assert new not in trie.root.children


def test_trie_evict_does_not_free_live_pages():
    """Eviction drops the trie entry but a page a live slot still maps
    is merely un-shared, never returned to the free list."""
    pool = PagePool(2)
    trie = PrefixTrie(R)
    p = pool.alloc()
    trie.register(_toks(R), [p], None, pool, tail_rows=R)   # trie: rc 2
    freed, _ = trie.evict(pool, PagePool(1), need_kv=1)
    assert freed == 0 and trie.n_entries == 0
    assert pool.refcount(p) == 1              # the "slot" still owns it


# ---------------------------------------------------------------------------
# manager


def _mgr(pool_pages=8, maxpages=4, slots=2, **kw):
    return PagedKVManager(slots=slots, page_rows=R, maxpages=maxpages,
                          pool_pages=pool_pages, family="dense", **kw)


def test_manager_admit_reserves_and_allocates_lazily():
    mgr = _mgr()
    start = mgr.admit(0, _toks(R + 2), budget=4, uid=7)
    assert start == 0                     # empty trie: nothing shared
    assert mgr.kv.in_use == 0             # allocation is lazy
    assert mgr._outstanding == 2          # ceil((R+2+4)/R) pages reserved
    plan = mgr.plan_tick({0: R})          # first prefill chunk
    assert mgr.kv.in_use == 1 and mgr._outstanding == 1
    assert plan["tables"].shape == (2, 4)
    assert (plan["kv_copy"] == np.arange(8)).all()   # no CoW yet
    mgr.advance(0, R)
    mgr.plan_tick({0: 2})
    assert mgr.kv.in_use == 2 and mgr._outstanding == 0
    mgr.advance(0, 2)
    mgr.release(0)
    assert mgr.kv.in_use == 0 and mgr._outstanding == 0


def test_manager_defers_when_pool_cannot_cover():
    mgr = _mgr(pool_pages=3, maxpages=4)
    assert mgr.admit(0, _toks(R), budget=2 * R) is not None   # 3 pages
    assert mgr.admit(1, _toks(R, seed=5), budget=0) is None   # deferred
    assert mgr.stats()["defers"] == 1
    # the freed reservation makes the retry succeed
    mgr.release(0)
    assert mgr.admit(1, _toks(R, seed=5), budget=0) is not None


def test_manager_eviction_recycles_trie_pages():
    """Pages held only by the trie are evicted to cover a new admission;
    pages a live slot maps survive eviction."""
    mgr = _mgr(pool_pages=2, maxpages=2, slots=1)
    prompt = _toks(R)
    mgr.admit(0, prompt, budget=0)
    mgr.plan_tick({0: R})
    mgr.advance(0, R)
    mgr.mark_prefilled(0)                 # full page registered in trie
    mgr.release(0)
    assert mgr.kv.in_use == 1             # trie keeps the prompt page
    # a different prompt needing 2 pages: must evict the trie entry
    assert mgr.admit(0, _toks(R, seed=4), budget=R) is not None
    assert mgr.stats()["evictions"] == 1
    assert mgr.trie.n_entries == 0


def test_manager_shared_prefix_and_cow():
    """Sharer maps registered pages without new allocations; its first
    write into the shared partial-tail page triggers CoW with a
    device-copy entry, and the trie's original page stays intact."""
    mgr = _mgr(pool_pages=6, maxpages=4)
    prompt = _toks(R + 2)                 # full page + 2-row tail
    mgr.admit(0, prompt, budget=0, uid=0)
    mgr.plan_tick({0: R + 2})
    p0, p1 = int(mgr.tables[0, 0]), int(mgr.tables[0, 1])
    mgr.advance(0, R + 2)
    mgr.mark_prefilled(0)                 # registers page + partial tail
    mgr.release(0)
    assert mgr.kv.refcount(p0) == 1 and mgr.kv.refcount(p1) == 1

    # sharer extends the registered prompt: full page + 2-row tail map
    sharer = prompt + _toks(3, seed=8)
    start = mgr.admit(1, sharer, budget=2, uid=1)
    assert start == R + 2
    assert int(mgr.tables[1, 0]) == p0 and int(mgr.tables[1, 1]) == p1
    assert mgr.kv.refcount(p0) == 2       # trie + sharer
    assert mgr.stats()["shared_tokens"] == R + 2

    # the sharer's remaining prompt rows land in the tail page: CoW
    plan = mgr.plan_tick({1: len(sharer) - start})
    new = int(mgr.tables[1, 1])
    assert new != p1
    assert plan["kv_copy"][new] == p1     # device copies old -> new
    assert mgr.kv.refcount(p1) == 1       # trie keeps the original
    assert mgr.stats()["cow_copies"] == 1
    assert int(mgr.tables[1, 0]) == p0    # untouched page still shared
    mgr.release(1)
    assert mgr.kv.refcount(p0) == 1       # trie only — alive for reuse


def test_manager_exhaustion_raises_only_without_reservation():
    """The RuntimeError path is a genuine invariant breach (allocating
    past every reservation), not reachable through admit's deferral."""
    mgr = _mgr(pool_pages=1, maxpages=4, slots=1)
    rec_prompt = _toks(2)
    assert mgr.admit(0, rec_prompt, budget=1) is not None
    mgr.plan_tick({0: 2})
    # forge an out-of-contract allocation: no pages left, empty trie
    rec = mgr._recs[0]
    with pytest.raises(RuntimeError, match="pool_pages"):
        mgr._alloc_kv(rec, 0, "new")


def test_manager_wrap_reuses_table_entries():
    """Generation past maxpages*R ring-recycles the block table in place
    (sole owner): no extra pages, pos keeps counting."""
    mgr = _mgr(pool_pages=4, maxpages=2, slots=1)
    mgr.admit(0, _toks(4), budget=4 * R)  # wraps: reservation = maxpages
    assert mgr._outstanding == 2
    pos = 0
    for take in (4,) + (R,) * 3:
        mgr.plan_tick({0: take})
        mgr.advance(0, take)
        pos += take
    assert mgr.kv.in_use == 2             # table is full, recycled in place
    assert mgr._recs[0].pos == pos


# ---------------------------------------------------------------------------
# check_regression (the CI gate's comparison engine)


def test_check_regression_compare_and_exit_codes(tmp_path, capsys):
    import json
    import sys

    sys.path.insert(0, "benchmarks")
    try:
        from check_regression import compare, load_rows, main, row_key
    finally:
        sys.path.pop(0)

    base_rows = [
        {"scheduler": "continuous", "workload": "mixed",
         "cache_kind": "ring", "offered_load": 8.0,
         "throughput_tok_s": 100.0, "p99_ms": 50.0},
        {"scheduler": "wave", "workload": "mixed", "cache_kind": "ring",
         "offered_load": 8.0, "throughput_tok_s": 40.0, "p99_ms": 900.0},
    ]
    good = [dict(base_rows[0], throughput_tok_s=95.0, p99_ms=55.0),
            dict(base_rows[1])]
    bad = [dict(base_rows[0], throughput_tok_s=40.0),   # collapse
           dict(base_rows[1], p99_ms=3000.0)]

    b = {row_key(r): r for r in base_rows}
    regs, imps, missing, added = compare(
        b, {row_key(r): r for r in good}, tol=0.25)
    assert not regs and not missing and not added
    regs, _, _, _ = compare(b, {row_key(r): r for r in bad}, tol=0.25)
    assert {(m, bv) for _, m, bv, _, _ in regs} == {
        ("throughput_tok_s", 100.0), ("p99_ms", 900.0)}

    base_p = tmp_path / "base.json"
    base_p.write_text(json.dumps({"rows": base_rows}))
    good_p = tmp_path / "good.json"
    good_p.write_text(json.dumps({"rows": good}))
    bad_p = tmp_path / "bad.json"
    bad_p.write_text(json.dumps({"rows": bad}))
    assert main([str(base_p), str(good_p), "--tol", "0.25"]) == 0
    assert main([str(base_p), str(bad_p), "--tol", "0.25"]) == 1
    out = capsys.readouterr().out
    assert "REGRESSION" in out

    # a vanished row only fails under --require-keys
    short_p = tmp_path / "short.json"
    short_p.write_text(json.dumps({"rows": good[:1]}))
    assert main([str(base_p), str(short_p), "--tol", "0.25"]) == 0
    assert main([str(base_p), str(short_p), "--tol", "0.25",
                 "--require-keys"]) == 1
    # duplicate keys are a hard error (silent last-wins would mask rows)
    dup_p = tmp_path / "dup.json"
    dup_p.write_text(json.dumps({"rows": [base_rows[0], base_rows[0]]}))
    with pytest.raises(SystemExit, match="duplicate"):
        load_rows(str(dup_p))
