"""Serving engine tests: wave batching, EOS handling, cache padding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import build
from repro.models.common import init_params
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    return ServingEngine(bundle, params,
                         ServeConfig(slots=3, max_new=8, eos_token=1))


def _reqs(n, vocab=256, maxp=20):
    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(
        3, vocab, size=int(rng.integers(4, maxp)), dtype=np.int32))
        for i in range(n)]


def test_engine_drains_queue(engine):
    results = engine.run(_reqs(7))
    assert [r.uid for r in results] == list(range(7))
    # 0 tokens is legal (first sampled token may be EOS)
    assert all(0 <= len(r.tokens) <= 8 for r in results)
    assert all(1 not in r.tokens for r in results)   # EOS stripped


def test_engine_greedy_deterministic():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    outs = []
    for _ in range(2):
        eng = ServingEngine(bundle, params,
                            ServeConfig(slots=2, max_new=6, eos_token=1))
        outs.append([r.tokens for r in eng.run(_reqs(3))])
    assert outs[0] == outs[1]


def test_engine_matches_manual_decode():
    """Engine's greedy continuation == hand-rolled prefill+decode loop."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    prompt = np.arange(5, 13, dtype=np.int32)

    eng = ServingEngine(bundle, params,
                        ServeConfig(slots=1, max_new=4, eos_token=-1))
    got = eng.run([Request(uid=0, prompt=prompt)])[0].tokens

    toks = jnp.asarray(prompt)[None, :]
    logits, cache = bundle.prefill(params, {"tokens": toks})
    from repro.serving.engine import _pad_cache_seq

    cache = _pad_cache_seq(cache, 4)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(3):
        logits, cache = bundle.decode(
            params, cache, {"tokens": jnp.asarray([[want[-1]]], jnp.int32)})
        want.append(int(jnp.argmax(logits[0, -1])))
    assert got == want


def test_engine_explicit_kernel_path_plumbs_into_model():
    """ServeConfig.kernel_path rebuilds the bundle with the dispatch path
    baked into the model config — no env-var reliance — and produces the
    same greedy tokens as the default path (path agreement end to end)."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                        mod.SMOKE.dtype)
    eng_default = ServingEngine(bundle, params,
                                ServeConfig(slots=1, max_new=4, eos_token=-1))
    eng_fused = ServingEngine(bundle, params,
                              ServeConfig(slots=1, max_new=4, eos_token=-1,
                                          kernel_path="fused"))
    assert eng_default.bundle.cfg.kernel_path is None
    assert eng_fused.bundle.cfg.kernel_path == "fused"
    prompt = np.arange(5, 13, dtype=np.int32)
    got_d = eng_default.run([Request(uid=0, prompt=prompt)])[0].tokens
    got_f = eng_fused.run([Request(uid=0, prompt=prompt)])[0].tokens
    assert got_d == got_f


def test_engine_mamba_family():
    """SSM caches (no seq axis) must serve without padding issues."""
    mod = configs.get("mamba2-1.3b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    eng = ServingEngine(bundle, params,
                        ServeConfig(slots=2, max_new=5, eos_token=1))
    results = eng.run(_reqs(4))
    assert len(results) == 4
    assert all(1 <= len(r.tokens) <= 5 for r in results)
