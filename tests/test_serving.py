"""Serving engine tests: wave batching, EOS handling, cache padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import KernelPolicy
from repro.models import build
from repro.models.common import init_params
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    return ServingEngine(bundle, params,
                         ServeConfig(slots=3, max_new=8, eos_token=1))


def _reqs(n, vocab=256, maxp=20):
    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(
        3, vocab, size=int(rng.integers(4, maxp)), dtype=np.int32))
        for i in range(n)]


def test_engine_drains_queue(engine):
    results = engine.run(_reqs(7))
    assert [r.uid for r in results] == list(range(7))
    # 0 tokens is legal (first sampled token may be EOS)
    assert all(0 <= len(r.tokens) <= 8 for r in results)
    assert all(1 not in r.tokens for r in results)   # EOS stripped


def test_engine_greedy_deterministic():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    outs = []
    for _ in range(2):
        eng = ServingEngine(bundle, params,
                            ServeConfig(slots=2, max_new=6, eos_token=1))
        outs.append([r.tokens for r in eng.run(_reqs(3))])
    assert outs[0] == outs[1]


def test_engine_matches_manual_decode():
    """Engine's greedy continuation == hand-rolled prefill+decode loop."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    prompt = np.arange(5, 13, dtype=np.int32)

    eng = ServingEngine(bundle, params,
                        ServeConfig(slots=1, max_new=4, eos_token=-1))
    got = eng.run([Request(uid=0, prompt=prompt)])[0].tokens

    toks = jnp.asarray(prompt)[None, :]
    logits, cache = bundle.prefill(params, {"tokens": toks})
    from repro.serving.engine import _pad_cache_seq

    cache = _pad_cache_seq(cache, 4)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(3):
        logits, cache = bundle.decode(
            params, cache, {"tokens": jnp.asarray([[want[-1]]], jnp.int32)})
        want.append(int(jnp.argmax(logits[0, -1])))
    assert got == want


def test_engine_explicit_policy_plumbs_into_model():
    """ServeConfig.policy rebuilds the bundle with the KernelPolicy baked
    into the model config — no env-var reliance — and produces the same
    greedy tokens as the default policy (path agreement end to end). The
    deprecated kernel_path= string spelling coerces into the same policy."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                        mod.SMOKE.dtype)
    eng_default = ServingEngine(bundle, params,
                                ServeConfig(slots=1, max_new=4, eos_token=-1))
    eng_fused = ServingEngine(bundle, params,
                              ServeConfig(slots=1, max_new=4, eos_token=-1,
                                          policy="fused"))
    assert eng_default.bundle.cfg.policy is None
    assert eng_fused.bundle.cfg.policy == KernelPolicy(path="fused")
    # the deprecated string kwarg lands on the same coerced policy
    legacy = ServeConfig(slots=1, max_new=4, eos_token=-1,
                         kernel_path="fused")
    assert legacy.policy == eng_fused.cfg.policy
    prompt = np.arange(5, 13, dtype=np.int32)
    got_d = eng_default.run([Request(uid=0, prompt=prompt)])[0].tokens
    got_f = eng_fused.run([Request(uid=0, prompt=prompt)])[0].tokens
    assert got_d == got_f


def test_engine_whole_policy_comparison_invalidates_bundle():
    """The bundle-rebuild check compares the WHOLE policy: an
    autotune-mode or per-op-override change must invalidate the cached
    bundle (its jitted steps baked the old choices in), while an
    identical policy must reuse it."""
    mod = configs.get("llama3.2-1b")
    pol = KernelPolicy(path="fused")
    bundle = build(dataclasses.replace(mod.SMOKE, policy=pol))
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    same = ServingEngine(bundle, params,
                         ServeConfig(slots=1, max_new=2, policy=pol))
    assert same.bundle is bundle                 # equal policy: no rebuild
    for changed in (
            dataclasses.replace(pol, autotune="off"),
            dataclasses.replace(pol, op_paths={"attention": "baseline"}),
            # a tuning-only change invalidates too: the jitted steps baked
            # the old kernel geometry in
            dataclasses.replace(pol, op_tuning={"ssd": {"q": 64}}),
    ):
        eng = ServingEngine(bundle, params,
                            ServeConfig(slots=1, max_new=2, policy=changed))
        assert eng.bundle is not bundle          # policy diff: rebuilt
        assert eng.bundle.cfg.policy == changed


def test_engine_mamba_family():
    """SSM caches (no seq axis) must serve without padding issues."""
    mod = configs.get("mamba2-1.3b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    eng = ServingEngine(bundle, params,
                        ServeConfig(slots=2, max_new=5, eos_token=1))
    results = eng.run(_reqs(4))
    assert len(results) == 4
    assert all(1 <= len(r.tokens) <= 5 for r in results)


# ---------------------------------------------------------------------------
# continuous batching


from repro.serving import clear_compile_cache, demo_engine  # noqa: E402


def _llama_bundle_params():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    return bundle, params


def test_run_returns_only_current_results():
    """Regression: a second run() must not replay the first call's
    results (the old wave engine returned ``sorted(self.results)``)."""
    bundle, params = _llama_bundle_params()
    for sched in ("continuous", "wave"):
        eng = ServingEngine(bundle, params, ServeConfig(
            slots=2, max_new=3, eos_token=-1, scheduler=sched))
        first = eng.run(_reqs(3))
        second = eng.run([Request(uid=100, prompt=np.arange(
            5, 12, dtype=np.int32))])
        assert [r.uid for r in first] == [0, 1, 2]
        assert [r.uid for r in second] == [100], sched
        assert len(eng.results) == 4          # history still accumulates


def test_sampling_rng_seedable():
    """ServeConfig.seed drives the sampling RNG: same seed, same sampled
    tokens; a different seed diverges. demo_engine(seed=) threads into
    the config, not just init_params."""
    bundle, params = _llama_bundle_params()

    def sample(seed):
        eng = ServingEngine(bundle, params, ServeConfig(
            slots=2, max_new=6, eos_token=-1, greedy=False,
            temperature=1.0, seed=seed))
        return [r.tokens for r in eng.run(_reqs(3))]

    assert sample(7) == sample(7)
    assert sample(7) != sample(8)
    eng = demo_engine(bundle, slots=2, max_new=2, seed=5)
    assert eng.cfg.seed == 5


def test_wave_no_dummy_slot_decode():
    """A short wave no longer pads itself with duplicate requests: each
    real request yields exactly one result and padding rows are done from
    the start (they never extend the wave)."""
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=4, max_new=3, eos_token=-1, scheduler="wave"))
    results = eng.run(_reqs(2))
    assert [r.uid for r in results] == [0, 1]
    assert all(len(r.tokens) == 3 for r in results)
    # per-request budgets: the slot with the small budget stops early
    # while the wave continues for the bigger one
    res = eng.run([Request(uid=10, prompt=np.arange(5, 12, dtype=np.int32),
                           max_new=1),
                   Request(uid=11, prompt=np.arange(5, 12, dtype=np.int32),
                           max_new=4)])
    assert len(res[0].tokens) == 1 and len(res[1].tokens) == 4


def test_no_wave_barrier():
    """Short requests admitted AFTER a long sequence finish BEFORE it:
    the freed slot is refilled while the long request keeps decoding."""
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=4, eos_token=-1, scheduler="continuous",
        prefill_chunk=8))
    rng = np.random.default_rng(0)
    reqs = [Request(uid=0, prompt=rng.integers(3, 256, size=6,
                                               dtype=np.int32),
                    max_new=48)]
    reqs += [Request(uid=i, prompt=rng.integers(3, 256, size=5,
                                                dtype=np.int32),
                     max_new=2) for i in range(1, 5)]
    results = {r.uid: r for r in eng.run(reqs)}
    long_res = results[0]
    late_shorts = [r for uid, r in results.items()
                   if uid > 0 and r.admitted_tick > results[1].admitted_tick]
    assert late_shorts, "expected shorts admitted after the first wave"
    for r in late_shorts:
        assert r.admitted_tick > long_res.admitted_tick
        assert r.finish_tick < long_res.finish_tick, (
            "short admitted after the long request must finish before it "
            "(no wave barrier)")


def test_evicted_slot_refilled_next_tick():
    """Every finish with work still queued is followed by an admission
    into that slot on the very next tick."""
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=3, eos_token=-1, scheduler="continuous",
        prefill_chunk=8))
    eng.run(_reqs(6))
    admits = {(e["slot"], e["tick"]) for e in eng.trace
              if e["event"] == "admit"}
    finishes = [e for e in eng.trace if e["event"] == "finish"]
    last_admit_tick = max(t for _, t in admits)
    for e in finishes:
        if e["tick"] < last_admit_tick:   # queue was non-empty then
            assert (e["slot"], e["tick"] + 1) in admits, (
                f"slot {e['slot']} freed at tick {e['tick']} was not "
                "refilled next tick")


def test_compile_count_bounded_by_buckets():
    """Across a mixed-length workload the block step compiles at most two
    shapes per capacity bucket (T=prefill_chunk and T=1) — never one per
    request length."""
    clear_compile_cache()
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=4, eos_token=-1, scheduler="continuous",
        prefill_chunk=4))
    rng = np.random.default_rng(1)
    reqs = [Request(uid=i, prompt=rng.integers(
        3, 256, size=plen, dtype=np.int32))
        for i, plen in enumerate((3, 5, 9, 14, 20, 11, 7))]
    eng.run(reqs)
    n = eng.compile_stats()["block"]
    assert n is not None and n <= 2, f"block step compiled {n} shapes"


def test_continuous_matches_manual_decode():
    """Chunked prefill + slot decode == hand-rolled prefill+decode, with a
    chunk smaller than the prompt so multiple prefill ticks happen."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    prompt = np.arange(5, 13, dtype=np.int32)

    eng = ServingEngine(bundle, params, ServeConfig(
        slots=1, max_new=4, eos_token=-1, scheduler="continuous",
        prefill_chunk=3))
    got = eng.run([Request(uid=0, prompt=prompt)])[0].tokens

    toks = jnp.asarray(prompt)[None, :]
    logits, cache = bundle.prefill(params, {"tokens": toks})
    from repro.serving.engine import _pad_cache_seq

    cache = _pad_cache_seq(cache, 4)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(3):
        logits, cache = bundle.decode(
            params, cache, {"tokens": jnp.asarray([[want[-1]]], jnp.int32)})
        want.append(int(jnp.argmax(logits[0, -1])))
    assert got == want


@pytest.mark.parametrize("arch", ["mamba2-1.3b", "zamba2-2.7b"])
def test_continuous_matches_manual_decode_ssm(arch):
    """Same exactness for the SSM and hybrid families: the masked-scan
    prefill must stop each slot's state exactly at its own length."""
    mod = configs.get(arch)
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    prompt = np.arange(5, 14, dtype=np.int32)

    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=3, eos_token=-1, scheduler="continuous",
        prefill_chunk=4))
    got = eng.run([Request(uid=0, prompt=prompt)])[0].tokens

    logits, cache = bundle.prefill(params,
                                   {"tokens": jnp.asarray(prompt)[None, :]})
    from repro.serving.engine import _pad_cache_seq

    cache = _pad_cache_seq(cache, 3)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(2):
        logits, cache = bundle.decode(
            params, cache, {"tokens": jnp.asarray([[want[-1]]], jnp.int32)})
        want.append(int(jnp.argmax(logits[0, -1])))
    assert got == want


def test_ring_cache_wraps_beyond_capacity():
    """max_context caps the ring capacity; generation beyond it slides the
    attention window instead of failing, and per-slot pos keeps counting."""
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=1, max_new=24, eos_token=-1, scheduler="continuous",
        prefill_chunk=8, max_context=16))
    res = eng.run([Request(uid=0, prompt=np.arange(
        5, 17, dtype=np.int32))])[0]
    assert len(res.tokens) == 24          # 12 + 24 > 16: wrapped fine
    assert eng._capacity == 16
    # prompt (12) + every decode input (23: the final emitted token is
    # never fed back) — pos counts absolute positions past the capacity
    assert int(eng._cache["pos"][0]) == 12 + 24 - 1


def test_continuous_per_request_max_new():
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=8, eos_token=-1, scheduler="continuous"))
    res = eng.run([Request(uid=0, prompt=np.arange(5, 10, dtype=np.int32),
                           max_new=2),
                   Request(uid=1, prompt=np.arange(5, 10, dtype=np.int32))])
    assert len(res[0].tokens) == 2 and len(res[1].tokens) == 8


def test_encdec_falls_back_to_wave():
    """Encoder-decoder bundles have no block-decode step: asking for the
    continuous scheduler warns and runs the wave path."""
    mod = configs.get("seamless-m4t-medium")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    with pytest.warns(UserWarning, match="falling back"):
        eng = ServingEngine(bundle, params, ServeConfig(
            slots=2, max_new=2, scheduler="continuous"))
    assert eng.scheduler == "wave"


def test_open_loop_arrivals_respected():
    """Requests with future arrival_s are not admitted before they
    arrive, and results carry latency bookkeeping."""
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=2, eos_token=-1, scheduler="continuous"))
    reqs = [Request(uid=0, prompt=np.arange(5, 10, dtype=np.int32),
                    arrival_s=0.0),
            Request(uid=1, prompt=np.arange(5, 10, dtype=np.int32),
                    arrival_s=0.15)]
    res = eng.run(reqs)
    r1 = [r for r in res if r.uid == 1][0]
    assert r1.first_token_s is not None and r1.first_token_s >= 0.15
    assert len(r1.token_s) == len(r1.tokens)
    assert r1.finish_s >= r1.first_token_s


# ---------------------------------------------------------------------------
# paged KV cache (serving/kvpool.py)


def _run_tokens(bundle, params, reqs, **cfg_kw):
    eng = ServingEngine(bundle, params, ServeConfig(**cfg_kw))
    return {r.uid: r.tokens for r in eng.run(reqs)}, eng


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b",
                                  "zamba2-2.7b"])
def test_paged_matches_ring_token_for_token(arch):
    """cache_kind='paged' is a memory-layout change, not a model change:
    greedy tokens must match the ring cache exactly across all three
    cache families (attention / SSM / hybrid), with chunked prefill and
    more requests than slots so slots recycle."""
    mod = configs.get(arch)
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    rng = np.random.default_rng(3)
    reqs = lambda: [Request(uid=i, prompt=rng.integers(
        3, 256, size=plen, dtype=np.int32), max_new=5)
        for i, plen in enumerate((5, 19, 11, 26, 8, 14))]
    rng = np.random.default_rng(3)
    ring, _ = _run_tokens(bundle, params, reqs(), slots=3, max_new=5,
                          eos_token=-1, scheduler="continuous",
                          prefill_chunk=6, cache_kind="ring")
    rng = np.random.default_rng(3)
    paged, eng = _run_tokens(bundle, params, reqs(), slots=3, max_new=5,
                             eos_token=-1, scheduler="continuous",
                             prefill_chunk=6, cache_kind="paged")
    assert paged == ring
    kv = eng.kv_stats()
    assert kv is not None
    if arch != "mamba2-1.3b":             # pure-SSM: no KV pages at all
        assert kv["allocs"] > 0


def test_paged_wrap_beyond_capacity_matches_ring():
    """Sliding-window wrap: for capacity S the paged gather row
    ``((p // R) % MP) * R + p % R`` equals ``p % S`` — bit-identical to
    the ring, including the overwrite order. The admission overflow
    warns once (satellite: no more silent degrade) and traces after."""
    bundle, params = _llama_bundle_params()
    req = lambda: [Request(uid=0, prompt=np.arange(5, 17, dtype=np.int32))]
    out = {}
    for kind in ("ring", "paged"):
        with pytest.warns(UserWarning, match="sliding-window"):
            out[kind], eng = _run_tokens(
                bundle, params, req(), slots=1, max_new=24, eos_token=-1,
                scheduler="continuous", prefill_chunk=8, max_context=16,
                cache_kind=kind)
        assert [e for e in eng.trace if e["event"] == "swa_degrade"]
    assert len(out["paged"][0]) == 24
    assert out["paged"] == out["ring"]


def test_paged_shared_prefix_shares_pages_and_matches_ring():
    """The tentpole's acceptance bar: a shared-prefix workload under the
    paged cache (a) produces exactly the ring cache's tokens, (b) maps
    prompt pages shared (shared_tokens > 0, CoW on divergence), and (c)
    peaks at strictly fewer physical pages than n_req full contexts."""
    bundle, params = _llama_bundle_params()
    from repro.kernels.layout import KV_PAGE_ROWS

    rng = np.random.default_rng(5)
    prefix = rng.integers(3, 256, size=40, dtype=np.int32)
    reqs = lambda: [Request(uid=i, prompt=np.concatenate(
        [prefix, rng.integers(3, 256, size=4, dtype=np.int32)]).astype(
            np.int32), max_new=4) for i in range(6)]
    rng = np.random.default_rng(5)
    ring, _ = _run_tokens(bundle, params, reqs(), slots=2, max_new=4,
                          eos_token=-1, scheduler="continuous",
                          prefill_chunk=8, max_context=64,
                          cache_kind="ring")
    rng = np.random.default_rng(5)
    paged, eng = _run_tokens(bundle, params, reqs(), slots=2, max_new=4,
                             eos_token=-1, scheduler="continuous",
                             prefill_chunk=8, max_context=64,
                             cache_kind="paged")
    assert paged == ring
    kv = eng.kv_stats()
    assert kv["shared_tokens"] > 0        # later waves mapped the prefix
    assert kv["cow_copies"] > 0           # divergent tails CoW'd
    full_ctx_pages = 6 * (eng._capacity // KV_PAGE_ROWS)
    assert kv["peak_pages_in_use"] < full_ctx_pages, (
        kv["peak_pages_in_use"], full_ctx_pages)


def test_paged_pool_exhaustion_defers_then_completes():
    """A pool too small for every queued request at once back-pressures:
    admissions defer until releases free pages, every request still
    completes, and the tokens still match the ring cache."""
    bundle, params = _llama_bundle_params()
    rng = np.random.default_rng(7)
    reqs = lambda: [Request(uid=i, prompt=rng.integers(
        3, 256, size=18, dtype=np.int32), max_new=4) for i in range(4)]
    rng = np.random.default_rng(7)
    ring, _ = _run_tokens(bundle, params, reqs(), slots=2, max_new=4,
                          eos_token=-1, scheduler="continuous",
                          prefill_chunk=8, cache_kind="ring")
    # 18 + 4 tokens -> 2 pages per request; 3 pages covers one slot plus
    # nothing to spare, so the second slot's admission must defer
    rng = np.random.default_rng(7)
    paged, eng = _run_tokens(bundle, params, reqs(), slots=2, max_new=4,
                             eos_token=-1, scheduler="continuous",
                             prefill_chunk=8, cache_kind="paged",
                             pool_pages=3, prefix_sharing=False)
    assert paged == ring
    assert len(paged) == 4                # nothing dropped
    assert eng.kv_stats()["defers"] > 0


def test_paged_pool_too_small_raises():
    """When even an empty engine cannot reserve one request's worst case,
    deferral would livelock — the engine raises with the knob to turn."""
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=1, max_new=8, eos_token=-1, scheduler="continuous",
        cache_kind="paged", pool_pages=1))
    with pytest.raises(RuntimeError, match="pool_pages"):
        eng.run([Request(uid=0, prompt=np.arange(
            5, 45, dtype=np.int32))])


def test_paged_page_rows_validated():
    bundle, params = _llama_bundle_params()
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=1, max_new=2, eos_token=-1, scheduler="continuous",
        cache_kind="paged", page_rows=12))
    with pytest.raises(ValueError, match="power-of-two"):
        eng.run([Request(uid=0, prompt=np.arange(5, 10, dtype=np.int32))])
    with pytest.raises(ValueError, match="cache_kind"):
        ServeConfig(slots=1, max_new=2, cache_kind="flat")
