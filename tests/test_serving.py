"""Serving engine tests: wave batching, EOS handling, cache padding."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.policy import KernelPolicy
from repro.models import build
from repro.models.common import init_params
from repro.serving import Request, ServeConfig, ServingEngine


@pytest.fixture(scope="module")
def engine():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    return ServingEngine(bundle, params,
                         ServeConfig(slots=3, max_new=8, eos_token=1))


def _reqs(n, vocab=256, maxp=20):
    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(
        3, vocab, size=int(rng.integers(4, maxp)), dtype=np.int32))
        for i in range(n)]


def test_engine_drains_queue(engine):
    results = engine.run(_reqs(7))
    assert [r.uid for r in results] == list(range(7))
    # 0 tokens is legal (first sampled token may be EOS)
    assert all(0 <= len(r.tokens) <= 8 for r in results)
    assert all(1 not in r.tokens for r in results)   # EOS stripped


def test_engine_greedy_deterministic():
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    outs = []
    for _ in range(2):
        eng = ServingEngine(bundle, params,
                            ServeConfig(slots=2, max_new=6, eos_token=1))
        outs.append([r.tokens for r in eng.run(_reqs(3))])
    assert outs[0] == outs[1]


def test_engine_matches_manual_decode():
    """Engine's greedy continuation == hand-rolled prefill+decode loop."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    prompt = np.arange(5, 13, dtype=np.int32)

    eng = ServingEngine(bundle, params,
                        ServeConfig(slots=1, max_new=4, eos_token=-1))
    got = eng.run([Request(uid=0, prompt=prompt)])[0].tokens

    toks = jnp.asarray(prompt)[None, :]
    logits, cache = bundle.prefill(params, {"tokens": toks})
    from repro.serving.engine import _pad_cache_seq

    cache = _pad_cache_seq(cache, 4)
    want = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(3):
        logits, cache = bundle.decode(
            params, cache, {"tokens": jnp.asarray([[want[-1]]], jnp.int32)})
        want.append(int(jnp.argmax(logits[0, -1])))
    assert got == want


def test_engine_explicit_policy_plumbs_into_model():
    """ServeConfig.policy rebuilds the bundle with the KernelPolicy baked
    into the model config — no env-var reliance — and produces the same
    greedy tokens as the default policy (path agreement end to end). The
    deprecated kernel_path= string spelling coerces into the same policy."""
    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                        mod.SMOKE.dtype)
    eng_default = ServingEngine(bundle, params,
                                ServeConfig(slots=1, max_new=4, eos_token=-1))
    eng_fused = ServingEngine(bundle, params,
                              ServeConfig(slots=1, max_new=4, eos_token=-1,
                                          policy="fused"))
    assert eng_default.bundle.cfg.policy is None
    assert eng_fused.bundle.cfg.policy == KernelPolicy(path="fused")
    # the deprecated string kwarg lands on the same coerced policy
    legacy = ServeConfig(slots=1, max_new=4, eos_token=-1,
                         kernel_path="fused")
    assert legacy.policy == eng_fused.cfg.policy
    prompt = np.arange(5, 13, dtype=np.int32)
    got_d = eng_default.run([Request(uid=0, prompt=prompt)])[0].tokens
    got_f = eng_fused.run([Request(uid=0, prompt=prompt)])[0].tokens
    assert got_d == got_f


def test_engine_whole_policy_comparison_invalidates_bundle():
    """The bundle-rebuild check compares the WHOLE policy: an
    autotune-mode or per-op-override change must invalidate the cached
    bundle (its jitted steps baked the old choices in), while an
    identical policy must reuse it."""
    mod = configs.get("llama3.2-1b")
    pol = KernelPolicy(path="fused")
    bundle = build(dataclasses.replace(mod.SMOKE, policy=pol))
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    same = ServingEngine(bundle, params,
                         ServeConfig(slots=1, max_new=2, policy=pol))
    assert same.bundle is bundle                 # equal policy: no rebuild
    for changed in (
            dataclasses.replace(pol, autotune="off"),
            dataclasses.replace(pol, op_paths={"attention": "baseline"}),
            # a tuning-only change invalidates too: the jitted steps baked
            # the old kernel geometry in
            dataclasses.replace(pol, op_tuning={"ssd": {"q": 64}}),
    ):
        eng = ServingEngine(bundle, params,
                            ServeConfig(slots=1, max_new=2, policy=changed))
        assert eng.bundle is not bundle          # policy diff: rebuilt
        assert eng.bundle.cfg.policy == changed


def test_engine_mamba_family():
    """SSM caches (no seq axis) must serve without padding issues."""
    mod = configs.get("mamba2-1.3b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         mod.SMOKE.dtype)
    eng = ServingEngine(bundle, params,
                        ServeConfig(slots=2, max_new=5, eos_token=1))
    results = eng.run(_reqs(4))
    assert len(results) == 4
    assert all(1 <= len(r.tokens) <= 5 for r in results)
