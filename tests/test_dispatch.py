"""Dispatch-layer tests: the version shim, path resolution/override, and
agreement of the fused / tile / interpret paths for reduce, scan, and
weighted scan (fp32 and bf16)."""
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.kernels import backend, ops, ref

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# version shim


def test_compiler_params_resolves_on_this_jax():
    cp = backend.compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert type(cp) is backend.compiler_params_cls()
    assert tuple(cp.dimension_semantics) == ("parallel", "arbitrary")


def test_compiler_params_drops_unknown_fields():
    # a knob from another JAX era must not crash the shim
    cp = backend.compiler_params(
        dimension_semantics=("arbitrary",),
        some_flag_from_the_future=True)
    assert not hasattr(cp, "some_flag_from_the_future")


def test_no_raw_compiler_params_outside_backend():
    """Regression guard for the 44-test break: only backend.py may spell
    out the per-version pltpu compiler-params class."""
    pat = re.compile(r"pltpu\s*\.\s*(?:TPU)?CompilerParams")
    offenders = [
        str(p.relative_to(SRC))
        for p in sorted(SRC.rglob("*.py"))
        if p.name != "backend.py" and pat.search(p.read_text())
    ]
    assert not offenders, (
        f"raw pltpu compiler-params construction in {offenders}; "
        "use repro.kernels.backend.compiler_params instead"
    )


# ---------------------------------------------------------------------------
# path resolution


def test_resolve_path_defaults_off_tpu(monkeypatch):
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    if backend.on_tpu():
        pytest.skip("CPU-only expectations")
    assert backend.resolve_path() == "fused"
    assert backend.resolve_path("tile") == "interpret"   # nothing to compile
    assert backend.resolve_path("interpret") == "interpret"
    assert backend.resolve_path(use_pallas=True) == "interpret"
    assert backend.resolve_path(use_pallas=False) == "fused"


def test_resolve_path_env_override(monkeypatch):
    monkeypatch.setenv(backend.ENV_PATH, "interpret")
    assert backend.resolve_path() == "interpret"
    assert dispatch.resolve_path() == "interpret"
    # explicit per-call choice beats the env var
    assert backend.resolve_path("fused") == "fused"
    monkeypatch.setenv(backend.ENV_PATH, "baseline")
    assert dispatch.resolve_path() == "baseline"


def test_resolve_path_rejects_unknown():
    with pytest.raises(ValueError):
        backend.resolve_path("cuda")
    with pytest.raises(ValueError):
        dispatch.resolve_path("warp")


def test_pallas_op_unknown_name():
    with pytest.raises(KeyError):
        backend.pallas_op("nonexistent_op", jnp.zeros((4,)))


def test_registry_has_all_ops():
    assert set(backend.available_ops()) >= {
        "segmented_reduce", "segmented_scan", "weighted_scan",
        "rmsnorm", "ssd_scan", "attention",
    }


# ---------------------------------------------------------------------------
# path agreement (the acceptance contract: one switch, same numbers)

KERNEL_PATHS = ["fused", "tile", "interpret"]


def _tol(dtype):
    return dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", KERNEL_PATHS)
def test_reduce_paths_agree(path, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 300)).astype(dtype)
    got = np.asarray(ops.segmented_reduce(x, path=path))
    want = np.asarray(x, np.float32).sum(-1)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", KERNEL_PATHS)
def test_scan_paths_agree(path, dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 200)).astype(dtype)
    got = np.asarray(ops.segmented_scan(x, path=path))
    want = np.cumsum(np.asarray(x, np.float32), axis=-1)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", KERNEL_PATHS)
def test_weighted_scan_paths_agree(path, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 160)).astype(dtype)
    la = (-jax.random.uniform(jax.random.PRNGKey(3), (2, 160))).astype(dtype)
    got = np.asarray(ops.weighted_scan(x, la, path=path))
    want = np.asarray(
        ref.weighted_scan_ref(x.astype(jnp.float32), la.astype(jnp.float32)))
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("path", ["fused", "xla_tile", "interpret",
                                  "baseline"])
def test_core_dispatch_reduce_scan_one_switch(path):
    """The benchmark entry contract: every contender from one argument."""
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 257))
    np.testing.assert_allclose(
        np.asarray(dispatch.reduce(x, path=path)),
        np.asarray(x).sum(-1), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(dispatch.scan(x, path=path)),
        np.cumsum(np.asarray(x), -1), rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("exclusive", [False, True])
def test_core_dispatch_scan_exclusive_paths(exclusive):
    x = jax.random.normal(jax.random.PRNGKey(5), (300,))
    want = np.asarray(dispatch.scan(x, path="baseline", exclusive=exclusive))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.scan(x, path=path, exclusive=exclusive))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


def test_core_dispatch_weighted_scan_paths():
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 300))
    la = -jax.random.uniform(jax.random.PRNGKey(7), (2, 300))
    want = np.asarray(dispatch.weighted_scan(x, la, path="baseline"))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.weighted_scan(x, la, path=path))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_core_dispatch_ssd_paths():
    b, L, h, p, g, n = 1, 100, 2, 8, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, L, g, n)) / np.sqrt(n)
    cc = jax.random.normal(ks[4], (b, L, g, n)) / np.sqrt(n)
    want = np.asarray(dispatch.ssd(x, dt, a, bb, cc, path="baseline"))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.ssd(x, dt, a, bb, cc, path=path))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_env_var_steers_op_execution(monkeypatch):
    """REPRO_KERNEL_PATH reroutes an unannotated call site end to end."""
    x = jnp.ones((2, 130))
    monkeypatch.setenv(backend.ENV_PATH, "interpret")
    got = np.asarray(ops.segmented_reduce(x))
    monkeypatch.setenv(backend.ENV_PATH, "fused")
    want = np.asarray(ops.segmented_reduce(x))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    np.testing.assert_allclose(want, 130.0)


@pytest.mark.parametrize("envval", ["fused", "tile", "interpret",
                                    "baseline", "xla_tile"])
def test_env_values_never_crash_kernel_ops(monkeypatch, envval):
    """The env var is process-wide and shared with repro.core.dispatch, so
    its algorithm-level values (baseline/xla_tile) must not blow up
    kernel-level call sites (e.g. every model's rmsnorm)."""
    monkeypatch.setenv(backend.ENV_PATH, envval)
    x = jnp.ones((2, 130))
    np.testing.assert_allclose(
        np.asarray(ops.segmented_reduce(x)), 130.0, rtol=1e-6)


def test_legacy_use_pallas_kwarg_still_works():
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 100))
    np.testing.assert_allclose(
        np.asarray(ops.segmented_reduce(x, use_pallas=True)),
        np.asarray(ops.segmented_reduce(x, use_pallas=False)),
        rtol=1e-4, atol=1e-3)
