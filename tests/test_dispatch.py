"""Dispatch-layer tests: the version shim, path resolution/override, and
agreement of the fused / tile / interpret paths for reduce, scan, and
weighted scan (fp32 and bf16)."""
import dataclasses
import re
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core import policy as kpolicy
from repro.kernels import backend, ops, ref

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# version shim


def test_compiler_params_resolves_on_this_jax():
    cp = backend.compiler_params(
        dimension_semantics=("parallel", "arbitrary"))
    assert type(cp) is backend.compiler_params_cls()
    assert tuple(cp.dimension_semantics) == ("parallel", "arbitrary")


def test_compiler_params_drops_unknown_fields():
    # a knob from another JAX era must not crash the shim
    cp = backend.compiler_params(
        dimension_semantics=("arbitrary",),
        some_flag_from_the_future=True)
    assert not hasattr(cp, "some_flag_from_the_future")


def test_no_raw_compiler_params_outside_backend():
    """Regression guard for the 44-test break: only backend.py may spell
    out the per-version pltpu compiler-params class."""
    pat = re.compile(r"pltpu\s*\.\s*(?:TPU)?CompilerParams")
    offenders = [
        str(p.relative_to(SRC))
        for p in sorted(SRC.rglob("*.py"))
        if p.name != "backend.py" and pat.search(p.read_text())
    ]
    assert not offenders, (
        f"raw pltpu compiler-params construction in {offenders}; "
        "use repro.kernels.backend.compiler_params instead"
    )


def test_no_pallas_triton_import_outside_triton_package():
    """Same discipline for the GPU twin subsystem: only
    ``repro.kernels.triton`` may import ``jax.experimental.pallas.triton``
    (and within the package, only its ``compat`` shim does)."""
    pat = re.compile(
        r"^\s*(?:import\s+jax\.experimental\.pallas\.triton"
        r"|from\s+jax\.experimental\.pallas\.triton\s+import"
        r"|from\s+jax\.experimental\.pallas\s+import\s+[^\n]*\btriton\b)",
        re.MULTILINE)
    offenders = []
    for p in sorted(SRC.rglob("*.py")):
        rel = p.relative_to(SRC)
        if rel.parts[:2] == ("kernels", "triton"):
            if rel.name != "compat.py" and pat.search(p.read_text()):
                offenders.append(f"{rel} (only compat.py may)")
            continue
        if pat.search(p.read_text()):
            offenders.append(str(rel))
    assert not offenders, (
        f"raw jax.experimental.pallas.triton import in {offenders}; "
        "route through repro.kernels.triton.compat / "
        "backend.compiler_params(backend='gpu')"
    )


def test_no_shard_map_import_outside_parallel_compat():
    """Only ``parallel/compat.py`` may import ``shard_map`` — the 0.4.x
    vs 0.6+ rename lives behind exactly one shim (the PR-1 break class:
    a renamed jax symbol imported from many files)."""
    pat = re.compile(
        r"^\s*(?:from\s+jax\.experimental\.shard_map\s+import"
        r"|import\s+jax\.experimental\.shard_map"
        r"|from\s+jax\s+import\s+[^\n]*\bshard_map\b)",
        re.MULTILINE)
    offenders = [
        str(p.relative_to(SRC))
        for p in sorted(SRC.rglob("*.py"))
        if p.relative_to(SRC).parts != ("parallel", "compat.py")
        and pat.search(p.read_text())
    ]
    assert not offenders, (
        f"raw shard_map import in {offenders}; "
        "import it from repro.parallel.compat instead"
    )


def test_no_make_mesh_outside_parallel():
    """Only the ``parallel`` package may call ``jax.make_mesh`` — every
    other layer consumes a MeshContext (or ``parallel.compat.make_mesh``),
    so mesh construction policy (axis types, version shims) has one home."""
    pat = re.compile(r"\bjax\s*\.\s*make_mesh\s*\(")
    offenders = [
        str(p.relative_to(SRC))
        for p in sorted(SRC.rglob("*.py"))
        if p.relative_to(SRC).parts[0] != "parallel"
        and pat.search(p.read_text())
    ]
    assert not offenders, (
        f"raw jax.make_mesh call in {offenders}; build meshes via "
        "repro.parallel.mesh_context.make_context or parallel.compat"
    )


# ---------------------------------------------------------------------------
# path resolution


def test_kernel_resolution_defaults_off_tpu(monkeypatch):
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    if backend.native_tile_backend() is not None:
        pytest.skip("CPU-only expectations")
    silent = dataclasses.replace(kpolicy.get_policy(),
                                 interpret_fallback="silent")
    resolve = lambda p=None: silent.resolve(level="kernel", explicit=p)
    assert resolve() == "fused"
    assert resolve("tile") == "interpret"   # nothing to compile
    assert resolve("interpret") == "interpret"
    # the legacy use_pallas bool folds into a label before resolution
    assert resolve(backend._merge_use_pallas(None, True)) == "interpret"
    assert resolve(backend._merge_use_pallas(None, False)) == "fused"


def test_tile_downgrade_warns_once_then_stays_silent(monkeypatch):
    """The off-accelerator tile→interpret downgrade must say so ONCE —
    naming the resolved backend and the way to silence it — and never
    again in the same process."""
    if backend.native_tile_backend() is not None:
        pytest.skip("downgrade only happens off-accelerator")
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.setattr(kpolicy, "_TILE_DOWNGRADE_WARNED", False)
    resolve = kpolicy.get_policy().resolve
    with pytest.warns(UserWarning, match="interpret") as rec:
        assert resolve(level="kernel", explicit="tile") == "interpret"
    msg = str(rec[0].message)
    assert jax.default_backend() in msg          # names the backend
    assert "path='interpret'" in msg             # names the silencer
    # second resolution: no warning at all
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve(level="kernel", explicit="tile") == "interpret"
    # an explicit interpret request never warns
    monkeypatch.setattr(kpolicy, "_TILE_DOWNGRADE_WARNED", False)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve(level="kernel", explicit="interpret") == "interpret"
    # interpret_fallback="silent" suppresses it entirely; "error" raises
    monkeypatch.setattr(kpolicy, "_TILE_DOWNGRADE_WARNED", False)
    silent = dataclasses.replace(kpolicy.get_policy(),
                                 interpret_fallback="silent")
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert silent.resolve(level="kernel", explicit="tile") == "interpret"
    strict = dataclasses.replace(kpolicy.get_policy(),
                                 interpret_fallback="error")
    with pytest.raises(RuntimeError, match="interpret_fallback"):
        strict.resolve(level="kernel", explicit="tile")


def test_explicit_tile_backend_labels_are_strict():
    """tile_tpu / tile_gpu force a backend and must raise clearly on the
    wrong host (the generic 'tile' is the lenient spelling)."""
    native = backend.native_tile_backend()
    resolve = lambda p: kpolicy.get_policy().resolve(level="kernel",
                                                     explicit=p)
    if native != "tile_tpu":
        with pytest.raises(RuntimeError, match="tile_tpu"):
            resolve("tile_tpu")
        with pytest.raises(RuntimeError, match="requires a TPU"):
            dispatch.reduce(jnp.ones((2, 64)), path="tile_tpu")
    if native != "tile_gpu":
        with pytest.raises(RuntimeError, match="tile_gpu"):
            resolve("tile_gpu")
    if native is not None:
        assert resolve("tile") == native


def test_resolution_env_override(monkeypatch):
    monkeypatch.setenv(backend.ENV_PATH, "interpret")
    assert kpolicy.get_policy().resolve(level="kernel") == "interpret"
    assert kpolicy.get_policy().resolve() == "interpret"
    # explicit per-call choice beats the env var
    assert kpolicy.get_policy().resolve(level="kernel",
                                        explicit="fused") == "fused"
    monkeypatch.setenv(backend.ENV_PATH, "baseline")
    assert kpolicy.get_policy().resolve() == "baseline"


def test_resolution_rejects_unknown():
    with pytest.raises(ValueError):
        kpolicy.get_policy().resolve(level="kernel", explicit="cuda")
    with pytest.raises(ValueError):
        kpolicy.get_policy().resolve(explicit="warp")


def test_pallas_op_unknown_name():
    with pytest.raises(KeyError):
        backend.pallas_op("nonexistent_op", jnp.zeros((4,)))


def test_registry_has_all_ops():
    assert set(backend.available_ops()) >= {
        "segmented_reduce", "segmented_scan", "weighted_scan",
        "rmsnorm", "ssd_scan", "attention",
    }


# ---------------------------------------------------------------------------
# path agreement (the acceptance contract: one switch, same numbers)

KERNEL_PATHS = ["fused", "tile", "interpret"]


def _tol(dtype):
    return dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", KERNEL_PATHS)
def test_reduce_paths_agree(path, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 300)).astype(dtype)
    got = np.asarray(ops.segmented_reduce(x, path=path))
    want = np.asarray(x, np.float32).sum(-1)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", KERNEL_PATHS)
def test_scan_paths_agree(path, dtype):
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 200)).astype(dtype)
    got = np.asarray(ops.segmented_scan(x, path=path))
    want = np.cumsum(np.asarray(x, np.float32), axis=-1)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", KERNEL_PATHS)
def test_weighted_scan_paths_agree(path, dtype):
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 160)).astype(dtype)
    la = (-jax.random.uniform(jax.random.PRNGKey(3), (2, 160))).astype(dtype)
    got = np.asarray(ops.weighted_scan(x, la, path=path))
    want = np.asarray(
        ref.weighted_scan_ref(x.astype(jnp.float32), la.astype(jnp.float32)))
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("path", ["fused", "xla_tile", "interpret",
                                  "baseline"])
def test_core_dispatch_reduce_scan_one_switch(path):
    """The benchmark entry contract: every contender from one argument."""
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 257))
    np.testing.assert_allclose(
        np.asarray(dispatch.reduce(x, path=path)),
        np.asarray(x).sum(-1), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(
        np.asarray(dispatch.scan(x, path=path)),
        np.cumsum(np.asarray(x), -1), rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("exclusive", [False, True])
def test_core_dispatch_scan_exclusive_paths(exclusive):
    x = jax.random.normal(jax.random.PRNGKey(5), (300,))
    want = np.asarray(dispatch.scan(x, path="baseline", exclusive=exclusive))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.scan(x, path=path, exclusive=exclusive))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


def test_core_dispatch_weighted_scan_paths():
    x = jax.random.normal(jax.random.PRNGKey(6), (2, 300))
    la = -jax.random.uniform(jax.random.PRNGKey(7), (2, 300))
    want = np.asarray(dispatch.weighted_scan(x, la, path="baseline"))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.weighted_scan(x, la, path=path))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_core_dispatch_ssd_paths():
    b, L, h, p, g, n = 1, 100, 2, 8, 1, 4
    ks = jax.random.split(jax.random.PRNGKey(8), 5)
    x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, L, g, n)) / np.sqrt(n)
    cc = jax.random.normal(ks[4], (b, L, g, n)) / np.sqrt(n)
    want = np.asarray(dispatch.ssd(x, dt, a, bb, cc, path="baseline"))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.ssd(x, dt, a, bb, cc, path=path))
        np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_core_dispatch_ssd_return_state_paths_agree():
    """The prefill->decode handoff state must agree on every path — the
    kernel path's padded-state slice (lam zero-pad => decay 1) is the
    subtle part, exercised here with L not a multiple of the chunk."""
    b, L, h, p, g, n = 1, 100, 2, 8, 1, 4   # L=100: forces padding
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, L, g, n)) / np.sqrt(n)
    cc = jax.random.normal(ks[4], (b, L, g, n)) / np.sqrt(n)
    y_want, h_want = dispatch.ssd(x, dt, a, bb, cc, path="baseline",
                                  return_state=True)
    assert h_want.shape == (b, h, p, n)
    for path in ("fused", "interpret"):
        y_got, h_got = dispatch.ssd(x, dt, a, bb, cc, path=path,
                                    return_state=True)
        assert h_got.shape == (b, h, p, n)
        np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_want),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(h_got), np.asarray(h_want),
                                   rtol=2e-3, atol=2e-3)
        # y must be identical with and without the state request
        y_only = dispatch.ssd(x, dt, a, bb, cc, path=path)
        np.testing.assert_allclose(np.asarray(y_got), np.asarray(y_only),
                                   rtol=0, atol=0)


def test_env_var_steers_op_execution(monkeypatch):
    """REPRO_KERNEL_PATH reroutes an unannotated call site end to end."""
    x = jnp.ones((2, 130))
    monkeypatch.setenv(backend.ENV_PATH, "interpret")
    got = np.asarray(ops.segmented_reduce(x))
    monkeypatch.setenv(backend.ENV_PATH, "fused")
    want = np.asarray(ops.segmented_reduce(x))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    np.testing.assert_allclose(want, 130.0)


@pytest.mark.parametrize("envval", ["fused", "tile", "interpret",
                                    "baseline", "xla_tile"])
def test_env_values_never_crash_kernel_ops(monkeypatch, envval):
    """The env var is process-wide and shared with repro.core.dispatch, so
    its algorithm-level values (baseline/xla_tile) must not blow up
    kernel-level call sites (e.g. every model's rmsnorm)."""
    monkeypatch.setenv(backend.ENV_PATH, envval)
    x = jnp.ones((2, 130))
    np.testing.assert_allclose(
        np.asarray(ops.segmented_reduce(x)), 130.0, rtol=1e-6)


def test_legacy_use_pallas_kwarg_still_works():
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 100))
    np.testing.assert_allclose(
        np.asarray(ops.segmented_reduce(x, use_pallas=True)),
        np.asarray(ops.segmented_reduce(x, use_pallas=False)),
        rtol=1e-4, atol=1e-3)


def test_conflicting_path_and_use_pallas_warns_path_wins():
    x = jnp.ones((2, 100))
    with pytest.warns(UserWarning, match="path= takes precedence"):
        assert backend._merge_use_pallas("fused", True) == "fused"
    with pytest.warns(UserWarning, match="path= takes precedence"):
        got = ops.segmented_reduce(x, path="fused", use_pallas=True)
    np.testing.assert_allclose(np.asarray(got), 100.0)
    with pytest.warns(UserWarning):
        assert backend._merge_use_pallas("tile", False) == "tile"


def test_agreeing_path_and_use_pallas_no_warning(recwarn):
    # interpret runs the same kernel body -> not a conflict with
    # use_pallas=True; matching values never warn
    assert backend._merge_use_pallas("interpret", True) == "interpret"
    assert backend._merge_use_pallas("fused", False) == "fused"
    silent = dataclasses.replace(kpolicy.get_policy(),
                                 interpret_fallback="silent")
    assert silent.resolve(
        level="kernel",
        explicit=backend._merge_use_pallas(None, False)) == "fused"
    assert not [w for w in recwarn.list
                if issubclass(w.category, UserWarning)]


# ---------------------------------------------------------------------------
# autodiff: kernel paths differentiate (backward rides the ref twin)


def test_kernel_paths_differentiate_like_fused():
    """pallas_call has no JVP rule in interpret mode, so the kernel
    registry wraps every tile entry in a custom VJP whose backward runs
    the reference formulation — a train step under policy='interpret'
    (or 'tile' on an accelerator) must produce the same gradients as
    'fused'."""
    x = jax.random.normal(jax.random.PRNGKey(12), (2, 130))

    def loss(path):
        return lambda a: jnp.sum(ops.segmented_scan(a, path=path) ** 2)

    g_fused = np.asarray(jax.grad(loss("fused"))(x))
    g_int = np.asarray(jax.grad(loss("interpret"))(x))
    np.testing.assert_allclose(g_int, g_fused, rtol=1e-4, atol=1e-4)

    def red_loss(path):
        return lambda a: jnp.sum(ops.segmented_reduce(a, path=path) ** 2)

    np.testing.assert_allclose(
        np.asarray(jax.grad(red_loss("interpret"))(x)),
        np.asarray(jax.grad(red_loss("fused"))(x)), rtol=1e-4, atol=1e-4)


def test_attention_and_ssd_interpret_paths_differentiate():
    ks = jax.random.split(jax.random.PRNGKey(13), 5)
    q = jax.random.normal(ks[0], (1, 2, 128, 16))
    k = jax.random.normal(ks[1], (1, 2, 128, 16))
    v = jax.random.normal(ks[2], (1, 2, 128, 16))

    def att_loss(path):
        return lambda qq: jnp.sum(ops.attention(qq, k, v, path=path) ** 2)

    g_f = np.asarray(jax.grad(att_loss("fused"))(q))
    g_i = np.asarray(jax.grad(att_loss("interpret"))(q))
    np.testing.assert_allclose(g_i, g_f, rtol=2e-3, atol=2e-3)

    x = 0.2 * jax.random.normal(ks[3], (1, 64, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (1, 64, 2)))
    a = -jnp.exp(jnp.zeros((2,)))
    bb = jax.random.normal(ks[0], (1, 64, 1, 4)) / 2.0
    cc = jax.random.normal(ks[1], (1, 64, 1, 4)) / 2.0

    def ssd_loss(path):
        return lambda xx: jnp.sum(
            dispatch.ssd(xx, dt, a, bb, cc, path=path) ** 2)

    g_f = np.asarray(jax.grad(ssd_loss("fused"))(x))
    g_i = np.asarray(jax.grad(ssd_loss("interpret"))(x))
    np.testing.assert_allclose(g_i, g_f, rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# exclusive scan: shift, never inclusive-minus-x (catastrophic cancellation)


@pytest.mark.parametrize("path", ["fused", "interpret", "baseline"])
def test_exclusive_scan_adversarial_magnitudes(path):
    """exclusive[i] must be exact when the preceding prefix is tiny and
    x[i] is huge — reconstructing it as ``inclusive - x`` absorbs the
    prefix into x[i]'s rounding and returns garbage."""
    x = jnp.asarray([0.1, 0.2, 0.3, 1e8, -1e8, 0.4], jnp.float32)
    got = np.asarray(dispatch.scan(x, path=path, exclusive=True))
    want = np.concatenate(
        [[0.0], np.cumsum(np.asarray(x, np.float64))[:-1]])
    # positions 0..3 have small true prefixes; the shift keeps them exact
    np.testing.assert_allclose(got[:4], want[:4], rtol=1e-6, atol=1e-6)
    assert got.shape == x.shape


def test_exclusive_scan_paths_agree_random():
    x = jax.random.normal(jax.random.PRNGKey(10), (4, 300))
    want = np.asarray(dispatch.scan(x, path="baseline", exclusive=True))
    for path in ("fused", "interpret"):
        got = np.asarray(dispatch.scan(x, path=path, exclusive=True))
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-2)


# ---------------------------------------------------------------------------
# ragged entries (the paper's footnote-4 case through the one switch)

RAGGED_PATHS = ["fused", "xla_tile", "interpret", "baseline"]


def _ragged_case(n, s, seed, dtype):
    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, s, n)).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    return jnp.asarray(x).astype(dtype), jnp.asarray(seg)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", RAGGED_PATHS)
def test_ragged_reduce_paths_agree(path, dtype):
    n, s = 300, 7
    x, seg = _ragged_case(n, s, 0, dtype)
    got = np.asarray(dispatch.ragged_reduce(x, seg, s, path=path))
    xs = np.asarray(x, np.float32)
    segn = np.asarray(seg)
    want = np.array([xs[segn == i].sum() for i in range(s)])
    tol = dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(got, want, **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("path", RAGGED_PATHS)
def test_ragged_scan_paths_agree(path, dtype):
    n, s = 300, 7
    x, seg = _ragged_case(n, s, 1, dtype)
    got = np.asarray(dispatch.ragged_scan(x, seg, s, path=path))
    xs = np.asarray(x, np.float32)
    segn = np.asarray(seg)
    want = np.empty(n, np.float32)
    for i in range(s):
        m = segn == i
        want[m] = np.cumsum(xs[m])
    tol = dict(rtol=1e-3, atol=1e-2) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(got, want, **tol)


@pytest.mark.parametrize("path", ["fused", "baseline"])
def test_ragged_batched_seg_ids(path):
    """Per-batch segment assignments (the MoE per-group layout)."""
    g, n, s = 3, 64, 5
    rng = np.random.default_rng(2)
    seg = np.sort(rng.integers(0, s, (g, n)), axis=-1).astype(np.int32)
    x = rng.normal(size=(g, n)).astype(np.float32)
    got = np.asarray(dispatch.ragged_reduce(jnp.asarray(x),
                                            jnp.asarray(seg), s, path=path))
    want = np.stack([[x[b][seg[b] == i].sum() for i in range(s)]
                     for b in range(g)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ragged_env_var_steers(monkeypatch):
    x, seg = _ragged_case(200, 5, 3, jnp.float32)
    monkeypatch.setenv(backend.ENV_PATH, "baseline")
    got_b = np.asarray(dispatch.ragged_scan(x, seg, 5))
    monkeypatch.setenv(backend.ENV_PATH, "fused")
    got_f = np.asarray(dispatch.ragged_scan(x, seg, 5))
    np.testing.assert_allclose(got_b, got_f, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# consumer discipline: every model/optim/serving op goes through the switch


def test_no_direct_core_primitive_imports_outside_core_kernels():
    """Same discipline as the compiler-params guard: the dispatch layer is
    the single source of truth for which formulation runs where. Modules
    outside repro.core/repro.kernels must not touch the primitives
    directly — that is exactly the bypass that made REPRO_KERNEL_PATH
    silently no-op for models, optim, and the ragged ops."""
    pat = re.compile(
        r"\b(tcu_segmented_reduce|tcu_scan|tcu_reduce|tcu_weighted_scan"
        r"|tcu_ragged_segment_reduce|tcu_ragged_segment_scan"
        r"|ssd_chunked)\b")
    offenders = []
    for p in sorted(SRC.rglob("*.py")):
        rel = p.relative_to(SRC)
        if rel.parts[0] in ("core", "kernels"):
            continue
        if pat.search(p.read_text()):
            offenders.append(str(rel))
    assert not offenders, (
        f"direct repro.core primitive use in {offenders}; route through "
        "repro.core.dispatch (path= / REPRO_KERNEL_PATH / autotuned auto)"
    )
