"""Grid-level (device) reduce/scan + pipeline tests.

These need >1 device, so they run in a subprocess with
``xla_force_host_platform_device_count`` set before jax initialises —
the main pytest process keeps the brief-mandated single device.
"""
import os
import subprocess
import sys
import textwrap

import pytest


def _run(ndev: int, body: str) -> str:
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={ndev}"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.parallel.compat import make_mesh, shard_map
    """) + textwrap.dedent(body)
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"}
    if "JAX_PLATFORMS" in os.environ:  # keep the parent's backend pin —
        # without it a TPU-enabled jaxlib probes for hardware and hangs
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, timeout=600, env=env,
                          cwd=__file__.rsplit("/tests/", 1)[0])
    assert proc.returncode == 0, proc.stderr[-3000:]
    return proc.stdout


def test_dist_reduce_correct():
    out = _run(4, """
        from repro.core import dist_reduce
        mesh = make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 2, 512))

        def f(xl):
            return dist_reduce(xl, "data")

        r = shard_map(f, mesh=mesh, in_specs=P("data"),
                      out_specs=P())(x)
        np.testing.assert_allclose(float(r), float(jnp.sum(x)), rtol=1e-4)
        print("REDUCE_OK")
    """)
    assert "REDUCE_OK" in out


def test_dist_scan_correct():
    out = _run(4, """
        from repro.core import dist_scan
        mesh = make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(1), (3, 2048))

        def g(xl):
            return dist_scan(xl, "data")

        s = shard_map(g, mesh=mesh, in_specs=P(None, "data"),
                      out_specs=P(None, "data"))(x)
        np.testing.assert_allclose(
            np.asarray(s), np.cumsum(np.asarray(x), -1),
            rtol=1e-3, atol=1e-2)
        print("SCAN_OK")
    """)
    assert "SCAN_OK" in out


def test_dist_weighted_scan_correct():
    out = _run(4, """
        from repro.core import dist_weighted_scan
        mesh = make_mesh((4,), ("data",))
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 1024))
        la = -jax.random.uniform(jax.random.PRNGKey(3), (2, 1024))

        def g(xl, ll):
            return dist_weighted_scan(xl, ll, "data")

        s = shard_map(g, mesh=mesh,
                      in_specs=(P(None, "data"), P(None, "data")),
                      out_specs=P(None, "data"))(x, la)
        xa, laa = np.asarray(x), np.asarray(la)
        ref = np.zeros_like(xa)
        for r in range(2):
            y = 0.0
            for i in range(1024):
                y = np.exp(laa[r, i]) * y + xa[r, i]
                ref[r, i] = y
        np.testing.assert_allclose(np.asarray(s), ref, rtol=1e-3, atol=1e-3)
        print("WSCAN_OK")
    """)
    assert "WSCAN_OK" in out


def test_pipeline_parallel_matches_sequential():
    out = _run(4, """
        from repro.parallel.pipeline import (PipelineConfig, pipeline_apply,
                                             pipeline_stats)
        mesh = make_mesh((4,), ("stage",))
        S, M, mb, d = 4, 8, 2, 16
        w = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * 0.1

        def block(wl, x):
            return x + jnp.tanh(x @ wl)

        x = jax.random.normal(jax.random.PRNGKey(1), (M * mb, d))
        cfg = PipelineConfig(n_stages=S, n_microbatches=M)
        y = pipeline_apply(block, w, x, cfg, mesh)
        ref = x
        for si in range(S):
            ref = block(w[si], ref)
        np.testing.assert_allclose(y, ref, rtol=1e-5, atol=1e-5)
        st = pipeline_stats(cfg)
        assert st["ticks"] == 11 and abs(st["bubble_fraction"] - 3/11) < 1e-9
        print("PIPE_OK")
    """)
    assert "PIPE_OK" in out


def test_training_shards_run_on_mesh():
    """End-to-end: 2x2 mesh, TP+DP smoke training step with sharded state."""
    out = _run(4, """
        from repro import configs
        from repro.configs.common import smoke_batch
        from repro.models import build
        from repro.optim import OptConfig
        from repro.parallel.sharding import Rules, use_rules
        from repro.training import (TrainConfig, init_train_state,
                                    make_train_step)
        from repro.training.train_lib import train_state_pspecs

        mesh = make_mesh((2, 2), ("data", "model"))
        rules = Rules(table={"batch": ("data",), "heads": "model",
                             "kv_heads": "model", "ff": "model",
                             "vocab": "model", "embed": None,
                             "layers": None},
                      fsdp="data", axis_sizes={"data": 2, "model": 2})
        mod = configs.get("llama3.2-1b")
        bundle = build(mod.SMOKE)
        opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
        with use_rules(rules), mesh:
            state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
            step = jax.jit(make_train_step(bundle, opt_cfg))
            batch = smoke_batch(mod.SMOKE)
            l0 = None
            for _ in range(3):
                state, m = step(state, batch)
                l0 = l0 or float(m["loss"])
            assert float(m["loss"]) < l0
        print("MESH_TRAIN_OK", l0, float(m["loss"]))
    """)
    assert "MESH_TRAIN_OK" in out


def test_shard_ops_route_and_match_unsharded():
    """ops.reduce/scan/weighted_scan on committed sharded arrays under an
    active MeshContext run the shard_map path and match the unsharded
    references (the tentpole's numerics contract)."""
    out = _run(4, """
        from jax.sharding import NamedSharding
        from repro import ops
        from repro.parallel import shard_ops
        from repro.parallel.mesh_context import make_context

        ctx = make_context("data=4")
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 4096))
        la = -jax.random.uniform(jax.random.PRNGKey(1), (3, 4096))
        want_r = np.asarray(ops.reduce(x))
        want_s = np.asarray(ops.scan(x))
        want_w = np.asarray(ops.weighted_scan(x, la))

        shd = NamedSharding(ctx.mesh, P(None, "data"))
        xs, las = jax.device_put(x, shd), jax.device_put(la, shd)
        with ctx:
            assert shard_ops._routing_ctx(xs, 1) is not None
            got_r = np.asarray(ops.reduce(xs))
            got_s = np.asarray(ops.scan(xs))
            got_w = np.asarray(ops.weighted_scan(xs, las))
        np.testing.assert_allclose(got_r, want_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(got_s, want_s, rtol=1e-3, atol=1e-2)
        np.testing.assert_allclose(got_w, want_w, rtol=1e-3, atol=1e-3)

        # non-divisible bucket axis: conservative fallback, still correct
        x_odd = jax.random.normal(jax.random.PRNGKey(2), (2, 1023))
        with ctx:
            assert shard_ops._routing_ctx(x_odd, 1) is None
            np.testing.assert_allclose(np.asarray(ops.reduce(x_odd)),
                                       np.asarray(jnp.sum(
                                           x_odd.astype(jnp.float32), -1)),
                                       rtol=1e-4, atol=1e-4)
        print("SHARD_OPS_OK")
    """)
    assert "SHARD_OPS_OK" in out


def test_shard_ops_ssd_matches_unsharded():
    """Sequence-sharded SSD (shard finals carried by the 1-semiseparable
    combine) against the unsharded op, y and final state both."""
    out = _run(4, """
        from jax.sharding import NamedSharding
        from repro import ops
        from repro.parallel.mesh_context import make_context

        ctx = make_context("data=4")
        bsz, L, h, p, g, n = 1, 128, 2, 8, 1, 4
        ks = jax.random.split(jax.random.PRNGKey(8), 5)
        x = 0.2 * jax.random.normal(ks[0], (bsz, L, h, p))
        dt = jax.nn.softplus(jax.random.normal(ks[1], (bsz, L, h)))
        a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
        bb = jax.random.normal(ks[3], (bsz, L, g, n)) / np.sqrt(n)
        cc = jax.random.normal(ks[4], (bsz, L, g, n)) / np.sqrt(n)
        want_y, want_h = ops.ssd(x, dt, a, bb, cc, return_state=True)

        seq = lambda nd: NamedSharding(
            ctx.mesh, P(*((None, "data") + (None,) * (nd - 2))))
        xs = jax.device_put(x, seq(4))
        dts = jax.device_put(dt, seq(3))
        bbs = jax.device_put(bb, seq(4))
        ccs = jax.device_put(cc, seq(4))
        with ctx:
            got_y, got_h = ops.ssd(xs, dts, a, bbs, ccs, return_state=True)
            got_y2 = ops.ssd(xs, dts, a, bbs, ccs)
        np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(got_h), np.asarray(want_h),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(got_y2), np.asarray(got_y),
                                   rtol=1e-5, atol=1e-5)
        print("SHARD_SSD_OK")
    """)
    assert "SHARD_SSD_OK" in out


def test_elastic_restart_across_mesh_sizes(tmp_path):
    """Fault-tolerance contract: checkpoint under a 4-device mesh, restore
    and continue under a 2-device mesh — values identical (elastic)."""
    out = _run(4, f"""
        from repro import configs
        from repro.checkpoint import ckpt
        from repro.configs.common import smoke_batch
        from repro.models import build
        from repro.optim import OptConfig
        from repro.parallel.sharding import Rules, use_rules
        from repro.training import init_train_state, make_train_step

        mod = configs.get("llama3.2-1b")
        bundle = build(mod.SMOKE)
        opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
        mesh = make_mesh((4,), ("data",))
        rules = Rules(table={{"batch": ("data",)}}, fsdp="data",
                      axis_sizes={{"data": 4}})
        with use_rules(rules), mesh:
            state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
            step = jax.jit(make_train_step(bundle, opt_cfg))
            state, m = step(state, smoke_batch(mod.SMOKE))
            ckpt.save("{tmp_path}", 1, state)
        print("SAVED", float(m["loss"]))
    """)
    assert "SAVED" in out
    out2 = _run(2, f"""
        from repro import configs
        from repro.checkpoint import ckpt
        from repro.configs.common import smoke_batch
        from repro.models import build
        from repro.optim import OptConfig
        from repro.parallel.sharding import Rules, use_rules
        from repro.training import init_train_state, make_train_step

        mod = configs.get("llama3.2-1b")
        bundle = build(mod.SMOKE)
        opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
        mesh = make_mesh((2,), ("data",))
        rules = Rules(table={{"batch": ("data",)}}, fsdp="data",
                      axis_sizes={{"data": 2}})
        with use_rules(rules), mesh:
            template = init_train_state(jax.random.PRNGKey(0), bundle,
                                        opt_cfg)
            state = ckpt.restore("{tmp_path}", 1, template)
            step = jax.jit(make_train_step(bundle, opt_cfg))
            state, m = step(state, smoke_batch(mod.SMOKE))
            assert int(state["opt"]["step"]) == 2     # resumed, not reset
        print("RESTORED_OK", float(m["loss"]))
    """)
    assert "RESTORED_OK" in out2
