"""KernelPolicy subsystem tests: context-manager scoping, hashability /
jit-static-arg use, per-op overrides, string-shorthand coercion, the
deprecation shims (warn once, keep working), and the grep guard pinning
env parsing to exactly one home (``repro.core.policy``)."""
import dataclasses
import functools
import re
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, dispatch
from repro.core import policy as kpolicy
from repro.core.policy import KernelPolicy
from repro.kernels import backend, ops
from repro.models.layers import ModelConfig
from repro.optim import OptConfig
from repro.serving import ServeConfig

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# env parsing has exactly one home


def test_env_vars_parsed_only_in_policy_module():
    """Outside core/policy.py, no module may read REPRO_KERNEL_PATH /
    REPRO_AUTOTUNE* via os.environ — the process default is built once by
    the policy layer, and everything else consumes the policy object.
    (Referencing the env-var *names* is fine; reading them is not.)"""
    pat = re.compile(
        r"os\.environ(?:\.get)?\s*[\[(][^)\]]*"
        r"(?:REPRO_KERNEL_PATH|REPRO_AUTOTUNE|ENV_PATH|ENV_AUTOTUNE"
        r"|ENV_TABLE)", re.DOTALL)
    offenders = []
    for p in sorted(SRC.rglob("*.py")):
        rel = p.relative_to(SRC)
        if rel == Path("core/policy.py"):
            continue
        if pat.search(p.read_text()):
            offenders.append(str(rel))
    assert not offenders, (
        f"kernel-selection env vars read outside core/policy.py in "
        f"{offenders}; consume repro.core.policy.get_policy() instead"
    )


def test_default_policy_built_from_env(monkeypatch):
    monkeypatch.delenv(kpolicy.ENV_PATH, raising=False)
    monkeypatch.delenv(kpolicy.ENV_AUTOTUNE, raising=False)
    monkeypatch.delenv(kpolicy.ENV_TABLE, raising=False)
    assert kpolicy.default_policy() == KernelPolicy()
    monkeypatch.setenv(kpolicy.ENV_PATH, "baseline")
    monkeypatch.setenv(kpolicy.ENV_AUTOTUNE, "off")
    monkeypatch.setenv(kpolicy.ENV_TABLE, "/tmp/t.json")
    pol = kpolicy.default_policy()
    assert pol.path == "baseline"
    assert pol.autotune == "off"
    assert pol.autotune_table == "/tmp/t.json"
    # the default IS the active policy when nothing is installed
    assert kpolicy.get_policy() == pol


# ---------------------------------------------------------------------------
# scoping: context managers nest and restore; set_policy is token-based


def test_nested_context_managers_restore_correctly():
    base = kpolicy.get_policy()
    with kpolicy.using_policy("fused") as outer:
        assert outer.path == "fused"
        assert kpolicy.get_policy().path == "fused"
        with kpolicy.using_policy(KernelPolicy(path="baseline")) as inner:
            assert inner.path == "baseline"
            assert kpolicy.get_policy().path == "baseline"
        assert kpolicy.get_policy().path == "fused"   # inner popped
    assert kpolicy.get_policy() == base               # fully restored


def test_nested_restore_even_on_exception():
    base = kpolicy.get_policy()
    with pytest.raises(RuntimeError):
        with kpolicy.using_policy("interpret"):
            raise RuntimeError("boom")
    assert kpolicy.get_policy() == base


def test_set_policy_token_reset():
    base = kpolicy.get_policy()
    tok = kpolicy.set_policy("baseline")
    assert kpolicy.get_policy().path == "baseline"
    kpolicy.reset_policy(tok)
    assert kpolicy.get_policy() == base


def test_policy_steers_op_execution_scoped():
    """A scoped policy reroutes an unannotated call end to end, and the
    numbers agree across policies (the dispatch-agreement contract)."""
    x = jnp.ones((2, 130))
    with kpolicy.using_policy("baseline"):
        got_b = np.asarray(dispatch.reduce(x))
    with kpolicy.using_policy("fused"):
        got_f = np.asarray(dispatch.reduce(x))
    np.testing.assert_allclose(got_b, got_f, rtol=1e-6)
    np.testing.assert_allclose(got_f, 130.0)


# ---------------------------------------------------------------------------
# hashability / jit-static-arg / repr round-trip


def test_policy_hashable_and_jit_static():
    pol = KernelPolicy(path="fused", op_paths={"attention": "baseline"})
    assert hash(pol) == hash(
        KernelPolicy(path="fused", op_paths={"attention": "baseline"}))
    assert pol in {pol}

    @functools.partial(jax.jit, static_argnums=1)
    def f(x, policy):
        return dispatch.reduce(x, policy=policy)

    x = jnp.ones((2, 64))
    np.testing.assert_allclose(np.asarray(f(x, pol)), 64.0)
    np.testing.assert_allclose(
        np.asarray(f(x, KernelPolicy(path="baseline"))), 64.0)


def test_policy_repr_roundtrip():
    pol = KernelPolicy(path="auto", op_paths={"attention": "fused"},
                       autotune="off", interpret_fallback="silent")
    assert eval(repr(pol), {"KernelPolicy": KernelPolicy}) == pol


def test_policy_validates_fields():
    with pytest.raises(ValueError, match="unknown path"):
        KernelPolicy(path="warp")
    with pytest.raises(ValueError, match="op_paths"):
        KernelPolicy(op_paths={"reduce": "warp"})
    with pytest.raises(ValueError, match="autotune mode"):
        KernelPolicy(autotune="maybe")
    with pytest.raises(ValueError, match="interpret_fallback"):
        KernelPolicy(interpret_fallback="explode")
    with pytest.raises(ValueError, match="backend"):
        KernelPolicy(backend="warpspeed")


# ---------------------------------------------------------------------------
# per-op overrides and string shorthands


def test_per_op_override_beats_global_path():
    pol = KernelPolicy(path="baseline", op_paths={"reduce": "fused"})
    assert pol.resolve(op="reduce", n=64, dtype=jnp.float32) == "fused"
    assert pol.resolve(op="scan", n=64, dtype=jnp.float32) == "baseline"
    # and end to end: reduce runs the matmul form, scan the native op
    x = jnp.ones((2, 64))
    np.testing.assert_allclose(np.asarray(dispatch.reduce(x, policy=pol)),
                               64.0)
    np.testing.assert_allclose(
        np.asarray(dispatch.scan(x, policy=pol))[:, -1], 64.0)


def test_explicit_path_kwarg_beats_op_override():
    pol = KernelPolicy(path="auto", op_paths={"reduce": "baseline"})
    assert pol.resolve(op="reduce", n=64, explicit="xla_tile") == "xla_tile"


def test_string_shorthands_coerce():
    assert KernelPolicy.from_spec("fused") == KernelPolicy(path="fused")
    assert KernelPolicy.from_spec("reduce=tile,scan=baseline") == \
        KernelPolicy(op_paths={"reduce": "tile", "scan": "baseline"})
    assert KernelPolicy.from_spec("baseline,attention=fused") == \
        KernelPolicy(path="baseline", op_paths={"attention": "fused"})
    assert KernelPolicy.from_spec(
        '{"path": "auto", "autotune": "off"}') == \
        KernelPolicy(path="auto", autotune="off")
    with pytest.raises(ValueError):
        KernelPolicy.from_spec("warp")
    with pytest.raises(TypeError):
        KernelPolicy.from_spec(1234)


def test_op_paths_mapping_normalises_sorted():
    a = KernelPolicy(op_paths={"scan": "fused", "reduce": "tile"})
    b = KernelPolicy(op_paths=(("reduce", "tile"), ("scan", "fused")))
    assert a == b
    assert a.op_paths == (("reduce", "tile"), ("scan", "fused"))


def test_op_paths_unknown_op_rejected_and_aliases_normalise():
    """A typo'd op name must raise at construction — a silently
    never-matching override is the no-op failure mode this subsystem
    exists to remove. Kernel-registry spellings alias onto the canonical
    names so one override steers both layers."""
    with pytest.raises(ValueError, match="unknown op"):
        KernelPolicy(op_paths={"atention": "fused"})
    assert KernelPolicy(op_paths={"segmented_reduce": "baseline"}) == \
        KernelPolicy(op_paths={"reduce": "baseline"})
    assert KernelPolicy(op_paths={"ssd_scan": "fused"}) == \
        KernelPolicy(op_paths={"ssd": "fused"})
    # a canonical-name override steers a kernel-registry-level call
    pol = KernelPolicy(op_paths={"reduce": "baseline"})
    assert pol.for_op("segmented_reduce") == "baseline"
    x = jnp.ones((2, 100))
    np.testing.assert_allclose(
        np.asarray(ops.segmented_reduce(x, policy=pol)), 100.0)


def test_per_call_string_overlays_active_policy():
    """A bare label per call means 'exactly this path' — it clears per-op
    overrides but keeps the rest of the active policy (e.g. the
    interpret_fallback behaviour)."""
    with kpolicy.using_policy(KernelPolicy(
            path="auto", op_paths={"reduce": "baseline"},
            interpret_fallback="silent")):
        pol = kpolicy.as_policy("fused")
        assert pol.path == "fused"
        assert pol.op_paths == ()
        assert pol.interpret_fallback == "silent"


# ---------------------------------------------------------------------------
# exactly one resolve implementation; the old entry points are gone


def test_legacy_resolve_path_entry_points_removed():
    """The PR-4 warn-once ``resolve_path`` delegates have been deleted:
    resolution has exactly one entry point, ``KernelPolicy.resolve`` (per
    call via ``path=``/``policy=`` on the ops themselves)."""
    assert not hasattr(dispatch, "resolve_path")
    assert not hasattr(backend, "resolve_path")
    # the one true implementation covers both levels the delegates served
    pol = kpolicy.get_policy()
    assert pol.resolve(explicit="xla_tile") == "xla_tile"
    assert pol.resolve(explicit="baseline") == "baseline"
    assert pol.resolve(level="kernel", explicit="fused") == "fused"
    assert pol.resolve(level="kernel", explicit="interpret") == "interpret"


def test_single_resolve_implementation_grep_guard():
    """No module outside core/policy.py re-implements resolution
    (= consults native_tile_backend to map the generic 'tile' label)."""
    pat = re.compile(r"native_tile_backend\(\)")
    offenders = []
    for p in sorted(SRC.rglob("*.py")):
        rel = p.relative_to(SRC)
        if rel == Path("core/policy.py") or \
                rel == Path("kernels/backend.py"):  # defines the probe
            continue
        if pat.search(p.read_text()):
            offenders.append(str(rel))
    # autotune legitimately checks lowering compatibility of table entries
    assert offenders in ([], ["core/autotune.py"]), (
        f"possible second resolve implementation in {offenders}")


# ---------------------------------------------------------------------------
# deprecation shims: config kwargs warn once and keep working


@pytest.mark.parametrize("cls,key", [
    (ModelConfig, "deprecated:ModelConfig.kernel_path"),
    (OptConfig, "deprecated:OptConfig.kernel_path"),
    (ServeConfig, "deprecated:ServeConfig.kernel_path"),
])
def test_config_kernel_path_shim_warns_once_and_coerces(cls, key):
    kwargs = dict(name="t", family="dense", n_layers=1, d_model=8,
                  vocab=16) if cls is ModelConfig else {}
    kpolicy._WARNED.discard(key)
    with pytest.warns(DeprecationWarning, match="kernel_path"):
        cfg = cls(**kwargs, kernel_path="fused")
    assert cfg.policy == KernelPolicy(path="fused")
    # once: the second construction is silent
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cfg2 = cls(**kwargs, kernel_path="baseline")
    assert cfg2.policy == KernelPolicy(path="baseline")
    # strings auto-coerce on the new field too, and explicit policy wins
    assert cls(**kwargs, policy="interpret").policy == \
        KernelPolicy(path="interpret")
    assert cls(**kwargs, policy="fused",
               kernel_path="baseline").policy == KernelPolicy(path="fused")
    # replace() keeps the coerced policy without re-warning
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert dataclasses.replace(cfg).policy == cfg.policy


def test_repro_ops_path_kwarg_warns_once_and_works():
    import repro.ops as rops

    x = jnp.ones((2, 100))
    kpolicy._WARNED.discard("deprecated:repro.ops.path")
    with pytest.warns(DeprecationWarning, match="policy="):
        got = rops.reduce(x, path="fused")
    np.testing.assert_allclose(np.asarray(got), 100.0)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        got = rops.reduce(x, path="baseline")
    np.testing.assert_allclose(np.asarray(got), 100.0)


def test_no_kernel_path_str_fields_left_in_src():
    """Acceptance criterion: ``kernel_path: str`` annotations are gone
    from src/ — the only surviving kernel_path spellings are the InitVar
    deprecation shims."""
    offenders = []
    for p in sorted(SRC.rglob("*.py")):
        rel = p.relative_to(SRC)
        if rel == Path("core/policy.py"):
            continue  # coerce_config_policy IS the deprecation shim
        for i, line in enumerate(p.read_text().splitlines(), 1):
            if re.search(r"kernel_path\s*:\s*str", line):
                offenders.append(f"{rel}:{i}")
    assert not offenders, (
        f"raw kernel_path string fields remain: {offenders}; use "
        "policy: KernelPolicy (kernel_path is InitVar-shimmed only)")


# ---------------------------------------------------------------------------
# policy-aware autotune plumbing


def test_policy_autotune_fields_gate_resolution(tmp_path):
    table = {"version": autotune.TABLE_VERSION, "backends": {
        autotune.current_backend(): {"jax": jax.__version__, "entries": {
            "reduce/f32/4": {"path": "baseline", "us": {}}}}}}
    path = tmp_path / "t.json"
    autotune.save_table(table, path)
    on = KernelPolicy(path="auto", autotune_table=str(path))
    assert on.resolve(op="reduce", n=16, dtype=jnp.float32) == "baseline"
    off = dataclasses.replace(on, autotune="off")
    if backend.native_tile_backend() is None:
        assert off.resolve(op="reduce", n=16, dtype=jnp.float32) == "fused"
    # an explicitly-named unusable table fails loudly through the policy
    bad = dataclasses.replace(on, autotune_table=str(tmp_path / "no.json"))
    with pytest.raises(ValueError, match="unusable"):
        bad.resolve(op="reduce", n=16, dtype=jnp.float32)
    autotune.invalidate_cache()


def test_backend_preference_field():
    pol = KernelPolicy(path="tile", backend="cpu")
    assert pol.resolve(op="reduce", n=64) == "interpret"
    native = backend.native_tile_backend()
    if native != "tile_gpu":
        with pytest.raises(RuntimeError, match="tile_gpu"):
            KernelPolicy(path="tile", backend="gpu").resolve(op="reduce",
                                                             n=64)
    if native != "tile_tpu":
        with pytest.raises(RuntimeError, match="tile_tpu"):
            KernelPolicy(path="tile", backend="tpu").resolve(op="reduce",
                                                             n=64)
