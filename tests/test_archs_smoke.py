"""Per-architecture smoke tests: reduced same-family configs, one forward /
train-step on CPU, asserting output shapes and no NaNs — plus prefill/decode
consistency (the decode path must reproduce the full forward logits)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.common import SMOKE_BATCH, SMOKE_SEQ, smoke_batch
from repro.models import build
from repro.models.common import init_params
from repro.optim import OptConfig
from repro.training import TrainConfig, init_train_state, make_train_step

ALL_ARCHS = configs.all_arch_ids()


def _setup(arch):
    mod = configs.get(arch)
    cfg = mod.SMOKE
    bundle = build(cfg)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                         cfg.dtype)
    return cfg, bundle, params


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_loss_finite(arch):
    cfg, bundle, params = _setup(arch)
    loss = bundle.loss(params, smoke_batch(cfg))
    assert np.isfinite(float(loss)), arch
    assert 3.0 < float(loss) < 8.0              # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    mod = configs.get(arch)
    cfg = mod.SMOKE
    bundle = build(cfg)
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=10)
    state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
    step = jax.jit(make_train_step(bundle, opt_cfg))
    batch = smoke_batch(cfg)
    state, m1 = step(state, batch)
    state, m2 = step(state, batch)
    assert np.isfinite(float(m1["loss"])) and np.isfinite(float(m2["loss"]))
    assert float(m2["loss"]) < float(m1["loss"])   # same batch twice learns
    assert np.isfinite(float(m1["grad_norm"]))
    flat = jax.tree.leaves(state["params"])
    assert all(bool(jnp.all(jnp.isfinite(p))) for p in flat)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_prefill_shapes(arch):
    cfg, bundle, params = _setup(arch)
    batch = {k: v for k, v in smoke_batch(cfg).items() if k != "labels"}
    logits, cache = bundle.prefill(params, batch)
    assert logits.shape[0] == SMOKE_BATCH
    assert logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert cache is not None


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg, bundle, params = _setup(arch)
    batch = {k: v for k, v in smoke_batch(cfg).items() if k != "labels"}
    logits, cache = bundle.prefill(params, batch)
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache2 = bundle.decode(params, cache, {"tokens": tok})
    assert logits2.shape == (SMOKE_BATCH, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits2.astype(jnp.float32))))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["llama3.2-1b", "mamba2-1.3b",
                                  "internlm2-20b", "zamba2-2.7b"])
def test_decode_consistent_with_full_forward(arch):
    """Teacher-forcing check: decoding token t+1 against the prefill cache
    must match the full forward over t+1 tokens at the last position."""
    cfg, bundle, params = _setup(arch)
    rng = jax.random.PRNGKey(42)
    t = 16
    tokens = jax.random.randint(rng, (2, t + 3), 0, cfg.vocab)

    logits_full, _ = bundle.prefill(params, {"tokens": tokens})
    _, cache = bundle.prefill(params, {"tokens": tokens[:, :t]})
    from repro.serving.engine import _pad_cache_seq

    cache = _pad_cache_seq(cache, 3)      # decode needs cache headroom
    for i in range(3):
        step_logits, cache = bundle.decode(
            params, cache, {"tokens": tokens[:, t + i:t + i + 1]})
        want = logits_full[:, t + i]
        got = step_logits[:, 0]
        np.testing.assert_allclose(
            np.asarray(got, np.float32), np.asarray(want, np.float32),
            rtol=2e-2, atol=2e-2)


def test_full_configs_match_assignment():
    """The FULL configs must carry the exact published numbers."""
    expect = {
        "zamba2-2.7b": dict(n_layers=54, d_model=2560, n_heads=32,
                            d_ff=10240, vocab=32000, ssm_state=64),
        "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, moe_d_ff=1536,
                                    vocab=151936, n_experts=128,
                                    experts_per_tok=8),
        "grok-1-314b": dict(n_layers=64, d_model=6144, n_heads=48,
                            n_kv_heads=8, moe_d_ff=32768, vocab=131072,
                            n_experts=8, experts_per_tok=2),
        "internvl2-76b": dict(n_layers=80, d_model=8192, n_heads=64,
                              n_kv_heads=8, d_ff=28672, vocab=128256),
        "llama3.2-1b": dict(n_layers=16, d_model=2048, n_heads=32,
                            n_kv_heads=8, d_ff=8192, vocab=128256),
        "internlm2-20b": dict(n_layers=48, d_model=6144, n_heads=48,
                              n_kv_heads=8, d_ff=16384, vocab=92544),
        "deepseek-67b": dict(n_layers=95, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=22016, vocab=102400),
        "h2o-danube-3-4b": dict(n_layers=24, d_model=3840, n_heads=32,
                                n_kv_heads=8, d_ff=10240, vocab=32000),
        "mamba2-1.3b": dict(n_layers=48, d_model=2048, vocab=50280,
                            ssm_state=128),
        "seamless-m4t-medium": dict(n_layers=12, enc_layers=12,
                                    d_model=1024, n_heads=16,
                                    n_kv_heads=16, d_ff=4096, vocab=256206),
    }
    for arch, fields in expect.items():
        cfg = configs.get(arch).FULL
        for k, v in fields.items():
            assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)


def test_all_archs_have_all_shape_cells():
    """Every arch either runs or explicitly skips each of the 4 shapes."""
    from repro.configs.common import SHAPE_TABLE

    for arch in ALL_ARCHS:
        mod = configs.get(arch)
        for shape in SHAPE_TABLE:
            assert shape in mod.SHAPES or shape in mod.SKIPS, (arch, shape)


def test_moe_identical_experts_equals_dense():
    """With every expert holding the same weights and ample capacity, MoE
    output must equal the plain SwiGLU MLP — routing becomes irrelevant."""
    from repro.models import layers as L
    from repro.models.common import swiglu

    cfg = configs.get("qwen3-moe-235b-a22b").SMOKE
    cfg = L.ModelConfig(**{**cfg.__dict__, "capacity_factor": 8.0})
    key = jax.random.PRNGKey(0)
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    w_in = jax.random.normal(key, (d, f)) / np.sqrt(d)
    w_gate = jax.random.normal(jax.random.fold_in(key, 1), (d, f)) / np.sqrt(d)
    w_out = jax.random.normal(jax.random.fold_in(key, 2), (f, d)) / np.sqrt(f)
    p = {
        "router": jax.random.normal(jax.random.fold_in(key, 3), (d, e)),
        "w_in": jnp.broadcast_to(w_in, (e, d, f)),
        "w_gate": jnp.broadcast_to(w_gate, (e, d, f)),
        "w_out": jnp.broadcast_to(w_out, (e, f, d)),
    }
    x = jax.random.normal(jax.random.fold_in(key, 4), (2, 8, d))
    got, aux = L.moe_apply(p, cfg, x)
    want = swiglu(x, w_in, w_gate, w_out)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-3, atol=1e-3)
    assert np.isfinite(float(aux))
