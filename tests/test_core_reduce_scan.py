"""Unit + property tests for the paper's matmul-form algebra (repro.core).

The property section used to fuzz with ``hypothesis``; tier-1 must survive
on a clean environment, so those invariants now run over deterministic
parametrized (size, seed) grids covering the same edge regions (tile
boundaries, tiny sizes, multi-level recursion depths).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    l_matrix,
    p_matrix,
    segsum,
    strict_u_matrix,
    tcu_reduce,
    tcu_scan,
    tcu_segmented_reduce,
    tcu_weighted_scan,
    u_matrix,
)

jax.config.update("jax_enable_x64", False)


# ---------------------------------------------------------------------------
# constructor identities (the paper's P/U/L definitions)


@pytest.mark.parametrize("t", [4, 16, 128])
def test_p_matrix_reduces_columns(t):
    rng = np.random.default_rng(0)
    a = rng.normal(size=(t, t)).astype(np.float32)
    v = np.asarray(p_matrix(t)) @ a
    np.testing.assert_allclose(v[0], a.sum(axis=0), rtol=1e-5)
    assert np.all(v[1:] == 0)


@pytest.mark.parametrize("t", [4, 16, 128])
def test_u_matrix_row_scan(t):
    rng = np.random.default_rng(1)
    a = rng.normal(size=(t, t)).astype(np.float32)
    np.testing.assert_allclose(a @ np.asarray(u_matrix(t)),
                               np.cumsum(a, axis=1), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("t", [4, 16, 128])
def test_l_matrix_exclusive_column_scan(t):
    rng = np.random.default_rng(2)
    a = rng.normal(size=(t, t)).astype(np.float32)
    la = np.asarray(l_matrix(t)) @ a
    expected = np.cumsum(a, axis=0) - a          # exclusive scan of columns
    np.testing.assert_allclose(la, expected, rtol=1e-4, atol=1e-4)


def test_paper_scan_identity_16():
    """Scan(A) = A U + (L A) 1 — the paper's Section 5 identity, verbatim."""
    t = 16
    rng = np.random.default_rng(3)
    v = rng.normal(size=(t * t,)).astype(np.float32)
    a = v.reshape(t, t)
    u = np.asarray(u_matrix(t))
    low = np.asarray(l_matrix(t))
    ones = np.ones((t, t), np.float32)
    scan = a @ u + (low @ a) @ ones
    np.testing.assert_allclose(scan.reshape(-1), np.cumsum(v),
                               rtol=1e-4, atol=1e-4)


def test_strict_u_exclusive():
    t = 16
    a = np.arange(t * t, dtype=np.float32).reshape(t, t)
    np.testing.assert_allclose(
        a @ np.asarray(strict_u_matrix(t)),
        np.cumsum(a, axis=1) - a, rtol=1e-5)


def test_segsum_degenerates_to_tril():
    t = 8
    m = np.exp(np.asarray(segsum(jnp.zeros((t,)))))
    np.testing.assert_allclose(m, np.tril(np.ones((t, t))), atol=1e-6)


def test_segsum_weighted_products():
    la = np.log(np.array([0.5, 0.25, 0.5, 1.0], np.float32))
    m = np.exp(np.asarray(segsum(jnp.asarray(la))))
    # M[i, j] = prod a[j+1..i]
    assert np.isclose(m[2, 0], 0.25 * 0.5)
    assert np.isclose(m[3, 1], 0.5 * 1.0)
    assert np.isclose(m[1, 1], 1.0)
    assert m[0, 2] == 0.0


# ---------------------------------------------------------------------------
# reduction


@pytest.mark.parametrize("formulation", ["fused", "tile"])
@pytest.mark.parametrize("n", [1, 7, 128, 200, 16384, 40000])
def test_reduce_sizes(formulation, n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    got = tcu_reduce(x, formulation=formulation)
    np.testing.assert_allclose(got, np.sum(np.asarray(x), dtype=np.float64),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
def test_reduce_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)).astype(dtype)
    got = tcu_reduce(x)
    assert got.dtype == jnp.float32            # f32 accumulation contract
    np.testing.assert_allclose(
        got, np.sum(np.asarray(x, np.float32)), rtol=2e-2, atol=0.5)


@pytest.mark.parametrize("formulation", ["fused", "tile"])
def test_segmented_reduce_batched(formulation):
    x = jax.random.normal(jax.random.PRNGKey(4), (3, 5, 700))
    got = tcu_segmented_reduce(x, formulation=formulation)
    np.testing.assert_allclose(got, np.asarray(x).sum(-1), rtol=1e-4,
                               atol=1e-3)


def test_formulations_agree():
    x = jax.random.normal(jax.random.PRNGKey(5), (4, 33000))
    a = tcu_segmented_reduce(x, formulation="fused")
    b = tcu_segmented_reduce(x, formulation="tile")
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# scan


@pytest.mark.parametrize("n", [1, 3, 128, 129, 500, 16384, 20000])
def test_scan_sizes(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    got = tcu_scan(x)
    np.testing.assert_allclose(got, np.cumsum(np.asarray(x)),
                               rtol=1e-3, atol=1e-2)


def test_scan_exclusive():
    x = jax.random.normal(jax.random.PRNGKey(6), (1000,))
    incl = np.cumsum(np.asarray(x))
    got = tcu_scan(x, exclusive=True)
    np.testing.assert_allclose(got[1:], incl[:-1], rtol=1e-3, atol=1e-2)
    assert abs(float(got[0])) < 1e-5


def test_scan_batched():
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 3, 777))
    got = tcu_scan(x)
    np.testing.assert_allclose(got, np.cumsum(np.asarray(x), axis=-1),
                               rtol=1e-3, atol=1e-2)


def test_weighted_scan_matches_sequential():
    n = 700
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(8), (n,)))
    la = np.asarray(-jax.random.uniform(jax.random.PRNGKey(9), (n,)))
    got = np.asarray(tcu_weighted_scan(jnp.asarray(x), jnp.asarray(la)))
    y, ref = 0.0, []
    for i in range(n):
        y = np.exp(la[i]) * y + x[i]
        ref.append(y)
    np.testing.assert_allclose(got, np.array(ref), rtol=1e-4, atol=1e-4)


def test_weighted_scan_zero_decay_is_plain_scan():
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 300))
    got = tcu_weighted_scan(x, jnp.zeros_like(x))
    np.testing.assert_allclose(got, np.cumsum(np.asarray(x), -1),
                               rtol=1e-4, atol=1e-3)


# ---------------------------------------------------------------------------
# scan small-input path (scan.py: n <= tile, exact-size triangle for n <= 8)


SMALL_NS = [1, 7, 8, 9, 50, 127, 128, 129]


@pytest.mark.parametrize("n", SMALL_NS)
def test_scan_small_inputs_exact(n):
    """Integer-valued inputs: f32 matmul-form sums are exact, so any padding
    slip in the ``t_eff = tile if n > 8 else n`` path shows up as != 0."""
    x = jnp.asarray(
        np.random.default_rng(n).integers(-50, 50, n), jnp.float32)
    got = np.asarray(tcu_scan(x))
    want = np.asarray(jnp.cumsum(x))
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", SMALL_NS)
def test_scan_small_inputs_exclusive_exact(n):
    x = jnp.asarray(
        np.random.default_rng(100 + n).integers(-50, 50, n), jnp.float32)
    got = np.asarray(tcu_scan(x, exclusive=True))
    incl = np.asarray(jnp.cumsum(x))
    want = np.concatenate([[0.0], incl[:-1]]).astype(np.float32)
    np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("n", SMALL_NS)
def test_scan_small_inputs_float(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    got = np.asarray(tcu_scan(x))
    want = np.asarray(jnp.cumsum(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n", [7, 50, 129])
def test_scan_small_inputs_batched(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (3, 2, n))
    got = np.asarray(tcu_scan(x))
    want = np.cumsum(np.asarray(x), axis=-1)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# properties (formerly hypothesis-fuzzed; now deterministic grids)


PROP_SIZES = [1, 2, 7, 8, 9, 100, 127, 128, 129, 500, 1000, 2000]


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("n", PROP_SIZES)
def test_prop_scan_last_equals_reduce(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    last = tcu_scan(x)[-1]
    total = tcu_reduce(x)
    np.testing.assert_allclose(last, total, rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("seed", [2, 3])
@pytest.mark.parametrize("n", [2, 9, 128, 129, 777, 1500])
def test_prop_scan_diff_recovers_input(n, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (n,))
    s = np.asarray(tcu_scan(x))
    np.testing.assert_allclose(np.diff(s), np.asarray(x)[1:],
                               rtol=1e-2, atol=1e-2)


@pytest.mark.parametrize("alpha", [-2.5, 0.0, 0.3, 3.0])
@pytest.mark.parametrize("n", [1, 100, 1000])
def test_prop_reduce_linear(n, alpha):
    x = jax.random.normal(jax.random.PRNGKey(n), (n,))
    a = tcu_reduce(alpha * x)
    b = alpha * tcu_reduce(x)
    np.testing.assert_allclose(a, b, rtol=1e-3, atol=1e-2)


@pytest.mark.parametrize("pad", [1, 100, 300])
@pytest.mark.parametrize("n", [1, 9, 128, 900])
def test_prop_zero_padding_invariance(n, pad):
    """The paper's arbitrary-segment-size strategy: zero padding does not
    change the reduction (§4.1)."""
    x = jax.random.normal(jax.random.PRNGKey(n * 31 + pad), (n,))
    xp = jnp.concatenate([x, jnp.zeros((pad,))])
    np.testing.assert_allclose(tcu_reduce(x), tcu_reduce(xp),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("seed", [4, 5])
@pytest.mark.parametrize("n", [2, 8, 127, 129, 600])
def test_prop_weighted_scan_associative_split(n, seed):
    """Splitting the sequence and carrying the state equals the fused scan —
    the invariant the cross-tile carry chain (and dist_weighted_scan) relies
    on."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (n,))
    la = -jax.random.uniform(k2, (n,))
    full = np.asarray(tcu_weighted_scan(x, la))
    cut = n // 2
    left = np.asarray(tcu_weighted_scan(x[:cut], la[:cut])) if cut else \
        np.zeros((0,))
    carry = left[-1] if cut else 0.0
    right = np.asarray(tcu_weighted_scan(x[cut:], la[cut:]))
    decay = np.exp(np.cumsum(np.asarray(la[cut:])))
    right_fixed = right + carry * decay
    np.testing.assert_allclose(
        np.concatenate([left, right_fixed]), full, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# ragged (irregular) segments — the paper's footnote-4 case, matmul-form


def test_ragged_reduce_matches_bincount():
    from repro.core.ragged import tcu_ragged_segment_reduce

    rng = np.random.default_rng(0)
    n, s = 1000, 7
    seg = np.sort(rng.integers(0, s, n)).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    got = np.asarray(tcu_ragged_segment_reduce(jnp.asarray(x),
                                               jnp.asarray(seg), s))
    want = np.array([x[seg == i].sum() for i in range(s)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_ragged_scan_restarts_per_segment():
    from repro.core.ragged import tcu_ragged_segment_scan

    rng = np.random.default_rng(1)
    n, s = 500, 5
    seg = np.sort(rng.integers(0, s, n)).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    got = np.asarray(tcu_ragged_segment_scan(jnp.asarray(x),
                                             jnp.asarray(seg), s))
    want = np.empty(n, np.float32)
    for i in range(s):
        m = seg == i
        want[m] = np.cumsum(x[m])
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


def test_ragged_scan_noncontiguous_raises_eagerly():
    """The scan's contract: seg_ids must be non-decreasing. With concrete
    ids and debug=True the violation raises immediately."""
    from repro.core.ragged import tcu_ragged_segment_scan

    x = jnp.ones((6,), jnp.float32)
    seg = jnp.asarray([0, 1, 0, 1, 2, 0], jnp.int32)   # id 0 reappears
    with pytest.raises(ValueError, match="non-decreasing"):
        tcu_ragged_segment_scan(x, seg, 3, debug=True)


def test_ragged_scan_noncontiguous_poisons_under_jit():
    """Under jit the ids are traced (cannot raise): debug=True NaN-poisons
    the output instead, so the violation is still loud."""
    from repro.core.ragged import tcu_ragged_segment_scan

    f = jax.jit(lambda a, s: tcu_ragged_segment_scan(a, s, 3, debug=True))
    x = jnp.ones((6,), jnp.float32)
    bad = jnp.asarray([0, 1, 0, 1, 2, 0], jnp.int32)
    assert np.isnan(np.asarray(f(x, bad))).all()
    good = jnp.sort(bad)
    out = np.asarray(f(x, good))
    assert not np.isnan(out).any()
    want = np.empty(6, np.float32)
    segn = np.asarray(good)
    for i in range(3):
        m = segn == i
        want[m] = np.cumsum(np.ones(m.sum(), np.float32))
    np.testing.assert_allclose(out, want, rtol=1e-5)


def test_ragged_scan_contiguous_debug_is_transparent():
    from repro.core.ragged import tcu_ragged_segment_scan

    rng = np.random.default_rng(7)
    seg = np.sort(rng.integers(0, 4, 100)).astype(np.int32)
    x = rng.normal(size=100).astype(np.float32)
    a = np.asarray(tcu_ragged_segment_scan(jnp.asarray(x), jnp.asarray(seg),
                                           4))
    b = np.asarray(tcu_ragged_segment_scan(jnp.asarray(x), jnp.asarray(seg),
                                           4, debug=True))
    np.testing.assert_allclose(a, b, rtol=0, atol=0)


def test_ragged_reduce_accepts_any_id_order():
    """The reduce is order-free bucketing — unsorted ids are valid there
    (only the scan has the contiguity contract)."""
    from repro.core.ragged import tcu_ragged_segment_reduce

    rng = np.random.default_rng(8)
    seg = rng.integers(0, 6, 200).astype(np.int32)     # deliberately unsorted
    x = rng.normal(size=200).astype(np.float32)
    got = np.asarray(tcu_ragged_segment_reduce(jnp.asarray(x),
                                               jnp.asarray(seg), 6))
    want = np.array([x[seg == i].sum() for i in range(6)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,s,seed", [
    (2, 1, 0), (17, 3, 1), (100, 12, 2), (399, 7, 3), (400, 5, 4),
])
def test_prop_ragged_reduce_total_invariant(n, s, seed):
    """Bucketing never changes the grand total (conservation)."""
    from repro.core.ragged import tcu_ragged_segment_reduce

    rng = np.random.default_rng(seed)
    seg = np.sort(rng.integers(0, s, n)).astype(np.int32)
    x = rng.normal(size=n).astype(np.float32)
    got = tcu_ragged_segment_reduce(jnp.asarray(x), jnp.asarray(seg), s)
    np.testing.assert_allclose(float(jnp.sum(got)), x.sum(),
                               rtol=1e-3, atol=1e-3)
