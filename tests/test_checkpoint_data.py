"""Checkpoint atomicity/roundtrip and deterministic data pipeline tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLMPipeline, \
    _philox_tokens


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((5,), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    path = ckpt.save(str(tmp_path), 7, tree)
    assert path.endswith("step_7")
    restored = ckpt.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_ignores_tmp(tmp_path):
    ckpt.save(str(tmp_path), 5, _tree())
    ckpt.save(str(tmp_path), 10, _tree())
    os.makedirs(tmp_path / "step_99.tmp")        # simulated crashed commit
    os.makedirs(tmp_path / "step_50")            # no manifest -> invalid
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_checkpoint_resave_same_step(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    tree2 = jax.tree.map(lambda x: x + 1 if x.dtype == jnp.float32 else x,
                         tree)
    ckpt.save(str(tmp_path), 3, tree2)
    restored = ckpt.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree2["params"]["w"]))


def test_checkpoint_bf16_preserved(tmp_path):
    tree = {"x": (jnp.arange(64, dtype=jnp.float32) * 0.1).astype(
        jnp.bfloat16)}
    ckpt.save(str(tmp_path), 1, tree)
    restored = ckpt.restore(str(tmp_path), 1, tree)
    assert restored["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["x"], np.float32),
                                  np.asarray(restored["x"], np.float32))


def test_train_state_roundtrip(tmp_path):
    """Full train-state checkpoint -> restore -> training continues
    bit-identically (the fault-tolerance contract)."""
    from repro import configs
    from repro.configs.common import smoke_batch
    from repro.models import build
    from repro.optim import OptConfig
    from repro.training import init_train_state, make_train_step

    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=50)
    state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
    step = jax.jit(make_train_step(bundle, opt_cfg))
    batch = smoke_batch(mod.SMOKE)
    state, _ = step(state, batch)

    ckpt.save(str(tmp_path), 1, state)
    restored = ckpt.restore(str(tmp_path), 1, state)
    s_a, m_a = step(state, batch)
    s_b, m_b = step(restored, batch)
    assert float(m_a["loss"]) == float(m_b["loss"])
    for a, b in zip(jax.tree.leaves(s_a["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# data pipeline


def test_data_deterministic_across_instances():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    a = SyntheticLMPipeline(cfg).host_batch(5)
    b = SyntheticLMPipeline(cfg).host_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_data_step_variation():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    p = SyntheticLMPipeline(cfg)
    assert not np.array_equal(p.host_batch(0)["tokens"],
                              p.host_batch(1)["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    hb = SyntheticLMPipeline(cfg).host_batch(0)
    full = _philox_tokens(cfg, 0, 0, 4)
    np.testing.assert_array_equal(hb["tokens"], full[:, :-1])
    np.testing.assert_array_equal(hb["labels"], full[:, 1:])


def test_data_host_shards_disjoint_and_stable():
    """A replacement host regenerates exactly its shard (no drift)."""
    cfg = DataConfig(vocab=500, seq_len=8, global_batch=16, seed=9)
    full = _philox_tokens(cfg, 3, 0, 16)
    lo_hi = [(0, 4), (4, 8), (8, 12), (12, 16)]
    shards = [_philox_tokens(cfg, 3, lo, hi) for lo, hi in lo_hi]
    np.testing.assert_array_equal(np.concatenate(shards), full)


def test_data_skip_to_resume():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    p = SyntheticLMPipeline(cfg)
    p.skip_to(7)
    it = iter(p)
    s, batch = next(it)
    assert s == 7
    np.testing.assert_array_equal(
        batch["tokens"], SyntheticLMPipeline(cfg).host_batch(7)["tokens"])
