"""Checkpoint atomicity/roundtrip and deterministic data pipeline tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticLMPipeline, \
    _philox_tokens


def _tree():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((5,), jnp.bfloat16)},
        "opt": {"step": jnp.int32(7)},
    }


def test_checkpoint_roundtrip(tmp_path):
    tree = _tree()
    path = ckpt.save(str(tmp_path), 7, tree)
    assert path.endswith("step_7")
    restored = ckpt.restore(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_latest_ignores_tmp(tmp_path):
    ckpt.save(str(tmp_path), 5, _tree())
    ckpt.save(str(tmp_path), 10, _tree())
    os.makedirs(tmp_path / "step_99.tmp")        # simulated crashed commit
    os.makedirs(tmp_path / "step_50")            # no manifest -> invalid
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_checkpoint_resave_same_step(tmp_path):
    tree = _tree()
    ckpt.save(str(tmp_path), 3, tree)
    tree2 = jax.tree.map(lambda x: x + 1 if x.dtype == jnp.float32 else x,
                         tree)
    ckpt.save(str(tmp_path), 3, tree2)
    restored = ckpt.restore(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree2["params"]["w"]))


def test_checkpoint_bf16_preserved(tmp_path):
    tree = {"x": (jnp.arange(64, dtype=jnp.float32) * 0.1).astype(
        jnp.bfloat16)}
    ckpt.save(str(tmp_path), 1, tree)
    restored = ckpt.restore(str(tmp_path), 1, tree)
    assert restored["x"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(tree["x"], np.float32),
                                  np.asarray(restored["x"], np.float32))


def test_train_state_roundtrip(tmp_path):
    """Full train-state checkpoint -> restore -> training continues
    bit-identically (the fault-tolerance contract)."""
    from repro import configs
    from repro.configs.common import smoke_batch
    from repro.models import build
    from repro.optim import OptConfig
    from repro.training import init_train_state, make_train_step

    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    opt_cfg = OptConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=50)
    state = init_train_state(jax.random.PRNGKey(0), bundle, opt_cfg)
    step = jax.jit(make_train_step(bundle, opt_cfg))
    batch = smoke_batch(mod.SMOKE)
    state, _ = step(state, batch)

    ckpt.save(str(tmp_path), 1, state)
    restored = ckpt.restore(str(tmp_path), 1, state)
    s_a, m_a = step(state, batch)
    s_b, m_b = step(restored, batch)
    assert float(m_a["loss"]) == float(m_b["loss"])
    for a, b in zip(jax.tree.leaves(s_a["params"]),
                    jax.tree.leaves(s_b["params"])):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# async checkpointing


def test_async_save_returns_before_commit(tmp_path):
    """save() must return while the write is still in flight; wait() is
    the commit barrier (the ISSUE's async acceptance criterion)."""
    import threading

    gate = threading.Event()
    writer = ckpt.AsyncCheckpointer(str(tmp_path), _pre_commit=gate.wait)
    tree = _tree()
    writer.save(4, tree)                      # returns with commit gated
    assert not (tmp_path / "step_4").exists()
    assert ckpt.latest_step(str(tmp_path)) is None
    gate.set()
    path = writer.wait()
    assert path.endswith("step_4")
    assert (tmp_path / "step_4" / "manifest.json").exists()
    restored = ckpt.restore(str(tmp_path), 4, tree)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_async_second_save_is_barrier(tmp_path):
    """A second save() observes the first one committed (no two writes in
    flight), and the committed checkpoint restores."""
    writer = ckpt.AsyncCheckpointer(str(tmp_path))
    tree = _tree()
    writer.save(1, tree)
    writer.save(2, tree)                      # waits for step 1 first
    assert (tmp_path / "step_1" / "manifest.json").exists()
    writer.wait()
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_async_save_error_surfaces_on_wait(tmp_path):
    def boom():
        raise RuntimeError("disk on fire")

    writer = ckpt.AsyncCheckpointer(str(tmp_path), _pre_commit=boom)
    writer.save(1, _tree())
    with pytest.raises(RuntimeError, match="disk on fire"):
        writer.wait()
    assert ckpt.latest_step(str(tmp_path)) is None


def test_keep_last_gc(tmp_path):
    writer = ckpt.AsyncCheckpointer(str(tmp_path), keep_last=2)
    tree = _tree()
    for step in (1, 2, 3, 4):
        writer.save(step, tree)
    writer.wait()
    assert ckpt.latest_step(str(tmp_path)) == 4
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert kept == ["step_3", "step_4"]


def test_crashed_tmp_cleaned_on_next_save(tmp_path):
    """A stale step_*.tmp from a crashed run is swept by the next save,
    and latest_step never saw it."""
    stale = tmp_path / "step_9.tmp"
    os.makedirs(stale)
    (stale / "host_0.npz").write_bytes(b"partial garbage")
    assert ckpt.latest_step(str(tmp_path)) is None
    writer = ckpt.AsyncCheckpointer(str(tmp_path))
    writer.save(10, _tree())
    writer.wait()
    assert not stale.exists()
    assert ckpt.latest_step(str(tmp_path)) == 10


# ---------------------------------------------------------------------------
# data pipeline


def test_data_deterministic_across_instances():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8, seed=3)
    a = SyntheticLMPipeline(cfg).host_batch(5)
    b = SyntheticLMPipeline(cfg).host_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_data_step_variation():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=8)
    p = SyntheticLMPipeline(cfg)
    assert not np.array_equal(p.host_batch(0)["tokens"],
                              p.host_batch(1)["tokens"])


def test_data_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    hb = SyntheticLMPipeline(cfg).host_batch(0)
    full = _philox_tokens(cfg, 0, 0, 4)
    np.testing.assert_array_equal(hb["tokens"], full[:, :-1])
    np.testing.assert_array_equal(hb["labels"], full[:, 1:])


def test_data_host_shards_disjoint_and_stable():
    """A replacement host regenerates exactly its shard (no drift)."""
    cfg = DataConfig(vocab=500, seq_len=8, global_batch=16, seed=9)
    full = _philox_tokens(cfg, 3, 0, 16)
    lo_hi = [(0, 4), (4, 8), (8, 12), (12, 16)]
    shards = [_philox_tokens(cfg, 3, lo, hi) for lo, hi in lo_hi]
    np.testing.assert_array_equal(np.concatenate(shards), full)


def test_data_host_range_remainder():
    """global_batch=10 over 4 hosts -> sizes [3, 3, 2, 2], slices disjoint
    and exactly covering [0, 10)."""
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=10)
    p = SyntheticLMPipeline(cfg)
    ranges = [p.host_range(process_index=i, process_count=4)
              for i in range(4)]
    assert [hi - lo for lo, hi in ranges] == [3, 3, 2, 2]
    covered = [i for lo, hi in ranges for i in range(lo, hi)]
    assert covered == list(range(10))


def test_data_host_range_divisible_matches_even_split():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=16)
    p = SyntheticLMPipeline(cfg)
    assert [p.host_range(process_index=i, process_count=4)
            for i in range(4)] == [(0, 4), (4, 8), (8, 12), (12, 16)]


def test_data_simulated_hosts_cover_global_batch():
    """Shards drawn per simulated host concatenate to the full batch even
    with a remainder (the multi-host data contract)."""
    cfg = DataConfig(vocab=500, seq_len=8, global_batch=10, seed=2)
    p = SyntheticLMPipeline(cfg)
    full = _philox_tokens(cfg, 4, 0, cfg.global_batch)
    shards = [_philox_tokens(cfg, 4, *p.host_range(process_index=i,
                                                   process_count=3))
              for i in range(3)]
    np.testing.assert_array_equal(np.concatenate(shards), full)


def test_data_skip_to_resume():
    cfg = DataConfig(vocab=1000, seq_len=16, global_batch=4)
    p = SyntheticLMPipeline(cfg)
    p.skip_to(7)
    it = iter(p)
    s, batch = next(it)
    assert s == 7
    np.testing.assert_array_equal(
        batch["tokens"], SyntheticLMPipeline(cfg).host_batch(7)["tokens"])
