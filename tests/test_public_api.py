"""Public-API surface check for ``repro.ops`` (the documented entry
point): the exported names are exactly the documented set, every export
resolves, the ops run under ``policy=`` in all its spellings, and
``KernelPolicy`` round-trips via ``repr``."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
import repro.ops as rops
from repro.core.policy import KernelPolicy

# THE documented surface (README "Kernel selection"); changing it is an
# API break and must update both the docs and this list.
DOCUMENTED = {
    # the paper's ops
    "reduce", "scan", "weighted_scan", "ragged_reduce", "ragged_scan",
    "rmsnorm", "attention", "ssd",
    # the multi-device composition of weighted_scan (shard_map body)
    "dist_weighted_scan",
    # the policy + tuning surface
    "KernelPolicy", "TuneSpec", "get_policy", "set_policy", "using_policy",
}


def test_all_is_exactly_the_documented_surface():
    assert set(rops.__all__) == DOCUMENTED
    assert rops.__all__ == sorted(rops.__all__), \
        "__all__ must stay sorted (stable diffs)"
    for name in rops.__all__:
        assert getattr(rops, name) is not None


def test_lazy_package_attr():
    assert repro.ops is rops
    assert repro.KernelPolicy is KernelPolicy
    assert repro.TuneSpec is rops.TuneSpec
    with pytest.raises(AttributeError):
        repro.nonexistent_attr


def test_kernel_policy_repr_roundtrips_through_public_import():
    pol = rops.KernelPolicy(path="baseline",
                            op_paths={"attention": "fused"},
                            autotune="off")
    assert eval(repr(pol), {"KernelPolicy": rops.KernelPolicy}) == pol


def test_every_op_runs_under_every_policy_spelling():
    x = jnp.ones((2, 64))
    for policy in (None, "fused", KernelPolicy(path="baseline"),
                   {"path": "fused"}):
        np.testing.assert_allclose(
            np.asarray(rops.reduce(x, policy=policy)), 64.0, rtol=1e-5)


def test_public_ops_smoke_and_agreement():
    """Every documented op computes the right thing through the façade."""
    k = jax.random.split(jax.random.PRNGKey(0), 8)
    x = jax.random.normal(k[0], (2, 64))
    np.testing.assert_allclose(np.asarray(rops.reduce(x)),
                               np.asarray(x).sum(-1), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(rops.scan(x)),
                               np.cumsum(np.asarray(x), -1),
                               rtol=1e-4, atol=1e-3)
    exc = np.asarray(rops.scan(x, exclusive=True))
    np.testing.assert_allclose(exc[:, 1:],
                               np.cumsum(np.asarray(x), -1)[:, :-1],
                               rtol=1e-4, atol=1e-3)
    la = -jax.random.uniform(k[1], (2, 64))
    ws = np.asarray(rops.weighted_scan(x, la))
    assert ws.shape == x.shape and np.isfinite(ws).all()
    seg = jnp.sort(jax.random.randint(k[2], (64,), 0, 4))
    rr = np.asarray(rops.ragged_reduce(x, seg, 4))
    assert rr.shape == (2, 4)
    np.testing.assert_allclose(rr.sum(-1), np.asarray(x).sum(-1),
                               rtol=1e-4, atol=1e-4)
    rs = np.asarray(rops.ragged_scan(x, seg, 4))
    assert rs.shape == x.shape
    w = jnp.ones((64,))
    rn = np.asarray(rops.rmsnorm(x, w))
    assert rn.shape == x.shape
    q = jax.random.normal(k[3], (1, 16, 2, 8))
    kk = jax.random.normal(k[4], (1, 16, 2, 8))
    v = jax.random.normal(k[5], (1, 16, 2, 8))
    at = np.asarray(rops.attention(q, kk, v, policy="fused"))
    assert at.shape == q.shape and np.isfinite(at).all()
    xs = 0.2 * jax.random.normal(k[6], (1, 32, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(k[7], (1, 32, 2)))
    a = -jnp.exp(jnp.zeros((2,)))
    bb = jax.random.normal(k[0], (1, 32, 1, 4)) / 2.0
    cc = jax.random.normal(k[1], (1, 32, 1, 4)) / 2.0
    y, h = rops.ssd(xs, dt, a, bb, cc, policy="fused", return_state=True)
    assert y.shape == xs.shape and h.shape == (1, 2, 8, 4)
