"""Per-kernel validation: Pallas body (interpret mode on CPU) vs the pure
jnp oracles in kernels/ref.py, swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_rmsnorm import fused_rmsnorm
from repro.kernels.ssd_scan import ssd_chunk_scan
from repro.kernels.tcu_reduce import tcu_segmented_reduce_tn
from repro.kernels.tcu_scan import tcu_segmented_scan_tn


# ---------------------------------------------------------------------------
# tcu_reduce kernel


@pytest.mark.parametrize("n,s", [(128, 128), (256, 128), (512, 384),
                                 (1024, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reduce_kernel_shapes(n, s, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n + s), (n, s)).astype(dtype)
    got = tcu_segmented_reduce_tn(x, interpret=True)
    want = np.asarray(x, np.float32).sum(axis=0)
    tol = 1e-4 if dtype == jnp.float32 else 2e-1
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [64, 100, 300, 1000])
def test_reduce_wrapper_padding(n):
    """ops.segmented_reduce pads arbitrary segment sizes (paper §4.1)."""
    x = jax.random.normal(jax.random.PRNGKey(n), (5, n))
    got = ops.segmented_reduce(x, use_pallas=True)
    np.testing.assert_allclose(got, ref.segmented_reduce_ref(x),
                               rtol=1e-4, atol=1e-3)


def test_reduce_kernel_rejects_unaligned():
    with pytest.raises(ValueError):
        tcu_segmented_reduce_tn(jnp.zeros((100, 128)), interpret=True)


# ---------------------------------------------------------------------------
# tcu_scan kernel


@pytest.mark.parametrize("s,n", [(128, 128), (128, 512), (256, 1024)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_scan_kernel_shapes(s, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(s + n), (s, n)).astype(dtype)
    got = tcu_segmented_scan_tn(x, interpret=True)
    want = np.cumsum(np.asarray(x, np.float32), axis=-1)
    tol = 1e-3 if dtype == jnp.float32 else 5e-1
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


@pytest.mark.parametrize("n", [50, 129, 640])
def test_scan_wrapper_padding(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (3, n))
    got = ops.segmented_scan(x, use_pallas=True)
    np.testing.assert_allclose(got, ref.segmented_scan_ref(x),
                               rtol=1e-3, atol=1e-2)


def test_scan_kernel_carry_across_chunks():
    """Tile-to-tile carry: constant input => scan is i+1 everywhere."""
    x = jnp.ones((128, 512), jnp.float32)
    got = np.asarray(tcu_segmented_scan_tn(x, interpret=True))
    want = np.tile(np.arange(1, 513, dtype=np.float32), (128, 1))
    np.testing.assert_allclose(got, want, rtol=1e-5)


# ---------------------------------------------------------------------------
# fused_rmsnorm kernel


@pytest.mark.parametrize("rows,d", [(128, 128), (256, 512), (128, 2048)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(rows, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows + d), (rows, d)).astype(
        dtype)
    w = (1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d,))).astype(
        dtype)
    got = fused_rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_rmsnorm_grad_matches_ref():
    """ops.rmsnorm custom VJP: gradient equals the reference gradient."""
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 256))
    w = jnp.ones((256,))

    g_kernel = jax.grad(
        lambda xx: jnp.sum(ops.rmsnorm(xx, w, use_pallas=True) ** 2))(x)
    g_ref = jax.grad(
        lambda xx: jnp.sum(ref.rmsnorm_ref(xx, w) ** 2))(x)
    np.testing.assert_allclose(g_kernel, g_ref, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# ssd_scan kernel


@pytest.mark.parametrize("bh,L,p,n", [(2, 128, 128, 8), (1, 256, 128, 16),
                                      (3, 384, 256, 32)])
def test_ssd_kernel_vs_sequential(bh, L, p, n):
    key = jax.random.PRNGKey(bh * L)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xdt = 0.1 * jax.random.normal(k1, (bh, L, p))
    lam = -0.5 * jax.random.uniform(k2, (bh, L))
    b = jax.random.normal(k3, (bh, L, n)) / np.sqrt(n)
    c = jax.random.normal(k4, (bh, L, n)) / np.sqrt(n)
    y, state = ssd_chunk_scan(xdt, lam, b, c, interpret=True)

    # sequential oracle: h_t = exp(lam_t) h_{t-1} + b_t xdt_t^T ; y = c_t.h_t
    xa, la, ba, ca = map(np.asarray, (xdt, lam, b, c))
    yref = np.zeros((bh, L, p), np.float32)
    for i in range(bh):
        h = np.zeros((n, p), np.float32)
        for t in range(L):
            h = np.exp(la[i, t]) * h + np.outer(ba[i, t], xa[i, t])
            yref[i, t] = ca[i, t] @ h
    np.testing.assert_allclose(y, yref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(state[0], h if bh == 1 else state[0],
                               rtol=1e-3, atol=1e-3)


def test_ssd_ops_wrapper_vs_ref():
    """ops.ssd_scan (pad + head-fold glue) against ref.ssd_scan_ref."""
    b, L, h, p, g, n = 2, 100, 4, 16, 2, 8
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 5)
    x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    bb = jax.random.normal(ks[3], (b, L, g, n)) / np.sqrt(n)
    cc = jax.random.normal(ks[4], (b, L, g, n)) / np.sqrt(n)
    got = ops.ssd_scan(x, dt, a, bb, cc, use_pallas=True)
    want = ref.ssd_scan_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_core_ssd_matches_ref():
    """The pure-JAX chunked SSD (core/ssd.py) against the sequential ref."""
    from repro.core.ssd import ssd_chunked

    b, L, h, p, g, n = 2, 300, 4, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, L, g, n)) / np.sqrt(n)
    cc = jax.random.normal(ks[4], (b, L, g, n)) / np.sqrt(n)
    got, _ = ssd_chunked(x, dt, a, bb, cc, chunk=128)
    want = ref.ssd_scan_ref(x, dt, a, bb, cc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# flash_attention kernel


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])
def test_flash_attention_vs_ref(causal, hq, hkv):
    b, lq, lk, d = 2, 256, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(hq * 10 + causal), 3)
    q = jax.random.normal(ks[0], (b, hq, lq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, hkv, lk, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, hkv, lk, d), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_sliding_window():
    b, h, L, d = 1, 2, 512, 64
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, h, L, d))
    k = jax.random.normal(ks[1], (b, h, L, d))
    v = jax.random.normal(ks[2], (b, h, L, d))
    got = flash_attention(q, k, v, causal=True, window=128, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=128)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_chunked_attention_vs_ref():
    """The XLA (dry-run) attention path against the oracle, incl. GQA+SWA."""
    from repro.models.xla_attention import chunked_attention

    b, hq, hkv, L, d = 2, 4, 2, 512, 32
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = jax.random.normal(ks[0], (b, L, hq, d))
    k = jax.random.normal(ks[1], (b, L, hkv, d))
    v = jax.random.normal(ks[2], (b, L, hkv, d))
    for window in (None, 100):
        got = chunked_attention(q, k, v, causal=True, window=window)
        want = ref.flash_attention_ref(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1),
            jnp.moveaxis(v, 2, 1), causal=True, window=window)
        np.testing.assert_allclose(
            np.asarray(jnp.moveaxis(got, 2, 1)), np.asarray(want),
            rtol=2e-3, atol=2e-3)
