"""HLO static-analyser tests: parsing, loop multipliers, collective and
memory-traffic conventions — on handcrafted modules and a real lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    analyse,
    parse_computations,
    roofline_terms,
)

MINI = """
HloModule mini

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %w = f32[128,128] constant({...})
  %y = f32[8,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128] all-reduce(%y), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[8,128]) -> (s32[], f32[8,128]) {
  %x0 = f32[8,128] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%z, %x0)
  ROOT %w0 = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_mini_module_loop_flops():
    h = analyse(MINI)
    # dot: 2*8*128*128 flops, body runs 10x
    assert h["flops"] >= 10 * 2 * 8 * 128 * 128
    assert h["flops"] < 11 * 2 * 8 * 128 * 128


def test_mini_module_collectives():
    h = analyse(MINI)
    # all-reduce convention: 2x result bytes, 10 iterations
    want = 10 * 2 * 8 * 128 * 4
    assert h["collective_bytes"]["all-reduce"] == want
    assert h["collective_total"] == want
    assert h["unknown_trip_whiles"] == 0


def test_tuple_with_index_comments_parsed():
    txt = MINI.replace(
        "(s32[], f32[8,128]) while",
        "(s32[], f32[8,128], s32[], s32[], s32[], /*index=5*/f32[8,128]) "
        "while")
    comps = parse_computations(txt)
    assert any(i.opcode == "while" for c in comps.values()
               for i in c.instrs)


def test_roofline_terms_dominant():
    terms = roofline_terms(
        {"flops": 197e12, "memory_bytes": 819e9 * 2,
         "collective_total": 50e9 * 0.5},
        peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(2.0)
    assert terms["collective_s"] == pytest.approx(0.5)
    assert terms["dominant"] == "memory_s"


def test_real_lowering_matmul_flops():
    """Lower C = A@B on this process's devices; analyser flops ~= 2MNK."""
    m, k, n = 256, 512, 128

    def f(a, b):
        return a @ b

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    h = analyse(lowered.compile().as_text())
    assert h["flops"] == pytest.approx(2 * m * k * n, rel=0.05)


def test_real_lowering_scan_multiplier():
    """A lax.scan of T matmuls must count T x the per-iteration flops."""
    t, d = 8, 64

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((t, d, d), jnp.float32),
        jax.ShapeDtypeStruct((4, d), jnp.float32))
    h = analyse(lowered.compile().as_text())
    want = t * 2 * 4 * d * d
    assert h["flops"] >= want
    assert h["flops"] < 2.0 * want


def test_memory_model_slices_not_full_buffers():
    """A scan that slices one row per step must charge per-slice traffic,
    not the whole stacked buffer per iteration."""
    t, d = 64, 256

    def f(w, x):
        def body(h, wl):
            return h + wl, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((t, d), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32))
    h = analyse(lowered.compile().as_text())
    full_buffer_per_iter = t * (t * d * 4)       # the wrong accounting
    assert h["memory_bytes"] < full_buffer_per_iter / 4


# ---------------------------------------------------------------------------
# carry-depth structure of the scan kernel paths (jaxpr-level, no timing):
# the linear tile path serialises its inter-block carry as an 'arbitrary'
# grid dimension whose extent grows with n, while tile_logdepth keeps every
# Pallas grid fully parallel and pays only O(log_radix n) tree-combine
# matmuls at the XLA level.


def _subjaxprs(v):
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for item in v:
            yield from _subjaxprs(item)


def _walk_eqns(jaxpr):
    for e in jaxpr.eqns:
        yield e
        for v in e.params.values():
            for sub in _subjaxprs(v):
                yield from _walk_eqns(sub)


def _scan_structure(path, n):
    """(serialised pallas grid steps, dot_general count) of a lowering."""
    import dataclasses

    from repro.core import policy as kpolicy
    from repro.kernels import ops

    pol = dataclasses.replace(kpolicy.get_policy(),
                              interpret_fallback="silent")
    x = jnp.ones((8, n), jnp.float32)
    jaxpr = jax.make_jaxpr(
        lambda a: ops.segmented_scan(a, policy=pol, path=path))(x).jaxpr
    serial, dots, semantics = 1, 0, []
    for e in _walk_eqns(jaxpr):
        if e.primitive.name == "dot_general":
            dots += 1
        if e.primitive.name != "pallas_call":
            continue
        grid = e.params["grid_mapping"].grid
        cp = e.params.get("compiler_params") or {}
        sem = (cp.get("mosaic") or {}).get("dimension_semantics") or ()
        semantics.extend(sem)
        for g, s in zip(grid, sem):
            if s == "arbitrary":
                serial *= g
    return serial, dots, semantics


def test_linear_tile_path_serialises_carry_with_n():
    base, _, sem = _scan_structure("interpret", 1024)
    quad, _, _ = _scan_structure("interpret", 4096)
    big, _, _ = _scan_structure("interpret", 16384)
    assert "arbitrary" in sem          # the carry dimension is sequential
    assert base >= 2
    assert quad == 4 * base            # serial steps scale linearly in n
    assert big == 16 * base


def test_logdepth_path_has_parallel_grids_and_log_combines():
    s1, d1, sem1 = _scan_structure("tile_logdepth", 1024)
    s2, d2, sem2 = _scan_structure("tile_logdepth", 16384)
    # local block kernels carry nothing between grid steps
    assert sem1 and set(sem1) == {"parallel"}
    assert sem2 and set(sem2) == {"parallel"}
    assert s1 == 1 and s2 == 1
    # a 16x larger input costs at most a couple more tree rounds, nothing
    # like the 16x serial-step growth of the linear path
    assert d1 >= 1
    assert d2 <= d1 + 4
