"""HLO static-analyser tests: parsing, loop multipliers, collective and
memory-traffic conventions — on handcrafted modules and a real lowering."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    analyse,
    parse_computations,
    roofline_terms,
)

MINI = """
HloModule mini

%body (p: (s32[], f32[8,128])) -> (s32[], f32[8,128]) {
  %p = (s32[], f32[8,128]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8,128] get-tuple-element(%p), index=1
  %w = f32[128,128] constant({...})
  %y = f32[8,128] dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,128] all-reduce(%y), replica_groups={}, to_apply=%add
  %one = s32[] constant(1)
  %ni = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,128]) tuple(%ni, %ar)
}

%cond (p2: (s32[], f32[8,128])) -> pred[] {
  %p2 = (s32[], f32[8,128]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %n = s32[] constant(10)
  ROOT %lt = pred[] compare(%i2, %n), direction=LT
}

%add (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x0: f32[8,128]) -> (s32[], f32[8,128]) {
  %x0 = f32[8,128] parameter(0)
  %z = s32[] constant(0)
  %init = (s32[], f32[8,128]) tuple(%z, %x0)
  ROOT %w0 = (s32[], f32[8,128]) while(%init), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
}
"""


def test_mini_module_loop_flops():
    h = analyse(MINI)
    # dot: 2*8*128*128 flops, body runs 10x
    assert h["flops"] >= 10 * 2 * 8 * 128 * 128
    assert h["flops"] < 11 * 2 * 8 * 128 * 128


def test_mini_module_collectives():
    h = analyse(MINI)
    # all-reduce convention: 2x result bytes, 10 iterations
    want = 10 * 2 * 8 * 128 * 4
    assert h["collective_bytes"]["all-reduce"] == want
    assert h["collective_total"] == want
    assert h["unknown_trip_whiles"] == 0


def test_tuple_with_index_comments_parsed():
    txt = MINI.replace(
        "(s32[], f32[8,128]) while",
        "(s32[], f32[8,128], s32[], s32[], s32[], /*index=5*/f32[8,128]) "
        "while")
    comps = parse_computations(txt)
    assert any(i.opcode == "while" for c in comps.values()
               for i in c.instrs)


def test_roofline_terms_dominant():
    terms = roofline_terms(
        {"flops": 197e12, "memory_bytes": 819e9 * 2,
         "collective_total": 50e9 * 0.5},
        peak_flops=197e12, hbm_bw=819e9, ici_bw=50e9)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(2.0)
    assert terms["collective_s"] == pytest.approx(0.5)
    assert terms["dominant"] == "memory_s"


def test_real_lowering_matmul_flops():
    """Lower C = A@B on this process's devices; analyser flops ~= 2MNK."""
    m, k, n = 256, 512, 128

    def f(a, b):
        return a @ b

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((m, k), jnp.float32),
        jax.ShapeDtypeStruct((k, n), jnp.float32))
    h = analyse(lowered.compile().as_text())
    assert h["flops"] == pytest.approx(2 * m * k * n, rel=0.05)


def test_real_lowering_scan_multiplier():
    """A lax.scan of T matmuls must count T x the per-iteration flops."""
    t, d = 8, 64

    def f(w, x):
        def body(h, wl):
            return jnp.tanh(h @ wl), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((t, d, d), jnp.float32),
        jax.ShapeDtypeStruct((4, d), jnp.float32))
    h = analyse(lowered.compile().as_text())
    want = t * 2 * 4 * d * d
    assert h["flops"] >= want
    assert h["flops"] < 2.0 * want


def test_memory_model_slices_not_full_buffers():
    """A scan that slices one row per step must charge per-slice traffic,
    not the whole stacked buffer per iteration."""
    t, d = 64, 256

    def f(w, x):
        def body(h, wl):
            return h + wl, None
        h, _ = jax.lax.scan(body, x, w)
        return h

    lowered = jax.jit(f).lower(
        jax.ShapeDtypeStruct((t, d), jnp.float32),
        jax.ShapeDtypeStruct((d,), jnp.float32))
    h = analyse(lowered.compile().as_text())
    full_buffer_per_iter = t * (t * d * 4)       # the wrong accounting
    assert h["memory_bytes"] < full_buffer_per_iter / 4
