"""Log-depth MatMulScan (``tile_logdepth``) tests: the pure tree
combines, both backends' glue (TPU/Pallas and Triton twins, interpret
mode on CPU), exactness vs the ``ref.py`` oracles across pow2 / non-pow2
/ lane-unaligned shapes and dtypes, exclusive scans through dispatch,
autodiff via the ref twin, and the policy/knob plumbing (label survives
resolution; ``radix``/``fan_in`` ride ``KNOB_SCHEMA``; the env shorthand
steers the scan family)."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core import policy as kpolicy
from repro.kernels import backend, matmul_scan, ops, ref
from repro.kernels.triton import ops as tops


def _cumsum(x):
    return np.cumsum(np.asarray(x, np.float64), axis=-1)


# ---------------------------------------------------------------------------
# the tree combines (pure XLA, no Pallas involved)


@pytest.mark.parametrize("m", [1, 3, 16, 17, 64, 257, 1024])
@pytest.mark.parametrize("radix", [2, 4, 16])
def test_tree_scan_matches_cumsum(m, radix):
    x = jax.random.normal(jax.random.PRNGKey(m), (5, m))
    got = matmul_scan.tree_scan(x, radix=radix, fan_in=radix)
    np.testing.assert_allclose(np.asarray(got), _cumsum(x),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("m", [1, 7, 16, 100, 512])
def test_tree_weighted_matches_sequential(m):
    k1, k2 = jax.random.split(jax.random.PRNGKey(m))
    t = jax.random.normal(k1, (3, m))
    logp = -jax.random.uniform(k2, (3, m))
    # t carries a trailing feature axis (F=1 for the scalar scans)
    got = matmul_scan.tree_weighted(logp, t[..., None],
                                    radix=4, fan_in=4)[..., 0]
    want = ref.weighted_scan_ref(t, logp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_tree_weighted_trailing_features():
    # the SSD glue runs the weighted tree over flattened (N*P) features
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    t = jax.random.normal(k1, (2, 33, 12))
    logp = -jax.random.uniform(k2, (2, 33))
    got = matmul_scan.tree_weighted(logp, t, radix=4, fan_in=4)
    want = jnp.stack([
        ref.weighted_scan_ref(t[..., j], logp) for j in range(t.shape[-1])
    ], axis=-1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# exactness vs the oracles through the registry (both backends' glue)


SHAPES = [(4, 100), (3, 1024), (2, 700), (8, 4096), (5,)]


@pytest.mark.parametrize("shape", SHAPES, ids=str)
def test_scan_tile_logdepth_matches_ref_f32(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape)
    got = ops.segmented_scan(x, path="tile_logdepth")
    np.testing.assert_allclose(np.asarray(got), _cumsum(x),
                               rtol=1e-5, atol=1e-3)


def test_scan_tile_logdepth_bf16_loose():
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 512), jnp.bfloat16)
    got = ops.segmented_scan(x, path="tile_logdepth")
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), _cumsum(x),
                               rtol=2e-2, atol=2e-1)


@pytest.mark.parametrize("n", [100, 1024])
def test_dispatch_scan_exclusive_logdepth(n):
    x = jax.random.normal(jax.random.PRNGKey(3), (3, n))
    got = dispatch.scan(x, path="tile_logdepth", exclusive=True)
    want = np.concatenate(
        [np.zeros((3, 1)), _cumsum(x)[:, :-1]], axis=-1)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("n", [100, 700, 2048])
def test_weighted_scan_tile_logdepth_matches_ref(n):
    k1, k2 = jax.random.split(jax.random.PRNGKey(4))
    x = jax.random.normal(k1, (3, n))
    la = -jax.random.uniform(k2, (3, n))
    got = ops.weighted_scan(x, la, path="tile_logdepth")
    want = ref.weighted_scan_ref(x, la)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def _ssd_case(L, key=5):
    ks = jax.random.split(jax.random.PRNGKey(key), 5)
    b, h, p, g, n = 2, 4, 32, 2, 16
    x = 0.2 * jax.random.normal(ks[0], (b, L, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(0.2 * jax.random.normal(ks[2], (h,)))
    bb = jax.random.normal(ks[3], (b, L, g, n)) / jnp.sqrt(float(n))
    cc = jax.random.normal(ks[4], (b, L, g, n)) / jnp.sqrt(float(n))
    return x, dt, a, bb, cc


@pytest.mark.parametrize("L", [200, 384])
def test_ssd_tile_logdepth_matches_ref(L):
    args = _ssd_case(L)
    y, h = ops.ssd_scan(*args, path="tile_logdepth", return_state=True)
    yr, hr = ref.ssd_scan_ref(*args, return_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)


# the Triton twins, kernel bodies through the interpreter on CPU


def test_triton_scan_logdepth_twin():
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 300))
    got = tops.scan_tile_logdepth_gpu(x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), _cumsum(x),
                               rtol=1e-5, atol=1e-3)


def test_triton_weighted_logdepth_twin():
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (3, 200))
    la = -jax.random.uniform(k2, (3, 200))
    got = tops.weighted_scan_tile_logdepth_gpu(x, la, interpret=True)
    want = ref.weighted_scan_ref(x, la)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-3)


def test_triton_ssd_logdepth_twin():
    args = _ssd_case(200, key=8)
    y, h = tops.ssd_tile_logdepth_gpu(*args, return_state=True,
                                      interpret=True)
    yr, hr = ref.ssd_scan_ref(*args, return_state=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# autodiff rides the ref twin


def test_tile_logdepth_differentiates_like_ref():
    x = jax.random.normal(jax.random.PRNGKey(9), (3, 130))
    g_ld = jax.grad(lambda a: ops.segmented_scan(
        a, path="tile_logdepth").sum())(x)
    g_ref = jax.grad(lambda a: jnp.cumsum(
        a.astype(jnp.float32), axis=-1).sum())(x)
    np.testing.assert_allclose(np.asarray(g_ld), np.asarray(g_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# policy / knob plumbing


def test_label_survives_resolution_and_strict_fallback():
    if backend.native_tile_backend() is not None:
        pytest.skip("off-accelerator expectations")
    silent = dataclasses.replace(kpolicy.get_policy(),
                                 interpret_fallback="silent")
    r = silent.resolve(explicit="tile_logdepth")
    assert r == "tile_logdepth"          # label kept, unlike 'tile'
    assert silent.resolve(level="kernel",
                          explicit="tile_logdepth") == "tile_logdepth"
    strict = dataclasses.replace(silent, interpret_fallback="error")
    with pytest.raises(RuntimeError, match="tile_logdepth"):
        strict.resolve(explicit="tile_logdepth")


def test_logdepth_downgrade_warns_once(monkeypatch):
    if backend.native_tile_backend() is not None:
        pytest.skip("downgrade only happens off-accelerator")
    monkeypatch.setattr(kpolicy, "_LOGDEPTH_DOWNGRADE_WARNED", False)
    resolve = kpolicy.get_policy().resolve
    with pytest.warns(UserWarning, match="tile_logdepth"):
        assert resolve(explicit="tile_logdepth") == "tile_logdepth"
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert resolve(explicit="tile_logdepth") == "tile_logdepth"


def test_radix_fan_in_ride_knob_schema():
    for op in ("scan", "weighted_scan", "ssd"):
        assert "radix" in kpolicy.KNOB_SCHEMA[op]
        assert "fan_in" in kpolicy.KNOB_SCHEMA[op]
    pol = kpolicy.KernelPolicy(path="tile_logdepth",
                               op_tuning={"scan": {"radix": 4, "fan_in": 8}},
                               interpret_fallback="silent")
    spec = pol.resolve(op="scan", n=1024, dtype=jnp.float32).tuning
    assert spec.get("radix") == 4 and spec.get("fan_in") == 8
    # the overridden knobs steer the glue without changing results
    x = jax.random.normal(jax.random.PRNGKey(10), (3, 1024))
    got = ops.segmented_scan(x, policy=pol)
    np.testing.assert_allclose(np.asarray(got), _cumsum(x),
                               rtol=1e-5, atol=1e-3)


def test_env_shorthand_steers_scan_family(monkeypatch):
    spec = "scan=tile_logdepth,weighted_scan=tile_logdepth,ssd=tile_logdepth"
    monkeypatch.setenv(kpolicy.ENV_PATH, spec)
    pol = kpolicy.get_policy()
    silent = dataclasses.replace(pol, interpret_fallback="silent")
    assert silent.resolve(op="scan", n=1024,
                          dtype=jnp.float32) == "tile_logdepth"
    assert silent.resolve(op="weighted_scan", n=1024,
                          dtype=jnp.float32) == "tile_logdepth"
    assert silent.resolve(op="ssd", n=1024,
                          dtype=jnp.float32) == "tile_logdepth"
    # other ops keep their default resolution
    assert silent.resolve(op="reduce", n=16,
                          dtype=jnp.float32) != "tile_logdepth"


def test_logdepth_registered_for_scan_family_only():
    reg = backend.available_ops()
    for name in ("segmented_scan", "weighted_scan", "ssd_scan"):
        op = backend._REGISTRY[name]
        assert op.tile_logdepth is not None, name
        assert op.tile_logdepth_gpu is not None, name
    with pytest.raises(RuntimeError, match="no log-depth"):
        backend.pallas_op("segmented_reduce", jnp.ones((2, 64)),
                          path="tile_logdepth")
    assert "segmented_reduce" in reg
