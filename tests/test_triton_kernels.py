"""Pallas-Triton (GPU) twin validation: every kernel body through the
Pallas interpreter on CPU vs the pure-jnp oracles in kernels/ref.py —
fp32 at tight tolerance, bf16 loose — plus the tile_gpu path contract
(forcing it off-GPU raises; ``auto`` never selects it there).

This module is what the dedicated CI job runs under
``REPRO_KERNEL_PATH=interpret``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import dispatch
from repro.core import policy as kpolicy
from repro.kernels import backend, ops, ref
from repro.kernels.triton import ops as tops
from repro.kernels.triton.fused_rmsnorm import triton_fused_rmsnorm
from repro.kernels.triton.flash_attention import triton_flash_attention
from repro.kernels.triton.ssd_scan import triton_ssd_chunk_scan
from repro.kernels.triton.tcu_reduce import triton_segmented_reduce
from repro.kernels.triton.tcu_scan import triton_segmented_scan


def _tol(dtype):
    return dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)


# ---------------------------------------------------------------------------
# tcu_reduce twin


@pytest.mark.parametrize("s,n", [(32, 64), (64, 256), (96, 448)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triton_reduce_kernel_shapes(s, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(s + n), (s, n)).astype(dtype)
    got = triton_segmented_reduce(x, interpret=True)
    want = np.asarray(x, np.float32).sum(axis=-1)
    np.testing.assert_allclose(got, want, **_tol(dtype))


@pytest.mark.parametrize("n", [50, 129, 1000])
def test_triton_reduce_glue_padding(n):
    """The tile_gpu glue pads arbitrary segment sizes (paper §4.1)."""
    x = jax.random.normal(jax.random.PRNGKey(n), (5, n))
    got = tops.reduce_tile_gpu(x, interpret=True)
    np.testing.assert_allclose(got, ref.segmented_reduce_ref(x),
                               rtol=1e-4, atol=1e-3)


def test_triton_reduce_kernel_rejects_unaligned():
    with pytest.raises(ValueError):
        triton_segmented_reduce(jnp.zeros((33, 64)), interpret=True)


# ---------------------------------------------------------------------------
# tcu_scan twin


@pytest.mark.parametrize("s,n", [(32, 64), (64, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triton_scan_kernel_shapes(s, n, dtype):
    x = jax.random.normal(jax.random.PRNGKey(s + n), (s, n)).astype(dtype)
    got = triton_segmented_scan(x, interpret=True)
    want = np.cumsum(np.asarray(x, np.float32), axis=-1)
    tol = dict(rtol=1e-3, atol=1e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-1)
    np.testing.assert_allclose(got, want, **tol)


def test_triton_scan_carry_across_chunks():
    """Chained-MMA carry: constant input => scan is i+1 everywhere, which
    only holds if the R @ E carry threads every 64-column chunk."""
    x = jnp.ones((32, 320), jnp.float32)
    got = np.asarray(triton_segmented_scan(x, interpret=True))
    want = np.tile(np.arange(1, 321, dtype=np.float32), (32, 1))
    np.testing.assert_allclose(got, want, rtol=1e-5)


@pytest.mark.parametrize("n", [50, 129, 640])
def test_triton_scan_glue_padding(n):
    x = jax.random.normal(jax.random.PRNGKey(n), (3, n))
    got = tops.scan_tile_gpu(x, interpret=True)
    np.testing.assert_allclose(got, ref.segmented_scan_ref(x),
                               rtol=1e-3, atol=1e-2)


# ---------------------------------------------------------------------------
# fused_rmsnorm twin


@pytest.mark.parametrize("rows,d", [(16, 128), (32, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triton_rmsnorm_kernel(rows, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(rows + d), (rows, d)).astype(
        dtype)
    w = (1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d,))).astype(
        dtype)
    got = triton_fused_rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_triton_rmsnorm_glue_pads_feature_dim():
    """Unlike the TPU twin, the GPU glue zero-pads d and divides by the
    TRUE d — the padded Σx² must stay exact."""
    x = jax.random.normal(jax.random.PRNGKey(2), (7, 100))
    w = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(3), (100,))
    got = tops.rmsnorm_tile_gpu_fwd(x, w, 1e-6, True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.rmsnorm_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# ssd_scan twin (+ weighted scan degeneration)


@pytest.mark.parametrize("bh,L,p,n", [(2, 128, 16, 16), (1, 192, 32, 16)])
def test_triton_ssd_kernel_vs_sequential(bh, L, p, n):
    key = jax.random.PRNGKey(bh * L)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    xdt = 0.1 * jax.random.normal(k1, (bh, L, p))
    lam = -0.5 * jax.random.uniform(k2, (bh, L))
    b = jax.random.normal(k3, (bh, L, n)) / np.sqrt(n)
    c = jax.random.normal(k4, (bh, L, n)) / np.sqrt(n)
    y, state = triton_ssd_chunk_scan(xdt, lam, b, c, interpret=True)

    # sequential oracle: h_t = exp(lam_t) h_{t-1} + b_t xdt_t^T ; y = c_t.h_t
    xa, la, ba, ca = map(np.asarray, (xdt, lam, b, c))
    yref = np.zeros((bh, L, p), np.float32)
    for i in range(bh):
        h = np.zeros((n, p), np.float32)
        for t in range(L):
            h = np.exp(la[i, t]) * h + np.outer(ba[i, t], xa[i, t])
            yref[i, t] = ca[i, t] @ h
    np.testing.assert_allclose(y, yref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(state[-1], h, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triton_ssd_glue_vs_ref_with_state(dtype):
    """tile_gpu glue (fold + 16-pad) against ref, L not a chunk multiple."""
    b, L, h, p, g, n = 2, 100, 4, 16, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = (0.2 * jax.random.normal(ks[0], (b, L, h, p))).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, L, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.2)
    bb = jax.random.normal(ks[3], (b, L, g, n)) / np.sqrt(n)
    cc = jax.random.normal(ks[4], (b, L, g, n)) / np.sqrt(n)
    y, st = tops.ssd_tile_gpu(x, dt, a, bb, cc, return_state=True,
                              interpret=True)
    yw, stw = ref.ssd_scan_ref(x, dt, a, bb, cc, return_state=True)
    tol = dict(rtol=2e-3, atol=2e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yw, np.float32), **tol)
    np.testing.assert_allclose(np.asarray(st), np.asarray(stw), **tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triton_weighted_scan_glue(dtype):
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 160)).astype(dtype)
    la = (-jax.random.uniform(jax.random.PRNGKey(5), (2, 160))).astype(dtype)
    got = tops.weighted_scan_tile_gpu(x, la, interpret=True)
    want = ref.weighted_scan_ref(x.astype(jnp.float32),
                                 la.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               **_tol(dtype))


# ---------------------------------------------------------------------------
# flash_attention twin


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(2, 2), (4, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_triton_flash_attention_vs_ref(causal, hq, hkv, dtype):
    b, lq, lk, d = 1, 128, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(hq * 10 + causal), 3)
    q = jax.random.normal(ks[0], (b, hq, lq, d)).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, lk, d)).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, lk, d)).astype(dtype)
    got = triton_flash_attention(q, k, v, causal=causal, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    tol = dict(rtol=2e-3, atol=2e-3) if dtype == jnp.float32 else \
        dict(rtol=5e-2, atol=5e-2)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_triton_flash_attention_sliding_window():
    b, h, L, d = 1, 2, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (b, h, L, d))
    k = jax.random.normal(ks[1], (b, h, L, d))
    v = jax.random.normal(ks[2], (b, h, L, d))
    got = triton_flash_attention(q, k, v, causal=True, window=96,
                                 interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=96)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_triton_attention_glue_unaligned_falls_back():
    """Block-strict kernel: unaligned lengths route to the oracle, so the
    tile_gpu path never crashes on odd decode shapes."""
    ks = jax.random.split(jax.random.PRNGKey(8), 3)
    q = jax.random.normal(ks[0], (1, 2, 100, 32))
    k = jax.random.normal(ks[1], (1, 2, 100, 32))
    v = jax.random.normal(ks[2], (1, 2, 100, 32))
    got = tops.attention_tile_gpu(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# the tile_gpu path contract on a non-GPU host


@pytest.mark.skipif(backend.on_gpu(), reason="contract is for non-GPU hosts")
def test_tile_gpu_off_gpu_raises_clear_error():
    x = jnp.ones((2, 100))
    with pytest.raises(RuntimeError, match="tile_gpu"):
        kpolicy.get_policy().resolve(level="kernel", explicit="tile_gpu")
    with pytest.raises(RuntimeError, match="requires a GPU"):
        ops.segmented_reduce(x, path="tile_gpu")
    with pytest.raises(RuntimeError, match="requires a GPU"):
        dispatch.reduce(x, path="tile_gpu")
    # the glue itself also refuses to compile off-GPU (defence in depth)
    with pytest.raises(RuntimeError, match="needs a GPU"):
        tops.reduce_tile_gpu(x, interpret=False)


@pytest.mark.skipif(backend.on_gpu(), reason="contract is for non-GPU hosts")
def test_auto_never_selects_tile_gpu_off_gpu(monkeypatch):
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    for n in (16, 512, 1 << 14):
        pol = kpolicy.get_policy()
        p = pol.resolve(op="segmented_reduce", n=n, dtype=jnp.float32,
                        level="kernel")
        assert p != "tile_gpu"
        assert pol.resolve(op="reduce", n=n,
                           dtype=jnp.float32) != "tile_gpu"


def test_registry_has_gpu_twins_for_all_five():
    """The tentpole contract: every kernel family carries a Triton twin."""
    if not backend.has_pallas_triton():
        pytest.skip("this JAX has no Pallas-Triton lowering")
    for name in ("segmented_reduce", "segmented_scan", "weighted_scan",
                 "rmsnorm", "ssd_scan", "attention"):
        assert backend.get_op(name).tile_gpu is not None, name
