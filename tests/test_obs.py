"""Observability subsystem tests: off-by-default guarantees, metrics
math, the resolution-event audit trail (including shard contexts),
serving tick-phase timings, checkpoint barrier durations, and the
autotune --check diff rendering."""
import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.core import autotune, dispatch
from repro.core.policy import KernelPolicy
from repro.obs import runtime as obs_runtime
from repro.obs.events import RESOLUTION_FIELDS, EventSink
from repro.obs.metrics import MetricsRegistry


# ---------------------------------------------------------------------------
# off by default


def test_disabled_by_default():
    assert obs.active() is None
    pol = KernelPolicy(interpret_fallback="silent")
    pol.resolve(op="reduce", n=1024, dtype=jnp.float32)  # must not record
    with obs.using_obs() as sess:
        assert sess.events.emitted == 0          # nothing retroactive
    assert obs.active() is None                  # scope restored


def test_resolve_emits_only_inside_scope():
    pol = KernelPolicy(interpret_fallback="silent")
    with obs.using_obs() as sess:
        pol.resolve(op="reduce", n=1024, dtype=jnp.float32)
        n_inside = sess.events.emitted
    pol.resolve(op="reduce", n=1024, dtype=jnp.float32)  # after exit
    assert n_inside == 1
    assert sess.events.emitted == n_inside


def test_using_obs_restores_previous_session():
    with obs.using_obs() as outer:
        with obs.using_obs() as inner:
            assert obs.active() is inner
        assert obs.active() is outer
    assert obs.active() is None


# ---------------------------------------------------------------------------
# metrics math


def test_counter_and_gauge():
    reg = MetricsRegistry()
    c = reg.counter("c", "help")
    c.inc()
    c.inc(2, op="reduce")
    assert c.value() == 1
    assert c.value(op="reduce") == 2
    with pytest.raises(ValueError):
        c.inc(-1)
    g = reg.gauge("g")
    g.set(3.5)
    g.set(7, slot="1")
    assert g.value() == 3.5
    assert g.value(slot="1") == 7.0


def test_metric_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("m")
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("m")


def test_histogram_bucket_math():
    reg = MetricsRegistry()
    h = reg.histogram("h", "lat", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 1.0, 2.0):      # 1.0 lands in le=1.0 (<= edge)
        h.observe(v)
    st = h.stats()
    assert st["count"] == 4
    assert st["sum"] == pytest.approx(3.55)
    assert st["counts"] == [1, 2, 1]     # per-bucket + the +Inf bucket
    txt = reg.prometheus_text()
    assert 'h_bucket{le="0.1"} 1' in txt
    assert 'h_bucket{le="1"} 3' in txt            # cumulative
    assert 'h_bucket{le="+Inf"} 4' in txt
    assert "h_sum 3.55" in txt
    assert "h_count 4" in txt


def test_histogram_rejects_bad_edges():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("empty", buckets=())
    with pytest.raises(ValueError):
        reg.histogram("dupe", buckets=(1.0, 1.0))


def test_snapshot_shape():
    reg = MetricsRegistry()
    reg.counter("c").inc(op="x")
    snap = reg.snapshot()
    assert snap["c"]["kind"] == "counter"
    assert snap["c"]["series"] == [{"labels": {"op": "x"}, "value": 1}]
    json.dumps(snap)                      # JSON-lines exporter contract


# ---------------------------------------------------------------------------
# event sink


def test_event_ring_bounded():
    sink = EventSink(ring=3)
    for i in range(10):
        sink.emit("k", i=i)
    assert sink.emitted == 10
    assert [e["i"] for e in sink.events()] == [7, 8, 9]
    with pytest.raises(ValueError):
        EventSink(ring=0)


def test_jsonl_tee_roundtrip(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with obs.using_obs(events_path=path) as sess:
        sess.emit("custom", value=1, arr=np.int32(7))  # stringified, not lost
    evs = obs.load_jsonl(path)
    assert len(evs) == 1
    assert evs[0]["kind"] == "custom" and evs[0]["value"] == 1
    assert "ts" in evs[0]


def test_format_resolution_tolerates_partial():
    line = obs.format_resolution({"op": "reduce", "chosen_path": "fused"})
    assert "op=reduce" in line and "path=fused" in line
    assert "n=-" in line and "src=-" in line
    full = obs.format_resolution({
        "op": "scan", "n": 2048, "shard_n": 512, "shard_divisor": 4,
        "dtype": "f32", "band": 11, "backend": "cpu", "level": "dispatch",
        "chosen_path": "baseline", "tuning": {"block_s": 64},
        "table_src": "heuristic"})
    assert "shard_divisor=4(shard_n=512)" in full
    assert "tuning=block_s=64" in full


# ---------------------------------------------------------------------------
# resolution audit trail


def _res_events(sess):
    return sess.events.events("resolution")


def test_resolution_event_schema_and_reresolve():
    pols = [KernelPolicy(interpret_fallback="silent"),
            KernelPolicy(path="baseline"),
            KernelPolicy(op_paths={"reduce": "fused"})]
    cases = [("reduce", 1 << 10, jnp.float32, None),
             ("scan", 1 << 8, jnp.bfloat16, None),
             ("reduce", 1 << 6, jnp.float32, "baseline")]
    for pol in pols:
        with obs.using_obs() as sess:
            for op, n, dtype, explicit in cases:
                got = pol.resolve(op=op, n=n, dtype=dtype,
                                  explicit=explicit)
                ev = _res_events(sess)[-1]
                assert all(f in ev for f in RESOLUTION_FIELDS)
                assert ev["op"] == op and ev["n"] == n
                assert ev["dtype"] == autotune.dtype_tag(dtype)
                assert ev["band"] == autotune.band(n)
                assert ev["chosen_path"] == str(got)
                # the event alone must re-resolve to the same choice
                again = pol.resolve(
                    op=ev["op"], n=ev["n"],
                    dtype=autotune.dtype_from_tag(ev["dtype"]),
                    level=ev["level"], explicit=ev["explicit"])
                assert str(again) == ev["chosen_path"]


def test_resolution_table_src_classification():
    pol = KernelPolicy(interpret_fallback="silent")
    with obs.using_obs() as sess:
        pol.resolve(op="reduce", n=512, dtype=jnp.float32,
                    explicit="baseline")
        pol.resolve(op="reduce")                       # auto, shapeless
        pol.resolve(op="reduce", n=512, dtype=jnp.float32)   # bucket hit
        KernelPolicy(autotune="off", interpret_fallback="silent").resolve(
            op="reduce", n=512, dtype=jnp.float32)
        srcs = [e["table_src"] for e in _res_events(sess)]
    assert srcs[0] == "none"
    assert srcs[1] == "static"
    assert srcs[2].endswith(".json")     # the consulted table file
    assert srcs[3] == "static"           # autotune off: no table consulted


def test_resolution_under_shard_context():
    from repro.parallel.mesh_context import MeshContext
    from repro.parallel.sharding import Rules

    ctx = MeshContext(mesh=None,
                      rules=Rules(table={}, axis_sizes={"model": 4}),
                      op_shard_axes={"reduce": "model"})
    pol = KernelPolicy(interpret_fallback="silent")
    with obs.using_obs() as sess:
        with ctx:
            got = pol.resolve(op="reduce", n=1024, dtype=jnp.float32)
            ev = _res_events(sess)[-1]
            assert ev["n"] == 1024                  # caller's shape...
            assert ev["shard_n"] == 256             # ...and the shard's
            assert ev["shard_divisor"] == 4
            assert ev["band"] == autotune.band(256)
            assert ev["chosen_path"] == str(
                pol.resolve(op="reduce", n=1024, dtype=jnp.float32))
        unsharded = _res_events(sess)[-1]
    # outside the context the same call is unsharded
    pol2 = KernelPolicy(interpret_fallback="silent")
    with obs.using_obs() as sess2:
        pol2.resolve(op="reduce", n=1024, dtype=jnp.float32)
        ev2 = _res_events(sess2)[-1]
    assert ev2["shard_divisor"] == 1 and ev2["shard_n"] == 1024
    assert got is not None and unsharded is not None


def test_kernel_invoke_event():
    x = jnp.arange(64, dtype=jnp.float32).reshape(4, 16)
    with obs.using_obs() as sess:
        dispatch.reduce(x, policy="interpret")
        invokes = sess.events.events("kernel_invoke")
    assert invokes, "pallas_op ran but emitted no kernel_invoke event"
    ev = invokes[-1]
    assert ev["n"] == 16 and ev["dtype"] == "f32"
    assert "path" in ev and "tuning" in ev


def test_resolution_counter_increments():
    pol = KernelPolicy(interpret_fallback="silent")
    with obs.using_obs() as sess:
        pol.resolve(op="reduce", n=256, dtype=jnp.float32)
        c = sess.metrics.get("repro_resolutions_total")
        assert c is not None
        assert sum(c.series().values()) == 1


# ---------------------------------------------------------------------------
# serving engine instrumentation


@pytest.fixture(scope="module")
def serving_parts():
    from repro import configs
    from repro.models import build
    from repro.models.common import init_params

    mod = configs.get("llama3.2-1b")
    bundle = build(mod.SMOKE)
    params = init_params(jax.random.PRNGKey(0), bundle.params_pspec,
                        mod.SMOKE.dtype)
    return bundle, params


def _requests(n, vocab=256):
    from repro.serving import Request

    rng = np.random.default_rng(0)
    return [Request(uid=i, prompt=rng.integers(
        3, vocab, size=int(rng.integers(4, 12)), dtype=np.int32))
        for i in range(n)]


def test_serving_tick_phases_sum_to_tick(serving_parts):
    from repro.serving import ServeConfig, ServingEngine

    bundle, params = serving_parts
    with obs.using_obs() as sess:
        eng = ServingEngine(bundle, params, ServeConfig(
            slots=2, max_new=4, eos_token=-1, scheduler="continuous"))
        eng.run(_requests(3))
        ph = sess.metrics.get("repro_serving_tick_phase_seconds")
        tick = sess.metrics.get("repro_serving_tick_seconds")
    assert ph is not None and tick is not None
    phase_sum = 0.0
    phases = set()
    for key, val in ph.series().items():
        phase_sum += val["sum"]
        phases.add(dict(key)["phase"])
    tick_stats = tick.stats()
    # the four phase intervals share their endpoints, so they sum to the
    # tick wall time up to float addition error
    assert phase_sum == pytest.approx(tick_stats["sum"], rel=1e-6)
    assert {"admission", "sample", "bookkeep"} <= phases
    assert phases & {"prefill", "decode"}
    counts = {dict(k)["phase"]: v["count"] for k, v in ph.series().items()}
    assert counts["admission"] == tick_stats["count"]


def test_serving_events_and_compile_cache(serving_parts):
    from repro.serving import ServeConfig, ServingEngine

    bundle, params = serving_parts
    with obs.using_obs() as sess:
        eng = ServingEngine(bundle, params, ServeConfig(
            slots=2, max_new=3, eos_token=-1, scheduler="continuous"))
        eng.run(_requests(2))
        serving = sess.events.events("serving")
        cache = sess.metrics.get("repro_serving_compile_cache_total")
        ttft = sess.metrics.get("repro_serving_ttft_seconds")
    kinds = {e["event"] for e in serving}
    assert "admit" in kinds and "finish" in kinds
    assert cache is not None and sum(cache.series().values()) >= 1
    assert ttft is not None and ttft.stats()["count"] >= 1


def test_serving_trace_ring_bounded(serving_parts):
    from repro.serving import ServeConfig, ServingEngine

    bundle, params = serving_parts
    eng = ServingEngine(bundle, params, ServeConfig(
        slots=2, max_new=4, eos_token=-1, scheduler="continuous",
        trace_ring=4))
    eng.run(_requests(4))
    assert len(eng.trace) <= 4           # bounded, newest-wins
    with pytest.raises(ValueError):
        ServeConfig(trace_ring=0)


# ---------------------------------------------------------------------------
# checkpoint instrumentation


def test_ckpt_phases_recorded(tmp_path):
    from repro.checkpoint import ckpt

    tree = {"params": {"w": jnp.arange(8, dtype=jnp.float32)}}
    with obs.using_obs() as sess:
        writer = ckpt.AsyncCheckpointer(str(tmp_path))
        writer.save(1, tree)
        writer.wait()
        snap = sess.metrics.get("repro_ckpt_snapshot_seconds")
        barrier = sess.metrics.get("repro_ckpt_commit_barrier_seconds")
        phases = {e["phase"] for e in sess.events.events("ckpt")}
    assert snap is not None and snap.stats()["count"] == 1
    assert barrier is not None and barrier.stats()["count"] == 1
    assert {"snapshot", "write", "commit_barrier"} <= phases


def test_ckpt_write_lands_in_issuing_session(tmp_path):
    """The background write records into the session active at save()
    time, even when the scope closes before the write finishes."""
    from repro.checkpoint import ckpt

    gate = threading.Event()
    tree = {"params": {"w": jnp.arange(4, dtype=jnp.float32)}}
    writer = ckpt.AsyncCheckpointer(str(tmp_path), _pre_commit=gate.wait)
    with obs.using_obs() as sess:
        writer.save(2, tree)             # write now gated, still in flight
    gate.set()                           # scope closed; release the write
    writer.wait()
    wh = sess.metrics.get("repro_ckpt_write_seconds")
    assert wh is not None and wh.stats()["count"] == 1


# ---------------------------------------------------------------------------
# autotune --check diff


def test_describe_bucket_renders_entry_and_live():
    ent = {"path": "fused", "us": {"fused": 12.5, "baseline": 20.0},
           "tuning": {"block_n": 256}}
    line = autotune.describe_bucket("reduce/f32/9", ent)
    assert "op=reduce" in line and "n=512" in line
    assert "path=fused" in line and "us=12.50" in line
    live = autotune.describe_bucket("reduce/f32/9")
    assert "op=reduce" in line and "path=" in live


def test_check_report_names_missing_and_stale(tmp_path):
    table = {"version": autotune.TABLE_VERSION, "backends": {
        autotune.current_backend(): {"jax": jax.__version__, "entries": {
            # one bucket outside the harness grid -> stale
            "reduce/f32/20": {"path": "fused", "us": {"fused": 1.0}},
        }}}}
    path = tmp_path / "table.json"
    path.write_text(json.dumps(table))
    problems = autotune.check_default(path)
    assert any("missing" in p for p in problems)
    assert any("stale" in p for p in problems)
    lines = autotune.check_report(path)
    assert any(l.strip().startswith("missing reduce/f32/4") for l in lines)
    stale = [l for l in lines if "stale" in l]
    assert len(stale) == 1 and "reduce/f32/20" in stale[0]
    assert "path=fused" in stale[0]


def test_dtype_tag_roundtrip():
    for dt in (jnp.float32, jnp.bfloat16, jnp.float16):
        assert autotune.dtype_from_tag(autotune.dtype_tag(dt)) == \
            jnp.dtype(dt)


# ---------------------------------------------------------------------------
# CLI scope + bench harness


def test_obs_scope_noop_without_flags():
    import argparse

    from repro.obs import cli as obs_cli

    args = argparse.Namespace(obs_events=None, metrics_out=None,
                              profile_dir=None)
    with obs_cli.obs_scope(args) as sess:
        assert sess is None
        assert obs.active() is None


def test_obs_scope_writes_artifacts(tmp_path):
    import argparse

    from repro.obs import cli as obs_cli

    ev = str(tmp_path / "e.jsonl")
    prom = str(tmp_path / "m.prom")
    args = argparse.Namespace(obs_events=ev, metrics_out=prom,
                              profile_dir=None)
    with obs_cli.obs_scope(args) as sess:
        sess.counter("repro_test_total", "x").inc()
        sess.emit("custom", a=1)
    assert obs.active() is None
    assert obs.load_jsonl(ev)[0]["a"] == 1
    assert "repro_test_total 1" in open(prom).read()


def test_time_stats_and_bandwidth_model(monkeypatch):
    from benchmarks import common

    calls = []
    st = common.time_stats(lambda: calls.append(1) or jnp.zeros(1),
                          iters=4, warmup=2)
    assert len(calls) == 6               # warmup ran but is not measured
    assert st["iters"] == 4 and st["warmup"] == 2
    assert st["p25_s"] <= st["median_s"] <= st["p75_s"]
    assert st["iqr_s"] == pytest.approx(st["p75_s"] - st["p25_s"])

    monkeypatch.setenv(common.ENV_PEAK_GBPS, "100")
    bm = common.bandwidth_model(2_000_000_000, 0.1)
    assert bm["achieved_gbps"] == pytest.approx(20.0)
    assert bm["peak_gbps"] == 100.0
    assert bm["pct_peak"] == pytest.approx(20.0)
