"""Autotune subsystem tests: bucketing, the heuristic fallback, table
round-trip (write -> load -> ``auto`` resolves per the table), backend
keying (a GPU section never steers a CPU host; unknown backend keys fail
loudly), env-var overrides, and the checked-in default's freshness."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, dispatch  # noqa: F401 (dispatch: ops)
from repro.core import policy as kpolicy
from repro.kernels import backend


def _resolve(level="dispatch", explicit=None, **kw):
    """The exact resolver every dispatch/kernel op calls (the pre-policy
    ``resolve_path`` delegates are gone)."""
    return kpolicy.get_policy().resolve(level=level, explicit=explicit, **kw)


@pytest.fixture(autouse=True)
def _fresh_cache():
    autotune.invalidate_cache()
    yield
    autotune.invalidate_cache()


def _write_table(path, entries, backend_name=None):
    table = {"version": autotune.TABLE_VERSION,
             "backends": {backend_name or autotune.current_backend(): {
                 "jax": jax.__version__,
                 "entries": entries}}}
    autotune.save_table(table, path)
    return table


# ---------------------------------------------------------------------------
# bucketing


def test_bucket_key_bands_and_dtypes():
    assert autotune.bucket_key("reduce", 16, jnp.float32) == "reduce/f32/4"
    assert autotune.bucket_key("reduce", 31, jnp.float32) == "reduce/f32/4"
    assert autotune.bucket_key("reduce", 32, jnp.bfloat16) == "reduce/bf16/5"
    assert autotune.bucket_key("scan", 1, None) == "scan/f32/0"
    # kernel-registry names alias onto the dispatch-level table keys
    assert autotune.bucket_key("segmented_reduce", 16, jnp.float32) == \
        "reduce/f32/4"
    # band clamp
    assert autotune.band(1 << 40) == autotune.MAX_BAND


def test_heuristic_crossover_off_tpu():
    if backend.on_tpu() or backend.on_gpu():
        pytest.skip("CPU-only expectations")
    assert autotune.heuristic("reduce", 16) == "fused"
    assert autotune.heuristic("reduce", 8192) == "baseline"
    # non-crossover ops keep the static choice at any size
    assert autotune.heuristic("attention", 8192) == "fused"
    assert autotune.heuristic("ssd", 8192) == "fused"
    # candidate filtering: kernel-level call sites never get "baseline"
    assert autotune.heuristic(
        "reduce", 8192, candidates=("fused", "tile", "interpret")) == "fused"


# ---------------------------------------------------------------------------
# table round-trip + auto resolution (the acceptance contract)


def test_table_roundtrip_auto_flips_across_buckets(tmp_path, monkeypatch):
    """`auto` provably changes its choice across segment-size buckets per
    the persisted table."""
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/4": {"path": "fused", "us": {"fused": 1.0}},
        "reduce/f32/12": {"path": "baseline", "us": {"baseline": 1.0}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    loaded = autotune.load_table(path)
    bk = autotune.current_backend()
    assert loaded["backends"][bk]["entries"]["reduce/f32/4"]["path"] == \
        "fused"
    # the exact resolver every dispatch op calls:
    assert _resolve(op="reduce", n=16, dtype=jnp.float32) == "fused"
    assert _resolve(op="reduce", n=4096, dtype=jnp.float32) == "baseline"
    # and the results still agree regardless of which path auto picked
    small = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    big = jax.random.normal(jax.random.PRNGKey(1), (2, 4096))
    np.testing.assert_allclose(np.asarray(dispatch.reduce(small)),
                               np.asarray(small).sum(-1), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(dispatch.reduce(big)),
                               np.asarray(big).sum(-1), rtol=1e-4, atol=1e-2)


def test_v1_legacy_table_still_loads(tmp_path, monkeypatch):
    """Pre-backend-axis tables (flat backend+entries) up-convert on load."""
    path = tmp_path / "v1.json"
    path.write_text(json.dumps({
        "version": 1, "backend": autotune.current_backend(),
        "jax": jax.__version__,
        "entries": {"reduce/f32/4": {"path": "baseline", "us": {}}},
    }))
    loaded = autotune.load_table(path)
    assert loaded["version"] == autotune.TABLE_VERSION
    bk = autotune.current_backend()
    assert loaded["backends"][bk]["entries"]["reduce/f32/4"]["path"] == \
        "baseline"
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    assert autotune.choose("reduce", 16, jnp.float32) == "baseline"


def test_v1_raw_gpu_spellings_normalise():
    """Old measure_table wrote jax.default_backend() verbatim — 'cuda' and
    'rocm' must up-convert onto the 'gpu' section, not fail validation."""
    import json as _json
    import tempfile
    for spelling in ("cuda", "rocm"):
        with tempfile.NamedTemporaryFile("w", suffix=".json",
                                         delete=False) as f:
            _json.dump({"version": 1, "backend": spelling,
                        "entries": {"reduce/f32/4": {"path": "fused",
                                                     "us": {}}}}, f)
        loaded = autotune.load_table(f.name)
        assert "gpu" in loaded["backends"], spelling


def test_autotune_off_restores_static_heuristic(tmp_path, monkeypatch):
    if backend.on_tpu() or backend.on_gpu():
        pytest.skip("CPU-only expectations")
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/12": {"path": "baseline", "us": {}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.setenv(autotune.ENV_AUTOTUNE, "off")
    autotune.invalidate_cache()
    assert autotune.choose("reduce", 4096, jnp.float32) is None
    # static auto off-TPU = fused, table and heuristic both bypassed
    assert _resolve(op="reduce", n=4096, dtype=jnp.float32) == "fused"
    assert _resolve(op="segmented_reduce", n=4096, dtype=jnp.float32,
                    level="kernel") == "fused"


def test_explicit_path_beats_table(tmp_path, monkeypatch):
    path = tmp_path / "table.json"
    _write_table(path, {"reduce/f32/4": {"path": "baseline", "us": {}}})
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    autotune.invalidate_cache()
    assert _resolve(op="reduce", n=16, dtype=jnp.float32,
                    explicit="xla_tile") == "xla_tile"


# ---------------------------------------------------------------------------
# backend keying (the GPU-table satellite contract)


def test_other_backend_section_never_consulted(tmp_path, monkeypatch):
    """A section measured on different hardware must not steer this host:
    the gpu/tpu sections say 'baseline' for a bucket where this host's
    heuristic says 'fused' — resolution must return the heuristic."""
    if backend.on_tpu() or backend.on_gpu():
        pytest.skip("CPU-only expectations")
    path = tmp_path / "table.json"
    table = {"version": autotune.TABLE_VERSION, "backends": {
        "gpu": {"jax": jax.__version__, "entries": {
            "reduce/f32/4": {"path": "baseline", "us": {}}}},
        "tpu": {"jax": jax.__version__, "entries": {
            "reduce/f32/4": {"path": "baseline", "us": {}}}},
    }}
    path.write_text(json.dumps(table))
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    assert autotune.current_entries() is None   # no section for this host
    # falls through to the heuristic (fused for a small reduce off-TPU)
    assert autotune.choose("reduce", 16, jnp.float32) == "fused"
    assert _resolve(op="reduce", n=16, dtype=jnp.float32) == "fused"


def test_env_table_unknown_backend_fails_loudly(tmp_path, monkeypatch):
    """$REPRO_AUTOTUNE_TABLE with unknown backend keys must raise, not
    silently fall back to the heuristic."""
    path = tmp_path / "table.json"
    path.write_text(json.dumps({
        "version": autotune.TABLE_VERSION, "backends": {
            "warpspeed": {"entries": {
                "reduce/f32/4": {"path": "fused", "us": {}}}}}}))
    with pytest.raises(ValueError, match="unknown backend key"):
        autotune.load_table(path)
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    with pytest.raises(ValueError, match="unknown backend key"):
        autotune.current_table()
    with pytest.raises(ValueError):
        autotune.choose("reduce", 16, jnp.float32)


def test_env_table_malformed_fails_loudly(tmp_path, monkeypatch):
    """Same discipline for any malformed explicit table: pointing
    resolution at a table and getting the heuristic is a silent no-op."""
    bad = tmp_path / "bad.json"
    bad.write_text('{"version": 2, "backends": {"cpu": {"entries": '
                   '{"reduce/f32/4": {"path": "warp"}}}}}')
    with pytest.raises(ValueError):
        autotune.load_table(bad)
    monkeypatch.setenv(autotune.ENV_TABLE, str(bad))
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    with pytest.raises(ValueError, match="unusable"):
        autotune.current_table()


def test_backend_incompatible_tile_entry_ignored(tmp_path, monkeypatch):
    """A hand-written cpu-section entry forcing tile_gpu must never make
    ``auto`` select an unlowerable backend — resolution falls back to the
    heuristic instead of raising mid-dispatch."""
    if backend.on_gpu():
        pytest.skip("needs a host without native Triton lowering")
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/4": {"path": "tile_gpu", "us": {}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    choice = autotune.choose("reduce", 16, jnp.float32)
    assert choice != "tile_gpu"
    # and end-to-end auto never raises
    x = jnp.ones((4, 16))
    np.testing.assert_allclose(np.asarray(dispatch.reduce(x)), 16.0)


def test_merge_tables_keeps_other_sections(tmp_path):
    """--write on a GPU host must drop its section in without touching the
    CPU one (and vice versa)."""
    base = {"version": autotune.TABLE_VERSION, "backends": {
        "cpu": {"jax": "x", "entries": {
            "reduce/f32/4": {"path": "fused", "us": {}}}}}}
    new = {"version": autotune.TABLE_VERSION, "backends": {
        "gpu": {"jax": "y", "entries": {
            "reduce/f32/4": {"path": "tile_gpu", "us": {}}}}}}
    merged = autotune.merge_tables(base, new)
    assert set(merged["backends"]) == {"cpu", "gpu"}
    assert merged["backends"]["cpu"]["entries"]["reduce/f32/4"]["path"] == \
        "fused"
    assert merged["backends"]["gpu"]["entries"]["reduce/f32/4"]["path"] == \
        "tile_gpu"


def test_kernel_level_auto_consults_table(tmp_path, monkeypatch):
    """Kernel-level 'auto' resolution is shape-aware too, with the table's
    dispatch-level labels translated onto the kernel registry's
    implementations (backend's "fused" = the native-op ref = the dispatch
    layer's "baseline"; the matmul forms have no kernel twin)."""
    if backend.on_tpu() or backend.on_gpu():
        pytest.skip("CPU-only expectations")
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/4": {"path": "interpret", "us": {}},
        # native op won -> kernel level runs it as its "fused" ref
        "reduce/f32/12": {"path": "baseline", "us": {}},
        # matmul form won (no kernel twin) -> fastest measured contender
        # that has one: interpret (2us) beats baseline (9us) here
        "reduce/f32/8": {"path": "fused",
                         "us": {"fused": 1.0, "interpret": 2.0,
                                "baseline": 9.0}},
        # matmul form won, nothing translatable recorded -> heuristic
        "reduce/f32/10": {"path": "fused", "us": {"fused": 1.0}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    assert _resolve(op="segmented_reduce", n=16, dtype=jnp.float32,
                    level="kernel") == "interpret"
    assert _resolve(op="segmented_reduce", n=4096, dtype=jnp.float32,
                    level="kernel") == "fused"
    assert _resolve(op="segmented_reduce", n=256, dtype=jnp.float32,
                    level="kernel") == "interpret"
    assert _resolve(op="segmented_reduce", n=1024, dtype=jnp.float32,
                    level="kernel") == "fused"


def test_model_ops_keep_fused_default():
    """attention/ssd never default onto the Pallas kernels via the
    heuristic — their chunked XLA forms shard under GSPMD and carry knobs
    the kernels drop; tile is explicit opt-in (or a measured table win)."""
    assert autotune.heuristic("attention", 16) == "fused"
    assert autotune.heuristic("ssd", 1 << 15) == "fused"


# ---------------------------------------------------------------------------
# default table + harness


def test_default_table_checked_in_and_fresh():
    assert autotune.DEFAULT_TABLE_PATH.exists(), \
        "src/repro/core/autotune_default.json must be checked in"
    problems = autotune.check_default()
    assert not problems, problems


def test_default_table_backend_keys_are_known():
    """The lint CI runs on the checked-in default: every section key must
    be a known backend (load_table enforces it)."""
    table = autotune.load_table(autotune.DEFAULT_TABLE_PATH)
    assert set(table["backends"]) <= set(autotune.KNOWN_BACKENDS)


def test_measure_table_smoke():
    table = autotune.measure_table(ops=("reduce",), bands=(4,),
                                   dtypes=(jnp.float32,), iters=1)
    assert table["version"] == autotune.TABLE_VERSION
    bk = autotune.current_backend()
    assert set(table["backends"]) == {bk}
    (key, ent), = table["backends"][bk]["entries"].items()
    assert key == "reduce/f32/4"
    assert ent["path"] in ent["us"]
    assert set(ent["us"]) >= set(autotune.OP_CONTENDERS["reduce"])
