"""Autotune subsystem tests: bucketing, the heuristic fallback, table
round-trip (write -> load -> ``auto`` resolves per the table), env-var
overrides, and the checked-in default's freshness."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune, dispatch
from repro.kernels import backend


@pytest.fixture(autouse=True)
def _fresh_cache():
    autotune.invalidate_cache()
    yield
    autotune.invalidate_cache()


def _write_table(path, entries):
    table = {"version": autotune.TABLE_VERSION,
             "backend": jax.default_backend(),
             "jax": jax.__version__,
             "entries": entries}
    autotune.save_table(table, path)
    return table


# ---------------------------------------------------------------------------
# bucketing


def test_bucket_key_bands_and_dtypes():
    assert autotune.bucket_key("reduce", 16, jnp.float32) == "reduce/f32/4"
    assert autotune.bucket_key("reduce", 31, jnp.float32) == "reduce/f32/4"
    assert autotune.bucket_key("reduce", 32, jnp.bfloat16) == "reduce/bf16/5"
    assert autotune.bucket_key("scan", 1, None) == "scan/f32/0"
    # kernel-registry names alias onto the dispatch-level table keys
    assert autotune.bucket_key("segmented_reduce", 16, jnp.float32) == \
        "reduce/f32/4"
    # band clamp
    assert autotune.band(1 << 40) == autotune.MAX_BAND


def test_heuristic_crossover_off_tpu():
    if backend.on_tpu():
        pytest.skip("CPU-only expectations")
    assert autotune.heuristic("reduce", 16) == "fused"
    assert autotune.heuristic("reduce", 8192) == "baseline"
    # non-crossover ops keep the static choice at any size
    assert autotune.heuristic("attention", 8192) == "fused"
    assert autotune.heuristic("ssd", 8192) == "fused"
    # candidate filtering: kernel-level call sites never get "baseline"
    assert autotune.heuristic(
        "reduce", 8192, candidates=("fused", "tile", "interpret")) == "fused"


# ---------------------------------------------------------------------------
# table round-trip + auto resolution (the acceptance contract)


def test_table_roundtrip_auto_flips_across_buckets(tmp_path, monkeypatch):
    """`auto` provably changes its choice across segment-size buckets per
    the persisted table."""
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/4": {"path": "fused", "us": {"fused": 1.0}},
        "reduce/f32/12": {"path": "baseline", "us": {"baseline": 1.0}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    loaded = autotune.load_table(path)
    assert loaded["entries"]["reduce/f32/4"]["path"] == "fused"
    # the exact resolver every dispatch op calls:
    assert dispatch.resolve_path(op="reduce", n=16,
                                 dtype=jnp.float32) == "fused"
    assert dispatch.resolve_path(op="reduce", n=4096,
                                 dtype=jnp.float32) == "baseline"
    # and the results still agree regardless of which path auto picked
    small = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    big = jax.random.normal(jax.random.PRNGKey(1), (2, 4096))
    np.testing.assert_allclose(np.asarray(dispatch.reduce(small)),
                               np.asarray(small).sum(-1), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(dispatch.reduce(big)),
                               np.asarray(big).sum(-1), rtol=1e-4, atol=1e-2)


def test_autotune_off_restores_static_heuristic(tmp_path, monkeypatch):
    if backend.on_tpu():
        pytest.skip("CPU-only expectations")
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/12": {"path": "baseline", "us": {}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.setenv(autotune.ENV_AUTOTUNE, "off")
    autotune.invalidate_cache()
    assert autotune.choose("reduce", 4096, jnp.float32) is None
    # static auto off-TPU = fused, table and heuristic both bypassed
    assert dispatch.resolve_path(op="reduce", n=4096,
                                 dtype=jnp.float32) == "fused"
    assert backend.resolve_path(op="segmented_reduce", n=4096,
                                dtype=jnp.float32) == "fused"


def test_explicit_path_beats_table(tmp_path, monkeypatch):
    path = tmp_path / "table.json"
    _write_table(path, {"reduce/f32/4": {"path": "baseline", "us": {}}})
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    autotune.invalidate_cache()
    assert dispatch.resolve_path("xla_tile", op="reduce", n=16,
                                 dtype=jnp.float32) == "xla_tile"


def test_table_backend_mismatch_is_ignored(tmp_path, monkeypatch):
    if backend.on_tpu():
        pytest.skip("CPU-only expectations")
    path = tmp_path / "table.json"
    table = {"version": autotune.TABLE_VERSION, "backend": "tpu",
             "entries": {"reduce/f32/4": {"path": "baseline", "us": {}}}}
    path.write_text(json.dumps(table))
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    # falls through to the heuristic (fused for a small reduce off-TPU)
    assert autotune.choose("reduce", 16, jnp.float32) == "fused"


def test_malformed_table_rejected_and_ignored(tmp_path, monkeypatch):
    bad = tmp_path / "bad.json"
    bad.write_text('{"version": 1, "entries": {"reduce/f32/4": '
                   '{"path": "warp"}}}')
    with pytest.raises(ValueError):
        autotune.load_table(bad)
    monkeypatch.setenv(autotune.ENV_TABLE, str(bad))
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    assert autotune.current_table() is None
    # resolution degrades to the heuristic, never crashes
    assert autotune.choose("reduce", 16, jnp.float32) in (
        "fused", "tile")


def test_kernel_level_auto_consults_table(tmp_path, monkeypatch):
    """backend.resolve_path('auto') is shape-aware too, with the table's
    dispatch-level labels translated onto the kernel registry's
    implementations (backend's "fused" = the native-op ref = the dispatch
    layer's "baseline"; the matmul forms have no kernel twin)."""
    if backend.on_tpu():
        pytest.skip("CPU-only expectations")
    path = tmp_path / "table.json"
    _write_table(path, {
        "reduce/f32/4": {"path": "interpret", "us": {}},
        # native op won -> kernel level runs it as its "fused" ref
        "reduce/f32/12": {"path": "baseline", "us": {}},
        # matmul form won (no kernel twin) -> fastest measured contender
        # that has one: interpret (2us) beats baseline (9us) here
        "reduce/f32/8": {"path": "fused",
                         "us": {"fused": 1.0, "interpret": 2.0,
                                "baseline": 9.0}},
        # matmul form won, nothing translatable recorded -> heuristic
        "reduce/f32/10": {"path": "fused", "us": {"fused": 1.0}},
    })
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(backend.ENV_PATH, raising=False)
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    assert backend.resolve_path(op="segmented_reduce", n=16,
                                dtype=jnp.float32) == "interpret"
    assert backend.resolve_path(op="segmented_reduce", n=4096,
                                dtype=jnp.float32) == "fused"
    assert backend.resolve_path(op="segmented_reduce", n=256,
                                dtype=jnp.float32) == "interpret"
    assert backend.resolve_path(op="segmented_reduce", n=1024,
                                dtype=jnp.float32) == "fused"


def test_model_ops_keep_fused_default():
    """attention/ssd never default onto the Pallas kernels via the
    heuristic — their chunked XLA forms shard under GSPMD and carry knobs
    the kernels drop; tile is explicit opt-in (or a measured table win)."""
    assert autotune.heuristic("attention", 16) == "fused"
    assert autotune.heuristic("ssd", 1 << 15) == "fused"


# ---------------------------------------------------------------------------
# default table + harness


def test_default_table_checked_in_and_fresh():
    assert autotune.DEFAULT_TABLE_PATH.exists(), \
        "src/repro/core/autotune_default.json must be checked in"
    problems = autotune.check_default()
    assert not problems, problems


def test_measure_table_smoke():
    table = autotune.measure_table(ops=("reduce",), bands=(4,),
                                   dtypes=(jnp.float32,), iters=1)
    assert table["version"] == autotune.TABLE_VERSION
    assert table["backend"] == jax.default_backend()
    (key, ent), = table["entries"].items()
    assert key == "reduce/f32/4"
    assert ent["path"] in ent["us"]
    assert set(ent["us"]) >= set(autotune.OP_CONTENDERS["reduce"])
