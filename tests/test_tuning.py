"""TuneSpec subsystem tests: spec validation (typo'd knobs fail loudly),
policy ``op_tuning`` normalisation and shorthands, resolve() returning the
spec alongside the path, every TPU and Triton kernel consuming caller-
supplied geometry (numerically identical to the oracle), the v3 autotune
sweep round-trip, and the grep guards banning literal block/chunk/warp
constants outside ``kernels/layout.py`` and direct ``repro.core``/
``repro.kernels`` imports in ``examples/``."""
import dataclasses
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import autotune
from repro.core import policy as kpolicy
from repro.core.policy import KernelPolicy, ResolvedPath, TuneSpec
from repro.kernels import backend, layout, ops, ref
from repro.kernels.triton import ops as tops

SRC = Path(__file__).resolve().parents[1] / "src" / "repro"
EXAMPLES = Path(__file__).resolve().parents[1] / "examples"

KERNEL_OPS = ("reduce", "scan", "weighted_scan", "rmsnorm", "attention",
              "ssd")


# ---------------------------------------------------------------------------
# TuneSpec validation


def test_tunespec_normalises_and_hashes():
    a = TuneSpec("reduce", {"block_n": 64, "block_s": 32})
    b = TuneSpec("reduce", (("block_s", 32), ("block_n", 64)))
    assert a == b and hash(a) == hash(b)
    assert a.knobs == (("block_n", 64), ("block_s", 32))   # sorted
    assert a.get("block_s") == 32 and a.get("num_warps") is None
    assert a.as_dict() == {"block_n": 64, "block_s": 32}
    assert a.label() == "block_n=64;block_s=32"
    assert TuneSpec("ssd").label() == "-"
    # kernel-registry spellings alias onto the canonical op names
    assert TuneSpec("segmented_reduce", {"block_s": 32}).op == "reduce"
    assert TuneSpec("ssd_scan", {"q": 64}).op == "ssd"


def test_tunespec_typod_knob_raises():
    """A typo'd knob must raise at construction — a silently never-matching
    knob is the no-op failure mode this subsystem exists to remove."""
    with pytest.raises(ValueError, match="unknown knob"):
        TuneSpec("reduce", {"blck_s": 32})
    with pytest.raises(ValueError, match="unknown knob"):
        TuneSpec("ssd", {"block_s": 32})     # wrong op's knob
    with pytest.raises(ValueError, match="unknown op"):
        TuneSpec("atention", {"block_q": 64})
    # ragged ops have no kernel, hence an empty schema: any knob rejects
    with pytest.raises(ValueError, match="unknown knob"):
        TuneSpec("ragged_reduce", {"block_s": 32})


def test_tunespec_value_validation():
    for bad in (0, -8, "64", 3.5, True):
        with pytest.raises(ValueError, match="positive int"):
            TuneSpec("reduce", {"block_s": bad})


def test_tunespec_from_spec_string_and_mismatch():
    assert TuneSpec.from_spec("ssd", "q=64,num_warps=8") == \
        TuneSpec("ssd", {"q": 64, "num_warps": 8})
    with pytest.raises(ValueError, match="knob=value"):
        TuneSpec.from_spec("ssd", "q:64")
    with pytest.raises(ValueError, match="used under"):
        TuneSpec.from_spec("reduce", TuneSpec("ssd", {"q": 64}))
    with pytest.raises(TypeError):
        TuneSpec.from_spec("ssd", 64)


def test_knob_schema_covers_known_ops_and_layout_defaults_validate():
    """Every op has a schema entry; every default/candidate value table in
    kernels/layout.py constructs a valid TuneSpec (the schema is the
    contract between the two modules)."""
    assert set(kpolicy.KNOB_SCHEMA) == set(kpolicy.KNOWN_OPS)
    for bk in ("tpu", "gpu"):
        for op in kpolicy.KNOWN_OPS:
            TuneSpec(op, layout.default_tuning(bk, op))
            for cand in layout.candidate_tuning(bk, op):
                TuneSpec(op, cand)


# ---------------------------------------------------------------------------
# KernelPolicy.op_tuning + shorthands


def test_policy_op_tuning_normalises_and_validates():
    a = KernelPolicy(op_tuning={"ssd": {"q": 64}})
    b = KernelPolicy(op_tuning=(("ssd_scan", TuneSpec("ssd", {"q": 64})),))
    assert a == b and hash(a) == hash(b)
    assert a.op_tuning == (("ssd", TuneSpec("ssd", {"q": 64})),)
    with pytest.raises(ValueError, match="unknown op"):
        KernelPolicy(op_tuning={"atention": {"block_q": 64}})
    with pytest.raises(ValueError, match="unknown knob"):
        KernelPolicy(op_tuning={"reduce": {"warp": 4}})


def test_op_tuning_alias_entries_merge_and_conflict_raises():
    """'ssd' and 'ssd_scan' are one op: knobs given under both spellings
    merge into one entry (so semantically identical policies stay equal),
    and a conflicting value for the same knob raises instead of silently
    resolving by insertion order."""
    a = KernelPolicy(op_tuning={"ssd": {"q": 256},
                                "ssd_scan": {"num_warps": 8}})
    assert a.op_tuning == (
        ("ssd", TuneSpec("ssd", {"q": 256, "num_warps": 8})),)
    with pytest.raises(ValueError, match="conflicting"):
        KernelPolicy(op_tuning={"ssd": {"q": 256}, "ssd_scan": {"q": 128}})


def test_policy_string_shorthand_dotted_tuning():
    pol = KernelPolicy.from_spec("tile,ssd.q=64,reduce=baseline")
    assert pol.path == "tile"
    assert pol.op_paths == (("reduce", "baseline"),)
    assert pol.op_tuning == (("ssd", TuneSpec("ssd", {"q": 64})),)
    # JSON spelling
    pol2 = KernelPolicy.from_spec(
        '{"path": "interpret", "op_tuning": {"ssd": {"q": 64}}}')
    assert pol2.path == "interpret"
    assert pol2.op_tuning == pol.op_tuning
    # alias in the dotted key
    assert KernelPolicy.from_spec("ssd_scan.q=64").op_tuning == \
        pol.op_tuning


def test_policy_repr_roundtrips_with_tuning():
    pol = KernelPolicy(path="interpret",
                       op_tuning={"reduce": {"block_s": 256}})
    assert eval(repr(pol), {"KernelPolicy": KernelPolicy,
                            "TuneSpec": TuneSpec}) == pol


def test_policy_from_cli_tune_arg():
    pol = kpolicy.policy_from_cli("interpret", None, "test:tune",
                                  tune_arg="ssd.q=64")
    assert pol.path == "interpret"
    assert pol.op_tuning == (("ssd", TuneSpec("ssd", {"q": 64})),)
    # --tune alone still yields a policy (on the env default)
    pol2 = kpolicy.policy_from_cli(None, None, "test:tune2",
                                   tune_arg="reduce.block_n=256")
    assert pol2 is not None
    assert dict(pol2.op_tuning)["reduce"].get("block_n") == 256
    with pytest.raises(ValueError, match="op.knob"):
        kpolicy.policy_from_cli(None, None, "test:tune3", tune_arg="q=64")
    # every comma part is validated: a path override smuggled after a
    # valid pair must raise, not silently change which formulation runs
    with pytest.raises(ValueError, match="belong in --policy"):
        kpolicy.policy_from_cli(None, None, "test:tune4",
                                tune_arg="ssd.q=64,attention=fused")


# ---------------------------------------------------------------------------
# resolve() returns the spec alongside the path


def test_resolve_returns_resolved_path_with_tuning():
    pol = KernelPolicy(path="interpret")
    r = pol.resolve(op="reduce", n=2048, dtype=jnp.float32)
    assert isinstance(r, ResolvedPath) and isinstance(r, str)
    assert r == "interpret"                       # str semantics intact
    assert r.tuning == TuneSpec("reduce", layout.default_tuning(
        "tpu", "reduce"))
    # the bucket-axis knob is clamped to the call size: the reported spec
    # is the geometry that runs, not the requested phantom
    small = pol.resolve(op="reduce", n=64, dtype=jnp.float32).tuning
    assert small.get("block_n") == 64 and small.get("block_s") == 128
    # no op context -> no spec
    assert pol.resolve(explicit="fused").tuning is None
    # ragged ops resolve an empty spec (no kernel, no knobs)
    assert pol.resolve(op="ragged_scan", n=64).tuning == \
        TuneSpec("ragged_scan")


def test_op_tuning_override_beats_defaults():
    pol = KernelPolicy(path="interpret",
                       op_tuning={"reduce": {"block_n": 256}})
    spec = pol.resolve(op="reduce", n=2048, dtype=jnp.float32).tuning
    assert spec.get("block_n") == 256
    # untouched knobs keep the layout default
    assert spec.get("block_s") == \
        layout.default_tuning("tpu", "reduce")["block_s"]
    # aliases steer the same override
    assert pol.resolve(op="segmented_reduce", n=2048).tuning == spec


def test_table_tuning_overlays_defaults_and_override_beats_table(
        tmp_path, monkeypatch):
    bk = autotune.current_backend()
    table = {"version": autotune.TABLE_VERSION, "backends": {bk: {
        "jax": jax.__version__, "entries": {
            "reduce/f32/11": {"path": "fused", "us": {},
                              "tuning": {"block_n": 256}}}}}}
    path = tmp_path / "t.json"
    autotune.save_table(table, path)
    pol = KernelPolicy(path="interpret", autotune_table=str(path))
    spec = pol.resolve(op="reduce", n=2048, dtype=jnp.float32).tuning
    assert spec.get("block_n") == 256             # table wins over default
    off = dataclasses.replace(pol, autotune="off")
    assert off.resolve(op="reduce", n=2048,
                       dtype=jnp.float32).tuning.get("block_n") == \
        layout.default_tuning("tpu", "reduce")["block_n"]
    ov = dataclasses.replace(pol, op_tuning={"reduce": {"block_n": 128}})
    assert ov.resolve(op="reduce", n=2048,
                      dtype=jnp.float32).tuning.get("block_n") == 128
    autotune.invalidate_cache()


# ---------------------------------------------------------------------------
# every kernel consumes caller-supplied geometry (interpret mode on CPU)


def _ssd_case(L=300):
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    x = 0.2 * jax.random.normal(ks[0], (1, L, 2, 8))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (1, L, 2)))
    a = -jnp.exp(0.1 * jax.random.normal(ks[2], (2,)))
    b = jax.random.normal(ks[3], (1, L, 1, 4)) / 2.0
    c = jax.random.normal(ks[4], (1, L, 1, 4)) / 2.0
    return x, dt, a, b, c


@pytest.mark.parametrize("tuning", [
    {"reduce": {"block_s": 256, "block_n": 8}},
    {"reduce": {"block_s": 128, "block_n": 256}},
])
def test_tpu_reduce_kernel_honours_spec(tuning):
    x = jax.random.normal(jax.random.PRNGKey(0), (5, 300))
    pol = KernelPolicy(path="interpret", op_tuning=tuning)
    got = ops.segmented_reduce(x, policy=pol)
    np.testing.assert_allclose(got, ref.segmented_reduce_ref(x),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("tuning", [
    {"scan": {"block_s": 8, "block_n": 256}},
    {"scan": {"block_s": 256, "block_n": 128}},
])
def test_tpu_scan_kernel_honours_spec(tuning):
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 300))
    pol = KernelPolicy(path="interpret", op_tuning=tuning)
    got = ops.segmented_scan(x, policy=pol)
    np.testing.assert_allclose(got, ref.segmented_scan_ref(x),
                               rtol=1e-4, atol=1e-3)


def test_tpu_weighted_scan_and_ssd_honour_chunk_spec():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 300))
    la = -jax.random.uniform(jax.random.PRNGKey(3), (3, 300))
    pol = KernelPolicy(path="interpret",
                       op_tuning={"weighted_scan": {"q": 256},
                                  "ssd": {"q": 256}})
    got = ops.weighted_scan(x, la, policy=pol)
    np.testing.assert_allclose(got, ref.weighted_scan_ref(x, la),
                               rtol=1e-4, atol=1e-3)
    args = _ssd_case()
    y = ops.ssd_scan(*args, policy=pol)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ssd_scan_ref(*args)),
                               rtol=1e-3, atol=1e-2)


def test_tpu_rmsnorm_and_attention_honour_block_spec():
    h = jax.random.normal(jax.random.PRNGKey(4), (4, 256))
    w = jnp.ones((256,))
    # block_q=64 is below one lane tile: the glue must pass it through
    # (the kernel only needs a sublane multiple), not round it up to 128
    pol = KernelPolicy(path="interpret",
                       op_tuning={"rmsnorm": {"row_block": 8},
                                  "attention": {"block_q": 64,
                                                "block_k": 256}})
    got = ops.rmsnorm(h, w, policy=pol)
    np.testing.assert_allclose(got, ref.rmsnorm_ref(h, w),
                               rtol=1e-4, atol=1e-4)
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 128))
    k = jax.random.normal(ks[1], (1, 2, 256, 128))
    v = jax.random.normal(ks[2], (1, 2, 256, 128))
    at = ops.attention(q, k, v, policy=pol)
    np.testing.assert_allclose(np.asarray(at),
                               np.asarray(ref.flash_attention_ref(q, k, v)),
                               rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("spec", [
    None,
    TuneSpec("reduce", {"block_s": 64, "block_n": 128, "num_warps": 8,
                        "num_stages": 3}),
])
def test_triton_reduce_scan_honour_spec(spec):
    x = jax.random.normal(jax.random.PRNGKey(6), (5, 300))
    got = tops.reduce_tile_gpu(x, tuning=spec, interpret=True)
    np.testing.assert_allclose(got, ref.segmented_reduce_ref(x),
                               rtol=1e-4, atol=1e-3)
    sspec = None if spec is None else \
        TuneSpec("scan", {"block_s": 64, "block_n": 128})
    got = tops.scan_tile_gpu(x, tuning=sspec, interpret=True)
    np.testing.assert_allclose(got, ref.segmented_scan_ref(x),
                               rtol=1e-4, atol=1e-3)


def test_triton_ssd_weighted_scan_honour_spec():
    x = jax.random.normal(jax.random.PRNGKey(8), (3, 200))
    la = -jax.random.uniform(jax.random.PRNGKey(9), (3, 200))
    spec = TuneSpec("weighted_scan", {"q": 128})
    got = tops.weighted_scan_tile_gpu(x, la, tuning=spec, interpret=True)
    np.testing.assert_allclose(got, ref.weighted_scan_ref(x, la),
                               rtol=1e-4, atol=1e-3)
    args = _ssd_case(200)
    y = tops.ssd_tile_gpu(*args, tuning=TuneSpec("ssd", {"q": 128}),
                          interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.ssd_scan_ref(*args)),
                               rtol=1e-3, atol=1e-2)


def test_triton_rmsnorm_block_d_clamps_to_small_or_unaligned_d():
    """The satellite fix: a block_d wider than the (padded) feature dim —
    the old hard-coded 128 on d=50 — must shrink to fit instead of
    crashing or padding 2.5x, for any caller-supplied spec."""
    for d in (24, 50, 130):
        x = jax.random.normal(jax.random.PRNGKey(d), (3, d))
        w = jnp.ones((d,))
        for spec in (None,
                     TuneSpec("rmsnorm", {"block_d": 128, "row_block": 32}),
                     TuneSpec("rmsnorm", {"block_d": 333})):
            got = tops.rmsnorm_tile_gpu_fwd(x, w, 1e-6, True, spec)
            np.testing.assert_allclose(got, ref.rmsnorm_ref(x, w),
                                       rtol=1e-4, atol=1e-4)


def test_triton_attention_honours_spec_with_oracle_fallback():
    ks = jax.random.split(jax.random.PRNGKey(10), 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 32))
    k = jax.random.normal(ks[1], (1, 2, 128, 32))
    v = jax.random.normal(ks[2], (1, 2, 128, 32))
    spec = TuneSpec("attention", {"block_q": 32, "block_k": 128})
    got = tops.attention_tile_gpu(q, k, v, tuning=spec, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.flash_attention_ref(q, k, v)),
                               rtol=1e-4, atol=1e-3)
    # unaligned length under any spec -> oracle, never a crash
    qq = jax.random.normal(ks[0], (1, 2, 100, 32))
    got = tops.attention_tile_gpu(qq, qq, qq, tuning=spec, interpret=True)
    assert np.isfinite(np.asarray(got)).all()


def test_registry_declares_knobs_and_candidates():
    """PallasOp entries carry the knob schema and expose >= 2 sweepable
    candidate specs per kernel family on both backends (the acceptance
    contract for the autotune sweep)."""
    for name in backend.available_ops():
        op = backend.get_op(name)
        canon = kpolicy.OP_ALIASES.get(name, name)
        assert op.knobs == kpolicy.KNOB_SCHEMA[canon]
        assert op.knobs, name                     # all 5 families tunable
        for bk in ("tpu", "gpu"):
            cands = op.candidate_tuning(bk)
            assert len(cands) >= 2, (name, bk)
            assert op.default_tuning(bk)


def test_grads_flow_through_tuned_kernel_paths():
    """The _diff_via_ref wrapper must keep tuning out of the oracle
    backward: gradients flow and match the fused path."""
    x = jax.random.normal(jax.random.PRNGKey(11), (4, 300))
    pol = KernelPolicy(path="interpret",
                       op_tuning={"reduce": {"block_n": 256}})
    g_tuned = jax.grad(lambda a: ops.segmented_reduce(
        a, policy=pol).sum())(x)
    g_fused = jax.grad(lambda a: ops.segmented_reduce(
        a, policy="fused").sum())(x)
    np.testing.assert_allclose(np.asarray(g_tuned), np.asarray(g_fused),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# autotune v3: upconvert, sweep, round-trip


def test_v2_table_upconverts_to_v3(tmp_path, monkeypatch):
    """A v2 file (backend sections, no tuning) loads as v3; its buckets
    steer paths as before and resolve the layout-default geometry."""
    path = tmp_path / "v2.json"
    path.write_text('{"version": 2, "backends": {"%s": {"jax": "x", '
                    '"entries": {"reduce/f32/4": {"path": "baseline", '
                    '"us": {}}}}}}' % autotune.current_backend())
    loaded = autotune.load_table(path)
    assert loaded["version"] == autotune.TABLE_VERSION
    pol = KernelPolicy(path="auto", autotune_table=str(path))
    autotune.invalidate_cache()
    r = pol.resolve(op="reduce", n=16, dtype=jnp.float32)
    assert r == "baseline"
    # layout defaults, bucket-axis knob clamped to the call size
    assert r.tuning == TuneSpec(
        "reduce", layout.clamp_spec(
            "tpu", "reduce", layout.default_tuning("tpu", "reduce"), n=16))
    assert r.tuning.get("block_n") == 16
    autotune.invalidate_cache()


def test_explicit_table_unknown_knob_fails_loudly(tmp_path, monkeypatch):
    path = tmp_path / "bad.json"
    path.write_text('{"version": 3, "backends": {"cpu": {"entries": '
                    '{"reduce/f32/4": {"path": "fused", "us": {}, '
                    '"tuning": {"warp_block": 4}}}}}}')
    with pytest.raises(ValueError, match="unknown tuning knob"):
        autotune.load_table(path)
    monkeypatch.setenv(autotune.ENV_TABLE, str(path))
    monkeypatch.delenv(autotune.ENV_AUTOTUNE, raising=False)
    autotune.invalidate_cache()
    with pytest.raises(ValueError, match="unusable"):
        autotune.current_table()
    autotune.invalidate_cache()


def test_sweep_emits_v3_tuning_that_roundtrips(tmp_path):
    """--write's sweep: >= 2 candidate specs timed per op (at a bucket
    size where they stay distinct after the clamp), the winner persisted
    as the entry's tuning, and resolvable back out of the table through
    KernelPolicy.resolve (the acceptance contract)."""
    table = autotune.measure_table(
        ops=("reduce",), bands=(10,), dtypes=(jnp.float32,), iters=1,
        sweep_interpret=True, max_candidates=2)
    bk = autotune.current_backend()
    ent = table["backends"][bk]["entries"]["reduce/f32/10"]
    assert len(ent["sweep"]) >= 2
    assert ent["tuning"] in [
        {k: v for k, v in sorted(c.items())}
        for c in layout.candidate_tuning(
            "gpu" if bk == "gpu" else "tpu", "reduce")]
    path = tmp_path / "swept.json"
    autotune.save_table(table, path)
    pol = KernelPolicy(path="auto", autotune_table=str(path))
    spec = pol.resolve(op="reduce", n=1024, dtype=jnp.float32).tuning
    for k, v in ent["tuning"].items():
        assert spec.get(k) == v
    autotune.invalidate_cache()


def test_sweep_deterministic_structure_on_cpu_interpret():
    """Two identical sweeps produce the same bucket keys, the same sweep
    labels, and winners drawn from the clamped candidate set — timing
    noise may move the argmin, never the structure. At a tiny bucket the
    candidates collapse onto ONE executed geometry and the sweep must
    dedupe to a single timing (a 'winner' between identical executions
    would be pure noise). The scan family sweeps a SECOND contender
    family — log-depth MatMulScan specs under 'tile_logdepth:'-prefixed
    keys — deduped and persisted by exactly the same rules."""
    kw = dict(ops=("reduce", "scan"), bands=(4,), dtypes=(jnp.float32,),
              iters=1, sweep_interpret=True, max_candidates=2)
    t1 = autotune.measure_table(**kw)
    t2 = autotune.measure_table(**kw)
    bk = autotune.current_backend()
    axis = "gpu" if bk == "gpu" else "tpu"
    e1, e2 = (t["backends"][bk]["entries"] for t in (t1, t2))
    assert set(e1) == set(e2) == {"reduce/f32/4", "scan/f32/4"}
    rows = max(4, min(4096, (1 << 16) // 16))   # _bench_inputs' grid
    for key in e1:
        assert set(e1[key]["sweep"]) == set(e2[key]["sweep"])
        assert set(e1[key]["us"]) == set(e2[key]["us"])
        op = key.split("/")[0]
        execs, persisted = [], []
        for c in layout.candidate_tuning(axis, op)[:2]:
            ex = layout.clamp_spec(axis, op, c, n=16, rows=rows)
            if ex not in execs:
                execs.append(ex)
                persisted.append(layout.clamp_spec(axis, op, c, n=16))
        ld_execs, ld_persisted = [], []
        for c in layout.logdepth_candidate_tuning(axis, op)[:2]:
            ex = layout.clamp_spec(axis, op, c, n=16, rows=rows)
            if ex not in ld_execs:
                ld_execs.append(ex)
                ld_persisted.append(layout.clamp_spec(axis, op, c, n=16))
        assert len(e1[key]["sweep"]) == len(execs) + len(ld_execs)
        prefixed = [s for s in e1[key]["sweep"]
                    if s.startswith("tile_logdepth:")]
        assert len(prefixed) == len(ld_execs)   # reduce sweeps none
        for t in (e1, e2):
            assert t[key]["tuning"] in [
                {k: v for k, v in sorted(c.items())}
                for c in persisted + ld_persisted]


def test_sweep_persists_bucket_axis_clamp_only():
    """Row-axis knobs must NOT be persisted at the probe input's row
    count: at band 13 the probe has 8 rows, so the executed sweep runs
    block_s=8, but a real call in that bucket won't share the probe's
    batch — the table keeps the candidate's block_s and lets each call's
    glue re-clamp."""
    table = autotune.measure_table(
        ops=("scan",), bands=(13,), dtypes=(jnp.float32,), iters=1,
        sweep_interpret=True, max_candidates=2)
    bk = autotune.current_backend()
    ent = table["backends"][bk]["entries"]["scan/f32/13"]
    axis = "gpu" if bk == "gpu" else "tpu"
    want_bs = layout.candidate_tuning(axis, "scan")[0]["block_s"]
    assert ent["tuning"]["block_s"] == want_bs   # not the probe's 8 rows


def test_no_native_tile_no_sweep_without_interpret():
    """The full-budget CPU --write must not drag interpret sweeps into the
    measured table (orders of magnitude slow at real sizes): without a
    native lowering and without sweep_interpret, entries carry no
    tuning."""
    if backend.native_tile_backend() is not None:
        pytest.skip("host has a native tile lowering")
    table = autotune.measure_table(ops=("reduce",), bands=(4,),
                                   dtypes=(jnp.float32,), iters=1)
    bk = autotune.current_backend()
    ent = table["backends"][bk]["entries"]["reduce/f32/4"]
    assert "tuning" not in ent and "sweep" not in ent
    assert "interpret" not in ent["us"]


# ---------------------------------------------------------------------------
# grep guards


def test_no_literal_geometry_constants_outside_layout():
    """Block/chunk/warp numbers are data now: outside kernels/layout.py no
    kernel file may define a geometry constant or default a geometry
    argument/kwarg to an int literal — geometry arrives via TuneSpec."""
    const_pat = re.compile(
        r"^(?:Q|ROW_BLOCK|SSD_Q|BLOCK_[A-Z0-9_]+|LANES|SUBLANES|TILE"
        r"|MMA_TILE)\s*=\s*\d+", re.MULTILINE)
    kwarg_pat = re.compile(
        r"\b(?:block_[a-z0-9]+|row_block|num_warps|num_stages|q|radix"
        r"|fan_in)\s*(?::\s*[^=,()\n]+)?=\s*\d+")
    offenders = []
    for p in sorted((SRC / "kernels").rglob("*.py")):
        rel = p.relative_to(SRC)
        if rel.name == "layout.py":
            continue
        text = p.read_text()
        for pat in (const_pat, kwarg_pat):
            for m in pat.finditer(text):
                line = text.count("\n", 0, m.start()) + 1
                offenders.append(f"{rel}:{line}:{m.group(0)!r}")
    assert not offenders, (
        f"literal kernel geometry outside kernels/layout.py: {offenders}; "
        "take block/chunk/warp values from the resolved TuneSpec "
        "(defaults live in repro.kernels.layout)")


def test_examples_use_public_facade_only():
    """Mirrors the src/ consumer-discipline guard: examples must go
    through the stable repro.ops facade (+ policy=) — never import
    repro.core or repro.kernels directly."""
    pat = re.compile(
        r"^\s*(?:from\s+repro\.(?:core|kernels)[.\s]"
        r"|import\s+repro\.(?:core|kernels)\b)", re.MULTILINE)
    offenders = []
    for p in sorted(EXAMPLES.glob("*.py")):
        if pat.search(p.read_text()):
            offenders.append(p.name)
    assert not offenders, (
        f"direct repro.core/repro.kernels import in examples: {offenders}; "
        "use the stable repro.ops facade (policy=, op_tuning) instead")


# ---------------------------------------------------------------------------
# end-to-end: pallas_op threads the spec; tuning shows up in benchmarks


def test_pallas_op_threads_spec_into_kernel(monkeypatch):
    """Prove the resolved spec reaches the kernel: a q too small for the
    TPU SSD kernel would be clamped by the glue, so instead spy on the
    kernel entry via the registry wrapper path — run under two specs and
    check both produce oracle-identical results while resolve() reports
    the requested geometry."""
    pol = KernelPolicy(path="interpret", op_tuning={"ssd": {"q": 256}})
    assert pol.resolve(op="ssd_scan", level="kernel").tuning.get("q") == 256
    args = _ssd_case(512)
    y1 = ops.ssd_scan(*args, policy=pol)
    y2 = ops.ssd_scan(*args, policy="interpret")   # default q
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-3)


def test_table_tuning_reaches_model_level_kernels(tmp_path, monkeypatch):
    """pallas_op extracts shape context for EVERY family (not just the
    reduction ops), so a v3 table's swept tuning for ssd/attention/rmsnorm
    actually reaches the kernel — spy on the registry entry to prove the
    spec that arrives is the table's, and that kernel-level ``auto`` for
    the model ops still keeps the static choice when the bucket has no
    entry (their ref twin is the materialised oracle)."""
    bk = autotune.current_backend()
    L = 512
    band = autotune.band(L)
    table = {"version": autotune.TABLE_VERSION, "backends": {bk: {
        "jax": jax.__version__, "entries": {
            f"ssd/f32/{band}": {"path": "interpret", "us": {},
                                "tuning": {"q": 256}}}}}}
    path = tmp_path / "t.json"
    autotune.save_table(table, path)
    seen = {}
    real = backend.get_op("ssd_scan")
    spy = dataclasses.replace(
        real, tile=lambda *a, tuning=None, **kw: seen.update(
            t=tuning) or real.tile(*a, tuning=tuning, **kw))
    monkeypatch.setitem(backend._REGISTRY, "ssd_scan", spy)
    pol = KernelPolicy(path="auto", autotune_table=str(path))
    args = _ssd_case(L)
    ops.ssd_scan(*args, policy=pol)          # auto -> table: interpret
    assert seen["t"].get("q") == 256
    # no entry for this bucket: kernel-level auto keeps the static choice
    # (fused off-accelerator) instead of the FUSED_DEFAULT_OPS heuristic
    # rerouting direct registry calls
    if backend.native_tile_backend() is None:
        assert pol.resolve(op="ssd_scan", n=1 << 15,
                           level="kernel") == "fused"
    autotune.invalidate_cache()


def test_benchmark_tuning_label():
    from benchmarks.common import tuning_label

    lbl = tuning_label("interpret", "reduce", 64, jnp.float32)
    assert "block_n=" in lbl and "block_s=" in lbl
    assert tuning_label("fused", "reduce", 64) == "-"
    assert tuning_label("tile_gpu", "reduce", 64) == "-" or \
        backend.native_tile_backend() == "tile_gpu"
